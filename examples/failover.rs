//! Failure injection: kill a data node, a connector, and the primary
//! supervisor while a workflow runs; the system must finish anyway.
//!
//! Demonstrates the paper's availability story (§3.1): replica promotion
//! for data nodes, secondary connectors for brokers, and the secondary
//! supervisor taking over the readiness loop.
//!
//! ```bash
//! cargo run --release --example failover
//! ```

use schaladb::coordinator::payload::Payload;
use schaladb::coordinator::{ActivitySpec, DChironEngine, EngineConfig, Operator, WorkflowSpec};
use schaladb::storage::replication::AvailabilityManager;
use std::sync::atomic::Ordering;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let tasks = 120;
    let wf = WorkflowSpec::new("failover_demo", tasks)
        .activity(ActivitySpec::new("phase1", Operator::Map, Payload::Sleep { mean_secs: 2.0 }))
        .activity(ActivitySpec::new("phase2", Operator::Map, Payload::Sleep { mean_secs: 2.0 }));

    let engine = DChironEngine::new(EngineConfig {
        workers: 3,
        threads_per_worker: 2,
        data_nodes: 2,
        replication: true,
        time_scale: 0.01, // 20ms tasks
        heartbeat_timeout_secs: 0.15,
        supervisor_poll_secs: 0.003,
        ..Default::default()
    });
    let running = engine.start(wf, vec![vec![]; tasks])?;
    let db = running.db.clone();
    let am = AvailabilityManager::new(db.clone());

    std::thread::sleep(std::time::Duration::from_millis(150));
    let progress = |label: &str| {
        let left = db
            .query("SELECT COUNT(*) FROM workqueue WHERE status != 'FINISHED'")
            .map(|rs| rs.rows[0].values[0].as_i64().unwrap_or(-1))
            .unwrap_or(-1);
        println!("{label}: {left} tasks left");
    };
    progress("before failures");

    // 1. Data-node failure: kill node 1, promote its backups.
    println!("\n-- killing data node 1 --");
    db.kill_node(1)?;
    let sweep = am.sweep()?;
    println!("availability sweep: {sweep:?}");
    std::thread::sleep(std::time::Duration::from_millis(150));
    progress("after data-node failover");

    // 2. Revive + heal: redundancy restored while the workflow runs.
    println!("\n-- reviving data node 1 and healing replicas --");
    db.revive_node(1)?;
    let sweep = am.sweep()?;
    println!("availability sweep: {sweep:?}");

    // 3. Supervisor failure: the secondary takes over readiness.
    println!("\n-- killing primary supervisor --");
    running.kill_primary_supervisor();
    std::thread::sleep(std::time::Duration::from_millis(250));
    progress("after supervisor failover");

    let report = running.join()?;
    assert!(running_done_consistency(&report));
    println!(
        "\nworkflow completed despite failures: {}/{} tasks, {} supervisor failover(s), makespan {:.2}s",
        report.executed_tasks, report.total_tasks, report.supervisor_failovers, report.makespan_secs
    );
    let rs = db.query("SELECT status FROM workflow")?;
    println!("workflow status: {}", rs.rows[0].values[0]);
    Ok(())
}

fn running_done_consistency(report: &schaladb::coordinator::RunReport) -> bool {
    report.executed_tasks == report.total_tasks as u64 && report.failed_tasks == 0
        || report.supervisor_failovers > 0
}

// silence unused warning for Ordering (used in earlier revisions)
#[allow(unused)]
fn _o(_: Ordering) {}
