//! End-to-end driver: the full three-layer stack on a real small workload.
//!
//! Runs the 7-activity Risers Fatigue Analysis workflow with the stress and
//! wear activities executing the AOT-compiled JAX/Pallas artifacts through
//! PJRT (L1+L2), scheduled by the d-Chiron engine over the distributed
//! in-memory DBMS (L3), with a steering monitor issuing the Table-2 query
//! mix and a Q8 adaptation mid-run. Requires `make artifacts`.
//!
//! ```bash
//! make artifacts && cargo run --release --example risers_end_to_end [conditions]
//! ```
//!
//! The summary block at the end is what EXPERIMENTS.md §End-to-end records.

use schaladb::coordinator::payload::RunnerRegistry;
use schaladb::coordinator::{DChironEngine, EngineConfig};
use schaladb::metrics;
use schaladb::runtime::{self, riser, PjrtService};
use schaladb::steering::{Monitor, SteeringClient};
use schaladb::storage::AccessKind;
use schaladb::workload;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let conditions: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);

    if !runtime::artifacts_available() {
        return Err(format!(
            "artifacts not found in {:?} — run `make artifacts` first",
            runtime::default_artifact_dir()
        )
        .into());
    }

    // L1/L2: PJRT service + riser runners over the AOT artifacts.
    let svc = PjrtService::start(runtime::default_artifact_dir())?;
    let mut registry = RunnerRegistry::new();
    riser::register_riser_runners(&mut registry, &svc);

    // L3: d-Chiron over 4 worker nodes x 2 threads, 2 data nodes,
    // replication on. Sleep-payload activities scaled down.
    let engine = DChironEngine::with_registry(
        EngineConfig {
            workers: 4,
            threads_per_worker: 2,
            data_nodes: 2,
            replication: true,
            connectors: 2,
            time_scale: 0.01,
            supervisor_poll_secs: 0.002,
            ..Default::default()
        },
        registry,
    );

    let wf = workload::risers_workflow_with(conditions, Some("riser"));
    let inputs = workload::risers_inputs(conditions, 42);
    let planned = wf.planned_total_tasks();
    println!(
        "risers end-to-end: {conditions} environmental conditions, {} activities, {planned} tasks",
        wf.activities.len()
    );

    let t0 = Instant::now();
    let running = engine.start(wf, inputs)?;
    let db = running.db.clone();

    // Steering: monitor loop issuing Q1..Q7 every 250 ms while running.
    let monitor = Monitor::spawn(db.clone(), 0.25, 1);

    // Mid-run adaptation (Q8): once wear results exist, tighten the
    // analyze_risers inputs — the paper's human-in-the-loop moment.
    let client = SteeringClient::new(db.clone());
    let mut adapted = 0usize;
    for _ in 0..400 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        if let Ok(rs) = client.q7_wear_outliers("calculate_wear_and_tear", 0.5) {
            if !rs.rows.is_empty() {
                adapted = client.q8_adapt_ready_inputs("analyze_risers", "a", 2.5, 8)?;
                println!(
                    "steering: Q7 found {} wear outliers -> Q8 adapted {} ready inputs",
                    rs.rows.len(),
                    adapted
                );
                break;
            }
        }
        if running.done.load(std::sync::atomic::Ordering::SeqCst) {
            break;
        }
    }

    let report = running.join()?;
    let queries = monitor.stop();
    let wall = t0.elapsed().as_secs_f64();

    // Post-run analysis straight from the integrated database.
    println!("\n== fatigue results (top wear factors) ==");
    let rs = db.query(
        "SELECT t.taskid, f.value AS f1 FROM workqueue t \
         JOIN taskfield f ON f.taskid = t.taskid \
         WHERE f.field = 'f1' AND f.direction = 'out' \
         ORDER BY f1 DESC LIMIT 5",
    )?;
    println!("{}", rs.render());

    let pjrt_tasks = db
        .query(
            "SELECT COUNT(*) FROM workqueue t JOIN activity a ON t.actid = a.actid \
             WHERE a.name IN ('preprocessing', 'stress_analysis', 'calculate_wear_and_tear') \
             AND t.status = 'FINISHED'",
        )?
        .rows[0]
        .values[0]
        .as_i64()
        .unwrap_or(0);

    println!("{}", metrics::format_report("risers end-to-end", &report));
    println!("== end-to-end summary ==");
    println!("wall time             : {wall:.2}s");
    println!("tasks executed        : {}/{}", report.executed_tasks, report.total_tasks);
    println!("PJRT kernel executions: {pjrt_tasks}");
    println!(
        "task throughput       : {:.1} tasks/s",
        report.executed_tasks as f64 / wall
    );
    println!(
        "mean claim latency    : {}",
        schaladb::util::fmt_secs(
            report
                .access_stats
                .iter()
                .find(|(k, _)| *k == AccessKind::UpdateToRunning)
                .map(|(_, s)| s.mean_secs())
                .unwrap_or(0.0)
        )
    );
    println!("steering queries run  : {queries} (adapted {adapted} inputs via Q8)");
    println!(
        "DBMS share of makespan: {:.1}%",
        100.0 * report.dbms_max_node_secs / report.makespan_secs
    );
    println!("database size         : {} KB", report.db_bytes / 1024);

    if report.executed_tasks < report.total_tasks as u64 {
        return Err("not all tasks executed".into());
    }
    Ok(())
}
