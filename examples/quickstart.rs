//! Quickstart: define a 3-activity parameter sweep, run it on d-Chiron,
//! inspect the work queue (paper Figure 3) and the run report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use schaladb::coordinator::payload::{Payload, SyntheticKind};
use schaladb::coordinator::{ActivitySpec, DChironEngine, EngineConfig, Operator, WorkflowSpec};
use schaladb::metrics;
use schaladb::steering::SteeringClient;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A parameter sweep: activity 1 computes y = a x^2 + b x + c per tuple,
    // activity 2 filters out small results, activity 3 gathers per group.
    let wf = WorkflowSpec::new("quickstart_sweep", 24)
        .activity(
            ActivitySpec::new(
                "sweep",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::Quadratic },
            )
            .with_fields(&["x", "y"]),
        )
        .activity(ActivitySpec::new(
            "select_best",
            Operator::Filter { field: "y", min: 40.0 },
            Payload::Sleep { mean_secs: 0.5 },
        ))
        .activity(ActivitySpec::new(
            "gather",
            Operator::Reduce { fanin: 8 },
            Payload::Sleep { mean_secs: 0.5 },
        ));

    // 2 worker nodes x 2 threads, 2 data nodes with replication; nominal
    // durations scaled 100x down so the demo finishes in seconds.
    let engine = DChironEngine::new(EngineConfig {
        workers: 2,
        threads_per_worker: 2,
        time_scale: 0.01,
        ..Default::default()
    });
    let inputs = (0..24)
        .map(|i| {
            vec![
                ("a".to_string(), 1.0 + (i % 3) as f64),
                ("b".to_string(), (i % 7) as f64 * 5.0),
                ("c".to_string(), (i % 5) as f64 * 3.0),
            ]
        })
        .collect();

    let running = engine.start(wf, inputs)?;
    let db = running.db.clone();
    let report = running.join()?;

    // The paper's Figure-3 view of the work queue.
    println!("== workqueue excerpt (Figure 3) ==");
    let rs = db.query(
        "SELECT taskid, actid, workerid, coreid, cmd, status, \
         ROUND(endtime - starttime, 3) AS secs \
         FROM workqueue ORDER BY workerid, taskid LIMIT 14",
    )?;
    println!("{}", rs.render());

    // Domain results live in the same database.
    println!("== best sweep results ==");
    let rs = db.query(
        "SELECT t.taskid, fx.value AS x, fy.value AS y \
         FROM workqueue t \
         JOIN taskfield fx ON fx.taskid = t.taskid AND fx.field = 'x' \
         JOIN taskfield fy ON fy.taskid = t.taskid AND fy.field = 'y' \
         WHERE t.actid = 1 ORDER BY y DESC LIMIT 5",
    )?;
    println!("{}", rs.render());

    let client = SteeringClient::new(db);
    let (bytes, per_table) = client.db_footprint();
    println!("database footprint: {} KB across {} tables", bytes / 1024, per_table.len());

    println!("{}", metrics::format_report("quickstart", &report));
    Ok(())
}
