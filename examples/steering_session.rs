//! Steering session: runs the risers workflow (synthetic physics, no
//! artifacts needed) and walks through the paper's Table-2 queries Q1–Q8
//! against the live database, printing each result.
//!
//! ```bash
//! cargo run --release --example steering_session
//! ```

use schaladb::coordinator::{DChironEngine, EngineConfig};
use schaladb::steering::SteeringClient;
use schaladb::workload;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let conditions = 64;
    let engine = DChironEngine::new(EngineConfig {
        workers: 3,
        threads_per_worker: 2,
        time_scale: 0.02, // stretch the run so steering observes it live
        ..Default::default()
    });
    let wf = workload::risers_workflow(conditions);
    let inputs = workload::risers_inputs(conditions, 7);
    println!(
        "starting '{}' with {} conditions ({} planned tasks)\n",
        wf.name,
        conditions,
        wf.planned_total_tasks()
    );
    let running = engine.start(wf, inputs)?;
    let db = running.db.clone();
    let client = SteeringClient::new(db.clone());

    // Give the run a moment, then steer while it executes.
    std::thread::sleep(std::time::Duration::from_millis(300));

    println!("Q1 — task status per node (last minute):");
    println!("{}", client.q1_recent_status_by_node()?.render());

    println!("Q2 — bytes per finished task on node000:");
    println!("{}", client.q2_bytes_by_task("node000")?.render());

    println!("Q3 — nodes with most failures (expected: none):");
    let q3 = client.q3_worst_nodes()?;
    println!("{}", if q3.rows.is_empty() { "  (no failures)\n".into() } else { q3.render() });

    println!("Q4 — tasks left for workflow 1: {}", client.q4_tasks_left(1)?);

    println!("\nQ5 — busiest activity (workflows running > 1 min):");
    let q5 = client.q5_busiest_activity()?;
    println!("{}", if q5.rows.is_empty() { "  (run is younger than one minute)\n".into() } else { q5.render() });

    println!("Q6 — execution times per unfinished activity:");
    println!("{}", client.q6_activity_times()?.render());

    // Wait for wear results so Q7/Q8 have data.
    for _ in 0..600 {
        if client.q7_wear_outliers("calculate_wear_and_tear", 0.2).map(|r| !r.rows.is_empty()).unwrap_or(false) {
            break;
        }
        if running.done.load(std::sync::atomic::Ordering::SeqCst) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    println!("Q7 — wear outliers (f1 > 0.2, slower than activity average):");
    println!("{}", client.q7_wear_outliers("calculate_wear_and_tear", 0.2)?.render());

    let adapted = client.q8_adapt_ready_inputs("analyze_risers", "a", 1.5, 4)?;
    println!("Q8 — adapted {adapted} ready analyze_risers inputs (a := 1.5)\n");

    let report = running.join()?;
    println!(
        "workflow finished: {} tasks in {:.2}s; steering overhead is folded into the run",
        report.executed_tasks, report.makespan_secs
    );

    // Provenance drill-down on one wear task, from the same database.
    let rs = db.query(
        "SELECT t.taskid FROM workqueue t JOIN activity a ON t.actid = a.actid \
         WHERE a.name = 'calculate_wear_and_tear' ORDER BY t.taskid LIMIT 1",
    )?;
    if let Some(row) = rs.rows.first() {
        let tid = row.values[0].as_i64().unwrap();
        println!("\nprovenance of task {tid}:");
        println!("{}", client.provenance_of(tid)?.render());
    }
    Ok(())
}
