"""L1 correctness: the Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; the kernel must match ref.py to
f32 tolerance for every tiling that divides the shape.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import stress_damage_ref
from compile.kernels.riser import EXPONENT, stress_damage, vmem_bytes

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape, scale=scale), dtype=jnp.float32)


def assert_matches_ref(a, phi, **kw):
    s, d = stress_damage(a, phi, **kw)
    s_ref, d_ref = stress_damage_ref(a, phi)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(d_ref), rtol=1e-4, atol=1e-4)


def test_default_shape_matches_ref():
    assert_matches_ref(rand((64, 128), 0), rand((128, 256), 1))


@pytest.mark.parametrize("block_b,block_s", [(8, 64), (16, 128), (32, 256), (64, 64)])
def test_tilings_are_equivalent(block_b, block_s):
    a = rand((64, 128), 2)
    phi = rand((128, 256), 3)
    assert_matches_ref(a, phi, block_b=block_b, block_s=block_s)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    bb=st.sampled_from([1, 2, 4, 8]),
    tiles_b=st.integers(1, 4),
    tiles_s=st.integers(1, 4),
    bs=st.sampled_from([8, 16, 32]),
    modes=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_hypothesis_shape_sweep(bb, tiles_b, tiles_s, bs, modes, seed, scale):
    B, S = bb * tiles_b, bs * tiles_s
    a = rand((B, modes), seed, scale)
    phi = rand((modes, S), seed + 1)
    s, d = stress_damage(a, phi, block_b=bb, block_s=bs)
    s_ref, d_ref = stress_damage_ref(a, phi)
    # accumulation-order differences scale with |s| ~ scale * sqrt(modes)
    s_atol = 1e-4 * max(scale * np.sqrt(modes) * 10.0, 1.0)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-4, atol=s_atol)
    # damage is a sum of |s|^3: tolerance scales with magnitude
    d_scale = max((scale * np.sqrt(modes)) ** EXPONENT, 1.0)
    np.testing.assert_allclose(
        np.asarray(d), np.asarray(d_ref), rtol=1e-3, atol=1e-3 * d_scale
    )


def test_zero_amplitudes_give_zero_damage():
    a = jnp.zeros((8, 16), jnp.float32)
    phi = rand((16, 32), 5)
    s, d = stress_damage(a, phi, block_b=8, block_s=32)
    assert float(jnp.max(jnp.abs(s))) == 0.0
    assert float(jnp.max(d)) == 0.0


def test_damage_is_monotone_in_amplitude():
    a = rand((8, 16), 6)
    phi = rand((16, 32), 7)
    _, d1 = stress_damage(a, phi, block_b=8, block_s=32)
    _, d2 = stress_damage(2.0 * a, phi, block_b=8, block_s=32)
    assert np.all(np.asarray(d2) >= np.asarray(d1))


def test_shape_validation():
    a = rand((10, 16), 8)  # B=10 not a multiple of block_b=8
    phi = rand((16, 32), 9)
    with pytest.raises(AssertionError):
        stress_damage(a, phi, block_b=8, block_s=32)
    with pytest.raises(AssertionError):
        stress_damage(rand((8, 12), 10), phi, block_b=8, block_s=32)


def test_vmem_estimate_fits_budget():
    # default tiling must leave room for double buffering in 16 MiB VMEM
    assert vmem_bytes() * 2 < 16 * 1024 * 1024
