"""L2 correctness: model shapes, value ranges, kernel-vs-ref at model
level, and the AOT text lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def env_batch(seed=0):
    rng = np.random.default_rng(seed)
    wind = rng.uniform(0.0, 30.0, model.BATCH)
    wave = rng.uniform(0.05, 0.4, model.BATCH)
    depth = rng.uniform(500.0, 2500.0, model.BATCH)
    return jnp.asarray(np.stack([wind, wave, depth], axis=1), dtype=jnp.float32)


def test_stress_model_shapes_and_finiteness():
    curv, damage = model.riser_stress(env_batch())
    assert curv.shape == (model.BATCH, 3)
    assert damage.shape == (model.BATCH,)
    assert np.all(np.isfinite(np.asarray(curv)))
    assert np.all(np.asarray(damage) >= 0.0)


def test_stress_model_matches_reference_kernel():
    env = env_batch(1)
    curv, damage = model.riser_stress(env)
    curv_ref, damage_ref = model.riser_stress_ref(env)
    np.testing.assert_allclose(np.asarray(curv), np.asarray(curv_ref), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(damage), np.asarray(damage_ref), rtol=1e-4, atol=1e-5
    )


def test_wear_model_bounded():
    curv, _ = model.riser_stress(env_batch(2))
    (f1,) = model.riser_wear(curv)
    f1 = np.asarray(f1)
    assert f1.shape == (model.BATCH,)
    assert np.all((f1 >= 0.0) & (f1 < 1.0))


def test_models_are_deterministic():
    env = env_batch(3)
    a = model.riser_stress(env)
    b = model.riser_stress(env)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))


def test_amplitudes_respond_to_environment():
    calm = jnp.asarray([[1.0, 0.1, 600.0]] * model.BATCH, dtype=jnp.float32)
    storm = jnp.asarray([[30.0, 0.35, 2400.0]] * model.BATCH, dtype=jnp.float32)
    _, d_calm = model.riser_stress(calm)
    _, d_storm = model.riser_stress(storm)
    assert float(d_storm[0]) > float(d_calm[0]), "storm must accumulate more damage"


@pytest.mark.parametrize("name", sorted(aot.MODELS))
def test_aot_lowering_produces_parsable_hlo_text(name):
    fn, shapes = aot.MODELS[name]
    text = aot.to_hlo_text(aot.lower_model(fn, shapes))
    assert "HloModule" in text
    assert "ROOT" in text
    # must be pure HLO text without Mosaic custom-calls (interpret=True)
    assert "mosaic" not in text.lower()
    assert len(text) > 300


def test_phi_matrix_is_normalized():
    phi = np.asarray(model.phi_matrix())
    assert phi.shape == (model.MODES, model.SEGMENTS)
    assert np.all(np.abs(phi) <= 1.0 / np.sqrt(model.MODES) + 1e-6)
