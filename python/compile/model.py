"""L2: the JAX models of the riser fatigue computation, calling the L1
Pallas kernel. Lowered once by aot.py; never imported at runtime.

Two model variants are exported as separate artifacts:

- ``riser_stress``: env (B, 3) [wind m/s, wave Hz, depth m] ->
  (curvature (B, 3), damage (B,)). The modal-amplitude expansion and the
  curvature reductions are plain jnp (XLA fuses them); the (B,M)x(M,S)
  stress matmul + damage accumulation is the Pallas kernel.
- ``riser_wear``: curvature (B, 3) -> wear factor f1 (B,) in [0, 1).
"""

import jax.numpy as jnp

from .kernels import riser as kernels

# Artifact shapes. BATCH must match rust/src/runtime/riser.rs::BATCH.
BATCH = 64
MODES = 128
SEGMENTS = 256


def phi_matrix(modes=MODES, segments=SEGMENTS):
    """Deterministic modal shape matrix (M, S): sinusoidal mode shapes with
    1/sqrt(M) normalization — a stand-in for the proprietary riser model
    (DESIGN.md §Substitutions)."""
    m = jnp.arange(1, modes + 1, dtype=jnp.float32)[:, None]
    s = jnp.arange(1, segments + 1, dtype=jnp.float32)[None, :]
    return (jnp.sin(m * s * (jnp.pi / segments)) / jnp.sqrt(float(modes))).astype(
        jnp.float32
    )


def modal_amplitudes(env, modes=MODES):
    """Environmental condition -> modal excitation amplitudes (B, M).

    wind drives low modes, wave frequency picks a resonant band, depth
    attenuates high modes. Smooth, deterministic, bounded.
    """
    wind = env[:, 0:1]
    wave = env[:, 1:2]
    depth = env[:, 2:3]
    k = jnp.arange(1, modes + 1, dtype=jnp.float32)[None, :]
    resonance = jnp.exp(-0.5 * ((k * wave - 8.0) / 4.0) ** 2)
    drive = jnp.log1p(jnp.abs(wind)) * (1.0 + 0.1 * jnp.sin(wind * 0.7 * k / modes))
    atten = jnp.exp(-k / (depth / 50.0 + 1.0))
    return (drive * resonance * atten).astype(jnp.float32)


def riser_stress(env):
    """env (B, 3) -> (curvature (B, 3), damage (B,))."""
    a = modal_amplitudes(env)
    stress, damage = kernels.stress_damage(a, phi_matrix())
    # curvature components: three orthogonal segment-weighted reductions
    s_idx = jnp.arange(SEGMENTS, dtype=jnp.float32)
    w1 = jnp.cos(jnp.pi * s_idx / SEGMENTS)
    w2 = jnp.sin(jnp.pi * s_idx / SEGMENTS)
    w3 = s_idx / SEGMENTS
    abs_s = jnp.abs(stress)
    cx = abs_s @ w1 / SEGMENTS
    cy = abs_s @ w2 / SEGMENTS
    cz = abs_s @ w3 / SEGMENTS
    curv = jnp.stack([cx, cy, cz], axis=1)
    return curv, damage / SEGMENTS


def riser_wear(curv):
    """curvature (B, 3) -> wear factor f1 (B,) in [0, 1)."""
    f1 = 1.0 - jnp.exp(-jnp.sum(curv * curv, axis=1))
    return (f1.astype(jnp.float32),)


def riser_stress_ref(env):
    """Model-level oracle: same computation with the reference kernel."""
    from .kernels.ref import stress_damage_ref

    a = modal_amplitudes(env)
    stress, damage = stress_damage_ref(a, phi_matrix())
    s_idx = jnp.arange(SEGMENTS, dtype=jnp.float32)
    w1 = jnp.cos(jnp.pi * s_idx / SEGMENTS)
    w2 = jnp.sin(jnp.pi * s_idx / SEGMENTS)
    w3 = s_idx / SEGMENTS
    abs_s = jnp.abs(stress)
    curv = jnp.stack(
        [abs_s @ w1 / SEGMENTS, abs_s @ w2 / SEGMENTS, abs_s @ w3 / SEGMENTS], axis=1
    )
    return curv, damage / SEGMENTS
