"""AOT compile path: lower the L2 models to HLO *text* artifacts for the
rust PJRT runtime. Run once via `make artifacts`; Python is never on the
request path.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import riser as kernels


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(fn, example_shapes):
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in example_shapes]
    return jax.jit(fn).lower(*specs)


MODELS = {
    "riser_stress": (model.riser_stress, [(model.BATCH, 3)]),
    "riser_wear": (model.riser_wear, [(model.BATCH, 3)]),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=sorted(MODELS))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {
        "batch": model.BATCH,
        "modes": model.MODES,
        "segments": model.SEGMENTS,
        "kernel_vmem_bytes_per_step": kernels.vmem_bytes(modes=model.MODES),
        "artifacts": {},
    }
    for name in args.models:
        fn, shapes = MODELS[name]
        text = to_hlo_text(lower_model(fn, shapes))
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["artifacts"][name] = {
            "path": os.path.basename(path),
            "input_shapes": shapes,
            "hlo_chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"kernel VMEM/step estimate: {meta['kernel_vmem_bytes_per_step']} bytes")


if __name__ == "__main__":
    main()
