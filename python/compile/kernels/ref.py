"""Pure-jnp oracle for the Pallas kernel — the CORE correctness signal.

Everything here is deliberately written in the most obvious way possible
(no tiling, no accumulation tricks) so the pytest comparison against the
Pallas implementation is meaningful.
"""

import jax.numpy as jnp

from .riser import EXPONENT


def stress_damage_ref(a, phi):
    """Reference modal stress + damage. a (B, M), phi (M, S)."""
    a = a.astype(jnp.float32)
    phi = phi.astype(jnp.float32)
    stress = a @ phi
    damage = jnp.sum(jnp.abs(stress) ** EXPONENT, axis=1)
    return stress, damage
