"""L1: the riser stress/damage hot spot as a Pallas kernel.

The per-task computation of the Risers Fatigue Analysis workflow is a
modal-superposition stress evaluation followed by a power-law damage
accumulation (Miner's rule): given modal amplitudes ``a[B, M]`` (derived
from the environmental condition) and the riser's modal shape matrix
``phi[M, S]`` over S segments,

    stress[b, s] = sum_m a[b, m] * phi[m, s]        (dense matmul -> MXU)
    damage[b]    = sum_s |stress[b, s]| ** EXPONENT (running reduction)

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles B and S;
each grid step loads an (BB, M) amplitude tile and an (M, BS) phi tile
into VMEM, issues one MXU matmul, writes the stress tile, and folds the
tile's damage contribution into a revisited (BB,) accumulator block —
the HBM<->VMEM schedule expressed with BlockSpecs instead of CUDA
threadblocks. ``interpret=True`` is mandatory on this image: CPU PJRT
cannot execute Mosaic custom-calls; the lowered HLO is portable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Damage exponent (S-N curve slope; 3 is typical for welded steel).
EXPONENT = 3.0


def _kernel(a_ref, phi_ref, s_ref, d_ref):
    j = pl.program_id(1)
    # (BB, M) @ (M, BS) on the MXU; accumulate in f32.
    st = jnp.dot(a_ref[...], phi_ref[...], preferred_element_type=jnp.float32)
    s_ref[...] = st
    partial = jnp.sum(jnp.abs(st) ** EXPONENT, axis=1)

    @pl.when(j == 0)
    def _init():
        d_ref[...] = partial

    @pl.when(j != 0)
    def _acc():
        d_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_b", "block_s"))
def stress_damage(a, phi, *, block_b=32, block_s=128):
    """Pallas stress + damage. Shapes: a (B, M), phi (M, S) with B % block_b
    == 0 and S % block_s == 0. Returns (stress (B, S) f32, damage (B,) f32).
    """
    B, M = a.shape
    M2, S = phi.shape
    assert M == M2, f"mode mismatch {M} != {M2}"
    assert B % block_b == 0, f"B={B} not a multiple of {block_b}"
    assert S % block_s == 0, f"S={S} not a multiple of {block_s}"
    grid = (B // block_b, S // block_s)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, M), lambda i, j: (i, 0)),
            pl.BlockSpec((M, block_s), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_s), lambda i, j: (i, j)),
            # revisited accumulator: every j maps to the same (i,) block
            pl.BlockSpec((block_b,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a.astype(jnp.float32), phi.astype(jnp.float32))


def vmem_bytes(block_b=32, block_s=128, modes=128):
    """Estimated VMEM working set per grid step (f32): amplitude tile +
    phi tile + stress tile + accumulator. Used by DESIGN.md §Perf."""
    return 4 * (block_b * modes + modes * block_s + block_b * block_s + block_b)
