//! Bench target regenerating the paper's exp5 rows on the calibrated
//! simulator (see DESIGN.md per-experiment index). `cargo bench --bench exp5_dbms_impact`.
use schaladb::sim::experiments;

fn main() {
    let out = experiments::run("exp5").expect("exp5");
    out.print();
    std::fs::create_dir_all("target/bench-results").ok();
    let path = format!("target/bench-results/{}.json", "exp5");
    std::fs::write(&path, out.json.to_string()).expect("write json");
    println!("json: {path}");
}
