//! Bench target regenerating the paper's exp3 rows on the calibrated
//! simulator (see DESIGN.md per-experiment index). `cargo bench --bench exp3_tasks_scaling`.
use schaladb::sim::experiments;

fn main() {
    let out = experiments::run("exp3").expect("exp3");
    out.print();
    std::fs::create_dir_all("target/bench-results").ok();
    let path = format!("target/bench-results/{}.json", "exp3");
    std::fs::write(&path, out.json.to_string()).expect("write json");
    println!("json: {path}");
}
