//! Bench target regenerating the paper's exp7 rows on the calibrated
//! simulator (see DESIGN.md per-experiment index). `cargo bench --bench exp7_steering_overhead`.
use schaladb::sim::experiments;

fn main() {
    let out = experiments::run("exp7").expect("exp7");
    out.print();
    std::fs::create_dir_all("target/bench-results").ok();
    let path = format!("target/bench-results/{}.json", "exp7");
    std::fs::write(&path, out.json.to_string()).expect("write json");
    println!("json: {path}");
}
