//! Real-engine small-scale comparison: d-Chiron vs centralized Chiron on
//! this machine (no simulation) — the Experiment-8 *shape* at laptop scale,
//! plus the steering-overhead check (Experiment 7) on the real engine.
//!
//! Durations are nominal-seconds scaled by `time_scale`, so "1 s tasks"
//! run as 2 ms of real sleep; the DBMS work is fully real.
//!
//! `cargo bench --bench engine_small_scale`

use schaladb::baseline::{ChironConfig, ChironEngine};
use schaladb::coordinator::{DChironEngine, EngineConfig};
use schaladb::steering::Monitor;
use schaladb::util::{fmt_secs, render_table};
use schaladb::workload::SyntheticWorkload;

const TIME_SCALE: f64 = 0.002;

fn dchiron(tasks: usize, dur: f64, workers: usize, threads: usize) -> (f64, f64) {
    let w = SyntheticWorkload { total_tasks: tasks, mean_task_secs: dur, activities: 3, seed: 9 };
    let r = DChironEngine::new(EngineConfig {
        workers,
        threads_per_worker: threads,
        time_scale: TIME_SCALE,
        supervisor_poll_secs: 0.001,
        ..Default::default()
    })
    .run(w.workflow(), w.inputs())
    .unwrap();
    assert_eq!(r.executed_tasks as usize, w.planned_tasks());
    (r.makespan_secs, r.dbms_max_node_secs)
}

fn chiron(tasks: usize, dur: f64, workers: usize, threads: usize) -> f64 {
    let w = SyntheticWorkload { total_tasks: tasks, mean_task_secs: dur, activities: 3, seed: 9 };
    let r = ChironEngine::new(ChironConfig {
        workers,
        threads_per_worker: threads,
        time_scale: TIME_SCALE,
        supervisor_poll_secs: 0.001,
        ..Default::default()
    })
    .run(w.workflow(), w.inputs())
    .unwrap();
    assert_eq!(r.executed_tasks as usize, w.planned_tasks());
    r.makespan_secs
}

fn main() {
    let workers = 4;
    let threads = 4;
    println!(
        "engine_small_scale: real engines, {workers} workers x {threads} threads, time-scale {TIME_SCALE}\n"
    );

    // Experiment-8 shape at small scale.
    let mut rows = Vec::new();
    for (label, tasks, dur) in [
        ("small x short", 600usize, 1.0f64),
        ("small x long", 600, 8.0),
        ("large x short", 2400, 1.0),
        ("large x long", 2400, 8.0),
    ] {
        let (d, _) = dchiron(tasks, dur, workers, threads);
        let c = chiron(tasks, dur, workers, threads);
        rows.push(vec![
            label.to_string(),
            tasks.to_string(),
            format!("{dur}s"),
            fmt_secs(d),
            fmt_secs(c),
            format!("{:.2}x", c / d),
        ]);
    }
    println!("== Chiron vs d-Chiron (real engines) ==");
    println!(
        "{}",
        render_table(&["workload", "tasks", "dur", "d-Chiron", "Chiron", "speedup"], &rows)
    );

    // Experiment-7 shape: steering overhead on the real engine.
    let tasks = 1200;
    let (base, _) = dchiron(tasks, 2.0, workers, threads);
    let w = SyntheticWorkload { total_tasks: tasks, mean_task_secs: 2.0, activities: 3, seed: 9 };
    let engine = DChironEngine::new(EngineConfig {
        workers,
        threads_per_worker: threads,
        time_scale: TIME_SCALE,
        supervisor_poll_secs: 0.001,
        ..Default::default()
    });
    let running = engine.start(w.workflow(), w.inputs()).unwrap();
    let monitor = Monitor::spawn(running.db.clone(), 0.030, 1); // "15s" scaled
    let steered = running.join().unwrap().makespan_secs;
    let queries = monitor.stop();
    println!("== steering overhead (real engine) ==");
    println!(
        "without queries: {}   with queries: {} ({} queries)   overhead {:+.1}%\n",
        fmt_secs(base),
        fmt_secs(steered),
        queries,
        100.0 * (steered / base - 1.0)
    );
}
