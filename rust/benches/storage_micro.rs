//! Microbenchmarks of the real storage engine: the numbers that (a)
//! document how far our in-process substrate is from the paper's networked
//! MySQL Cluster (DESIGN.md §Substitutions) and (b) drive the §Perf
//! iteration log in EXPERIMENTS.md.
//!
//! `cargo bench --bench storage_micro`

use schaladb::metrics::Histogram;
use schaladb::storage::checkpoint::checkpoint_node;
use schaladb::storage::cluster::{ClusterConfig, ConcurrencyMode, DurabilityConfig};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, DbCluster, StatementResult, Value};
use schaladb::util::fmt_secs;
use std::sync::Arc;
use std::time::Instant;

struct Bench {
    name: &'static str,
    hist: Histogram,
}

impl Bench {
    fn run(name: &'static str, iters: usize, mut f: impl FnMut(usize)) -> Bench {
        // warmup
        for i in 0..(iters / 10).max(1) {
            f(usize::MAX - i);
        }
        let mut hist = Histogram::new();
        for i in 0..iters {
            let t0 = Instant::now();
            f(i);
            hist.record(t0.elapsed().as_secs_f64());
        }
        Bench { name, hist }
    }

    fn row(&self) -> Vec<String> {
        vec![
            self.name.to_string(),
            self.hist.count().to_string(),
            fmt_secs(self.hist.mean()),
            fmt_secs(self.hist.quantile(0.5)),
            fmt_secs(self.hist.quantile(0.99)),
        ]
    }
}

fn wq_cluster(workers: usize, rows: usize) -> Arc<DbCluster> {
    wq_cluster_mode(workers, rows, ConcurrencyMode::TwoPL)
}

fn wq_cluster_mode(workers: usize, rows: usize, mode: ConcurrencyMode) -> Arc<DbCluster> {
    let c = DbCluster::start(ClusterConfig::builder().concurrency(mode).build().unwrap()).unwrap();
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {workers} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    let mut batch = Vec::new();
    for i in 0..rows {
        batch.push(format!("({i}, {}, {}, 'READY', 1.0, NULL, NULL)", i % 3, i % workers));
        if batch.len() == 512 {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, actid, workerid, status, dur, starttime, endtime) VALUES {}",
                batch.join(", ")
            ))
            .unwrap();
            batch.clear();
        }
    }
    if !batch.is_empty() {
        c.execute(&format!(
            "INSERT INTO workqueue (taskid, actid, workerid, status, dur, starttime, endtime) VALUES {}",
            batch.join(", ")
        ))
        .unwrap();
    }
    c
}

// Network front-end: the multi-client workload driver. The same claim
// stream runs twice — 8 worker threads hitting DbCluster directly
// (in-process baseline) and 8 wire-protocol clients + 2 remote steering
// scanners through a spawned `server::Server` over loopback TCP. Both
// runs are deterministic (`starttime = 0.0`, disjoint point claims), so
// the two clusters must end byte-equal; the remote path must keep at
// least 25% of the in-process claim throughput. Emits BENCH_server.json.
fn bench_server(quick: bool, workers: usize, rows: usize) -> Vec<Bench> {
    use schaladb::server::{Client, Server, ServerConfig};
    use std::sync::atomic::{AtomicBool, Ordering};

    let it = |n: usize| if quick { (n / 20).max(10) } else { n };
    let per_thread = it(1_000).min(rows / workers);
    let n_scanners = 2usize;
    let point_claim = "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
                       WHERE taskid = ? AND status = 'READY' AND workerid = ?";

    // in-process baseline: direct exec_prepared from 8 threads
    let twin = wq_cluster(workers, rows);
    let p = twin.prepare(point_claim).unwrap();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..workers {
        let c = twin.clone();
        let p = p.clone();
        handles.push(std::thread::spawn(move || {
            let mut lat = Vec::with_capacity(per_thread);
            for i in 0..per_thread {
                let tid = (w + i * workers) as i64;
                let t = Instant::now();
                c.exec_prepared(
                    w as u32,
                    AccessKind::UpdateToRunning,
                    &p,
                    &[Value::Int(tid), Value::Int(w as i64)],
                )
                .unwrap();
                lat.push(t.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut inproc_hist = Histogram::new();
    for h in handles {
        for s in h.join().unwrap() {
            inproc_hist.record(s);
        }
    }
    let inproc_rate = (workers * per_thread) as f64 / t0.elapsed().as_secs_f64();

    // remote: the identical stream through the wire protocol, with
    // steering scanners reading concurrently over their own connections
    let cluster = wq_cluster(workers, rows);
    let server = Server::bind(
        "127.0.0.1:0".parse().unwrap(),
        cluster.clone(),
        ServerConfig::default(),
    )
    .unwrap();
    let addr = server.local_addr();

    let stop = Arc::new(AtomicBool::new(false));
    let mut scan_handles = Vec::new();
    for _ in 0..n_scanners {
        let stop = stop.clone();
        scan_handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr, 0, AccessKind::Steering).unwrap();
            let mut lat = Vec::new();
            while !stop.load(Ordering::SeqCst) {
                let t = Instant::now();
                c.query("SELECT status, COUNT(*) FROM workqueue GROUP BY status").unwrap();
                lat.push(t.elapsed().as_secs_f64());
            }
            c.close().unwrap();
            lat
        }));
    }

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..workers {
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr, w as u32, AccessKind::UpdateToRunning).unwrap();
            let (stmt, _) = c.prepare(point_claim).unwrap();
            let mut lat = Vec::with_capacity(per_thread);
            for i in 0..per_thread {
                let tid = (w + i * workers) as i64;
                let t = Instant::now();
                c.exec(stmt, &[Value::Int(tid), Value::Int(w as i64)]).unwrap();
                lat.push(t.elapsed().as_secs_f64());
            }
            c.close().unwrap();
            lat
        }));
    }
    let mut remote_hist = Histogram::new();
    for h in handles {
        for s in h.join().unwrap() {
            remote_hist.record(s);
        }
    }
    let remote_rate = (workers * per_thread) as f64 / t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);
    let mut scan_hist = Histogram::new();
    for h in scan_handles {
        for s in h.join().unwrap() {
            scan_hist.record(s);
        }
    }
    drop(server); // clean shutdown: accept loop joined, handlers reaped

    assert_eq!(
        cluster.fingerprint().unwrap(),
        twin.fingerprint().unwrap(),
        "remote claim stream must leave the cluster byte-equal to the in-process twin"
    );
    let ratio = remote_rate / inproc_rate;
    println!(
        "remote claims over TCP ({workers} clients + {n_scanners} scanners, \
         {} scans): {remote_rate:.0}/s vs in-process {inproc_rate:.0}/s \
         -> {:.0}% retained\n",
        scan_hist.count(),
        ratio * 100.0
    );
    assert!(
        ratio >= 0.25,
        "remote claim throughput must keep >= 25% of in-process, got {:.0}%",
        ratio * 100.0
    );

    std::fs::create_dir_all("target/bench-results").ok();
    let mut obj = schaladb::util::json::Json::obj()
        .set("wq_rows", rows as f64)
        .set("partitions", workers as f64)
        .set("claim_clients", workers as f64)
        .set("steering_scanners", n_scanners as f64)
        .set("claims_per_client", per_thread as f64)
        .set("claims_per_sec_remote", remote_rate)
        .set("claims_per_sec_in_process", inproc_rate)
        .set("remote_over_in_process_ratio", ratio)
        .set("remote_scans", scan_hist.count() as f64);
    let out = vec![
        Bench { name: "claim (in-process twin)", hist: inproc_hist },
        Bench { name: "remote claim (wire)", hist: remote_hist },
        Bench { name: "remote steering scan (wire)", hist: scan_hist },
    ];
    for b in &out {
        obj = obj.set(
            b.name,
            schaladb::util::json::Json::obj()
                .set("mean_secs", b.hist.mean())
                .set("p50_secs", b.hist.quantile(0.5))
                .set("p99_secs", b.hist.quantile(0.99)),
        );
    }
    std::fs::write("target/bench-results/BENCH_server.json", obj.to_string()).unwrap();
    println!("json: target/bench-results/BENCH_server.json");
    out
}

// Observability overhead: the CI gate behind BENCH_obs.json. The same
// point-claim stream runs with the obs registry live (spans, counters,
// latch/WAL timing, slow-op ring) and quiesced via `set_enabled(false)` —
// three interleaved rounds, best rate per arm, so scheduler noise does not
// masquerade as instrumentation cost. The workflow gates overhead <= 5%.
fn bench_obs(quick: bool, workers: usize, rows: usize) -> Vec<Bench> {
    use schaladb::obs::Counter;

    let threads = 4usize;
    // a 5% gate needs a measurement window that dwarfs scheduler jitter,
    // so quick mode keeps far more iterations here than the other sections
    let per_thread = if quick { 500 } else { 2_000 }.min(rows / workers);
    let point_sql = "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
                     WHERE taskid = ? AND status = 'READY' AND workerid = ?";
    let run = |enabled: bool| -> (f64, Histogram) {
        let c = wq_cluster(workers, rows);
        c.obs().set_enabled(enabled);
        let p = c.prepare(point_sql).unwrap();
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..threads {
            let c = c.clone();
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                let w = t % workers;
                let mut lat = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    // distinct READY taskids in this worker's partition
                    let tid = (w + i * workers) as i64;
                    let params = [Value::Int(tid), Value::Int(w as i64)];
                    let t1 = Instant::now();
                    c.exec_prepared(t as u32, AccessKind::UpdateToRunning, &p, &params)
                        .unwrap();
                    lat.push(t1.elapsed().as_secs_f64());
                }
                lat
            }));
        }
        let mut hist = Histogram::new();
        for h in handles {
            for s in h.join().unwrap() {
                hist.record(s);
            }
        }
        let rate = (threads * per_thread) as f64 / t0.elapsed().as_secs_f64();
        // the comparison is honest only if the instrumented arm really
        // recorded and the quiesced arm really skipped
        let counted = c.obs().counter(Counter::DmlFast);
        if enabled {
            assert!(
                counted >= (threads * per_thread) as u64,
                "instrumented arm must count every claim, saw {counted}"
            );
        } else {
            assert_eq!(counted, 0, "quiesced registry must not count");
        }
        (rate, hist)
    };

    let mut best_on = 0.0f64;
    let mut best_off = 0.0f64;
    let mut hist_on = Histogram::new();
    let mut hist_off = Histogram::new();
    for round in 0..3 {
        let (r_off, h_off) = run(false);
        let (r_on, h_on) = run(true);
        println!("obs overhead round {round}: quiesced {r_off:.0}/s, instrumented {r_on:.0}/s");
        if r_off > best_off {
            best_off = r_off;
            hist_off = h_off;
        }
        if r_on > best_on {
            best_on = r_on;
            hist_on = h_on;
        }
    }
    let overhead_frac = ((best_off - best_on) / best_off).max(0.0);
    println!(
        "obs overhead (best of 3): instrumented {best_on:.0}/s vs quiesced {best_off:.0}/s \
         -> {:.2}% overhead\n",
        overhead_frac * 100.0
    );

    std::fs::create_dir_all("target/bench-results").ok();
    let mut obj = schaladb::util::json::Json::obj()
        .set("wq_rows", rows as f64)
        .set("partitions", workers as f64)
        .set("claim_threads", threads as f64)
        .set("claims_per_thread", per_thread as f64)
        .set("claims_per_sec_instrumented", best_on)
        .set("claims_per_sec_quiesced", best_off)
        .set("overhead_frac", overhead_frac);
    let out = vec![
        Bench { name: "claim (obs instrumented)", hist: hist_on },
        Bench { name: "claim (obs quiesced)", hist: hist_off },
    ];
    for b in &out {
        obj = obj.set(
            b.name,
            schaladb::util::json::Json::obj()
                .set("mean_secs", b.hist.mean())
                .set("p50_secs", b.hist.quantile(0.5))
                .set("p99_secs", b.hist.quantile(0.99)),
        );
    }
    std::fs::write("target/bench-results/BENCH_obs.json", obj.to_string()).unwrap();
    println!("json: target/bench-results/BENCH_obs.json");
    out
}

// Optimistic concurrency for the claim loop: the same PK-probe point claim
// that the DML fast-path section measures, swept across 1/2/4/8/16 worker
// threads under the three execution tiers — OCC (read + compute off-lock,
// validate-and-install under a short commit section), the 2PL compiled
// fast path (write latches held for the whole statement), and the
// interpreted executor. Claims are NOW()-free and disjoint (each thread
// owns a lane of taskids inside its partition), so every arm does the same
// logical work and the sweep isolates latch vs validation cost. A hot-row
// arm hammers one row from 8 threads so the retry machinery shows up in
// the numbers too. Emits BENCH_occ.json, including the machine's core
// count so the CI gate knows which ratios are physically meaningful.
fn bench_occ(quick: bool, workers: usize, rows: usize) -> Vec<Bench> {
    let it = |n: usize| if quick { (n / 20).max(10) } else { n };
    let point_sql = "UPDATE workqueue SET status = 'RUNNING', starttime = 1.0 \
                     WHERE taskid = ? AND status = 'READY' AND workerid = ?";

    #[derive(Clone, Copy)]
    enum Arm {
        Occ,
        Fast,
        Interp,
    }

    let run_claims = |threads: usize, arm: Arm| -> (f64, u64, u64, u64) {
        let mode = match arm {
            Arm::Occ => ConcurrencyMode::Occ,
            _ => ConcurrencyMode::TwoPL,
        };
        // When threads > partitions, several threads share a partition;
        // each walks its own lane of that partition's residue class so
        // claims stay disjoint.
        let lanes = (threads + workers - 1) / workers;
        let per_thread = it(1_000).min(rows / (workers * lanes));
        let c = wq_cluster_mode(workers, rows, mode);
        let p = c.prepare(point_sql).unwrap();
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..threads {
            let c = c.clone();
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                let w = t % workers;
                let lane = t / workers;
                for i in 0..per_thread {
                    // partition w holds taskids congruent to w mod workers
                    let tid = (w + (lane + i * lanes) * workers) as i64;
                    let params = [Value::Int(tid), Value::Int(w as i64)];
                    let r = match arm {
                        Arm::Interp => c.exec_prepared_interpreted(
                            t as u32,
                            AccessKind::UpdateToRunning,
                            &p,
                            &params,
                        ),
                        _ => c.exec_prepared(t as u32, AccessKind::UpdateToRunning, &p, &params),
                    };
                    r.unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rate = (threads * per_thread) as f64 / t0.elapsed().as_secs_f64();
        let rc = c.route_counts();
        (rate, rc.occ_dml, rc.occ_retries, rc.occ_fallbacks)
    };

    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    std::fs::create_dir_all("target/bench-results").ok();
    let mut obj = schaladb::util::json::Json::obj()
        .set("wq_rows", rows as f64)
        .set("partitions", workers as f64)
        .set("cores", cores as f64);
    for &threads in &[1usize, 2, 4, 8, 16] {
        let (interp, _, _, _) = run_claims(threads, Arm::Interp);
        let (fast, _, _, _) = run_claims(threads, Arm::Fast);
        let (occ, dml, retries, fallbacks) = run_claims(threads, Arm::Occ);
        println!(
            "occ claim loop, {threads} thread(s): interpreted {interp:.0}/s, \
             2pl fast {fast:.0}/s, occ {occ:.0}/s ({:.2}x vs 2pl; \
             {dml} occ commits, {retries} retries, {fallbacks} fallbacks)",
            occ / fast
        );
        obj = obj
            .set(&format!("claims_per_sec_interpreted_{threads}t"), interp)
            .set(&format!("claims_per_sec_2pl_{threads}t"), fast)
            .set(&format!("claims_per_sec_occ_{threads}t"), occ)
            .set(&format!("occ_vs_2pl_{threads}t"), occ / fast)
            .set(&format!("occ_dml_{threads}t"), dml as f64)
            .set(&format!("occ_retries_{threads}t"), retries as f64)
            .set(&format!("occ_fallbacks_{threads}t"), fallbacks as f64);
    }
    println!();

    // hot-row contention: 8 threads bump one row's dur. Under 2PL the
    // write latch serializes them; under OCC every loser revalidates, so
    // this is the worst case for validation — and the arm that proves the
    // retry counters move.
    let bump_sql = "UPDATE workqueue SET dur = dur + 1.0 WHERE taskid = ? AND workerid = ?";
    let run_hot = |mode: ConcurrencyMode| -> (f64, u64, u64, u64) {
        let c = wq_cluster_mode(workers, rows, mode);
        let p = c.prepare(bump_sql).unwrap();
        let n = it(1_000);
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..8u32 {
            let c = c.clone();
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..n {
                    c.exec_prepared(
                        t,
                        AccessKind::Other,
                        &p,
                        &[Value::Int(0), Value::Int(0)],
                    )
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rate = (8 * n) as f64 / t0.elapsed().as_secs_f64();
        let rc = c.route_counts();
        (rate, rc.occ_dml, rc.occ_retries, rc.occ_fallbacks)
    };
    let (hot_2pl, _, _, _) = run_hot(ConcurrencyMode::TwoPL);
    let (hot_occ, hot_dml, hot_retries, hot_fallbacks) = run_hot(ConcurrencyMode::Occ);
    println!(
        "hot-row bump, 8 threads on 1 row: 2pl {hot_2pl:.0}/s, occ {hot_occ:.0}/s \
         ({hot_dml} occ commits, {hot_retries} retries, {hot_fallbacks} fallbacks)\n"
    );
    obj = obj
        .set("hot_row_per_sec_2pl", hot_2pl)
        .set("hot_row_per_sec_occ", hot_occ)
        .set("hot_row_occ_dml", hot_dml as f64)
        .set("hot_row_occ_retries", hot_retries as f64)
        .set("hot_row_occ_fallbacks", hot_fallbacks as f64);

    // single-thread latency view of the three tiers
    let mut out = Vec::new();
    let c = wq_cluster_mode(workers, rows, ConcurrencyMode::Occ);
    let p = c.prepare(point_sql).unwrap();
    out.push(Bench::run("occ point claim (latency)", it(5_000), |i| {
        let tid = (i % rows) as i64;
        c.exec_prepared(
            0,
            AccessKind::UpdateToRunning,
            &p,
            &[Value::Int(tid), Value::Int(tid % workers as i64)],
        )
        .unwrap();
    }));
    let c2 = wq_cluster_mode(workers, rows, ConcurrencyMode::TwoPL);
    let p2 = c2.prepare(point_sql).unwrap();
    out.push(Bench::run("2pl point claim (latency)", it(5_000), |i| {
        let tid = (i % rows) as i64;
        c2.exec_prepared(
            0,
            AccessKind::UpdateToRunning,
            &p2,
            &[Value::Int(tid), Value::Int(tid % workers as i64)],
        )
        .unwrap();
    }));
    let c3 = wq_cluster_mode(workers, rows, ConcurrencyMode::TwoPL);
    let p3 = c3.prepare(point_sql).unwrap();
    out.push(Bench::run("interpreted point claim (latency)", it(5_000), |i| {
        let tid = (i % rows) as i64;
        c3.exec_prepared_interpreted(
            0,
            AccessKind::UpdateToRunning,
            &p3,
            &[Value::Int(tid), Value::Int(tid % workers as i64)],
        )
        .unwrap();
    }));
    for b in &out {
        obj = obj.set(
            b.name,
            schaladb::util::json::Json::obj()
                .set("mean_secs", b.hist.mean())
                .set("p50_secs", b.hist.quantile(0.5))
                .set("p99_secs", b.hist.quantile(0.99)),
        );
    }
    std::fs::write("target/bench-results/BENCH_occ.json", obj.to_string()).unwrap();
    println!("json: target/bench-results/BENCH_occ.json");
    out
}

// Elastic topology: live rebalance + split under a concurrent claim
// stream — the CI gate behind BENCH_rebalance.json. Four claim threads
// run the disjoint point-claim stream while the admin path registers a
// fresh node and hands partition 0's primary to it; time-to-cut is the
// rebalance call's wall time, and the claims that land inside that window
// measure the throughput dip. Then the quiesced split of an untouched
// partition times the re-deal. The claim id set is deterministic, so an
// untouched twin replaying the same claims must end byte-equal: topology
// surgery may slow the stream down, never change its content.
fn bench_topology(workers: usize, rows: usize) -> Vec<Bench> {
    use std::sync::atomic::{AtomicU64, Ordering};

    let threads = 4usize.min(workers);
    let cap = rows / workers; // READY taskids in each claimed partition lane
    let per_steady = cap / 3;
    let per_move = cap - per_steady;
    let point_sql = "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
                     WHERE taskid = ? AND status = 'READY' AND workerid = ?";

    let c = wq_cluster(workers, rows);
    let p = c.prepare(point_sql).unwrap();
    let epoch0 = c.cluster_epoch();

    // phase 1 — steady state: the same claim stream with no surgery, the
    // denominator for the dip measurement
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..threads {
        let c = c.clone();
        let p = p.clone();
        handles.push(std::thread::spawn(move || {
            let w = t % workers;
            let mut lat = Vec::with_capacity(per_steady);
            for i in 0..per_steady {
                // partition w holds taskids congruent to w mod workers
                let tid = (w + i * workers) as i64;
                let params = [Value::Int(tid), Value::Int(w as i64)];
                let t1 = Instant::now();
                c.exec_prepared(t as u32, AccessKind::UpdateToRunning, &p, &params).unwrap();
                lat.push(t1.elapsed().as_secs_f64());
            }
            lat
        }));
    }
    let mut hist_steady = Histogram::new();
    for h in handles {
        for s in h.join().unwrap() {
            hist_steady.record(s);
        }
    }
    let steady_rate = (threads * per_steady) as f64 / t0.elapsed().as_secs_f64();

    // phase 2 — the same stream keeps firing while a node joins and
    // partition 0 (thread 0's lane) is handed to it mid-claim
    let done = Arc::new(AtomicU64::new(0));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..threads {
        let c = c.clone();
        let p = p.clone();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            let w = t % workers;
            let mut lat = Vec::with_capacity(per_move);
            for i in per_steady..cap {
                let tid = (w + i * workers) as i64;
                let params = [Value::Int(tid), Value::Int(w as i64)];
                let t1 = Instant::now();
                loop {
                    match c.exec_prepared(t as u32, AccessKind::UpdateToRunning, &p, &params) {
                        Ok(StatementResult::Affected(n)) => {
                            assert_eq!(n, 1, "claim of task {tid} must land exactly once");
                            break;
                        }
                        Ok(other) => panic!("claim of task {tid} returned {other:?}"),
                        // the latched final cut may bounce a claim; it
                        // must succeed on retry, never vanish
                        Err(schaladb::Error::Unavailable(_)) => continue,
                        Err(e) => panic!("claim of task {tid} failed: {e}"),
                    }
                }
                lat.push(t1.elapsed().as_secs_f64());
                done.fetch_add(1, Ordering::Relaxed);
            }
            lat
        }));
    }
    let new_node = c.add_node().unwrap();
    let before_cut = done.load(Ordering::Relaxed);
    let t_cut = Instant::now();
    c.rebalance_partition("workqueue", 0, new_node).unwrap();
    let time_to_cut = t_cut.elapsed().as_secs_f64();
    let claims_during_cut = done.load(Ordering::Relaxed) - before_cut;
    let mut hist_move = Histogram::new();
    for h in handles {
        for s in h.join().unwrap() {
            hist_move.record(s);
        }
    }
    let move_rate = (threads * per_move) as f64 / t0.elapsed().as_secs_f64();
    let topo = c.topology();
    let wq = topo.tables.iter().find(|t| t.table == "workqueue").unwrap();
    assert_eq!(wq.partitions[0].primary, new_node, "rebalance must have flipped the primary");

    // phase 3 — quiesced split of a partition the claim threads never
    // touched: cap READY rows re-dealt across the doubled residue classes
    let split_src = workers - 1;
    let t_split = Instant::now();
    let new_pidx = c.split_partition("workqueue", split_src).unwrap();
    let split_secs = t_split.elapsed().as_secs_f64();
    assert_eq!(new_pidx, workers, "split appends the new partition at the end");

    // phase 4 — the untouched twin replays the identical claim set on the
    // original topology; byte-equality proves surgery changed placement,
    // not content
    let twin = wq_cluster(workers, rows);
    let tp = twin.prepare(point_sql).unwrap();
    for t in 0..threads {
        let w = t % workers;
        for i in 0..cap {
            let tid = (w + i * workers) as i64;
            let params = [Value::Int(tid), Value::Int(w as i64)];
            match twin.exec_prepared(0, AccessKind::UpdateToRunning, &tp, &params).unwrap() {
                StatementResult::Affected(1) => {}
                other => panic!("twin claim of task {tid} returned {other:?}"),
            }
        }
    }
    assert_eq!(
        c.fingerprint().unwrap(),
        twin.fingerprint().unwrap(),
        "moved + split cluster must stay byte-equal to the untouched twin"
    );

    let cut_rate = claims_during_cut as f64 / time_to_cut.max(1e-9);
    let retention = cut_rate / steady_rate;
    println!(
        "live rebalance under {threads} claim threads: steady {steady_rate:.0}/s, \
         move window {move_rate:.0}/s; cut took {}, {claims_during_cut} claims landed \
         during it ({:.0}% of steady rate); split of {cap} rows took {}\n",
        fmt_secs(time_to_cut),
        retention * 100.0,
        fmt_secs(split_secs)
    );

    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    std::fs::create_dir_all("target/bench-results").ok();
    let mut obj = schaladb::util::json::Json::obj()
        .set("wq_rows", rows as f64)
        .set("partitions", workers as f64)
        .set("cores", cores as f64)
        .set("claim_threads", threads as f64)
        .set("claims_per_thread", cap as f64)
        .set("claims_per_sec_steady", steady_rate)
        .set("claims_per_sec_move_window", move_rate)
        .set("claims_during_cut", claims_during_cut as f64)
        .set("claims_per_sec_during_cut", cut_rate)
        .set("cut_retention_frac", retention)
        .set("time_to_cut_secs", time_to_cut)
        .set("split_secs", split_secs)
        .set("split_rows_redealt", cap as f64)
        .set("epochs_advanced", (c.cluster_epoch() - epoch0) as f64)
        .set("moved_ok", 1.0)
        .set("split_ok", 1.0)
        .set("fingerprint_equal", 1.0);
    let out = vec![
        Bench { name: "claim (steady state)", hist: hist_steady },
        Bench { name: "claim (during topology change)", hist: hist_move },
    ];
    for b in &out {
        obj = obj.set(
            b.name,
            schaladb::util::json::Json::obj()
                .set("mean_secs", b.hist.mean())
                .set("p50_secs", b.hist.quantile(0.5))
                .set("p99_secs", b.hist.quantile(0.99)),
        );
    }
    std::fs::write("target/bench-results/BENCH_rebalance.json", obj.to_string()).unwrap();
    println!("json: target/bench-results/BENCH_rebalance.json");
    out
}

fn main() {
    // STORAGE_MICRO_QUICK=1: CI smoke mode — same benches, ~5% of the
    // iterations, so the workflow exercises every path in seconds.
    let quick = std::env::var("STORAGE_MICRO_QUICK").map_or(false, |v| v != "0");
    let it = |n: usize| if quick { (n / 20).max(10) } else { n };
    let workers = 8;
    let rows = if quick { 4_000 } else { 20_000 };
    println!(
        "storage_micro: {rows} WQ rows, {workers} partitions, 2 data nodes, replication on{}\n",
        if quick { " (quick mode)" } else { "" }
    );
    let mut benches = Vec::new();

    // STORAGE_MICRO_SECTION=server: only the network front-end section —
    // the CI server-smoke job's quick gate.
    if std::env::var("STORAGE_MICRO_SECTION").as_deref() == Ok("server") {
        let server_benches = bench_server(quick, workers, rows);
        let rows_out: Vec<Vec<String>> = server_benches.iter().map(|b| b.row()).collect();
        println!(
            "{}",
            schaladb::util::render_table(
                &["operation", "iters", "mean", "p50", "p99"],
                &rows_out
            )
        );
        return;
    }

    // STORAGE_MICRO_SECTION=obs: only the observability overhead section —
    // the CI obs-smoke job's quick gate behind BENCH_obs.json.
    if std::env::var("STORAGE_MICRO_SECTION").as_deref() == Ok("obs") {
        let obs_benches = bench_obs(quick, workers, rows);
        let rows_out: Vec<Vec<String>> = obs_benches.iter().map(|b| b.row()).collect();
        println!(
            "{}",
            schaladb::util::render_table(
                &["operation", "iters", "mean", "p50", "p99"],
                &rows_out
            )
        );
        return;
    }

    // STORAGE_MICRO_SECTION=occ: only the OCC claim-loop sweep — the CI
    // occ-bench job's quick gate behind BENCH_occ.json.
    if std::env::var("STORAGE_MICRO_SECTION").as_deref() == Ok("occ") {
        let occ_benches = bench_occ(quick, workers, rows);
        let rows_out: Vec<Vec<String>> = occ_benches.iter().map(|b| b.row()).collect();
        println!(
            "{}",
            schaladb::util::render_table(
                &["operation", "iters", "mean", "p50", "p99"],
                &rows_out
            )
        );
        return;
    }

    // STORAGE_MICRO_SECTION=topology: only the elastic-topology section —
    // the CI topology-chaos job's quick gate behind BENCH_rebalance.json.
    if std::env::var("STORAGE_MICRO_SECTION").as_deref() == Ok("topology") {
        let topo_benches = bench_topology(workers, rows);
        let rows_out: Vec<Vec<String>> = topo_benches.iter().map(|b| b.row()).collect();
        println!(
            "{}",
            schaladb::util::render_table(
                &["operation", "iters", "mean", "p50", "p99"],
                &rows_out
            )
        );
        return;
    }

    // point insert (supervisor task generation path)
    {
        let c = wq_cluster(workers, rows);
        let base = rows as i64 + 1_000_000;
        benches.push(Bench::run("insert 1 row", it(2_000), |i| {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                 VALUES ({}, 1, {}, 'READY', 1.0)",
                base + i as i64,
                i % workers
            ))
            .unwrap();
        }));
    }

    // getREADYtasks: the paper's hottest query (indexed + partition-pruned)
    {
        let c = wq_cluster(workers, rows);
        benches.push(Bench::run("getREADYtasks (LIMIT 4)", it(5_000), |i| {
            c.query(&format!(
                "SELECT taskid, actid, dur FROM workqueue \
                 WHERE workerid = {} AND status = 'READY' ORDER BY taskid LIMIT 4",
                i % workers
            ))
            .unwrap();
        }));
    }

    // the atomic claim (UPDATE ... LIMIT 1 RETURNING)
    {
        let c = wq_cluster(workers, rows);
        benches.push(Bench::run("claim (UPDATE..RETURNING)", it(5_000), |i| {
            c.exec(&format!(
                "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                 WHERE workerid = {} AND status = 'READY' ORDER BY taskid LIMIT 1 \
                 RETURNING taskid",
                i % workers
            ))
            .unwrap();
        }));
    }

    // point status update by PK
    {
        let c = wq_cluster(workers, rows);
        benches.push(Bench::run("updateToFINISHED (by PK)", it(5_000), |i| {
            c.execute(&format!(
                "UPDATE workqueue SET status = 'FINISHED', endtime = NOW() WHERE taskid = {}",
                i % rows
            ))
            .unwrap();
        }));
    }

    // analytical aggregate over the whole WQ (monitoring-style)
    {
        let c = wq_cluster(workers, rows);
        benches.push(Bench::run("full-WQ GROUP BY status", it(200), |_| {
            c.query("SELECT status, COUNT(*) FROM workqueue GROUP BY status").unwrap();
        }));
    }

    // steering-style join (WQ x WQ self-join via actid aggregation)
    {
        let c = wq_cluster(workers, rows);
        c.exec("CREATE TABLE node (nodeid INT NOT NULL, hostname TEXT) PRIMARY KEY (nodeid)")
            .unwrap();
        for w in 0..workers {
            c.execute(&format!("INSERT INTO node (nodeid, hostname) VALUES ({w}, 'n{w}')"))
                .unwrap();
        }
        benches.push(Bench::run("join WQ x node + GROUP BY", it(200), |_| {
            c.query(
                "SELECT n.hostname, COUNT(*) FROM workqueue t JOIN node n \
                 ON t.workerid = n.nodeid GROUP BY n.hostname",
            )
            .unwrap();
        }));
    }

    // multi-statement transaction (2 partitions, 2PC + replica apply)
    {
        let c = wq_cluster(workers, rows);
        benches.push(Bench::run("txn: 2 updates, 2 partitions", it(2_000), |i| {
            let a = i % workers;
            let b = (i + 1) % workers;
            schaladb::storage::txn::TxnBuilder::new(
                c.clone(),
                0,
                schaladb::storage::AccessKind::Other,
            )
            .stmt(&format!(
                "UPDATE workqueue SET dur = dur + 1 WHERE taskid = {}",
                a * 10
            ))
            .unwrap()
            .stmt(&format!(
                "UPDATE workqueue SET dur = dur + 1 WHERE taskid = {}",
                b * 10 + 1
            ))
            .unwrap()
            .commit()
            .unwrap();
        }));
    }

    // prepared vs parse-per-call — the prepared-statement API's headline
    // number. A point SELECT by PK makes statement processing (format! +
    // lex + parse versus a cached plan + value binding) the dominant cost,
    // which is exactly the overhead the prepared path removes from every
    // per-task round-trip.
    {
        let c = wq_cluster(workers, rows);
        let iters = it(20_000);
        let parse_bench = Bench::run("point SELECT (parse per call)", iters, |i| {
            c.query(&format!(
                "SELECT taskid, actid, workerid, status, dur, starttime, endtime \
                 FROM workqueue WHERE taskid = {} AND status != 'NOPE' AND dur >= 0.0",
                i % rows
            ))
            .unwrap();
        });
        let p = c
            .prepare(
                "SELECT taskid, actid, workerid, status, dur, starttime, endtime \
                 FROM workqueue WHERE taskid = ? AND status != 'NOPE' AND dur >= 0.0",
            )
            .unwrap();
        let prep_bench = Bench::run("point SELECT (prepared)", iters, |i| {
            c.query_prepared(&p, &[Value::Int((i % rows) as i64)]).unwrap();
        });
        let speedup = parse_bench.hist.mean() / prep_bench.hist.mean();
        println!("prepared speedup over parse-per-call (point SELECT): {speedup:.1}x\n");
        benches.push(parse_bench);
        benches.push(prep_bench);
    }

    // batched bind: one prepared row template expanded 64x vs assembling
    // and parsing a 64-row INSERT string per call (the supervisor's old
    // task-generation path).
    {
        let batch = 64usize;
        let c = wq_cluster(workers, 0);
        let mut next = 0i64;
        let parse_bench = Bench::run("64-row INSERT (format!+parse)", it(300), |_| {
            let mut vals = Vec::with_capacity(batch);
            for _ in 0..batch {
                vals.push(format!("({next}, 1, {}, 'READY', 1.0)", next % workers as i64));
                next += 1;
            }
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, actid, workerid, status, dur) VALUES {}",
                vals.join(", ")
            ))
            .unwrap();
        });
        let c2 = wq_cluster(workers, 0);
        let p = c2
            .prepare(
                "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                 VALUES (?, ?, ?, 'READY', ?)",
            )
            .unwrap();
        let mut next2 = 0i64;
        let prep_bench = Bench::run("64-row INSERT (prepared batch)", it(300), |_| {
            let bound: Vec<Vec<Value>> = (0..batch)
                .map(|_| {
                    let id = next2;
                    next2 += 1;
                    vec![
                        Value::Int(id),
                        Value::Int(1),
                        Value::Int(id % workers as i64),
                        Value::Float(1.0),
                    ]
                })
                .collect();
            c2.exec_prepared_batch(0, AccessKind::InsertTasks, &p, &bound).unwrap();
        });
        let speedup = parse_bench.hist.mean() / prep_bench.hist.mean();
        println!("prepared speedup over parse-per-call (64-row INSERT): {speedup:.1}x\n");
        benches.push(parse_bench);
        benches.push(prep_bench);
    }

    // concurrent claims: 8 threads hammering distinct partitions
    {
        let c = wq_cluster(workers, rows);
        let t0 = Instant::now();
        let claims = it(1_000);
        let mut handles = Vec::new();
        for w in 0..workers {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..claims {
                    c.exec(&format!(
                        "UPDATE workqueue SET status = 'RUNNING' \
                         WHERE workerid = {w} AND status = 'READY' ORDER BY taskid LIMIT 1 \
                         RETURNING taskid"
                    ))
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = (workers * claims) as f64;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "concurrent claims: {} claims across {workers} threads in {} -> {:.0} claims/s\n",
            workers * claims,
            fmt_secs(dt),
            total / dt
        );
    }

    // compiled DML fast path vs interpreted: the claim-loop numbers this
    // optimization exists for. The worker's point claim (conditional UPDATE
    // by PK, partition pinned) runs through exec_prepared (compiled plan)
    // and exec_prepared_interpreted (AST reference) on identical clusters,
    // at 1/4/8 worker threads. Emits BENCH_dml_fastpath.json.
    {
        let point_sql = "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                         WHERE taskid = ? AND status = 'READY' AND workerid = ?";
        let per_thread = it(2_000);
        let run_claims = |threads: usize, fast: bool| -> f64 {
            let c = wq_cluster(workers, rows);
            let p = c.prepare(point_sql).unwrap();
            let t0 = Instant::now();
            let mut handles = Vec::new();
            for t in 0..threads {
                let c = c.clone();
                let p = p.clone();
                handles.push(std::thread::spawn(move || {
                    let w = t % workers;
                    for i in 0..per_thread {
                        // distinct READY taskids inside this worker's
                        // partition: taskid = w + i*workers
                        let tid = (w + i * workers) as i64;
                        let params = [Value::Int(tid), Value::Int(w as i64)];
                        let r = if fast {
                            c.exec_prepared(
                                t as u32,
                                AccessKind::UpdateToRunning,
                                &p,
                                &params,
                            )
                        } else {
                            c.exec_prepared_interpreted(
                                t as u32,
                                AccessKind::UpdateToRunning,
                                &p,
                                &params,
                            )
                        };
                        r.unwrap();
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            (threads * per_thread) as f64 / t0.elapsed().as_secs_f64()
        };
        let mut obj = schaladb::util::json::Json::obj()
            .set("wq_rows", rows as f64)
            .set("partitions", workers as f64)
            .set("claims_per_thread", per_thread as f64);
        for &threads in &[1usize, 4, 8] {
            let interp = run_claims(threads, false);
            let fastr = run_claims(threads, true);
            let speedup = fastr / interp;
            println!(
                "claim loop (point update), {threads} thread(s): \
                 interpreted {interp:.0}/s, fast {fastr:.0}/s -> {speedup:.2}x"
            );
            obj = obj
                .set(&format!("claims_per_sec_interpreted_{threads}t"), interp)
                .set(&format!("claims_per_sec_fast_{threads}t"), fastr)
                .set(&format!("speedup_{threads}t"), speedup);
        }
        println!();

        // latency view of the same statement, plus the LIMIT-1 claim shape
        let c = wq_cluster(workers, rows);
        let p = c.prepare(point_sql).unwrap();
        let interp_bench = Bench::run("point claim (interpreted)", it(5_000), |i| {
            let tid = (i % rows) as i64;
            c.exec_prepared_interpreted(
                0,
                AccessKind::UpdateToRunning,
                &p,
                &[Value::Int(tid), Value::Int(tid % workers as i64)],
            )
            .unwrap();
        });
        let c2 = wq_cluster(workers, rows);
        let p2 = c2.prepare(point_sql).unwrap();
        let fast_bench = Bench::run("point claim (compiled fast path)", it(5_000), |i| {
            let tid = (i % rows) as i64;
            c2.exec_prepared(
                0,
                AccessKind::UpdateToRunning,
                &p2,
                &[Value::Int(tid), Value::Int(tid % workers as i64)],
            )
            .unwrap();
        });
        let point_speedup = interp_bench.hist.mean() / fast_bench.hist.mean();
        println!("compiled fast path speedup (point claim latency): {point_speedup:.1}x\n");

        let claim_sql = "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                         WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
                         RETURNING taskid";
        let c3 = wq_cluster(workers, rows);
        let p3 = c3.prepare(claim_sql).unwrap();
        let interp_limit = Bench::run("claim LIMIT 1 (interpreted)", it(2_000), |i| {
            c3.exec_prepared_interpreted(
                0,
                AccessKind::UpdateToRunning,
                &p3,
                &[Value::Int((i % workers) as i64)],
            )
            .unwrap();
        });
        let c4 = wq_cluster(workers, rows);
        let p4 = c4.prepare(claim_sql).unwrap();
        let fast_limit = Bench::run("claim LIMIT 1 (compiled fast path)", it(2_000), |i| {
            c4.exec_prepared(
                0,
                AccessKind::UpdateToRunning,
                &p4,
                &[Value::Int((i % workers) as i64)],
            )
            .unwrap();
        });
        for b in [&interp_bench, &fast_bench, &interp_limit, &fast_limit] {
            obj = obj.set(
                b.name,
                schaladb::util::json::Json::obj()
                    .set("mean_secs", b.hist.mean())
                    .set("p50_secs", b.hist.quantile(0.5))
                    .set("p99_secs", b.hist.quantile(0.99)),
            );
        }
        obj = obj.set("point_claim_latency_speedup", point_speedup);
        std::fs::create_dir_all("target/bench-results").ok();
        std::fs::write("target/bench-results/BENCH_dml_fastpath.json", obj.to_string())
            .unwrap();
        println!("json: target/bench-results/BENCH_dml_fastpath.json");
        benches.push(interp_bench);
        benches.push(fast_bench);
        benches.push(interp_limit);
        benches.push(fast_limit);
    }

    // durability & recovery: (a) group-commit throughput against per-op
    // flushing on the point-insert commit stream, (b) time-to-rejoin after
    // a kill + process restart (checkpoint load, WAL replay, redo-ship
    // catch-up, hand-off). Emits BENCH_recovery.json.
    {
        let bench_dir = std::path::PathBuf::from("target/bench-recovery");
        let _ = std::fs::remove_dir_all(&bench_dir);
        let durable_wq = |tag: &str, group: usize, seed_rows: usize| -> Arc<DbCluster> {
            let c = DbCluster::start(
                ClusterConfig::builder()
                    .durability(DurabilityConfig::new(bench_dir.join(tag), group))
                    .build()
                    .unwrap(),
            )
            .unwrap();
            c.exec(&format!(
                "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
                 status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
                 PARTITION BY HASH(workerid) PARTITIONS {workers} \
                 PRIMARY KEY (taskid) INDEX (status)"
            ))
            .unwrap();
            let ins = c
                .prepare(
                    "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                     VALUES (?, ?, ?, 'READY', ?)",
                )
                .unwrap();
            let rows_bound: Vec<Vec<Value>> = (0..seed_rows)
                .map(|i| {
                    vec![
                        Value::Int(i as i64),
                        Value::Int((i % 3) as i64),
                        Value::Int((i % workers) as i64),
                        Value::Float(1.0),
                    ]
                })
                .collect();
            for chunk in rows_bound.chunks(512) {
                if !chunk.is_empty() {
                    c.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, chunk).unwrap();
                }
            }
            c
        };

        // (a) group commit vs per-op flush: one-commit point inserts
        let insert_rate = |tag: &str, group: usize| -> f64 {
            let c = durable_wq(tag, group, 0);
            let p = c
                .prepare(
                    "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                     VALUES (?, 1, ?, 'READY', 1.0)",
                )
                .unwrap();
            let n = it(4_000);
            let t0 = Instant::now();
            for i in 0..n {
                c.exec_prepared(
                    0,
                    AccessKind::InsertTasks,
                    &p,
                    &[Value::Int(i as i64), Value::Int((i % workers) as i64)],
                )
                .unwrap();
            }
            n as f64 / t0.elapsed().as_secs_f64()
        };
        let per_op_flush = insert_rate("gc1", 1);
        let grouped = insert_rate("gc64", 64);
        let gc_speedup = grouped / per_op_flush;
        println!(
            "group commit (64) vs per-op flush: {grouped:.0}/s vs {per_op_flush:.0}/s \
             -> {gc_speedup:.2}x\n"
        );

        // (b) time-to-rejoin: checkpoint, keep writing, kill, restart,
        // sweep until the node serves again
        let c = durable_wq("rejoin", 8, rows);
        let am = AvailabilityManager::new(c.clone());
        checkpoint_node(&c, 0).unwrap();
        checkpoint_node(&c, 1).unwrap();
        let upd = c
            .prepare("UPDATE workqueue SET dur = dur + 1.0 WHERE taskid = ? AND workerid = ?")
            .unwrap();
        let touch = |n: usize| {
            for i in 0..n {
                let tid = (i % rows.max(1)) as i64;
                c.exec_prepared(
                    0,
                    AccessKind::Other,
                    &upd,
                    &[Value::Int(tid), Value::Int(tid % workers as i64)],
                )
                .unwrap();
            }
        };
        touch(it(2_000)); // WAL tail past the checkpoints
        c.kill_node(1).unwrap();
        am.sweep().unwrap();
        touch(it(1_000)); // writes the rejoiner must catch up on
        let t0 = Instant::now();
        let start = c.restart_node(1).unwrap();
        let mut shipped = 0u64;
        let mut reseeded = 0usize;
        let mut done = false;
        for _ in 0..100 {
            let r = am.sweep().unwrap();
            shipped += r.shipped_ops;
            reseeded += r.reseeded_parts;
            if r.rejoined > 0 {
                done = true;
                break;
            }
        }
        assert!(done, "rejoin did not complete within 100 sweeps");
        let rejoin_secs = t0.elapsed().as_secs_f64();
        println!(
            "time-to-rejoin ({} partitions restored, {} wal records replayed locally, \
             {shipped} shipped, {reseeded} reseeded): {}\n",
            start.partitions,
            start.replayed,
            fmt_secs(rejoin_secs)
        );

        std::fs::create_dir_all("target/bench-results").ok();
        let obj = schaladb::util::json::Json::obj()
            .set("wq_rows", rows as f64)
            .set("partitions", workers as f64)
            .set("inserts_per_sec_per_op_flush", per_op_flush)
            .set("inserts_per_sec_group_commit_64", grouped)
            .set("group_commit_speedup", gc_speedup)
            .set("rejoin_secs", rejoin_secs)
            .set("rejoin_partitions", start.partitions as f64)
            .set("rejoin_local_replayed", start.replayed as f64)
            .set("rejoin_shipped_ops", shipped as f64)
            .set("rejoin_reseeded_parts", reseeded as f64);
        std::fs::write("target/bench-results/BENCH_recovery.json", obj.to_string()).unwrap();
        println!("json: target/bench-results/BENCH_recovery.json");
        let _ = std::fs::remove_dir_all(&bench_dir);
    }

    // Snapshot representation (exp7 shape): copy-on-write chunked
    // snapshots vs the seed clone-the-world path on a 100k-row partition
    // with one dirty chunk; snapshot-acquire latency while claim-style
    // writers hammer the same partition latch; and zone-map pruning on a
    // selective steering scan. Emits BENCH_snapshot.json — CI gates on
    // the acquire-under-writers p50 against the recorded baseline.
    {
        use schaladb::storage::partition::{PartitionStore, CHUNK_SLOTS};
        use schaladb::storage::table_def::TableDef;
        use schaladb::storage::{ColumnType, Row, Schema};
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::RwLock;

        let n_rows: usize = 100_000; // the acceptance floor, even in quick mode
        let schema = Schema::of(&[
            ("taskid", ColumnType::Int),
            ("actid", ColumnType::Int),
            ("workerid", ColumnType::Int),
            ("status", ColumnType::Str),
            ("dur", ColumnType::Float),
        ]);
        let def = TableDef::new("wq_snap", schema)
            .with_primary_key("taskid")
            .unwrap()
            .with_index("status")
            .unwrap();
        let store = Arc::new(RwLock::new(PartitionStore::new(Arc::new(def))));
        {
            let mut g = store.write().unwrap();
            for i in 0..n_rows {
                g.insert(Row::new(vec![
                    Value::Int(i as i64),
                    Value::Int((i % 3) as i64),
                    Value::Int(0),
                    Value::str("READY"),
                    Value::Float(1.0),
                ]))
                .unwrap();
            }
        }
        let touch = {
            let store = store.clone();
            move |i: usize| {
                let slot = i % n_rows;
                let mut g = store.write().unwrap();
                g.update(
                    slot,
                    Row::new(vec![
                        Value::Int(slot as i64),
                        Value::Int(1),
                        Value::Int(0),
                        Value::str("RUNNING"),
                        Value::Float(2.0),
                    ]),
                )
                .unwrap();
            }
        };
        // one dirty row per iteration, then take the snapshot under the
        // read latch — exactly what each steering read pays per commit
        let t1 = touch.clone();
        let s1 = store.clone();
        let clone_world = Bench::run("snapshot 100k (seed deep clone)", it(200), move |i| {
            t1(i);
            let g = s1.read().unwrap();
            std::hint::black_box(g.snapshot_rows().len());
        });
        let t2 = touch.clone();
        let s2 = store.clone();
        let chunked = Bench::run("snapshot 100k (CoW, 1 dirty chunk)", it(200), move |i| {
            t2(i);
            let g = s2.read().unwrap();
            std::hint::black_box(g.snapshot().len());
        });
        let snap_speedup = clone_world.hist.quantile(0.5) / chunked.hist.quantile(0.5);
        println!(
            "chunked snapshot vs clone-the-world (100k rows, 1 of {} chunks dirty): {:.1}x",
            n_rows.div_ceil(CHUNK_SLOTS),
            snap_speedup
        );
        assert!(
            snap_speedup >= 10.0,
            "chunked snapshot must be >= 10x the seed deep-clone path, got {snap_speedup:.1}x"
        );

        // acquire latency while 4 claim-style writers contend on the same
        // partition latch (the exp7 interference shape)
        let stop = Arc::new(AtomicBool::new(false));
        let mut writer_handles = Vec::new();
        for t in 0..4usize {
            let store = store.clone();
            let stop = stop.clone();
            writer_handles.push(std::thread::spawn(move || {
                let mut i = t;
                while !stop.load(Ordering::Relaxed) {
                    let slot = i % n_rows;
                    {
                        let mut g = store.write().unwrap();
                        g.update(
                            slot,
                            Row::new(vec![
                                Value::Int(slot as i64),
                                Value::Int(2),
                                Value::Int(0),
                                Value::str("RUNNING"),
                                Value::Float(3.0),
                            ]),
                        )
                        .unwrap();
                    }
                    i += 7;
                }
            }));
        }
        let s3 = store.clone();
        let acquire = Bench::run("snapshot acquire under 4 writers", it(2_000), move |_| {
            let g = s3.read().unwrap();
            std::hint::black_box(g.snapshot().len());
        });
        stop.store(true, Ordering::Relaxed);
        for h in writer_handles {
            h.join().unwrap();
        }
        println!(
            "snapshot acquire under writers: p50 {} p99 {}\n",
            fmt_secs(acquire.hist.quantile(0.5)),
            fmt_secs(acquire.hist.quantile(0.99))
        );

        // zone-map pruning on a selective steering scan (cluster level):
        // taskids are inserted round-robin, so chunk zone maps carry tight
        // taskid ranges and `taskid >= hi` excludes all but the tail chunk
        let c = wq_cluster(workers, rows);
        let before = c.route_counts();
        let hi = rows as i64 - 10;
        let pruned_scan = Bench::run("steering scan (zone-pruned)", it(300), {
            let c = c.clone();
            move |_| {
                c.query(&format!(
                    "SELECT taskid, dur FROM workqueue WHERE taskid >= {hi}"
                ))
                .unwrap();
            }
        });
        let unpruned_scan = Bench::run("steering scan (unprunable)", it(300), {
            let c = c.clone();
            move |_| {
                c.query("SELECT taskid, dur FROM workqueue WHERE status = 'NOPE'").unwrap();
            }
        });
        let after = c.route_counts();
        let pruned = after.chunks_pruned - before.chunks_pruned;
        let scanned = after.chunks_scanned - before.chunks_scanned;
        assert!(pruned > 0, "selective steering scan must prune chunks via zone maps");
        println!(
            "zone pruning on selective scan: {pruned} chunks pruned, {scanned} scanned \
             (pruned p50 {}, unprunable p50 {})\n",
            fmt_secs(pruned_scan.hist.quantile(0.5)),
            fmt_secs(unpruned_scan.hist.quantile(0.5))
        );

        std::fs::create_dir_all("target/bench-results").ok();
        let obj = schaladb::util::json::Json::obj()
            .set("partition_rows", n_rows as f64)
            .set("chunk_slots", CHUNK_SLOTS as f64)
            .set("clone_world_p50_secs", clone_world.hist.quantile(0.5))
            .set("chunked_p50_secs", chunked.hist.quantile(0.5))
            .set("snapshot_speedup_p50", snap_speedup)
            .set("acquire_under_writers_p50_secs", acquire.hist.quantile(0.5))
            .set("acquire_under_writers_p99_secs", acquire.hist.quantile(0.99))
            .set("pruned_scan_p50_secs", pruned_scan.hist.quantile(0.5))
            .set("unpruned_scan_p50_secs", unpruned_scan.hist.quantile(0.5))
            .set("chunks_pruned", pruned as f64)
            .set("chunks_scanned", scanned as f64);
        std::fs::write("target/bench-results/BENCH_snapshot.json", obj.to_string()).unwrap();
        println!("json: target/bench-results/BENCH_snapshot.json");
        benches.push(clone_world);
        benches.push(chunked);
        benches.push(acquire);
        benches.push(pruned_scan);
        benches.push(unpruned_scan);
    }

    // scatter-gather vs centralized: the steering analytics that motivated
    // the query subsystem. Each iteration first touches one row so the
    // versioned snapshot cache is invalidated — both paths pay the same
    // staleness, as in a live hybrid workload. Emits BENCH_scatter.json.
    {
        let c = wq_cluster(workers, rows);
        c.exec("CREATE TABLE node (nodeid INT NOT NULL, hostname TEXT) PRIMARY KEY (nodeid)")
            .unwrap();
        for w in 0..workers {
            c.execute(&format!("INSERT INTO node (nodeid, hostname) VALUES ({w}, 'n{w}')"))
                .unwrap();
        }
        let q_group = "SELECT status, COUNT(*) AS n, AVG(dur), MIN(dur), MAX(dur) \
                       FROM workqueue GROUP BY status ORDER BY status";
        let q_join = "SELECT n.hostname, COUNT(*) AS c FROM workqueue t \
                      JOIN node n ON t.workerid = n.nodeid \
                      GROUP BY n.hostname ORDER BY c DESC, n.hostname";
        let iters = it(200);
        let dirty = |c: &DbCluster, i: usize| {
            c.execute(&format!(
                "UPDATE workqueue SET dur = dur + 0.0 WHERE taskid = {}",
                i % rows
            ))
            .unwrap();
        };
        let central_group = Bench::run("steering GROUP BY (centralized 2PL)", iters, |i| {
            dirty(&c, i);
            c.query_centralized(q_group).unwrap();
        });
        let scatter_group = Bench::run("steering GROUP BY (scatter-gather)", iters, |i| {
            dirty(&c, i);
            c.query(q_group).unwrap();
        });
        let central_join = Bench::run("steering join (centralized 2PL)", iters, |i| {
            dirty(&c, i);
            c.query_centralized(q_join).unwrap();
        });
        let scatter_join = Bench::run("steering join (snapshot-join)", iters, |i| {
            dirty(&c, i);
            c.query(q_join).unwrap();
        });
        let group_speedup = central_group.hist.mean() / scatter_group.hist.mean();
        let join_speedup = central_join.hist.mean() / scatter_join.hist.mean();
        println!(
            "scatter-gather vs centralized (steering queries): \
             GROUP BY {group_speedup:.2}x, join {join_speedup:.2}x\n"
        );
        std::fs::create_dir_all("target/bench-results").ok();
        let mut obj = schaladb::util::json::Json::obj()
            .set("wq_rows", rows as f64)
            .set("partitions", workers as f64)
            .set("group_by_speedup", group_speedup)
            .set("join_speedup", join_speedup);
        for b in [&central_group, &scatter_group, &central_join, &scatter_join] {
            obj = obj.set(
                b.name,
                schaladb::util::json::Json::obj()
                    .set("mean_secs", b.hist.mean())
                    .set("p50_secs", b.hist.quantile(0.5))
                    .set("p99_secs", b.hist.quantile(0.99)),
            );
        }
        std::fs::write("target/bench-results/BENCH_scatter.json", obj.to_string()).unwrap();
        println!("json: target/bench-results/BENCH_scatter.json");
        benches.push(central_group);
        benches.push(scatter_group);
        benches.push(central_join);
        benches.push(scatter_join);
    }

    // network front-end: remote vs in-process claim throughput
    benches.extend(bench_server(quick, workers, rows));

    // observability: instrumented vs quiesced claim throughput
    benches.extend(bench_obs(quick, workers, rows));

    // optimistic concurrency: OCC vs 2PL vs interpreted claim loop
    benches.extend(bench_occ(quick, workers, rows));

    // elastic topology: live rebalance + split under the claim stream
    benches.extend(bench_topology(workers, rows));

    let rows_out: Vec<Vec<String>> = benches.iter().map(|b| b.row()).collect();
    println!(
        "{}",
        schaladb::util::render_table(&["operation", "iters", "mean", "p50", "p99"], &rows_out)
    );
    std::fs::create_dir_all("target/bench-results").ok();
    let mut obj = schaladb::util::json::Json::obj();
    for b in &benches {
        obj = obj.set(
            b.name,
            schaladb::util::json::Json::obj()
                .set("mean_secs", b.hist.mean())
                .set("p50_secs", b.hist.quantile(0.5))
                .set("p99_secs", b.hist.quantile(0.99)),
        );
    }
    std::fs::write("target/bench-results/storage_micro.json", obj.to_string()).unwrap();
    println!("json: target/bench-results/storage_micro.json");
}
