//! Bench target regenerating the paper's exp6 rows on the calibrated
//! simulator (see DESIGN.md per-experiment index). `cargo bench --bench exp6_query_breakdown`.
use schaladb::sim::experiments;

fn main() {
    let out = experiments::run("exp6").expect("exp6");
    out.print();
    std::fs::create_dir_all("target/bench-results").ok();
    let path = format!("target/bench-results/{}.json", "exp6");
    std::fs::write(&path, out.json.to_string()).expect("write json");
    println!("json: {path}");
}
