//! Bench target regenerating the paper's exp8 rows on the calibrated
//! simulator (see DESIGN.md per-experiment index). `cargo bench --bench exp8_chiron_vs_dchiron`.
use schaladb::sim::experiments;

fn main() {
    let out = experiments::run("exp8").expect("exp8");
    out.print();
    std::fs::create_dir_all("target/bench-results").ok();
    let path = format!("target/bench-results/{}.json", "exp8");
    std::fs::write(&path, out.json.to_string()).expect("write json");
    println!("json: {path}");
}
