//! Ablations of SchalaDB's §3.2 design choices on the real engine:
//!
//! 1. **WQ partitioning**: W partitions (one per worker, the paper's
//!    design) vs a single shared partition — isolates the locality /
//!    contention claim ("each worker node accesses its own WQ partition
//!    ... reduces race conditions").
//! 2. **Replication factor**: one backup per partition (paper) vs none —
//!    the write-path cost of availability.
//! 3. **Claim batch size**: how many candidates one `getREADYtasks`
//!    fetches (the knob that amortizes claim races).
//!
//! `cargo bench --bench ablation_partitioning`

use schaladb::coordinator::{DChironEngine, EngineConfig};
use schaladb::storage::cluster::ClusterConfig;
use schaladb::storage::DbCluster;
use schaladb::util::{fmt_secs, render_table};
use schaladb::workload::SyntheticWorkload;
use std::sync::Arc;
use std::time::Instant;

/// Claim throughput against a WQ with the given partition count.
fn claim_throughput(partitions: usize, replication: bool, threads: usize) -> f64 {
    let c = DbCluster::start(ClusterConfig::builder().replication(replication).build().unwrap())
        .unwrap();
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, status TEXT) \
         PARTITION BY HASH(workerid) PARTITIONS {partitions} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    let total = 8_000;
    let mut vals = Vec::new();
    for i in 0..total {
        // worker ids span the thread count; the table's partition count
        // decides whether they collide on storage
        vals.push(format!("({i}, {}, 'READY')", i % threads));
        if vals.len() == 512 {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status) VALUES {}",
                vals.join(", ")
            ))
            .unwrap();
            vals.clear();
        }
    }
    if !vals.is_empty() {
        c.execute(&format!(
            "INSERT INTO workqueue (taskid, workerid, status) VALUES {}",
            vals.join(", ")
        ))
        .unwrap();
    }

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..threads {
        let c: Arc<DbCluster> = c.clone();
        handles.push(std::thread::spawn(move || {
            let mut n = 0u64;
            loop {
                let rs = c
                    .exec(&format!(
                        "UPDATE workqueue SET status = 'RUNNING' \
                         WHERE workerid = {w} AND status = 'READY' \
                         ORDER BY taskid LIMIT 1 RETURNING taskid"
                    ))
                    .unwrap()
                    .rows();
                if rs.rows.is_empty() {
                    break;
                }
                n += 1;
            }
            n
        }));
    }
    let claimed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(claimed as usize, total);
    total as f64 / t0.elapsed().as_secs_f64()
}

fn engine_makespan(claim_batch: usize) -> f64 {
    let w = SyntheticWorkload { total_tasks: 1_200, mean_task_secs: 1.0, activities: 3, seed: 5 };
    let r = DChironEngine::new(EngineConfig {
        workers: 4,
        threads_per_worker: 4,
        time_scale: 0.001,
        supervisor_poll_secs: 0.001,
        claim_batch,
        ..Default::default()
    })
    .run(w.workflow(), w.inputs())
    .unwrap();
    r.makespan_secs
}

fn main() {
    let threads = 8;

    println!("== ablation 1: WQ partitioning (8 claiming threads, 8k tasks) ==");
    let mut rows = Vec::new();
    for parts in [1usize, 2, 4, 8] {
        let tput = claim_throughput(parts, true, threads);
        rows.push(vec![
            format!("{parts} partition(s)"),
            format!("{tput:.0} claims/s"),
        ]);
    }
    println!("{}", render_table(&["WQ layout", "claim throughput"], &rows));

    println!("== ablation 2: replication factor (8 partitions) ==");
    let mut rows = Vec::new();
    for (label, repl) in [("1 backup/partition (paper)", true), ("no replication", false)] {
        let tput = claim_throughput(8, repl, threads);
        rows.push(vec![label.to_string(), format!("{tput:.0} claims/s")]);
    }
    println!("{}", render_table(&["replication", "claim throughput"], &rows));

    println!("== ablation 3: claim batch size (full engine, 1200 x 1s scaled) ==");
    let mut rows = Vec::new();
    for batch in [1usize, 4, 16] {
        let m = engine_makespan(batch);
        rows.push(vec![format!("batch {batch}"), fmt_secs(m)]);
    }
    println!("{}", render_table(&["getREADYtasks batch", "makespan"], &rows));
}
