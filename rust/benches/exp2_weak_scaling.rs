//! Bench target regenerating the paper's exp2 rows on the calibrated
//! simulator (see DESIGN.md per-experiment index). `cargo bench --bench exp2_weak_scaling`.
use schaladb::sim::experiments;

fn main() {
    let out = experiments::run("exp2").expect("exp2");
    out.print();
    std::fs::create_dir_all("target/bench-results").ok();
    let path = format!("target/bench-results/{}.json", "exp2");
    std::fs::write(&path, out.json.to_string()).expect("write json");
    println!("json: {path}");
}
