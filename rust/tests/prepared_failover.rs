//! Prepared statements across failure injection: a `Prepared` handle is a
//! cached plan, not a connection, so it must keep executing after the
//! primary connector dies (WorkerLink secondary failover) and after a data
//! node is killed and its backups promoted.

use schaladb::storage::cluster::ClusterConfig;
use schaladb::storage::connector::{assign_links, Connector, WorkerLink};
use schaladb::storage::{AccessKind, DbCluster, Value};
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn wq_cluster() -> Arc<DbCluster> {
    let c = DbCluster::start(ClusterConfig::default()).unwrap();
    c.exec(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, stdout TEXT) \
         PARTITION BY HASH(workerid) PARTITIONS 4 \
         PRIMARY KEY (taskid) INDEX (status)",
    )
    .unwrap();
    c
}

fn seed(c: &DbCluster, n: i64) {
    let ins = c
        .prepare("INSERT INTO workqueue (taskid, workerid, status) VALUES (?, ?, 'READY')")
        .unwrap();
    let rows: Vec<Vec<Value>> =
        (0..n).map(|i| vec![Value::Int(i), Value::Int(i % 4)]).collect();
    c.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, &rows).unwrap();
}

fn link_with_two_connectors(c: &Arc<DbCluster>) -> (WorkerLink, Arc<Connector>, Arc<Connector>) {
    let conns = vec![Connector::new(0, 0, c.clone()), Connector::new(1, 1, c.clone())];
    let links = assign_links(&[0], &conns).unwrap();
    let link = links.into_iter().next().unwrap();
    (link, conns[0].clone(), conns[1].clone())
}

#[test]
fn prepared_handle_survives_connector_kill() {
    let c = wq_cluster();
    seed(&c, 16);
    let (link, primary, secondary) = link_with_two_connectors(&c);

    let claim = link
        .prepare(
            "UPDATE workqueue SET status = 'RUNNING' \
             WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
             RETURNING taskid",
        )
        .unwrap();

    // claims flow through the primary while it lives
    let rs = link
        .exec_prepared(AccessKind::UpdateToRunning, &claim, &[Value::Int(1)])
        .unwrap()
        .rows();
    assert_eq!(rs.rows.len(), 1);
    assert_eq!(primary.brokered.load(Ordering::Relaxed), 1);

    // kill the primary connector: the *same handle* keeps claiming via the
    // secondary, with no re-prepare
    primary.kill();
    let rs = link
        .exec_prepared(AccessKind::UpdateToRunning, &claim, &[Value::Int(1)])
        .unwrap()
        .rows();
    assert_eq!(rs.rows.len(), 1);
    assert_eq!(secondary.brokered.load(Ordering::Relaxed), 1);

    // and back again after revival
    primary.revive();
    link.exec_prepared(AccessKind::UpdateToRunning, &claim, &[Value::Int(1)])
        .unwrap()
        .rows();
    assert_eq!(primary.brokered.load(Ordering::Relaxed), 2);

    // 3 claims happened exactly once each
    let left = c
        .query("SELECT COUNT(*) FROM workqueue WHERE status = 'RUNNING'")
        .unwrap();
    assert_eq!(left.rows[0].values[0], Value::Int(3));
}

#[test]
fn prepared_batch_survives_connector_kill() {
    let c = wq_cluster();
    let (link, primary, _secondary) = link_with_two_connectors(&c);
    let ins = link
        .prepare("INSERT INTO workqueue (taskid, workerid, status) VALUES (?, ?, 'READY')")
        .unwrap();
    let rows: Vec<Vec<Value>> =
        (0..8).map(|i| vec![Value::Int(i), Value::Int(i % 4)]).collect();
    link.exec_prepared_batch(AccessKind::InsertTasks, &ins, &rows).unwrap();
    primary.kill();
    let rows2: Vec<Vec<Value>> =
        (8..16).map(|i| vec![Value::Int(i), Value::Int(i % 4)]).collect();
    link.exec_prepared_batch(AccessKind::InsertTasks, &ins, &rows2).unwrap();
    assert_eq!(c.table_rows("workqueue").unwrap(), 16);
}

#[test]
fn prepared_handle_survives_data_node_failover() {
    let c = wq_cluster();
    seed(&c, 32);
    let sel = c
        .prepare("SELECT COUNT(*) FROM workqueue WHERE workerid = ? AND status = ?")
        .unwrap();
    let finish = c
        .prepare(
            "UPDATE workqueue SET status = 'FINISHED', stdout = ? WHERE taskid = ?",
        )
        .unwrap();

    let before = c.query_prepared(&sel, &[Value::Int(2), Value::str("READY")]).unwrap();
    assert_eq!(before.rows[0].values[0], Value::Int(8));

    // kill a data node and promote its backups; the handles were prepared
    // before the failure and must keep working against promoted replicas
    c.kill_node(0).unwrap();
    assert!(c.promote_dead_primaries() > 0);

    let after = c.query_prepared(&sel, &[Value::Int(2), Value::str("READY")]).unwrap();
    assert_eq!(after.rows[0].values[0], Value::Int(8));

    // writes too — including a value that would have broken the old
    // format!-built SQL
    let n = c
        .exec_prepared(
            0,
            AccessKind::UpdateToFinished,
            &finish,
            &[Value::str("task said: 'done'"), Value::Int(2)],
        )
        .unwrap()
        .affected();
    assert_eq!(n, 1);
    let rs = c.query("SELECT stdout FROM workqueue WHERE taskid = 2").unwrap();
    assert_eq!(rs.rows[0].values[0], Value::str("task said: 'done'"));

    // heal path: revive the node, reseed replicas, handle still valid
    c.revive_node(0).unwrap();
    c.heal().unwrap();
    let healed = c.query_prepared(&sel, &[Value::Int(2), Value::str("READY")]).unwrap();
    assert_eq!(healed.rows[0].values[0], Value::Int(7));
}

#[test]
fn prepare_after_failover_reuses_the_shared_plan_cache() {
    let c = wq_cluster();
    seed(&c, 8);
    let sql = "SELECT taskid FROM workqueue WHERE taskid = ?";
    c.prepare(sql).unwrap();
    let cached = c.cached_plans();
    c.kill_node(1).unwrap();
    c.promote_dead_primaries();
    // preparing the same text after failover is a cache hit, and the plan
    // still executes
    let p = c.prepare(sql).unwrap();
    assert_eq!(c.cached_plans(), cached);
    let rs = c.query_prepared(&p, &[Value::Int(3)]).unwrap();
    assert_eq!(rs.rows.len(), 1);
}
