//! Elastic-topology gate: online partition moves, splits, and node
//! additions under live load — with the cluster's state **byte-equal** to
//! an untouched twin at every quiescent point.
//!
//! The twin protocol (same as `chaos_recovery.rs`): every operation is
//! applied to cluster A (the elastic one, whose topology is reshaped
//! mid-stream) and, iff A committed it, to cluster B (never reshaped,
//! never killed). `fingerprint()` serializes committed rows sorted and
//! partition-agnostic, so a cluster that moved a partition onto a brand
//! new node or split a hot partition in two must still render the exact
//! bytes of the twin that did neither.
//!
//! Concurrency: the admin operations run while ≥4 claim threads hammer
//! reserved rows (each must commit exactly once, on both clusters) and
//! steering scanners sweep the table — claims and scans racing a cut
//! either land before it or retry through the `Unavailable` window.
//!
//! The CI `topology-chaos` job runs this under a seed × partition ×
//! concurrency-mode matrix via `TOPO_SEED` / `TOPO_PARTITIONS` /
//! `TOPO_MODE`; a plain `cargo test` sweeps a small built-in matrix.
//! `TOPO_MODE=occ` runs cluster A's point claims through the optimistic
//! path while the twin stays on 2PL, making the byte-equality a
//! cross-discipline proof as well.

use schaladb::storage::cluster::{ClusterConfig, ConcurrencyMode, DurabilityConfig};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, DbCluster, NodeState, Prepared, Value};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Deterministic LCG so every (seed, partitions) cell replays identically.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 11
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

fn schema(c: &DbCluster, parts: usize) {
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE prov (provid INT NOT NULL, taskid INT, note TEXT) PRIMARY KEY (provid)")
        .unwrap();
}

/// The prepared statement set one cluster runs the stream through.
struct Stmts {
    insert: Prepared,
    claim: Prepared,
    finish: Prepared,
    delete: Prepared,
    prov: Prepared,
}

impl Stmts {
    fn prepare(c: &DbCluster) -> Stmts {
        Stmts {
            insert: c
                .prepare(
                    "INSERT INTO workqueue (taskid, workerid, status, dur) \
                     VALUES (?, ?, 'READY', ?)",
                )
                .unwrap(),
            claim: c
                .prepare(
                    "UPDATE workqueue SET status = 'RUNNING' \
                     WHERE taskid = ? AND workerid = ? AND status = 'READY'",
                )
                .unwrap(),
            finish: c
                .prepare(
                    "UPDATE workqueue SET status = 'FINISHED', dur = dur + 1.5 \
                     WHERE taskid = ? AND workerid = ?",
                )
                .unwrap(),
            delete: c
                .prepare("DELETE FROM workqueue WHERE taskid = ? AND workerid = ?")
                .unwrap(),
            prov: c
                .prepare("INSERT INTO prov (provid, taskid, note) VALUES (?, ?, ?)")
                .unwrap(),
        }
    }
}

/// One op of the committed stream.
#[derive(Clone, Debug)]
enum Op {
    Insert { id: i64, worker: i64, dur: f64 },
    Claim { id: i64, worker: i64 },
    Finish { id: i64, worker: i64 },
    Delete { id: i64, worker: i64 },
    Prov { id: i64, task: i64, note: String },
}

fn apply(c: &DbCluster, s: &Stmts, op: &Op) -> schaladb::Result<usize> {
    let r = match op {
        Op::Insert { id, worker, dur } => c.exec_prepared(
            0,
            AccessKind::InsertTasks,
            &s.insert,
            &[Value::Int(*id), Value::Int(*worker), Value::Float(*dur)],
        )?,
        Op::Claim { id, worker } => c.exec_prepared(
            0,
            AccessKind::UpdateToRunning,
            &s.claim,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Finish { id, worker } => c.exec_prepared(
            0,
            AccessKind::UpdateToFinished,
            &s.finish,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Delete { id, worker } => c.exec_prepared(
            0,
            AccessKind::Other,
            &s.delete,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Prov { id, task, note } => c.exec_prepared(
            0,
            AccessKind::InsertProvenance,
            &s.prov,
            &[Value::Int(*id), Value::Int(*task), Value::str(note.clone())],
        )?,
    };
    Ok(r.affected())
}

/// Streams ops into A; every op A commits is mirrored to B (the untouched
/// twin). Ops that fail on A with an availability error (a cut or kill
/// window) are dropped entirely — they committed nowhere.
struct Driver {
    a: Arc<DbCluster>,
    b: Arc<DbCluster>,
    sa: Stmts,
    sb: Stmts,
    rng: Rng,
    parts: i64,
    next_id: i64,
    next_prov: i64,
    live: Vec<(i64, i64)>,
}

impl Driver {
    fn new(a: Arc<DbCluster>, b: Arc<DbCluster>, seed: u64, parts: usize) -> Driver {
        let sa = Stmts::prepare(&a);
        let sb = Stmts::prepare(&b);
        Driver {
            a,
            b,
            sa,
            sb,
            rng: Rng(seed.wrapping_mul(0x9e3779b97f4a7c15) | 1),
            parts: parts as i64,
            next_id: 0,
            next_prov: 0,
            live: Vec::new(),
        }
    }

    fn gen(&mut self) -> Op {
        let roll = self.rng.below(10);
        if self.live.is_empty() || roll < 4 {
            let id = self.next_id;
            self.next_id += 1;
            return Op::Insert {
                id,
                worker: self.rng.below(self.parts as u64) as i64,
                dur: (self.rng.below(1000) as f64) / 8.0,
            };
        }
        let pick = self.rng.below(self.live.len() as u64) as usize;
        let (id, worker) = self.live[pick];
        match roll {
            4 | 5 => Op::Claim { id, worker },
            6 => Op::Finish { id, worker },
            7 => Op::Delete { id, worker },
            _ => {
                let pid = self.next_prov;
                self.next_prov += 1;
                Op::Prov { id: pid, task: id, note: format!("note {pid}") }
            }
        }
    }

    fn drive(&mut self, n: usize) {
        for _ in 0..n {
            let op = self.gen();
            match apply(&self.a, &self.sa, &op) {
                Ok(affected_a) => {
                    let affected_b =
                        apply(&self.b, &self.sb, &op).expect("twin must accept mirrored op");
                    assert_eq!(
                        affected_a, affected_b,
                        "twin diverged on {op:?}: {affected_a} != {affected_b}"
                    );
                    match &op {
                        Op::Insert { id, worker, .. } => self.live.push((*id, *worker)),
                        Op::Delete { id, .. } => self.live.retain(|(i, _)| i != id),
                        _ => {}
                    }
                }
                Err(schaladb::Error::Unavailable(_)) => { /* nothing committed */ }
                Err(e) => panic!("unexpected failure on {op:?}: {e}"),
            }
        }
    }
}

fn fingerprints_equal(a: &DbCluster, b: &DbCluster) {
    let fa = a.fingerprint().unwrap();
    let fb = b.fingerprint().unwrap();
    assert!(!fa.is_empty());
    assert_eq!(fa, fb, "elastic cluster state diverged from the untouched twin");
}

/// Seed reserved rows on both clusters: `chunks` disjoint ranges of
/// `per_chunk` tasks each, spread over all workers, for the concurrent
/// claimers to consume exactly once during the admin operations.
fn seed_reserved(
    d: &mut Driver,
    chunks: usize,
    per_chunk: usize,
    parts: i64,
) -> Vec<Vec<(i64, i64)>> {
    let mut out = Vec::with_capacity(chunks);
    for c in 0..chunks {
        let mut chunk = Vec::with_capacity(per_chunk);
        for k in 0..per_chunk {
            let id = 1_000_000 + (c * per_chunk + k) as i64;
            let w = (c * per_chunk + k) as i64 % parts;
            let op = Op::Insert { id, worker: w, dur: 1.0 };
            assert_eq!(apply(&d.a, &d.sa, &op).unwrap(), 1);
            assert_eq!(apply(&d.b, &d.sb, &op).unwrap(), 1);
            chunk.push((id, w));
        }
        out.push(chunk);
    }
    out
}

/// Spawn one claim thread per reserved chunk. Each claim retries through
/// transient unavailability (a cut in progress) and must commit exactly
/// once on A, then mirror to B.
fn spawn_claimers(
    a: &Arc<DbCluster>,
    b: &Arc<DbCluster>,
    chunks: Vec<Vec<(i64, i64)>>,
    claimed: &Arc<AtomicUsize>,
) -> Vec<std::thread::JoinHandle<()>> {
    chunks
        .into_iter()
        .map(|chunk| {
            let a = a.clone();
            let b = b.clone();
            let claimed = claimed.clone();
            std::thread::spawn(move || {
                let sa = Stmts::prepare(&a);
                let sb = Stmts::prepare(&b);
                for (id, w) in chunk {
                    let op = Op::Claim { id, worker: w };
                    let na = loop {
                        match apply(&a, &sa, &op) {
                            Ok(n) => break n,
                            Err(schaladb::Error::Unavailable(_)) => {
                                std::thread::sleep(std::time::Duration::from_micros(200));
                            }
                            Err(e) => panic!("claim failed during topology change: {e}"),
                        }
                    };
                    let nb = apply(&b, &sb, &op).unwrap();
                    assert_eq!(na, nb);
                    assert_eq!(na, 1, "reserved row must be claimable exactly once");
                    claimed.fetch_add(1, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_micros(50));
                }
            })
        })
        .collect()
}

/// Spawn steering scanners that sweep the workqueue until `stop` flips.
/// A scan racing a cut may see one `Unavailable`; it must never see any
/// other error, and must keep scanning afterwards.
fn spawn_scanners(
    a: &Arc<DbCluster>,
    n: usize,
    stop: &Arc<AtomicBool>,
    scans: &Arc<AtomicUsize>,
) -> Vec<std::thread::JoinHandle<()>> {
    (0..n)
        .map(|_| {
            let a = a.clone();
            let stop = stop.clone();
            let scans = scans.clone();
            std::thread::spawn(move || {
                let sel = a
                    .prepare("SELECT status, COUNT(*) FROM workqueue GROUP BY status")
                    .unwrap();
                while !stop.load(Ordering::SeqCst) {
                    match a.exec_prepared(0, AccessKind::Steering, &sel, &[]) {
                        Ok(_) => {
                            scans.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(schaladb::Error::Unavailable(_)) => {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(e) => panic!("steering scan failed during topology change: {e}"),
                    }
                }
            })
        })
        .collect()
}

/// Point-DML concurrency mode for cluster A, from `TOPO_MODE`
/// (`2pl` | `occ`, default 2PL). The CI matrix sets it.
fn topo_mode() -> ConcurrencyMode {
    std::env::var("TOPO_MODE")
        .ok()
        .and_then(|s| ConcurrencyMode::from_name(&s))
        .unwrap_or_default()
}

/// Seed matrix: one cell from the environment (the CI job matrix), or a
/// small built-in sweep for plain `cargo test`.
fn matrix() -> Vec<(u64, usize)> {
    let seed = std::env::var("TOPO_SEED").ok().and_then(|s| s.parse().ok());
    let parts = std::env::var("TOPO_PARTITIONS").ok().and_then(|s| s.parse().ok());
    match (seed, parts) {
        (Some(s), Some(p)) => vec![(s, p)],
        _ => vec![(1, 2), (2, 4)],
    }
}

/// Live add-node, move, role-flip rebalance and split — all while 4 claim
/// threads and 2 steering scanners run — then the byte-equality gate.
fn run_live_cell(seed: u64, parts: usize) {
    let a = DbCluster::start(
        ClusterConfig::builder().concurrency(topo_mode()).build().unwrap(),
    )
    .unwrap();
    // The twin always runs pessimistic 2PL on the original topology.
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a, parts);
    schema(&b, parts);
    let mut d = Driver::new(a.clone(), b.clone(), seed, parts);

    d.drive(300);
    let chunks = seed_reserved(&mut d, 4, 12, parts as i64);
    fingerprints_equal(&a, &b);

    let claimed = Arc::new(AtomicUsize::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let scans = Arc::new(AtomicUsize::new(0));
    let claimers = spawn_claimers(&a, &b, chunks, &claimed);
    let scanners = spawn_scanners(&a, 2, &stop, &scans);

    // Admin sequence, each step under live load with ops between steps.
    let epoch0 = a.cluster_epoch();
    let new_node = a.add_node().unwrap();
    let before = a.topology();
    assert!(before
        .nodes
        .iter()
        .any(|n| n.id == new_node && n.state == NodeState::Joining));

    // Move partition 0's primary onto the brand new (empty) node.
    a.rebalance_partition("workqueue", 0, new_node).unwrap();
    d.drive(150);

    // Role-flip rebalance: partition 1 onto its own backup, if it has one.
    let wq = |t: &schaladb::storage::Topology| {
        t.tables.iter().find(|tt| tt.table == "workqueue").cloned().unwrap()
    };
    if let Some(backup) = wq(&a.topology()).partitions[1].backup {
        a.rebalance_partition("workqueue", 1, backup).unwrap();
        d.drive(100);
    }

    // Split the last partition in two.
    let split_pidx = parts - 1;
    let new_pidx = a.split_partition("workqueue", split_pidx).unwrap();
    assert_eq!(new_pidx, parts);
    d.drive(150);

    stop.store(true, Ordering::SeqCst);
    for h in scanners {
        h.join().unwrap();
    }
    for h in claimers {
        h.join().unwrap();
    }
    assert_eq!(claimed.load(Ordering::SeqCst), 4 * 12);
    assert!(scans.load(Ordering::SeqCst) > 0, "scanners must make progress");

    // The reshaped cluster must render the twin's exact bytes.
    fingerprints_equal(&a, &b);

    // And the topology must reflect every step: the new node serves, the
    // moved partition's primary changed, the split partition exists.
    let after = a.topology();
    assert!(after.epoch > epoch0, "admin cuts must bump the cluster epoch");
    assert!(after
        .nodes
        .iter()
        .any(|n| n.id == new_node && n.state == NodeState::Alive));
    let map = wq(&after);
    assert_eq!(map.partitions.len(), parts + 1);
    assert_eq!(map.partitions[0].primary, new_node);

    // The stream keeps committing on the reshaped topology.
    d.drive(100);
    fingerprints_equal(&a, &b);
}

#[test]
fn live_move_flip_and_split_equal_twin() {
    for (seed, parts) in matrix() {
        run_live_cell(seed, parts);
    }
}

/// Add a node, race a live move against a kill of the donor primary, then
/// restart the donor and let the sweep rejoin it — the cluster must stay
/// byte-equal to the twin whether the kill landed before, during, or
/// after the cut.
#[test]
fn add_node_move_survives_donor_kill_and_rejoin() {
    let dir = std::env::temp_dir()
        .join(format!("schaladb-topo-kill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let a = DbCluster::start(
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 8))
            .concurrency(topo_mode())
            .build()
            .unwrap(),
    )
    .unwrap();
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a, 4);
    schema(&b, 4);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), 5, 4);

    d.drive(300);
    fingerprints_equal(&a, &b);

    let new_node = a.add_node().unwrap();
    let donor = a
        .topology()
        .tables
        .iter()
        .find(|t| t.table == "workqueue")
        .unwrap()
        .partitions[0]
        .primary;

    // Race: move partition 0 onto the new node while the donor dies.
    let mover = {
        let a = a.clone();
        std::thread::spawn(move || a.rebalance_partition("workqueue", 0, new_node))
    };
    let killer = {
        let a = a.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_micros(300));
            a.kill_node(donor)
        })
    };
    // Either outcome is legal — the move may finish first (the donor dies
    // after handing off) or lose the race (it fails `Unavailable` and the
    // partition stays put, intact). Both must preserve every committed row.
    let move_result = mover.join().unwrap();
    killer.join().unwrap().unwrap();
    if let Err(e) = &move_result {
        assert!(
            matches!(e, schaladb::Error::Unavailable(_)),
            "a raced move may only fail as Unavailable, got: {e}"
        );
    }

    // The sweep promotes whatever the dead donor still served; the stream
    // keeps committing around the hole either way.
    am.sweep().unwrap();
    d.drive(150);
    fingerprints_equal(&a, &b);

    // Restart the donor and sweep until it rejoins — past a topology that
    // changed (or half-changed) while it was down.
    let start = a.restart_node(donor).unwrap();
    assert!(start.partitions > 0);
    let mut rejoined = false;
    for _ in 0..200 {
        let r = am.sweep().unwrap();
        if r.rejoined > 0 {
            rejoined = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(rejoined, "donor must rejoin after the raced move");
    assert!(a.node(donor).unwrap().is_alive());
    am.sweep().unwrap();
    d.drive(100);
    fingerprints_equal(&a, &b);

    // If the move won the race, the new node must be serving partition 0;
    // either way the map is coherent and every partition has a live home.
    let topo = a.topology();
    if move_result.is_ok() {
        let wq =
            topo.tables.iter().find(|t| t.table == "workqueue").unwrap();
        assert_eq!(wq.partitions[0].primary, new_node);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// A split committed while a node is down must survive that node's rejoin:
/// the rejoining replicas catch up against the *post-split* placement.
#[test]
fn split_then_rejoin_catches_up_on_new_topology() {
    let a = DbCluster::start(
        ClusterConfig::builder().concurrency(topo_mode()).build().unwrap(),
    )
    .unwrap();
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a, 2);
    schema(&b, 2);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), 9, 2);

    d.drive(250);
    // Kill node 1; its backups get promoted and the stream continues.
    a.kill_node(1).unwrap();
    am.sweep().unwrap();
    d.drive(100);

    // Split partition 0 while node 1 is down (its dead replica cannot be
    // seeded — the split must proceed on the live side alone).
    let new_pidx = a.split_partition("workqueue", 0).unwrap();
    assert_eq!(new_pidx, 2);
    d.drive(100);
    fingerprints_equal(&a, &b);

    // Rejoin node 1 against the post-split topology.
    a.restart_node(1).unwrap();
    let mut rejoined = false;
    for _ in 0..200 {
        let r = am.sweep().unwrap();
        if r.rejoined > 0 {
            rejoined = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(rejoined, "node must rejoin after an in-absence split");
    am.sweep().unwrap();
    d.drive(100);
    fingerprints_equal(&a, &b);

    // Prove the rejoined replicas are faithful on the split layout: fail
    // over onto them and compare bytes again.
    a.kill_node(0).unwrap();
    am.sweep().unwrap();
    fingerprints_equal(&a, &b);
}

/// Elastic topology survives a whole-cluster stop: grow to three nodes,
/// move a partition onto the new node, split it, checkpoint everywhere
/// (the clean-shutdown baseline), then `DbCluster::open` the directory.
/// Node-dir discovery must bring back all three nodes, the widest
/// post-split definition must win the def election over stale pre-split
/// checkpoints, and the state must stay byte-equal to the untouched twin.
#[test]
fn elastic_topology_round_trips_whole_cluster_cold_start() {
    use schaladb::storage::checkpoint::checkpoint_node;
    let parts = 4usize;
    let dir =
        std::env::temp_dir().join(format!("schaladb-topo-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk_config = || {
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 8))
            .concurrency(topo_mode())
            .build()
            .unwrap()
    };
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&b, parts);
    let fp_before;
    {
        let a = DbCluster::start(mk_config()).unwrap();
        schema(&a, parts);
        let mut d = Driver::new(a.clone(), b.clone(), 23, parts);
        d.drive(250);
        // pre-admin checkpoints: these keep the narrow 4-partition def and
        // must lose the def election once the split widens the table
        assert!(checkpoint_node(&a, 0).unwrap().written > 0);
        assert!(checkpoint_node(&a, 1).unwrap().written > 0);

        let new_node = a.add_node().unwrap();
        a.rebalance_partition("workqueue", 0, new_node).unwrap();
        d.drive(100);
        a.split_partition("workqueue", 0).unwrap();
        d.drive(100);
        fingerprints_equal(&a, &b);

        // clean-shutdown baseline: checkpoint every node (what `dchiron
        // serve` does on shutdown), then stop the whole cluster
        for id in 0..a.num_nodes() as u32 {
            checkpoint_node(&a, id).unwrap();
        }
        fp_before = a.fingerprint().unwrap();
        // scope end: Arcs drop, node WALs flush — clean whole-cluster stop
    }

    let a = DbCluster::open(mk_config()).unwrap();
    assert_eq!(a.num_nodes(), 3, "node-dir discovery must bring back the added node");
    assert_eq!(a.fingerprint().unwrap(), fp_before, "cold start lost elastic state");
    fingerprints_equal(&a, &b);

    // the reopened, widened topology still serves on every partition
    let sa = Stmts::prepare(&a);
    let sb = Stmts::prepare(&b);
    for k in 0..40i64 {
        let op = Op::Insert { id: 5_000_000 + k, worker: k % parts as i64, dur: 3.0 };
        assert_eq!(apply(&a, &sa, &op).unwrap(), 1, "insert {k} after cold start");
        assert_eq!(apply(&b, &sb, &op).unwrap(), 1);
    }
    fingerprints_equal(&a, &b);
    let _ = std::fs::remove_dir_all(&dir);
}
