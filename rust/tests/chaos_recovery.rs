//! Chaos / recovery gate: kill a data node mid-workload, restart it from
//! its per-partition checkpoints + WAL segment tails, let the availability
//! sweep drive the redo-ship catch-up and the serving hand-off — and
//! demand that the surviving cluster's state is **byte-equal** to a
//! never-killed twin cluster fed the identical committed stream.
//!
//! The twin protocol: every operation is applied to cluster A (the chaos
//! victim, running with durable per-partition WAL segments) and, iff A
//! committed it, to cluster B (plain, never touched). Since both clusters
//! use canonical slot allocation and the same deterministic op stream,
//! their `fingerprint()` — a sorted serialization of all committed rows —
//! must match at every quiescent point, including after kill → restart →
//! rejoin → re-promotion.
//!
//! The CI `chaos-recovery` job runs this under a seed × partition ×
//! concurrency-mode matrix via `CHAOS_SEED` / `CHAOS_PARTITIONS` /
//! `CHAOS_MODE`; a plain `cargo test` sweeps a small built-in matrix.
//! `CHAOS_MODE=occ` runs the chaos victim's point claims through the
//! optimistic path while the twin stays on 2PL — byte-equality then also
//! proves OCC commits are indistinguishable from pessimistic ones.

use schaladb::storage::checkpoint::checkpoint_node;
use schaladb::storage::cluster::{ClusterConfig, ConcurrencyMode, DurabilityConfig};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, DbCluster, Prepared, Value};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Deterministic LCG so every (seed, partitions) cell replays identically.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 11
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

fn schema(c: &DbCluster, parts: usize) {
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE prov (provid INT NOT NULL, taskid INT, note TEXT) PRIMARY KEY (provid)")
        .unwrap();
}

/// The prepared statement set one cluster runs the stream through.
struct Stmts {
    insert: Prepared,
    claim: Prepared,
    finish: Prepared,
    delete: Prepared,
    prov: Prepared,
}

impl Stmts {
    fn prepare(c: &DbCluster) -> Stmts {
        Stmts {
            insert: c
                .prepare(
                    "INSERT INTO workqueue (taskid, workerid, status, dur) \
                     VALUES (?, ?, 'READY', ?)",
                )
                .unwrap(),
            claim: c
                .prepare(
                    "UPDATE workqueue SET status = 'RUNNING' \
                     WHERE taskid = ? AND workerid = ? AND status = 'READY'",
                )
                .unwrap(),
            finish: c
                .prepare(
                    "UPDATE workqueue SET status = 'FINISHED', dur = dur + 1.5 \
                     WHERE taskid = ? AND workerid = ?",
                )
                .unwrap(),
            delete: c
                .prepare("DELETE FROM workqueue WHERE taskid = ? AND workerid = ?")
                .unwrap(),
            prov: c
                .prepare("INSERT INTO prov (provid, taskid, note) VALUES (?, ?, ?)")
                .unwrap(),
        }
    }
}

/// One op of the committed stream.
#[derive(Clone, Debug)]
enum Op {
    Insert { id: i64, worker: i64, dur: f64 },
    Claim { id: i64, worker: i64 },
    Finish { id: i64, worker: i64 },
    Delete { id: i64, worker: i64 },
    Prov { id: i64, task: i64, note: String },
}

fn apply(c: &DbCluster, s: &Stmts, op: &Op) -> schaladb::Result<usize> {
    let r = match op {
        Op::Insert { id, worker, dur } => c.exec_prepared(
            0,
            AccessKind::InsertTasks,
            &s.insert,
            &[Value::Int(*id), Value::Int(*worker), Value::Float(*dur)],
        )?,
        Op::Claim { id, worker } => c.exec_prepared(
            0,
            AccessKind::UpdateToRunning,
            &s.claim,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Finish { id, worker } => c.exec_prepared(
            0,
            AccessKind::UpdateToFinished,
            &s.finish,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Delete { id, worker } => c.exec_prepared(
            0,
            AccessKind::Other,
            &s.delete,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Prov { id, task, note } => c.exec_prepared(
            0,
            AccessKind::InsertProvenance,
            &s.prov,
            &[Value::Int(*id), Value::Int(*task), Value::str(note.clone())],
        )?,
    };
    Ok(r.affected())
}

/// The chaos driver: streams ops into A; every op A commits is mirrored to
/// B (the never-killed twin). Tracks live task ids so later ops reference
/// real rows.
struct Driver {
    a: Arc<DbCluster>,
    b: Arc<DbCluster>,
    sa: Stmts,
    sb: Stmts,
    rng: Rng,
    parts: i64,
    next_id: i64,
    next_prov: i64,
    /// (taskid, workerid) of rows believed live on both clusters.
    live: Vec<(i64, i64)>,
}

impl Driver {
    fn new(a: Arc<DbCluster>, b: Arc<DbCluster>, seed: u64, parts: usize) -> Driver {
        let sa = Stmts::prepare(&a);
        let sb = Stmts::prepare(&b);
        Driver {
            a,
            b,
            sa,
            sb,
            rng: Rng(seed.wrapping_mul(0x9e3779b97f4a7c15) | 1),
            parts: parts as i64,
            next_id: 0,
            next_prov: 0,
            live: Vec::new(),
        }
    }

    fn gen(&mut self) -> Op {
        let roll = self.rng.below(10);
        if self.live.is_empty() || roll < 4 {
            let id = self.next_id;
            self.next_id += 1;
            return Op::Insert {
                id,
                worker: self.rng.below(self.parts as u64) as i64,
                dur: (self.rng.below(1000) as f64) / 8.0,
            };
        }
        let pick = self.rng.below(self.live.len() as u64) as usize;
        let (id, worker) = self.live[pick];
        match roll {
            4 | 5 => Op::Claim { id, worker },
            6 => Op::Finish { id, worker },
            7 => Op::Delete { id, worker },
            _ => {
                let pid = self.next_prov;
                self.next_prov += 1;
                Op::Prov {
                    id: pid,
                    task: id,
                    note: format!("tab\there 'n {} \\slash\nline", pid),
                }
            }
        }
    }

    /// Apply `n` generated ops. Ops that fail on A with an availability
    /// error (a kill window) are dropped from the stream entirely — they
    /// committed nowhere, so the twin must not see them either.
    fn drive(&mut self, n: usize) {
        for _ in 0..n {
            let op = self.gen();
            match apply(&self.a, &self.sa, &op) {
                Ok(affected_a) => {
                    let affected_b =
                        apply(&self.b, &self.sb, &op).expect("twin must accept mirrored op");
                    assert_eq!(
                        affected_a, affected_b,
                        "twin diverged on {op:?}: {affected_a} != {affected_b}"
                    );
                    match &op {
                        Op::Insert { id, worker, .. } => self.live.push((*id, *worker)),
                        Op::Delete { id, .. } => self.live.retain(|(i, _)| i != id),
                        _ => {}
                    }
                }
                Err(schaladb::Error::Unavailable(_)) => { /* nothing committed */ }
                Err(e) => panic!("unexpected failure on {op:?}: {e}"),
            }
        }
    }
}

fn fingerprints_equal(a: &DbCluster, b: &DbCluster) {
    let fa = a.fingerprint().unwrap();
    let fb = b.fingerprint().unwrap();
    assert!(!fa.is_empty());
    assert_eq!(fa, fb, "chaos cluster state diverged from the never-killed twin");
}

fn run_cell(seed: u64, parts: usize) {
    let dir = std::env::temp_dir().join(format!(
        "schaladb-chaos-s{seed}-p{parts}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let a = DbCluster::start(
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 8))
            .concurrency(chaos_mode())
            .build()
            .unwrap(),
    )
    .unwrap();
    // The twin always runs pessimistic 2PL: under CHAOS_MODE=occ the
    // byte-equality below is a cross-discipline proof, not a mirror test.
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a, parts);
    schema(&b, parts);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), seed, parts);

    // Phase 1: a healthy prefix, then cut per-partition checkpoints.
    d.drive(300);
    // reserved rows for the concurrent claimers during the rejoin window
    let reserved: Vec<(i64, i64)> = (0..40)
        .map(|k| (1_000_000 + k, k % parts as i64))
        .collect();
    for (id, w) in &reserved {
        let op = Op::Insert { id: *id, worker: *w, dur: 1.0 };
        assert_eq!(apply(&a, &d.sa, &op).unwrap(), 1);
        assert_eq!(apply(&b, &d.sb, &op).unwrap(), 1);
    }
    fingerprints_equal(&a, &b);
    assert!(checkpoint_node(&a, 0).unwrap().written > 0);
    assert!(checkpoint_node(&a, 1).unwrap().written > 0);

    // Phase 2: build a WAL tail past the checkpoints.
    d.drive(200);

    // Phase 3: kill node 1; the sweep promotes its backups (new epoch) and
    // the stream keeps committing against the survivor.
    let epoch0 = a.cluster_epoch();
    a.kill_node(1).unwrap();
    let r = am.sweep().unwrap();
    assert!(r.promoted > 0, "node 1 must have hosted primaries");
    assert!(a.cluster_epoch() > epoch0);
    d.drive(150);
    fingerprints_equal(&a, &b);

    // Phase 4: process restart — local recovery from checkpoint + torn-tail
    // WAL replay, then online catch-up while claims keep flowing.
    let start = a.restart_node(1).unwrap();
    assert!(start.partitions > 0);
    assert!(
        start.from_checkpoint > 0,
        "phase-1 checkpoints must be found: {start:?}"
    );
    assert!(start.replayed > 0, "the phase-2 tail must replay locally: {start:?}");

    let stop_claims = Arc::new(AtomicU64::new(0));
    let claimer = {
        let a = a.clone();
        let b = b.clone();
        let reserved = reserved.clone();
        let claimed = stop_claims.clone();
        std::thread::spawn(move || {
            let sa = Stmts::prepare(&a);
            let sb = Stmts::prepare(&b);
            for (id, w) in reserved {
                let op = Op::Claim { id, worker: w };
                // retry through any transient unavailability: the cluster
                // must keep serving claims throughout the rejoin
                let na = loop {
                    match apply(&a, &sa, &op) {
                        Ok(n) => break n,
                        Err(schaladb::Error::Unavailable(_)) => {
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(e) => panic!("claim failed during rejoin: {e}"),
                    }
                };
                let nb = apply(&b, &sb, &op).unwrap();
                assert_eq!(na, nb);
                assert_eq!(na, 1, "reserved row must be claimable exactly once");
                claimed.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        })
    };

    let mut rejoined = false;
    for _ in 0..200 {
        let r = am.sweep().unwrap();
        if r.rejoined > 0 {
            rejoined = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    assert!(rejoined, "node 1 must finish rejoining while claims run");
    assert!(a.node(1).unwrap().is_alive());
    claimer.join().unwrap();
    assert_eq!(stop_claims.load(Ordering::SeqCst), 40);

    // Phase 5: the byte-equality gate. Commits racing the hand-off are
    // covered by the under-latch mirror-set validation (they land on both
    // replicas or are shipped by the final cut), so no heal sweep is
    // *required* here; the sweeps only assert that a healthy cluster
    // sweep is harmless after a rejoin.
    am.sweep().unwrap();
    am.sweep().unwrap();
    d.drive(100);
    am.sweep().unwrap();
    fingerprints_equal(&a, &b);

    // Phase 6: re-promotion — kill the never-restarted node so the
    // rejoined one serves everything. Still byte-equal to the twin, which
    // proves the rejoined replicas (not just the survivors) are faithful.
    a.kill_node(0).unwrap();
    let r = am.sweep().unwrap();
    assert!(r.promoted > 0, "rejoined node must be promotable");
    fingerprints_equal(&a, &b);
    assert!(a.cluster_epoch() >= 2);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Point-DML concurrency mode for the chaos victim, from `CHAOS_MODE`
/// (`2pl` | `occ`, default 2PL). The CI matrix sets it; local runs can
/// flip it by hand.
fn chaos_mode() -> ConcurrencyMode {
    std::env::var("CHAOS_MODE")
        .ok()
        .and_then(|s| ConcurrencyMode::from_name(&s))
        .unwrap_or_default()
}

/// Seed matrix: one cell from the environment (the CI job matrix), or a
/// small built-in sweep for plain `cargo test`.
fn matrix() -> Vec<(u64, usize)> {
    let seed = std::env::var("CHAOS_SEED").ok().and_then(|s| s.parse().ok());
    let parts = std::env::var("CHAOS_PARTITIONS").ok().and_then(|s| s.parse().ok());
    match (seed, parts) {
        (Some(s), Some(p)) => vec![(s, p)],
        _ => vec![(1, 2), (2, 4), (3, 2)],
    }
}

#[test]
fn chaos_kill_restart_rejoin_equals_twin() {
    for (seed, parts) in matrix() {
        run_cell(seed, parts);
    }
}

/// Without a durability dir a restart has nothing local to recover from:
/// every partition re-seeds over the redo-ship path, and the cluster still
/// converges to the twin.
#[test]
fn restart_without_durability_reseeds_everything() {
    let a = DbCluster::start(ClusterConfig::default()).unwrap();
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a, 2);
    schema(&b, 2);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), 7, 2);
    d.drive(200);
    a.kill_node(0).unwrap();
    am.sweep().unwrap();
    d.drive(100);
    let start = a.restart_node(0).unwrap();
    assert_eq!(start.from_checkpoint, 0);
    assert_eq!(start.replayed, 0);
    let r = am.sweep().unwrap();
    assert_eq!(r.rejoined, 1);
    // a memory-only restart recovers purely over the redo-ship stream:
    // either the peers' retained tails replay from LSN 0, or partitions
    // whose tail was truncated re-seed from snapshots
    assert!(
        r.shipped_ops > 0 || r.reseeded_parts > 0,
        "memory-only restart must recover over the wire: {r:?}"
    );
    am.sweep().unwrap();
    fingerprints_equal(&a, &b);
    // and the reseeded node can take over
    a.kill_node(1).unwrap();
    am.sweep().unwrap();
    fingerprints_equal(&a, &b);
}

/// Whole-cluster stop → `DbCluster::open` cold start: every partition
/// comes back from its newest checkpoint plus WAL-tail replay, replica
/// pairs reconcile by (epoch, LSN), and the reopened cluster is
/// byte-equal to the live twin — then keeps serving commits.
#[test]
fn full_cluster_stop_cold_starts_byte_equal() {
    let dir =
        std::env::temp_dir().join(format!("schaladb-chaos-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk_config = || {
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 8))
            .concurrency(chaos_mode())
            .build()
            .unwrap()
    };
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&b, 4);
    let fp_before;
    {
        let a = DbCluster::start(mk_config()).unwrap();
        schema(&a, 4);
        let mut d = Driver::new(a.clone(), b.clone(), 11, 4);
        d.drive(250);
        assert!(checkpoint_node(&a, 0).unwrap().written > 0);
        assert!(checkpoint_node(&a, 1).unwrap().written > 0);
        d.drive(120); // WAL tail past the checkpoints
        fp_before = a.fingerprint().unwrap();
        // scope end: the last Arcs drop, the node WALs' Drop flushes the
        // buffered group-commit tail — a clean whole-cluster stop
    }

    let a = DbCluster::open(mk_config()).unwrap();
    assert!(a.cluster_epoch() > 0, "cold start must re-stamp a fresh epoch");
    assert_eq!(a.fingerprint().unwrap(), fp_before, "cold start lost committed state");
    fingerprints_equal(&a, &b);

    // the reopened cluster still serves: fresh inserts + claims on both
    let sa = Stmts::prepare(&a);
    let sb = Stmts::prepare(&b);
    for k in 0..30 {
        let ins = Op::Insert { id: 2_000_000 + k, worker: k % 4, dur: 2.0 };
        assert_eq!(apply(&a, &sa, &ins).unwrap(), 1);
        assert_eq!(apply(&b, &sb, &ins).unwrap(), 1);
        let claim = Op::Claim { id: 2_000_000 + k, worker: k % 4 };
        assert_eq!(apply(&a, &sa, &claim).unwrap(), 1);
        assert_eq!(apply(&b, &sb, &claim).unwrap(), 1);
    }
    fingerprints_equal(&a, &b);
    let _ = std::fs::remove_dir_all(&dir);
}
