//! Integration tests over the storage engine as a whole: SQL surface,
//! concurrency invariants, durability, failover — the behaviours the
//! workflow layers rely on.

use schaladb::storage::cluster::ClusterConfig;
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::value::Value;
use schaladb::storage::{checkpoint, AccessKind, DbCluster};
use schaladb::util::prop;
use std::sync::Arc;

fn wq(workers: usize) -> Arc<DbCluster> {
    let c = DbCluster::start(ClusterConfig::default()).unwrap();
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT) PARTITION BY HASH(workerid) PARTITIONS {workers} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c
}

fn seed(c: &DbCluster, n: usize, workers: usize) {
    let mut vals = Vec::new();
    for i in 0..n {
        vals.push(format!("({i}, {}, 'READY', 1.0)", i % workers));
        if vals.len() == 256 {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status, dur) VALUES {}",
                vals.join(", ")
            ))
            .unwrap();
            vals.clear();
        }
    }
    if !vals.is_empty() {
        c.execute(&format!(
            "INSERT INTO workqueue (taskid, workerid, status, dur) VALUES {}",
            vals.join(", ")
        ))
        .unwrap();
    }
}

/// The fundamental scheduling invariant: N threads claiming concurrently
/// never double-claim and never lose a task.
#[test]
fn concurrent_claims_are_exactly_once() {
    let workers = 6;
    let c = wq(workers);
    seed(&c, 1200, workers);
    let mut handles = Vec::new();
    for w in 0..workers {
        for _ in 0..2 {
            // two threads per partition: intra-partition racing
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut claimed = Vec::new();
                loop {
                    let rs = c
                        .exec(&format!(
                            "UPDATE workqueue SET status = 'RUNNING' \
                             WHERE workerid = {w} AND status = 'READY' \
                             ORDER BY taskid LIMIT 1 RETURNING taskid"
                        ))
                        .unwrap()
                        .rows();
                    match rs.rows.first() {
                        Some(r) => claimed.push(r.values[0].as_i64().unwrap()),
                        None => break,
                    }
                }
                claimed
            }));
        }
    }
    let mut all: Vec<i64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    all.sort_unstable();
    let before = all.len();
    all.dedup();
    assert_eq!(before, all.len(), "a task was claimed twice");
    assert_eq!(all.len(), 1200, "tasks lost");
}

/// Claims keep working while a data node dies and comes back mid-stream.
#[test]
fn claims_survive_data_node_failure() {
    let workers = 4;
    let c = wq(workers);
    seed(&c, 400, workers);
    let am = AvailabilityManager::new(c.clone());

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut handles = Vec::new();
    for w in 0..workers {
        let c = c.clone();
        let stop = stop.clone();
        handles.push(std::thread::spawn(move || {
            let mut n = 0;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                match c.exec(&format!(
                    "UPDATE workqueue SET status = 'RUNNING' \
                     WHERE workerid = {w} AND status = 'READY' \
                     ORDER BY taskid LIMIT 1 RETURNING taskid"
                )) {
                    Ok(rs) => {
                        if rs.rows().rows.is_empty() {
                            break;
                        }
                        n += 1;
                    }
                    Err(_) => std::thread::sleep(std::time::Duration::from_micros(200)),
                }
            }
            n
        }));
    }
    std::thread::sleep(std::time::Duration::from_millis(5));
    c.kill_node(0).unwrap();
    am.sweep().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(10));
    c.revive_node(0).unwrap();
    am.sweep().unwrap();
    let total: i64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    assert!(total > 0);
    let rs = c
        .query("SELECT COUNT(*) FROM workqueue WHERE status = 'RUNNING'")
        .unwrap();
    assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), total, "claims lost or duplicated");
}

/// Checkpoint mid-workload, recover into a fresh cluster, totals match.
#[test]
fn checkpoint_recovery_preserves_scheduler_state() {
    let workers = 4;
    let c = wq(workers);
    seed(&c, 500, workers);
    c.execute("UPDATE workqueue SET status = 'RUNNING' WHERE taskid < 100").unwrap();
    c.execute("UPDATE workqueue SET status = 'FINISHED' WHERE taskid < 50").unwrap();

    let dir = std::env::temp_dir().join(format!("schaladb-it-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    checkpoint::checkpoint(&c, &dir).unwrap();
    let r = checkpoint::recover(&dir, ClusterConfig::default()).unwrap();

    for status in ["READY", "RUNNING", "FINISHED"] {
        let a = c
            .query(&format!("SELECT COUNT(*) FROM workqueue WHERE status = '{status}'"))
            .unwrap();
        let b = r
            .query(&format!("SELECT COUNT(*) FROM workqueue WHERE status = '{status}'"))
            .unwrap();
        assert_eq!(a.rows[0].values[0], b.rows[0].values[0], "{status} count drifted");
    }
    // scheduling continues on the recovered cluster
    let rs = r
        .exec(
            "UPDATE workqueue SET status = 'RUNNING' WHERE workerid = 1 AND status = 'READY' \
             ORDER BY taskid LIMIT 1 RETURNING taskid",
        )
        .unwrap()
        .rows();
    assert_eq!(rs.rows.len(), 1);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Property: any interleaving of claims/finishes keeps status counts
/// consistent with the number of operations applied.
#[test]
fn prop_status_transitions_conserve_rows() {
    prop::check("status transitions conserve rows", 15, |g| {
        let workers = g.usize(1, 4);
        let n = g.usize(10, 60);
        let c = wq(workers);
        seed(&c, n, workers);
        let mut claims = 0;
        let mut finishes = 0;
        for _ in 0..g.usize(5, 40) {
            let w = g.usize(0, workers - 1);
            if g.bool() {
                let got = c
                    .exec(&format!(
                        "UPDATE workqueue SET status = 'RUNNING' \
                         WHERE workerid = {w} AND status = 'READY' \
                         ORDER BY taskid LIMIT 1 RETURNING taskid"
                    ))
                    .unwrap()
                    .rows()
                    .rows
                    .len();
                claims += got;
            } else {
                let got = c
                    .execute(&format!(
                        "UPDATE workqueue SET status = 'FINISHED' \
                         WHERE workerid = {w} AND status = 'RUNNING' LIMIT 1"
                    ))
                    .unwrap();
                finishes += got;
            }
        }
        let count = |s: &str| -> i64 {
            c.query(&format!("SELECT COUNT(*) FROM workqueue WHERE status = '{s}'"))
                .unwrap()
                .rows[0]
                .values[0]
                .as_i64()
                .unwrap()
        };
        assert_eq!(count("FINISHED"), finishes as i64);
        assert_eq!(count("RUNNING"), (claims - finishes) as i64);
        assert_eq!(count("READY"), (n - claims) as i64);
    });
}

/// Property: hash partition routing is total and stable — every row lands
/// in exactly one partition and is findable both by partition-pinned and
/// unpinned queries.
#[test]
fn prop_partition_routing_total() {
    prop::check("partition routing total", 15, |g| {
        let workers = g.usize(1, 6);
        let c = wq(workers);
        let n = g.usize(1, 50);
        let mut expected_per_worker = vec![0i64; workers];
        for i in 0..n {
            let w = g.usize(0, workers * 3); // ids beyond partition count too
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status, dur) \
                 VALUES ({i}, {w}, 'READY', 1.0)"
            ))
            .unwrap();
            expected_per_worker[w % workers] += 0; // routing is internal
            let _ = w;
        }
        let total = c
            .query("SELECT COUNT(*) FROM workqueue")
            .unwrap()
            .rows[0]
            .values[0]
            .as_i64()
            .unwrap();
        assert_eq!(total, n as i64);
        // every row is findable by its workerid-pinned query
        let rs = c.query("SELECT taskid, workerid FROM workqueue").unwrap();
        for row in &rs.rows {
            let tid = row.values[0].as_i64().unwrap();
            let wid = row.values[1].as_i64().unwrap();
            let hit = c
                .query(&format!(
                    "SELECT taskid FROM workqueue WHERE workerid = {wid} AND taskid = {tid}"
                ))
                .unwrap();
            assert_eq!(hit.rows.len(), 1);
        }
    });
}

/// Tagged stats land under the right access kind (the instrument the whole
/// Experiment 5/6 pipeline depends on).
#[test]
fn stats_tags_route_correctly() {
    let c = wq(2);
    seed(&c, 10, 2);
    c.exec_tagged(0, AccessKind::GetReadyTasks, "SELECT * FROM workqueue WHERE workerid = 0")
        .unwrap();
    c.exec_tagged(1, AccessKind::UpdateToFinished, "UPDATE workqueue SET status = 'FINISHED' WHERE taskid = 1")
        .unwrap();
    assert_eq!(c.stats.get(AccessKind::GetReadyTasks).count, 1);
    assert_eq!(c.stats.get(AccessKind::UpdateToFinished).count, 1);
    assert!(c.stats.max_node_secs() > 0.0);
    let pct: f64 = c.stats.percentages().iter().map(|(_, p)| p).sum();
    assert!((pct - 100.0).abs() < 1e-9);
}

/// SQL surface smoke over every clause the steering queries use.
#[test]
fn steering_sql_surface() {
    let c = wq(3);
    seed(&c, 30, 3);
    c.exec("CREATE TABLE node (nodeid INT NOT NULL, hostname TEXT) PRIMARY KEY (nodeid)")
        .unwrap();
    for w in 0..3 {
        c.execute(&format!("INSERT INTO node (nodeid, hostname) VALUES ({w}, 'node{w}')"))
            .unwrap();
    }
    let rs = c
        .query(
            "SELECT n.hostname, t.status, COUNT(*) AS n_tasks, SUM(t.dur) AS total_dur \
             FROM workqueue t JOIN node n ON t.workerid = n.nodeid \
             WHERE t.taskid BETWEEN 0 AND 100 AND t.status LIKE 'REA%' \
             GROUP BY n.hostname, t.status HAVING COUNT(*) > 1 \
             ORDER BY n_tasks DESC, n.hostname LIMIT 10",
        )
        .unwrap();
    assert_eq!(rs.rows.len(), 3);
    assert_eq!(rs.rows[0].values[2], Value::Int(10));
    // CASE + IN + IS NULL
    let rs = c
        .query(
            "SELECT CASE WHEN taskid IN (1, 2) THEN 'special' ELSE 'normal' END AS kind, \
             COUNT(*) FROM workqueue WHERE dur IS NOT NULL GROUP BY kind ORDER BY kind",
        )
        .unwrap();
    assert_eq!(rs.rows.len(), 2);
}
