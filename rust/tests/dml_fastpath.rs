//! Differential property tests for the compiled DML fast path.
//!
//! Two clusters share one manual clock and receive the identical statement
//! stream: one executes through `exec_prepared` (compiled fast plans where
//! the shape allows), the other through `exec_prepared_interpreted` (the
//! AST-walking reference executor). Every per-statement result and the full
//! post-state must match — across partition counts, concurrent claim races,
//! dead-primary failover, and abort paths. Unsupported shapes must fall
//! back, observable through the `fast_dml` route counter.

use schaladb::storage::cluster::{ClusterConfig, DbCluster};
use schaladb::storage::{AccessKind, Value};
use schaladb::util::clock::{self, ManualClock, SharedClock};
use schaladb::util::rng::Rng;
use std::sync::Arc;

const CLAIM: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                     WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
                     RETURNING taskid";
const CLAIM_BY_PK: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                           WHERE taskid = ? AND status = 'READY' AND workerid = ?";
const FINISH: &str = "UPDATE workqueue SET status = 'FINISHED', dur = ? \
                      WHERE taskid = ? AND workerid = ?";
const FAIL: &str = "UPDATE workqueue SET failtries = failtries + 1, \
                    status = CASE WHEN failtries + 1 >= ? THEN 'FAILED' ELSE 'READY' END \
                    WHERE taskid = ? AND workerid = ?";
const INSERT: &str = "INSERT INTO workqueue (taskid, workerid, status, failtries, dur) \
                      VALUES (?, ?, 'READY', 0, ?)";
const GET_READY: &str = "SELECT taskid, status FROM workqueue \
                         WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 3";
const DELETE: &str = "DELETE FROM workqueue WHERE taskid = ? AND workerid = ?";
const IN_LIST: &str = "UPDATE workqueue SET dur = ? WHERE taskid IN (?, ?)";
const BREAK_NOT_NULL: &str = "UPDATE workqueue SET failtries = NULL \
                              WHERE taskid = ? AND workerid = ?";

fn cluster(parts: usize, clock: SharedClock) -> Arc<DbCluster> {
    let c = DbCluster::start(ClusterConfig::builder().clock(clock).build().unwrap()).unwrap();
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, failtries INT NOT NULL, dur FLOAT, starttime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c
}

struct Pair {
    fast: Arc<DbCluster>,
    reference: Arc<DbCluster>,
    clock: Arc<ManualClock>,
}

fn pair(parts: usize) -> Pair {
    let (shared, manual) = clock::manual(0.0);
    Pair {
        fast: cluster(parts, shared.clone()),
        reference: cluster(parts, shared),
        clock: manual,
    }
}

impl Pair {
    /// Run one statement on both executors and demand identical outcomes
    /// (result rows / affected counts, or identical error text).
    fn exec_both(&self, sql: &str, params: &[Value]) {
        let pf = self.fast.prepare(sql).unwrap();
        let pr = self.reference.prepare(sql).unwrap();
        let a = self.fast.exec_prepared(0, AccessKind::Other, &pf, params);
        let b = self.reference.exec_prepared_interpreted(0, AccessKind::Other, &pr, params);
        match (a, b) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "result mismatch: {sql} {params:?}"),
            (Err(x), Err(y)) => assert_eq!(
                x.to_string(),
                y.to_string(),
                "error mismatch: {sql} {params:?}"
            ),
            (a, b) => panic!("divergent outcome for {sql} {params:?}: fast={a:?} ref={b:?}"),
        }
    }

    /// Compare the full table contents via the shared interpreted read
    /// path (fair to both sides).
    fn assert_same_state(&self, ctx: &str) {
        let q = "SELECT * FROM workqueue ORDER BY taskid";
        let a = self.fast.query_centralized(q).unwrap();
        let b = self.reference.query_centralized(q).unwrap();
        assert_eq!(a, b, "post-state diverged ({ctx})");
    }

    /// One random point operation mirrored to both executors.
    fn random_op(&self, rng: &mut Rng, parts: usize, next_id: &mut i64) {
        self.clock.advance(0.25);
        let w = rng.index(parts) as i64;
        let tid = if *next_id > 0 { rng.range(0, *next_id) } else { 0 };
        let tw = tid % parts as i64;
        match rng.index(10) {
            0 | 1 => self.exec_both(CLAIM, &[Value::Int(w)]),
            2 => self.exec_both(CLAIM_BY_PK, &[Value::Int(tid), Value::Int(tw)]),
            3 => self.exec_both(
                FINISH,
                &[Value::Float(rng.uniform(0.1, 5.0)), Value::Int(tid), Value::Int(tw)],
            ),
            4 => self.exec_both(FAIL, &[Value::Int(3), Value::Int(tid), Value::Int(tw)]),
            5 | 6 => {
                let id = *next_id;
                *next_id += 1;
                self.exec_both(
                    INSERT,
                    &[
                        Value::Int(id),
                        Value::Int(id % parts as i64),
                        Value::Float(rng.uniform(0.1, 2.0)),
                    ],
                );
            }
            7 => self.exec_both(GET_READY, &[Value::Int(w)]),
            8 => self.exec_both(DELETE, &[Value::Int(tid), Value::Int(tw)]),
            _ => {
                // unsupported shape: both sides interpret (fallback parity)
                let other = rng.range(0, (*next_id).max(1));
                self.exec_both(
                    IN_LIST,
                    &[Value::Float(9.9), Value::Int(tid), Value::Int(other)],
                );
            }
        }
    }
}

#[test]
fn fast_path_equals_interpreted_across_partition_counts() {
    for parts in [1usize, 2, 3, 8] {
        let p = pair(parts);
        let mut rng = Rng::new(42 + parts as u64);
        let mut next_id = 0i64;
        // seed through the same mirrored path
        for _ in 0..30 {
            let id = next_id;
            next_id += 1;
            p.exec_both(
                INSERT,
                &[Value::Int(id), Value::Int(id % parts as i64), Value::Float(1.0)],
            );
        }
        for _ in 0..250 {
            p.random_op(&mut rng, parts, &mut next_id);
        }
        p.assert_same_state(&format!("{parts} partitions"));
        // the fast executor actually served the stream; the reference
        // never touched it
        assert!(
            p.fast.route_counts().fast_dml > 0,
            "fast path unused at {parts} partitions"
        );
        assert_eq!(p.reference.route_counts().fast_dml, 0);
    }
}

#[test]
fn abort_paths_leave_identical_state() {
    let p = pair(4);
    let mut next_id = 0i64;
    for _ in 0..12 {
        let id = next_id;
        next_id += 1;
        p.exec_both(INSERT, &[Value::Int(id), Value::Int(id % 4), Value::Float(1.0)]);
    }
    // NOT NULL violation aborts the statement on both executors
    p.exec_both(BREAK_NOT_NULL, &[Value::Int(3), Value::Int(3)]);
    // duplicate-PK batch insert aborts atomically on both executors
    let rows: Vec<Vec<Value>> = [100i64, 101, 5]
        .iter()
        .map(|i| vec![Value::Int(*i), Value::Int(0), Value::Float(1.0)])
        .collect();
    let pf = p.fast.prepare(INSERT).unwrap();
    let pr = p.reference.prepare(INSERT).unwrap();
    let a = p.fast.exec_prepared_batch(0, AccessKind::InsertTasks, &pf, &rows);
    let stmt = pr.bind_batch(&rows).unwrap();
    let b = p.reference.exec_stmt(0, AccessKind::InsertTasks, &stmt);
    assert!(a.is_err() && b.is_err(), "duplicate PK must abort both paths");
    p.assert_same_state("after aborts");
    // and a successful batch lands identically
    let ok_rows: Vec<Vec<Value>> = (200..230)
        .map(|i| vec![Value::Int(i), Value::Int(i % 4), Value::Float(0.5)])
        .collect();
    let a = p
        .fast
        .exec_prepared_batch(0, AccessKind::InsertTasks, &pf, &ok_rows)
        .unwrap();
    let stmt = pr.bind_batch(&ok_rows).unwrap();
    let b = p.reference.exec_stmt(0, AccessKind::InsertTasks, &stmt).unwrap();
    assert_eq!(a, b);
    p.assert_same_state("after batch insert");
}

#[test]
fn fast_path_equals_interpreted_under_dead_primary_failover() {
    let p = pair(4);
    let mut rng = Rng::new(7);
    let mut next_id = 0i64;
    for _ in 0..40 {
        let id = next_id;
        next_id += 1;
        p.exec_both(INSERT, &[Value::Int(id), Value::Int(id % 4), Value::Float(1.0)]);
    }
    for _ in 0..60 {
        p.random_op(&mut rng, 4, &mut next_id);
    }
    // identical DDL order means identical placements: kill the same node
    // on both sides and promote
    p.fast.kill_node(0).unwrap();
    p.reference.kill_node(0).unwrap();
    let a = p.fast.promote_dead_primaries();
    let b = p.reference.promote_dead_primaries();
    assert_eq!(a, b, "promotion counts must match");
    assert!(a > 0, "some primaries lived on node 0");
    for _ in 0..80 {
        p.random_op(&mut rng, 4, &mut next_id);
    }
    p.assert_same_state("under failover");
    // revive + heal, keep going
    p.fast.revive_node(0).unwrap();
    p.reference.revive_node(0).unwrap();
    assert_eq!(p.fast.heal().unwrap(), p.reference.heal().unwrap());
    for _ in 0..40 {
        p.random_op(&mut rng, 4, &mut next_id);
    }
    p.assert_same_state("after heal");
    assert!(p.fast.route_counts().fast_dml > 0);
}

#[test]
fn concurrent_fast_claims_never_double_claim() {
    let parts = 4usize;
    let c = cluster(parts, clock::wall());
    let ins = c.prepare(INSERT).unwrap();
    let rows: Vec<Vec<Value>> = (0..200)
        .map(|i| vec![Value::Int(i), Value::Int(i % parts as i64), Value::Float(1.0)])
        .collect();
    c.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, &rows).unwrap();

    // 8 threads over 4 partitions: two threads race on every partition
    let mut handles = Vec::new();
    for t in 0..8u32 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let claim = c.prepare(CLAIM).unwrap();
            let w = (t % parts as u32) as i64;
            let mut got = Vec::new();
            loop {
                let rs = c
                    .exec_prepared(t, AccessKind::UpdateToRunning, &claim, &[Value::Int(w)])
                    .unwrap()
                    .rows();
                match rs.rows.first() {
                    Some(r) => got.push(r.values[0].as_i64().unwrap()),
                    None => break,
                }
            }
            got
        }));
    }
    let mut all: Vec<i64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert_eq!(all.len(), 200, "every task claimed");
    all.sort_unstable();
    all.dedup();
    assert_eq!(all.len(), 200, "no task claimed twice");
    assert!(
        c.route_counts().fast_dml >= 200,
        "claims must take the compiled fast path"
    );
    let rs = c
        .query_centralized("SELECT COUNT(*) FROM workqueue WHERE status = 'RUNNING'")
        .unwrap();
    assert_eq!(rs.rows[0].values[0], Value::Int(200));
}

#[test]
fn unsupported_shapes_fall_back_and_the_router_counts_adoption() {
    let c = cluster(4, clock::wall());
    let ins = c.prepare(INSERT).unwrap();
    assert!(ins.fast_plan().is_some(), "single-row insert classifies");
    for i in 0..8i64 {
        c.exec_prepared(
            0,
            AccessKind::InsertTasks,
            &ins,
            &[Value::Int(i), Value::Int(i % 4), Value::Float(1.0)],
        )
        .unwrap();
    }
    let after_seed = c.route_counts().fast_dml;
    assert_eq!(after_seed, 8, "each fast insert counts once");

    // OR predicates do not classify: the handle has no fast plan, the
    // statement still works, and the counter does not move
    let or_upd = c
        .prepare("UPDATE workqueue SET dur = ? WHERE taskid = ? OR taskid = ?")
        .unwrap();
    assert!(or_upd.fast_plan().is_none(), "OR predicate must not classify");
    let n = c
        .exec_prepared(
            0,
            AccessKind::Other,
            &or_upd,
            &[Value::Float(2.0), Value::Int(1), Value::Int(2)],
        )
        .unwrap()
        .affected();
    assert_eq!(n, 2);
    assert_eq!(c.route_counts().fast_dml, after_seed, "fallback must not count");

    // the claim classifies and counts
    let claim = c.prepare(CLAIM).unwrap();
    assert!(claim.fast_plan().is_some());
    let rs = c
        .exec_prepared(0, AccessKind::UpdateToRunning, &claim, &[Value::Int(1)])
        .unwrap()
        .rows();
    assert_eq!(rs.rows.len(), 1);
    assert_eq!(c.route_counts().fast_dml, after_seed + 1);

    // interpreted-reference executions never count
    c.exec_prepared_interpreted(0, AccessKind::UpdateToRunning, &claim, &[Value::Int(2)])
        .unwrap();
    assert_eq!(c.route_counts().fast_dml, after_seed + 1);
}
