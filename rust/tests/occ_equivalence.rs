//! Differential property gate for the optimistic (OCC) point-DML path.
//!
//! The claim loop is the paper's hottest statement shape, and PR 8 gives
//! it a third execution tier: OCC (validate-and-install) above the 2PL
//! compiled fast path above the interpreted reference executor. This
//! suite demands the tiers are *indistinguishable by state*: the same
//! committed stream through OCC, through 2PL, and through the
//! interpreter must leave byte-identical clusters (`fingerprint()`
//! equality) — serially, under concurrent claim races across 1/2/4/8
//! partitions, under dead-primary failover, and across a kill → restart
//! → rejoin window. It also pins the OCC telemetry invariants:
//!
//! - `route_counts().occ_*` equals the obs registry's OCC counters;
//! - `Hist::OccValidate` holds exactly one sample per validation attempt,
//!   so its count is `occ_dml + occ_retries`;
//! - `Hist::OccRetryDist` holds exactly one sample per OCC completion
//!   (commit or fallback), so its count is `occ_dml + occ_fallbacks`.

use schaladb::obs::{Counter, Hist};
use schaladb::storage::cluster::{
    ClusterConfig, ConcurrencyMode, DbCluster, DurabilityConfig,
};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, Value};
use schaladb::util::clock::{self, ManualClock, SharedClock};
use schaladb::util::rng::Rng;
use std::sync::Arc;

const CLAIM_BY_PK: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                           WHERE taskid = ? AND workerid = ? AND status = 'READY'";
/// NOW()-free claim for wall-clock tests that compare clusters executing
/// at different instants.
const CLAIM_FIXED: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = 1.0 \
                           WHERE taskid = ? AND workerid = ? AND status = 'READY'";
const FINISH: &str = "UPDATE workqueue SET status = 'FINISHED', dur = dur + ? \
                      WHERE taskid = ? AND workerid = ?";
const DELETE: &str = "DELETE FROM workqueue WHERE taskid = ? AND workerid = ?";
const INSERT: &str = "INSERT INTO workqueue (taskid, workerid, status, dur, starttime) \
                      VALUES (?, ?, 'READY', ?, 0.0)";

fn cluster(parts: usize, clock: SharedClock, mode: ConcurrencyMode) -> Arc<DbCluster> {
    let c = DbCluster::start(
        ClusterConfig::builder().clock(clock).concurrency(mode).build().unwrap(),
    )
    .unwrap();
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c
}

fn seed(c: &DbCluster, tasks: i64, parts: usize) {
    let ins = c.prepare(INSERT).unwrap();
    let rows: Vec<Vec<Value>> = (0..tasks)
        .map(|i| vec![Value::Int(i), Value::Int(i % parts as i64), Value::Float(1.0)])
        .collect();
    c.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, &rows).unwrap();
}

/// Assert the OCC telemetry pairing invariants on one cluster.
fn assert_occ_counters_consistent(c: &DbCluster, ctx: &str) {
    let rc = c.route_counts();
    let obs = c.obs();
    assert_eq!(obs.counter(Counter::OccDml), rc.occ_dml, "occ_dml ledgers ({ctx})");
    assert_eq!(
        obs.counter(Counter::OccRetries),
        rc.occ_retries,
        "occ_retries ledgers ({ctx})"
    );
    assert_eq!(
        obs.counter(Counter::OccFallbacks),
        rc.occ_fallbacks,
        "occ_fallbacks ledgers ({ctx})"
    );
    assert_eq!(
        obs.hist(Hist::OccValidate).count(),
        rc.occ_dml + rc.occ_retries,
        "one occ_validate sample per validation attempt ({ctx})"
    );
    assert_eq!(
        obs.hist(Hist::OccRetryDist).count(),
        rc.occ_dml + rc.occ_fallbacks,
        "one retry-distribution sample per OCC completion ({ctx})"
    );
}

// ---------- serial three-tier equivalence ----------

/// One statement stream mirrored across the three execution tiers, all on
/// one frozen manual clock so `NOW()` is identical everywhere.
struct Triple {
    occ: Arc<DbCluster>,
    twopl: Arc<DbCluster>,
    interp: Arc<DbCluster>,
    clock: Arc<ManualClock>,
}

impl Triple {
    fn new(parts: usize) -> Triple {
        let (shared, manual) = clock::manual(0.0);
        Triple {
            occ: cluster(parts, shared.clone(), ConcurrencyMode::Occ),
            twopl: cluster(parts, shared.clone(), ConcurrencyMode::TwoPL),
            interp: cluster(parts, shared, ConcurrencyMode::TwoPL),
            clock: manual,
        }
    }

    /// Run one statement on all three executors; every per-statement
    /// outcome (rows / affected count / error text) must match.
    fn exec_all(&self, sql: &str, params: &[Value]) {
        let po = self.occ.prepare(sql).unwrap();
        let pt = self.twopl.prepare(sql).unwrap();
        let pi = self.interp.prepare(sql).unwrap();
        let o = self.occ.exec_prepared(0, AccessKind::Other, &po, params);
        let t = self.twopl.exec_prepared(0, AccessKind::Other, &pt, params);
        let i = self.interp.exec_prepared_interpreted(0, AccessKind::Other, &pi, params);
        match (&o, &t) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "occ vs 2pl mismatch: {sql} {params:?}"),
            (Err(x), Err(y)) => {
                assert_eq!(x.to_string(), y.to_string(), "error mismatch: {sql}")
            }
            _ => panic!("divergent outcome for {sql} {params:?}: occ={o:?} 2pl={t:?}"),
        }
        match (&t, &i) {
            (Ok(x), Ok(y)) => assert_eq!(x, y, "2pl vs interp mismatch: {sql} {params:?}"),
            (Err(x), Err(y)) => {
                assert_eq!(x.to_string(), y.to_string(), "error mismatch: {sql}")
            }
            _ => panic!("divergent outcome for {sql} {params:?}: 2pl={t:?} interp={i:?}"),
        }
    }

    fn assert_fingerprints_equal(&self, ctx: &str) {
        let fo = self.occ.fingerprint().unwrap();
        let ft = self.twopl.fingerprint().unwrap();
        let fi = self.interp.fingerprint().unwrap();
        assert!(!fo.is_empty());
        assert_eq!(fo, ft, "OCC state diverged from 2PL ({ctx})");
        assert_eq!(ft, fi, "2PL state diverged from interpreted ({ctx})");
    }
}

#[test]
fn occ_equals_2pl_equals_interpreted_across_partition_counts() {
    for parts in [1usize, 2, 4, 8] {
        let t = Triple::new(parts);
        let mut rng = Rng::new(0x0CC0 + parts as u64);
        let mut next_id: i64 = 0;
        for _ in 0..40i64 {
            let id = next_id;
            next_id += 1;
            t.exec_all(
                INSERT,
                &[Value::Int(id), Value::Int(id % parts as i64), Value::Float(1.0)],
            );
        }
        for _ in 0..250 {
            t.clock.advance(0.25);
            let tid = rng.range(0, next_id);
            let tw = tid % parts as i64;
            match rng.index(8) {
                0 | 1 | 2 => t.exec_all(CLAIM_BY_PK, &[Value::Int(tid), Value::Int(tw)]),
                3 => t.exec_all(
                    FINISH,
                    &[Value::Float(0.5), Value::Int(tid), Value::Int(tw)],
                ),
                4 => t.exec_all(DELETE, &[Value::Int(tid), Value::Int(tw)]),
                5 | 6 => {
                    let id = next_id;
                    next_id += 1;
                    t.exec_all(
                        INSERT,
                        &[Value::Int(id), Value::Int(id % parts as i64), Value::Float(2.0)],
                    );
                }
                _ => {
                    // a miss: PK exists but the partition-key pred fails
                    t.exec_all(CLAIM_BY_PK, &[Value::Int(tid), Value::Int(tw + 1)]);
                }
            }
        }
        t.assert_fingerprints_equal(&format!("serial stream, {parts} partitions"));
        assert!(
            t.occ.route_counts().occ_dml > 0,
            "the stream must actually commit through OCC at {parts} partitions"
        );
        assert_eq!(
            t.twopl.route_counts().occ_dml,
            0,
            "a TwoPL-mode cluster must never touch the OCC path"
        );
        assert_occ_counters_consistent(&t.occ, "serial stream");
    }
}

// ---------- concurrent claim races ----------

/// Two threads per partition race PK claims over every task; exactly one
/// racer wins each row. Afterwards the OCC cluster must be byte-equal to
/// a 2PL cluster driven through the identical protocol, and the OCC
/// telemetry must reconcile exactly.
#[test]
fn concurrent_occ_claim_races_match_2pl_state() {
    for parts in [1usize, 2, 4, 8] {
        let tasks = 40 * parts as i64;
        let run = |mode: ConcurrencyMode| {
            let c = cluster(parts, clock::wall(), mode);
            seed(&c, tasks, parts);
            let mut handles = Vec::new();
            for t in 0..(parts * 2) as u32 {
                let c = c.clone();
                handles.push(std::thread::spawn(move || {
                    let claim = c.prepare(CLAIM_FIXED).unwrap();
                    let w = (t as usize % parts) as i64;
                    let mut won = 0u64;
                    // every task of this worker, attempted by both racers
                    let mut id = w;
                    while id < tasks {
                        let n = c
                            .exec_prepared(
                                t,
                                AccessKind::UpdateToRunning,
                                &claim,
                                &[Value::Int(id), Value::Int(w)],
                            )
                            .unwrap()
                            .affected();
                        won += n as u64;
                        id += parts as i64;
                    }
                    won
                }));
            }
            let won: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            assert_eq!(
                won, tasks as u64,
                "every task claimed exactly once at {parts} partitions ({mode:?})"
            );
            c
        };
        let occ = run(ConcurrencyMode::Occ);
        let twopl = run(ConcurrencyMode::TwoPL);
        assert_eq!(
            occ.fingerprint().unwrap(),
            twopl.fingerprint().unwrap(),
            "racing OCC claims diverged from racing 2PL claims at {parts} partitions"
        );
        let rc = occ.route_counts();
        assert!(rc.occ_dml > 0, "races must commit through OCC at {parts} partitions");
        assert_eq!(twopl.route_counts().occ_dml, 0);
        assert_occ_counters_consistent(&occ, &format!("{parts}-partition race"));
    }
}

// ---------- failover ----------

/// Kill a node mid-stream, promote its backups, keep claiming: OCC and
/// 2PL must stay byte-equal through the epoch bump, and claims issued
/// while the primary is dead-but-unpromoted must still commit (OCC
/// defers to the interpreted path rather than wedging).
#[test]
fn occ_equals_2pl_under_dead_primary_failover() {
    let parts = 4usize;
    let tasks = 80i64;
    let run = |mode: ConcurrencyMode| {
        let c = cluster(parts, clock::wall(), mode);
        seed(&c, tasks, parts);
        let claim = c.prepare(CLAIM_FIXED).unwrap();
        let fin = c.prepare(FINISH).unwrap();
        // healthy prefix
        for id in 0..tasks / 2 {
            c.exec_prepared(
                0,
                AccessKind::UpdateToRunning,
                &claim,
                &[Value::Int(id), Value::Int(id % parts as i64)],
            )
            .unwrap();
        }
        // node 1 dies; claims in the unpromoted window may or may not
        // commit (OCC defers to the interpreted path there rather than
        // wedging) — tolerate Unavailable, the re-drive below converges
        c.kill_node(1).unwrap();
        for id in tasks / 2..tasks {
            let _ = c.exec_prepared(
                0,
                AccessKind::UpdateToRunning,
                &claim,
                &[Value::Int(id), Value::Int(id % parts as i64)],
            );
        }
        assert!(c.promote_dead_primaries() > 0, "node 1 must have hosted primaries");
        // re-drive against the promoted survivors: the `status = 'READY'`
        // predicate makes this idempotent (0 if the window already
        // claimed it), so both runs converge to the same final state
        for id in tasks / 2..tasks {
            let n = c
                .exec_prepared(
                    0,
                    AccessKind::UpdateToRunning,
                    &claim,
                    &[Value::Int(id), Value::Int(id % parts as i64)],
                )
                .unwrap()
                .affected();
            assert!(n <= 1, "a claim can only land once ({mode:?})");
        }
        for id in 0..tasks / 4 {
            c.exec_prepared(
                0,
                AccessKind::UpdateToFinished,
                &fin,
                &[Value::Float(0.5), Value::Int(id), Value::Int(id % parts as i64)],
            )
            .unwrap();
        }
        c
    };
    let occ = run(ConcurrencyMode::Occ);
    let twopl = run(ConcurrencyMode::TwoPL);
    assert_eq!(
        occ.fingerprint().unwrap(),
        twopl.fingerprint().unwrap(),
        "OCC diverged from 2PL across dead-primary failover"
    );
    assert!(occ.route_counts().occ_dml > 0);
    assert_occ_counters_consistent(&occ, "failover stream");
}

// ---------- kill / restart / rejoin mid-stream ----------

/// The chaos shape, OCC edition: a durable OCC cluster loses a node,
/// restarts it from checkpoint+WAL, and rejoins it while racing claimers
/// keep committing; a never-killed 2PL twin fed the identical committed
/// stream must stay byte-equal at the end. (The CI chaos matrix runs the
/// full generated-stream version of this via `CHAOS_MODE=occ`.)
#[test]
fn occ_claims_survive_kill_restart_rejoin_mid_stream() {
    let parts = 4usize;
    let tasks = 60i64;
    let dir = std::env::temp_dir().join(format!(
        "schaladb-occ-rejoin-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let a = DbCluster::start(
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 4))
            .concurrency(ConcurrencyMode::Occ)
            .build()
            .unwrap(),
    )
    .unwrap();
    a.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    let b = cluster(parts, clock::wall(), ConcurrencyMode::TwoPL);
    seed(&a, tasks, parts);
    seed(&b, tasks, parts);
    let am = AvailabilityManager::new(a.clone());

    // claim a prefix on both, then lose node 1
    for id in 0..tasks / 3 {
        for c in [&a, &b] {
            let claim = c.prepare(CLAIM_FIXED).unwrap();
            c.exec_prepared(
                0,
                AccessKind::UpdateToRunning,
                &claim,
                &[Value::Int(id), Value::Int(id % parts as i64)],
            )
            .unwrap();
        }
    }
    a.kill_node(1).unwrap();
    assert!(am.sweep().unwrap().promoted > 0);
    a.restart_node(1).unwrap();

    // racing claimers drain the remaining tasks on A while the rejoin
    // runs; whatever A commits is replayed on the twin afterwards
    let mut handles = Vec::new();
    for t in 0..4u32 {
        let a = a.clone();
        handles.push(std::thread::spawn(move || {
            let claim = a.prepare(CLAIM_FIXED).unwrap();
            let mut won = Vec::new();
            for id in tasks / 3..tasks {
                let w = id % parts as i64;
                loop {
                    match a.exec_prepared(
                        t,
                        AccessKind::UpdateToRunning,
                        &claim,
                        &[Value::Int(id), Value::Int(w)],
                    ) {
                        Ok(r) => {
                            if r.affected() == 1 {
                                won.push(id);
                            }
                            break;
                        }
                        Err(schaladb::Error::Unavailable(_)) => {
                            std::thread::sleep(std::time::Duration::from_micros(100));
                        }
                        Err(e) => panic!("claim failed during rejoin: {e}"),
                    }
                }
            }
            won
        }));
    }
    let mut rejoined = false;
    for _ in 0..200 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
    }
    let mut all: Vec<i64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert!(rejoined, "node 1 must rejoin under OCC claim load");
    all.sort_unstable();
    let expect: Vec<i64> = (tasks / 3..tasks).collect();
    assert_eq!(all, expect, "each remaining task claimed exactly once across racers");

    // replay the committed tail on the twin, then demand byte-equality
    let claim = b.prepare(CLAIM_FIXED).unwrap();
    for id in tasks / 3..tasks {
        let n = b
            .exec_prepared(
                0,
                AccessKind::UpdateToRunning,
                &claim,
                &[Value::Int(id), Value::Int(id % parts as i64)],
            )
            .unwrap()
            .affected();
        assert_eq!(n, 1);
    }
    assert_eq!(
        a.fingerprint().unwrap(),
        b.fingerprint().unwrap(),
        "OCC cluster diverged from the never-killed 2PL twin across kill/restart/rejoin"
    );
    assert!(a.route_counts().occ_dml > 0);
    assert_occ_counters_consistent(&a, "rejoin stream");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------- fallback / retry accounting under contention ----------

/// Hammer one row from many threads: every increment must land exactly
/// once whatever mix of OCC commits, retries, and 2PL fallbacks the
/// scheduler produces — and the telemetry must account for that mix
/// exactly. (Whether `occ_retries` is nonzero depends on interleaving;
/// the invariants must hold either way.)
#[test]
fn contended_single_row_updates_stay_exact_and_accounted() {
    let c = cluster(1, clock::wall(), ConcurrencyMode::Occ);
    seed(&c, 4, 1);
    const THREADS: usize = 8;
    const PER_THREAD: usize = 50;
    let mut handles = Vec::new();
    for t in 0..THREADS as u32 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let bump = c
                .prepare("UPDATE workqueue SET dur = dur + ? WHERE taskid = ? AND workerid = ?")
                .unwrap();
            for _ in 0..PER_THREAD {
                let n = c
                    .exec_prepared(
                        t,
                        AccessKind::Other,
                        &bump,
                        &[Value::Float(1.0), Value::Int(2), Value::Int(0)],
                    )
                    .unwrap()
                    .affected();
                assert_eq!(n, 1);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let rs = c
        .query_centralized("SELECT dur FROM workqueue WHERE taskid = 2")
        .unwrap();
    assert_eq!(
        rs.rows[0].values[0],
        Value::Float(1.0 + (THREADS * PER_THREAD) as f64),
        "every contended increment must land exactly once"
    );
    let rc = c.route_counts();
    assert!(rc.occ_dml > 0, "single-row contention must still commit via OCC");
    // This shape is always OCC-eligible on a healthy cluster and the row
    // always matches, so every statement completes as exactly one OCC
    // commit or one counted fallback to 2PL — no third bucket.
    assert_eq!(
        rc.occ_dml + rc.occ_fallbacks,
        (THREADS * PER_THREAD) as u64,
        "each contended update is an OCC commit or a counted 2PL fallback"
    );
    assert_occ_counters_consistent(&c, "contended row");
}

/// OCC commits are durable across a whole-cluster stop: claims validated
/// past the write latches land in the WAL like any 2PL commit, so
/// `DbCluster::open` cold-starts the cluster back byte-equal to a 2PL
/// twin — and the reopened cluster keeps validating new OCC claims.
/// Node 1 is left checkpoint-less to force pure WAL replay on its side.
#[test]
fn occ_commits_survive_whole_cluster_cold_start() {
    let parts = 4usize;
    let tasks = 48i64;
    let dir =
        std::env::temp_dir().join(format!("schaladb-occ-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk_config = || {
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 4))
            .concurrency(ConcurrencyMode::Occ)
            .build()
            .unwrap()
    };
    let b = cluster(parts, clock::wall(), ConcurrencyMode::TwoPL);
    seed(&b, tasks, parts);
    let fp_before;
    {
        let a = DbCluster::start(mk_config()).unwrap();
        a.exec(&format!(
            "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
             status TEXT, dur FLOAT, starttime FLOAT) \
             PARTITION BY HASH(workerid) PARTITIONS {parts} \
             PRIMARY KEY (taskid) INDEX (status)"
        ))
        .unwrap();
        seed(&a, tasks, parts);
        let ca = a.prepare(CLAIM_FIXED).unwrap();
        let cb = b.prepare(CLAIM_FIXED).unwrap();
        for id in 0..tasks / 2 {
            let params = [Value::Int(id), Value::Int(id % parts as i64)];
            let na = a.exec_prepared(0, AccessKind::UpdateToRunning, &ca, &params).unwrap();
            let nb = b.exec_prepared(0, AccessKind::UpdateToRunning, &cb, &params).unwrap();
            assert_eq!(na, nb, "claim {id} diverged before the stop");
        }
        assert!(a.route_counts().occ_dml > 0, "claims must go through the OCC tier");
        // checkpoint node 0 only: node 1 must cold-start from WAL replay
        assert!(
            schaladb::storage::checkpoint::checkpoint_node(&a, 0).unwrap().written > 0
        );
        let fa = a.prepare(FINISH).unwrap();
        let fb = b.prepare(FINISH).unwrap();
        for id in 0..tasks / 4 {
            let params =
                [Value::Float(0.5), Value::Int(id), Value::Int(id % parts as i64)];
            let na = a.exec_prepared(0, AccessKind::UpdateToFinished, &fa, &params).unwrap();
            let nb = b.exec_prepared(0, AccessKind::UpdateToFinished, &fb, &params).unwrap();
            assert_eq!(na, nb, "finish {id} diverged before the stop");
        }
        fp_before = a.fingerprint().unwrap();
        // scope end: Arcs drop, node WALs flush — clean whole-cluster stop
    }

    let a = DbCluster::open(mk_config()).unwrap();
    assert_eq!(a.fingerprint().unwrap(), fp_before, "cold start lost OCC commits");
    assert_eq!(a.fingerprint().unwrap(), b.fingerprint().unwrap());

    // the reopened cluster keeps validating fresh OCC claims
    let ca = a.prepare(CLAIM_FIXED).unwrap();
    let cb = b.prepare(CLAIM_FIXED).unwrap();
    for id in tasks / 2..tasks {
        let params = [Value::Int(id), Value::Int(id % parts as i64)];
        let na = a.exec_prepared(0, AccessKind::UpdateToRunning, &ca, &params).unwrap();
        let nb = b.exec_prepared(0, AccessKind::UpdateToRunning, &cb, &params).unwrap();
        assert_eq!(na, nb, "claim {id} diverged after cold start");
    }
    assert!(a.route_counts().occ_dml > 0, "reopened cluster must still run OCC");
    assert_eq!(a.fingerprint().unwrap(), b.fingerprint().unwrap());
    let _ = std::fs::remove_dir_all(&dir);
}
