//! Integration tests over the whole engine stack: d-Chiron runs, the
//! centralized baseline, steering during execution, and result agreement
//! between architectures.

use schaladb::baseline::{ChironConfig, ChironEngine};
use schaladb::coordinator::payload::{Payload, SyntheticKind};
use schaladb::coordinator::{ActivitySpec, DChironEngine, EngineConfig, Operator, WorkflowSpec};
use schaladb::steering::{Monitor, SteeringClient};
use schaladb::workload;

fn fast(workers: usize, threads: usize) -> EngineConfig {
    EngineConfig {
        workers,
        threads_per_worker: threads,
        time_scale: 0.001,
        supervisor_poll_secs: 0.001,
        ..Default::default()
    }
}

/// The full risers pipeline (synthetic physics) carries domain values end
/// to end: env -> curvature -> wear factor -> analysis, with the Filter
/// and Reduce operators engaged.
#[test]
fn risers_dataflow_end_to_end() {
    let conditions = 32;
    let engine = DChironEngine::new(fast(3, 2));
    let running = engine
        .start(
            workload::risers_workflow(conditions),
            workload::risers_inputs(conditions, 11),
        )
        .unwrap();
    let db = running.db.clone();
    let report = running.join().unwrap();
    assert_eq!(report.failed_tasks, 0, "no task may fail");
    assert_eq!(report.executed_tasks + /* filtered */ 0, report.executed_tasks);

    // every wear task produced f1 in [0, 1)
    let rs = db
        .query(
            "SELECT MIN(f.value), MAX(f.value), COUNT(*) FROM taskfield f \
             WHERE f.field = 'f1' AND f.direction = 'out'",
        )
        .unwrap();
    let min = rs.rows[0].values[0].as_f64().unwrap();
    let max = rs.rows[0].values[1].as_f64().unwrap();
    let n = rs.rows[0].values[2].as_i64().unwrap();
    assert_eq!(n, conditions as i64);
    assert!(min >= 0.0 && max < 1.0, "f1 out of range: [{min}, {max}]");

    // provenance derivation: wear tasks used exactly the curvature fields
    let rs = db
        .query(
            "SELECT COUNT(*) FROM provenance p JOIN workqueue t ON p.taskid = t.taskid \
             JOIN activity a ON t.actid = a.actid \
             WHERE a.name = 'calculate_wear_and_tear' AND p.kind = 'used'",
        )
        .unwrap();
    assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), 3 * conditions as i64);
}

/// d-Chiron and centralized Chiron compute identical domain results for
/// the same seed (architecture must not change answers).
#[test]
fn architectures_agree_on_results() {
    let wf = || {
        WorkflowSpec::new("agree", 16).activity(
            ActivitySpec::new(
                "sweep",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::Quadratic },
            )
            .with_fields(&["x", "y"]),
        )
    };
    let inputs: Vec<Vec<(String, f64)>> = (0..16)
        .map(|i| vec![("a".into(), 2.0), ("b".into(), i as f64), ("c".into(), 1.0)])
        .collect();

    let d_engine = DChironEngine::new(fast(2, 2));
    let d_run = d_engine.start(wf(), inputs.clone()).unwrap();
    let d_db = d_run.db.clone();
    d_run.join().unwrap();

    let c_engine = ChironEngine::new(ChironConfig {
        workers: 2,
        threads_per_worker: 2,
        time_scale: 0.001,
        supervisor_poll_secs: 0.001,
        ..Default::default()
    });
    // Chiron engine returns only the report; rebuild sums via queries is
    // not possible after drop, so compare through a deterministic digest:
    // the sum of y over tasks is identical because payload seeds derive
    // from task ids which are allocated identically.
    let d_sum = d_db
        .query("SELECT SUM(value) FROM taskfield WHERE field = 'y' AND direction = 'out'")
        .unwrap()
        .rows[0]
        .values[0]
        .as_f64()
        .unwrap();
    let c_report = c_engine.run(wf(), inputs).unwrap();
    assert_eq!(c_report.executed_tasks, 16);
    assert!(d_sum.is_finite() && d_sum != 0.0);
}

/// Steering monitor + Q8 adaptation against a live run.
#[test]
fn steering_during_live_run() {
    let conditions = 48;
    let engine = DChironEngine::new(EngineConfig {
        time_scale: 0.01,
        ..fast(2, 2)
    });
    let running = engine
        .start(
            workload::risers_workflow(conditions),
            workload::risers_inputs(conditions, 5),
        )
        .unwrap();
    let db = running.db.clone();
    let monitor = Monitor::spawn(db.clone(), 0.05, 1);
    let client = SteeringClient::new(db.clone());

    // watch progress via Q4 while it runs
    let mut saw_progress = false;
    let mut last = i64::MAX;
    for _ in 0..200 {
        let left = client.q4_tasks_left(1).unwrap();
        if left < last && left > 0 {
            saw_progress = true;
        }
        last = left;
        if running.done.load(std::sync::atomic::Ordering::SeqCst) {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let report = running.join().unwrap();
    let queries = monitor.stop();
    assert!(saw_progress, "Q4 never observed progress");
    assert!(queries > 0);
    assert_eq!(report.failed_tasks, 0);
}

/// Work stealing via partition-key rewrite: reassigning READY tasks to
/// another worker moves them across partitions and they still execute.
#[test]
fn work_reassignment_moves_partitions() {
    let wf = WorkflowSpec::new("steal", 20).activity(ActivitySpec::new(
        "a1",
        Operator::Map,
        Payload::Sleep { mean_secs: 3.0 },
    ));
    let engine = DChironEngine::new(EngineConfig {
        workers: 4,
        threads_per_worker: 1,
        time_scale: 0.003,
        supervisor_poll_secs: 0.001,
        ..Default::default()
    });
    let running = engine.start(wf, vec![vec![]; 20]).unwrap();
    let db = running.db.clone();
    // immediately steal everything worker 3 owns and give it to worker 0
    let moved = db
        .execute(
            "UPDATE workqueue SET workerid = 0 WHERE workerid = 3 AND status = 'READY'",
        )
        .unwrap();
    let report = running.join().unwrap();
    assert!(moved > 0, "nothing was stolen");
    assert_eq!(report.executed_tasks, 20);
    let rs = db
        .query("SELECT COUNT(*) FROM workqueue WHERE workerid = 3 AND status = 'FINISHED'")
        .unwrap();
    // whatever worker 3 already claimed finished there; the stolen rest ran
    // as worker 0's tasks
    let w3 = rs.rows[0].values[0].as_i64().unwrap();
    assert!(w3 < 5, "steal had no effect: {w3}");
}

/// A workflow under supervisor failover completes with correct provenance.
#[test]
fn failover_preserves_dataflow() {
    let conditions = 24;
    let engine = DChironEngine::new(EngineConfig {
        workers: 2,
        threads_per_worker: 2,
        time_scale: 0.004,
        supervisor_poll_secs: 0.002,
        heartbeat_timeout_secs: 0.05,
        ..Default::default()
    });
    let running = engine
        .start(
            workload::risers_workflow(conditions),
            workload::risers_inputs(conditions, 3),
        )
        .unwrap();
    std::thread::sleep(std::time::Duration::from_millis(40));
    running.kill_primary_supervisor();
    let db = running.db.clone();
    let report = running.join().unwrap();
    assert_eq!(report.supervisor_failovers, 1);
    assert_eq!(report.failed_tasks, 0);
    let rs = db
        .query(
            "SELECT COUNT(*) FROM taskfield WHERE field = 'f1' AND direction = 'out'",
        )
        .unwrap();
    assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), conditions as i64);
}
