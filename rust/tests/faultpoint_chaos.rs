//! Failpoint chaos gate: drive the claim workload through deterministic
//! fault-injection schedules (`util::failpoint`) covering every
//! durability-critical seam — WAL append/flush/truncate, checkpoint
//! tmp-write/rename, rejoin seed/catch-up/final-cut, rebalance/split cut,
//! cold-start open — and demand the surviving cluster stays **byte-equal**
//! to a never-faulted twin fed the identical committed stream.
//!
//! Beyond the schedule sweep, this suite gates the two recovery paths the
//! failpoints exist to prove out:
//! - **disk loss**: a node restarted with its durability directory wiped
//!   (or its checkpoint corrupted) recovers by shipping the peer replica's
//!   checkpoint + WAL tail cross-node (`RejoinStart::{disk_lost,shipped}`);
//! - **whole-cluster cold start**: `DbCluster::open` round-trips a full
//!   stop — every partition from its newest valid checkpoint plus
//!   torn-tail-tolerant WAL replay, replica pairs reconciled by
//!   (epoch, LSN) — with fingerprint equality, and refuses with a typed
//!   `Error::Recovery` when the directory cannot define a schema.
//!
//! Injected-error semantics: a WAL-commit failpoint fires *after* the
//! in-memory commit installed on both replicas (the engine logs after the
//! latched apply), so the driver treats an injected commit error as
//! committed and mirrors the op to the twin — recovery then proves the
//! durability hole is healed from the serving replicas' memory, not from
//! the torn log.
//!
//! The CI `fault-matrix` job runs this under `FAULT_SEED` × `FAULT_MODE`
//! (`2pl` | `occ`); a plain `cargo test` sweeps a small built-in matrix.
//! Failpoints are process-global, so every test here serializes on one
//! gate and resets the registry on both sides.

use schaladb::storage::checkpoint::checkpoint_node;
use schaladb::storage::cluster::{ClusterConfig, ConcurrencyMode, DurabilityConfig};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, DbCluster, Prepared, Value};
use schaladb::util::failpoint::{self, Action};
use std::sync::{Arc, Mutex, MutexGuard};

static GATE: Mutex<()> = Mutex::new(());

/// Serialize a test against the process-global failpoint registry: take
/// the gate, reset on entry, and reset again when dropped so a panicking
/// test cannot leak an armed failpoint into the next one.
struct Serial(#[allow(dead_code)] MutexGuard<'static, ()>);

fn serial() -> Serial {
    let g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    failpoint::reset();
    Serial(g)
}

impl Drop for Serial {
    fn drop(&mut self) {
        failpoint::reset();
    }
}

fn one_shot_err() -> Action {
    Action::OneShot(Box::new(Action::Err))
}

/// Is this the error a fired `Err`-action failpoint injects?
fn is_injected(e: &schaladb::Error) -> bool {
    e.to_string().contains("failpoint")
}

/// Deterministic LCG so every (seed, mode) cell replays identically.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.0 >> 11
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n.max(1)
    }
}

const PARTS: usize = 4;

fn schema(c: &DbCluster) {
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {PARTS} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE prov (provid INT NOT NULL, taskid INT, note TEXT) PRIMARY KEY (provid)")
        .unwrap();
}

struct Stmts {
    insert: Prepared,
    claim: Prepared,
    finish: Prepared,
    delete: Prepared,
    prov: Prepared,
}

impl Stmts {
    fn prepare(c: &DbCluster) -> Stmts {
        Stmts {
            insert: c
                .prepare(
                    "INSERT INTO workqueue (taskid, workerid, status, dur) \
                     VALUES (?, ?, 'READY', ?)",
                )
                .unwrap(),
            claim: c
                .prepare(
                    "UPDATE workqueue SET status = 'RUNNING' \
                     WHERE taskid = ? AND workerid = ? AND status = 'READY'",
                )
                .unwrap(),
            finish: c
                .prepare(
                    "UPDATE workqueue SET status = 'FINISHED', dur = dur + 1.5 \
                     WHERE taskid = ? AND workerid = ?",
                )
                .unwrap(),
            delete: c
                .prepare("DELETE FROM workqueue WHERE taskid = ? AND workerid = ?")
                .unwrap(),
            prov: c
                .prepare("INSERT INTO prov (provid, taskid, note) VALUES (?, ?, ?)")
                .unwrap(),
        }
    }
}

#[derive(Clone, Debug)]
enum Op {
    Insert { id: i64, worker: i64, dur: f64 },
    Claim { id: i64, worker: i64 },
    Finish { id: i64, worker: i64 },
    Delete { id: i64, worker: i64 },
    Prov { id: i64, task: i64, note: String },
}

fn apply(c: &DbCluster, s: &Stmts, op: &Op) -> schaladb::Result<usize> {
    let r = match op {
        Op::Insert { id, worker, dur } => c.exec_prepared(
            0,
            AccessKind::InsertTasks,
            &s.insert,
            &[Value::Int(*id), Value::Int(*worker), Value::Float(*dur)],
        )?,
        Op::Claim { id, worker } => c.exec_prepared(
            0,
            AccessKind::UpdateToRunning,
            &s.claim,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Finish { id, worker } => c.exec_prepared(
            0,
            AccessKind::UpdateToFinished,
            &s.finish,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Delete { id, worker } => c.exec_prepared(
            0,
            AccessKind::Other,
            &s.delete,
            &[Value::Int(*id), Value::Int(*worker)],
        )?,
        Op::Prov { id, task, note } => c.exec_prepared(
            0,
            AccessKind::InsertProvenance,
            &s.prov,
            &[Value::Int(*id), Value::Int(*task), Value::str(note.clone())],
        )?,
    };
    Ok(r.affected())
}

/// Streams ops into A (the fault victim); every op A commits — including
/// ops whose WAL logging was killed by an injected failpoint *after* the
/// in-memory commit — is mirrored to B, the never-faulted twin.
struct Driver {
    a: Arc<DbCluster>,
    b: Arc<DbCluster>,
    sa: Stmts,
    sb: Stmts,
    rng: Rng,
    next_id: i64,
    next_prov: i64,
    /// (taskid, workerid) of rows believed live on both clusters.
    live: Vec<(i64, i64)>,
    /// Ops whose commit was torn by an injected WAL error (committed in
    /// memory, durability hole) — mirrored to the twin anyway.
    injected_commits: usize,
}

impl Driver {
    fn new(a: Arc<DbCluster>, b: Arc<DbCluster>, seed: u64, id_base: i64) -> Driver {
        let sa = Stmts::prepare(&a);
        let sb = Stmts::prepare(&b);
        Driver {
            a,
            b,
            sa,
            sb,
            rng: Rng(seed.wrapping_mul(0x9e3779b97f4a7c15) | 1),
            next_id: id_base,
            next_prov: id_base,
            live: Vec::new(),
            injected_commits: 0,
        }
    }

    fn gen(&mut self) -> Op {
        let roll = self.rng.below(10);
        if self.live.is_empty() || roll < 4 {
            let id = self.next_id;
            self.next_id += 1;
            return Op::Insert {
                id,
                worker: self.rng.below(PARTS as u64) as i64,
                dur: (self.rng.below(1000) as f64) / 8.0,
            };
        }
        let pick = self.rng.below(self.live.len() as u64) as usize;
        let (id, worker) = self.live[pick];
        match roll {
            4 | 5 => Op::Claim { id, worker },
            6 => Op::Finish { id, worker },
            7 => Op::Delete { id, worker },
            _ => {
                let pid = self.next_prov;
                self.next_prov += 1;
                Op::Prov { id: pid, task: id, note: format!("note {pid}") }
            }
        }
    }

    fn bookkeep(&mut self, op: &Op, affected: usize) {
        match op {
            Op::Insert { id, worker, .. } if affected > 0 => self.live.push((*id, *worker)),
            Op::Delete { id, .. } if affected > 0 => self.live.retain(|(i, _)| i != id),
            _ => {}
        }
    }

    fn drive(&mut self, n: usize) {
        for _ in 0..n {
            let op = self.gen();
            match apply(&self.a, &self.sa, &op) {
                Ok(affected_a) => {
                    let affected_b =
                        apply(&self.b, &self.sb, &op).expect("twin must accept mirrored op");
                    assert_eq!(
                        affected_a, affected_b,
                        "twin diverged on {op:?}: {affected_a} != {affected_b}"
                    );
                    self.bookkeep(&op, affected_a);
                }
                // A fired WAL-commit failpoint surfaces after the latched
                // in-memory apply installed on both replicas: the op IS
                // committed, only its log record is torn. Mirror it.
                Err(e) if is_injected(&e) => {
                    self.injected_commits += 1;
                    let affected_b =
                        apply(&self.b, &self.sb, &op).expect("twin must accept mirrored op");
                    self.bookkeep(&op, affected_b);
                }
                Err(schaladb::Error::Unavailable(_)) => { /* committed nowhere */ }
                Err(e) => panic!("unexpected failure on {op:?}: {e}"),
            }
        }
    }

    /// Drive until the named (armed) failpoint fires, bounded.
    fn drive_until_hit(&mut self, name: &str, max_ops: usize) {
        let before = failpoint::hits(name);
        for _ in 0..max_ops {
            self.drive(1);
            if failpoint::hits(name) > before {
                return;
            }
        }
        panic!("failpoint '{name}' never fired within {max_ops} ops");
    }
}

fn fingerprints_equal(a: &DbCluster, b: &DbCluster) {
    let fa = a.fingerprint().unwrap();
    let fb = b.fingerprint().unwrap();
    assert!(!fa.is_empty());
    assert_eq!(fa, fb, "fault victim diverged from the never-faulted twin");
}

/// Point-DML concurrency mode for the victim, from `FAULT_MODE`
/// (`2pl` | `occ`, default 2PL). The CI fault-matrix sets it.
fn fault_mode() -> ConcurrencyMode {
    std::env::var("FAULT_MODE")
        .ok()
        .and_then(|s| ConcurrencyMode::from_name(&s))
        .unwrap_or_default()
}

fn fault_seeds() -> Vec<u64> {
    match std::env::var("FAULT_SEED").ok().and_then(|s| s.parse().ok()) {
        Some(s) => vec![s],
        None => vec![1, 2],
    }
}

fn tmpdir(tag: &str, seed: u64) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "schaladb-fault-{tag}-s{seed}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn victim(dir: &std::path::Path, group_commit: usize) -> Arc<DbCluster> {
    DbCluster::start(
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.to_path_buf(), group_commit))
            .concurrency(fault_mode())
            .build()
            .unwrap(),
    )
    .unwrap()
}

/// The tentpole gate: one seed's full failpoint schedule. Every armed
/// site is proven to fire (hit counter), every recovery ends byte-equal
/// to the twin.
fn run_schedule(seed: u64) {
    let dir = tmpdir("sched", seed);
    let a = victim(&dir, 8);
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a);
    schema(&b);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), seed, 0);

    // Healthy prefix + durable baseline.
    d.drive(250);
    fingerprints_equal(&a, &b);
    assert!(checkpoint_node(&a, 0).unwrap().written > 0);
    assert!(checkpoint_node(&a, 1).unwrap().written > 0);

    // --- WAL seams, fired from inside the live claim stream ---
    for site in ["wal-append-before-flush", "wal-flush"] {
        failpoint::set(site, one_shot_err());
        d.drive_until_hit(site, 400);
        fingerprints_equal(&a, &b);
    }
    assert!(d.injected_commits > 0, "WAL failpoints must tear real commits");

    // --- checkpoint seams: the cut fails cleanly, a retry succeeds ---
    for site in [
        "ckpt-before-tmp-write",
        "ckpt-after-tmp-write",
        "ckpt-after-rename",
        "wal-truncate",
    ] {
        d.drive(30); // make the incremental checkpoint have work to do
        failpoint::set(site, one_shot_err());
        let r = checkpoint_node(&a, 0);
        assert!(r.is_err(), "armed {site} must fail the checkpoint: {r:?}");
        assert_eq!(failpoint::hits(site), 1, "{site} must have fired exactly once");
        checkpoint_node(&a, 0).unwrap_or_else(|e| panic!("retry after {site} failed: {e}"));
        fingerprints_equal(&a, &b);
    }

    // --- rejoin seams, cycle 1: seed + catch-up ---
    let epoch0 = a.cluster_epoch();
    a.kill_node(1).unwrap();
    assert!(am.sweep().unwrap().promoted > 0);
    assert!(a.cluster_epoch() > epoch0);
    d.drive(100);

    failpoint::set("rejoin-seed", one_shot_err());
    let r = a.restart_node(1);
    assert!(r.is_err(), "armed rejoin-seed must fail the restart: {r:?}");
    assert_eq!(failpoint::hits("rejoin-seed"), 1);
    // the failed restart left the node dead and retryable
    let start = a.restart_node(1).unwrap();
    assert!(start.partitions > 0);
    assert!(start.from_checkpoint > 0, "phase-1 checkpoints must be found: {start:?}");

    failpoint::set("rejoin-catchup", one_shot_err());
    let r = am.sweep();
    assert!(r.is_err(), "armed rejoin-catchup must surface through the sweep: {r:?}");
    assert_eq!(failpoint::hits("rejoin-catchup"), 1);
    let mut rejoined = false;
    for _ in 0..50 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
    }
    assert!(rejoined, "node 1 must rejoin after the catch-up failpoint cleared");
    d.drive(60);
    fingerprints_equal(&a, &b);

    // --- rejoin seams, cycle 2: the final cut itself ---
    // (no promoted assert: after the first rejoin node 1 may be
    // backup-only, so killing it promotes nothing)
    a.kill_node(1).unwrap();
    am.sweep().unwrap();
    d.drive(60);
    a.restart_node(1).unwrap();
    failpoint::set("rejoin-final-cut", one_shot_err());
    let r = am.sweep().unwrap();
    assert_eq!(r.rejoined, 0, "armed rejoin-final-cut must defer the hand-off");
    assert_eq!(failpoint::hits("rejoin-final-cut"), 1);
    let mut rejoined = false;
    for _ in 0..50 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
    }
    assert!(rejoined, "node 1 must rejoin once the final cut is clean");
    d.drive(60);
    fingerprints_equal(&a, &b);

    // --- admin seams: rebalance and split cuts fail typed, retry clean ---
    let new_node = a.add_node().unwrap();
    failpoint::set("rebalance-cut", one_shot_err());
    let r = a.rebalance_partition("workqueue", 0, new_node);
    assert!(r.is_err(), "armed rebalance-cut must fail the move: {r:?}");
    assert_eq!(failpoint::hits("rebalance-cut"), 1);
    a.rebalance_partition("workqueue", 0, new_node).unwrap();
    d.drive(40);
    fingerprints_equal(&a, &b);

    failpoint::set("split-cut", one_shot_err());
    let r = a.split_partition("workqueue", 0);
    assert!(r.is_err(), "armed split-cut must fail the split: {r:?}");
    assert_eq!(failpoint::hits("split-cut"), 1);
    a.split_partition("workqueue", 0).unwrap();
    d.drive(40);
    am.sweep().unwrap();
    fingerprints_equal(&a, &b);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn failpoint_schedule_survivor_equals_twin() {
    let _g = serial();
    for seed in fault_seeds() {
        failpoint::reset();
        run_schedule(seed);
    }
}

/// Disk loss: node 1 restarts with its durability directory wiped. The
/// restart detects the loss, ships the peer replica's checkpoint + WAL
/// tail cross-node, rejoins, and stays byte-equal — then survives being
/// promoted to serve everything.
#[test]
fn wiped_durability_dir_recovers_via_peer_shipping() {
    let _g = serial();
    let seed = fault_seeds()[0];
    let dir = tmpdir("wipe", seed);
    let a = victim(&dir, 8);
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a);
    schema(&b);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), seed, 0);

    d.drive(300);
    assert!(checkpoint_node(&a, 0).unwrap().written > 0);
    assert!(checkpoint_node(&a, 1).unwrap().written > 0);
    d.drive(150);

    a.kill_node(1).unwrap();
    assert!(am.sweep().unwrap().promoted > 0);
    d.drive(50);

    // the disk is gone: nothing local survives the restart
    std::fs::remove_dir_all(dir.join("node1")).unwrap();
    let start = a.restart_node(1).unwrap();
    assert!(start.disk_lost, "missing durability dir must be detected: {start:?}");
    assert!(start.shipped > 0, "recovery must ship from the peer: {start:?}");
    assert!(
        start.from_checkpoint > 0,
        "shipped checkpoints must actually load: {start:?}"
    );

    let mut rejoined = false;
    for _ in 0..50 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
    }
    assert!(rejoined, "disk-loss node must rejoin via shipped state");
    d.drive(80);
    fingerprints_equal(&a, &b);

    // the shipped replicas are faithful enough to serve everything
    a.kill_node(0).unwrap();
    assert!(am.sweep().unwrap().promoted > 0);
    fingerprints_equal(&a, &b);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt-checkpoint fallback: flip a byte in a checkpoint file; the
/// restart detects the checksum mismatch, discards the file (never loads
/// garbage), recovers that partition from the peer, and stays byte-equal.
#[test]
fn corrupt_checkpoint_is_detected_and_recovered_from_peer() {
    let _g = serial();
    let seed = fault_seeds()[0];
    let dir = tmpdir("corrupt", seed);
    let a = victim(&dir, 8);
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&a);
    schema(&b);
    let am = AvailabilityManager::new(a.clone());
    let mut d = Driver::new(a.clone(), b.clone(), seed, 0);

    d.drive(250);
    assert!(checkpoint_node(&a, 0).unwrap().written > 0);
    assert!(checkpoint_node(&a, 1).unwrap().written > 0);
    d.drive(100);

    a.kill_node(1).unwrap();
    assert!(am.sweep().unwrap().promoted > 0);

    // flip one byte in the middle of node 1's largest checkpoint
    let target = largest_ckpt(&dir.join("node1"));
    let mut bytes = std::fs::read(&target).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x5a;
    std::fs::write(&target, &bytes).unwrap();

    let start = a.restart_node(1).unwrap();
    assert!(
        start.ckpt_rejected >= 1,
        "the flipped checkpoint must fail its checksum: {start:?}"
    );
    assert!(
        !target.is_file(),
        "a rejected checkpoint must be discarded, not left to re-poison restarts"
    );

    let mut rejoined = false;
    for _ in 0..50 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
    }
    assert!(rejoined, "corrupt-checkpoint node must still rejoin");
    d.drive(60);
    am.sweep().unwrap();
    fingerprints_equal(&a, &b);
    let _ = std::fs::remove_dir_all(&dir);
}

fn largest_ckpt(node_dir: &std::path::Path) -> std::path::PathBuf {
    std::fs::read_dir(node_dir)
        .unwrap()
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().map_or(false, |x| x == "ckpt"))
        .max_by_key(|p| p.metadata().map(|m| m.len()).unwrap_or(0))
        .expect("node dir must hold at least one checkpoint")
}

/// Whole-cluster cold start: stop everything, `DbCluster::open` the
/// durability dir, and the reopened cluster fingerprints byte-equal to
/// both the pre-shutdown state and the live twin — then keeps committing.
/// Node 1 is deliberately left checkpoint-less so its replicas rebuild
/// from origin-covering WAL replay alone.
#[test]
fn cold_start_round_trips_full_cluster_stop() {
    let _g = serial();
    let seed = fault_seeds()[0];
    let dir = tmpdir("cold", seed);
    let mk_config = || {
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 4))
            .concurrency(fault_mode())
            .build()
            .unwrap()
    };
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&b);
    let fp_before;
    {
        let a = DbCluster::start(mk_config()).unwrap();
        schema(&a);
        let mut d = Driver::new(a.clone(), b.clone(), seed, 0);
        d.drive(300);
        // checkpoint node 0 only: node 1 cold-starts from pure WAL replay
        assert!(checkpoint_node(&a, 0).unwrap().written > 0);
        d.drive(150);
        fp_before = a.fingerprint().unwrap();
        // d (and its Arc clones) drops here; dropping the last Arc drops
        // the NodeWals, whose Drop flushes the buffered group-commit tail
    }

    // the cold-start seam itself is a failpoint site
    failpoint::set("cold-start-open", one_shot_err());
    let r = DbCluster::open(mk_config());
    assert!(r.is_err(), "armed cold-start-open must refuse the open");
    assert_eq!(failpoint::hits("cold-start-open"), 1);

    let a = DbCluster::open(mk_config()).unwrap();
    assert!(a.cluster_epoch() > 0, "cold start must re-stamp a fresh epoch");
    assert_eq!(a.fingerprint().unwrap(), fp_before, "cold start lost committed state");
    fingerprints_equal(&a, &b);

    // the reopened cluster is live: keep committing, stay byte-equal
    let mut d = Driver::new(a.clone(), b.clone(), seed + 17, 1_000_000);
    d.drive(150);
    fingerprints_equal(&a, &b);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Cold start skips (never loads) a corrupt checkpoint and rebuilds that
/// partition from the other replica's files.
#[test]
fn cold_start_skips_corrupt_checkpoint() {
    let _g = serial();
    let seed = fault_seeds()[0];
    let dir = tmpdir("coldcorrupt", seed);
    let mk_config = || {
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 4))
            .build()
            .unwrap()
    };
    let b = DbCluster::start(ClusterConfig::default()).unwrap();
    schema(&b);
    let fp_before;
    {
        let a = DbCluster::start(mk_config()).unwrap();
        schema(&a);
        let mut d = Driver::new(a.clone(), b.clone(), seed, 0);
        d.drive(200);
        assert!(checkpoint_node(&a, 0).unwrap().written > 0);
        assert!(checkpoint_node(&a, 1).unwrap().written > 0);
        d.drive(100);
        fp_before = a.fingerprint().unwrap();
    }

    let target = largest_ckpt(&dir.join("node0"));
    let mut bytes = std::fs::read(&target).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x5a;
    std::fs::write(&target, &bytes).unwrap();

    let a = DbCluster::open(mk_config()).unwrap();
    assert_eq!(
        a.fingerprint().unwrap(),
        fp_before,
        "cold start must recover the corrupted partition from the peer replica"
    );
    fingerprints_equal(&a, &b);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Cold start refuses, with the typed `Error::Recovery`, when it cannot
/// proceed safely: no durability config at all, or WAL segments whose
/// schema no readable checkpoint defines.
#[test]
fn cold_start_refuses_undefinable_state() {
    let _g = serial();
    let r = DbCluster::open(ClusterConfig::default());
    assert!(
        matches!(r, Err(schaladb::Error::Recovery(_))),
        "open without durability must refuse typed"
    );

    let seed = fault_seeds()[0];
    let dir = tmpdir("refuse", seed);
    let mk_config = || {
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 4))
            .build()
            .unwrap()
    };
    {
        let a = DbCluster::start(mk_config()).unwrap();
        schema(&a);
        let b = DbCluster::start(ClusterConfig::default()).unwrap();
        schema(&b);
        let mut d = Driver::new(a.clone(), b.clone(), seed, 0);
        d.drive(80);
        // no checkpoint is ever cut: on disk there are only WAL segments
    }
    let r = DbCluster::open(mk_config());
    match r {
        Err(schaladb::Error::Recovery(m)) => {
            assert!(m.contains("no readable checkpoint"), "unexpected refusal: {m}")
        }
        other => panic!("WAL-without-schema must refuse typed, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
