//! Wire-protocol round-trip gate: concurrent TCP clients (claim workers,
//! steering scanners, and an open multi-statement transaction) against a
//! `server::Server`, with an in-process twin cluster fed the identical
//! committed stream — final `fingerprint()` must be byte-equal. Plus the
//! hostile-input suite (malformed, oversize, and torn frames; abrupt
//! disconnect with an open txn) proving the server never panics and the
//! dropped session's transaction rolls back, and the failover regression:
//! prepared handles held by remote sessions keep working across a data
//! node kill → promotion → restart → rejoin.

use schaladb::server::wire::{self, Request, Response};
use schaladb::server::{Client, Server, ServerConfig};
use schaladb::storage::cluster::{ClusterConfig, DurabilityConfig};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, DbCluster, StatementResult, Value};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const WORKERS: usize = 8;
const TASKS_PER_WORKER: usize = 25;

fn any_addr() -> std::net::SocketAddr {
    "127.0.0.1:0".parse().unwrap()
}

fn schema_sql() -> String {
    format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {WORKERS} \
         PRIMARY KEY (taskid) INDEX (status)"
    )
}

fn seed_rows() -> Vec<Vec<Value>> {
    (0..WORKERS * TASKS_PER_WORKER)
        .map(|i| {
            vec![
                Value::Int(i as i64),
                Value::Int((i % WORKERS) as i64),
                Value::Float(1.0),
            ]
        })
        .collect()
}

const SEED_SQL: &str =
    "INSERT INTO workqueue (taskid, workerid, status, dur) VALUES (?, ?, 'READY', ?)";

const CLAIM_SQL: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
     WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
     RETURNING taskid";

/// The tentpole gate: 8 remote claim workers + 2 remote steering scanners
/// + 1 remote multi-statement transaction, all concurrent, against an
/// in-process twin running the identical committed stream. Byte-equal at
/// the end, observed *over the wire* via the Stats fingerprint.
#[test]
fn remote_multi_client_run_matches_in_process_twin() {
    let cluster = DbCluster::start(ClusterConfig::default()).unwrap();
    let server = Server::bind(any_addr(), cluster, ServerConfig::default()).unwrap();
    let addr = server.local_addr();
    let twin = DbCluster::start(ClusterConfig::default()).unwrap();

    // identical schema + seed on both sides; the server side entirely
    // over the wire (DDL via ExecSql, seed via prepared batch insert)
    let mut admin = Client::connect(addr, 0, AccessKind::Other).unwrap();
    admin.exec_sql(&schema_sql()).unwrap();
    let (ins, nparams) = admin.prepare(SEED_SQL).unwrap();
    assert_eq!(nparams, 3);
    let r = admin.exec_batch(ins, AccessKind::InsertTasks, &seed_rows()).unwrap();
    assert_eq!(r.affected(), WORKERS * TASKS_PER_WORKER);

    twin.exec(&schema_sql()).unwrap();
    let tins = twin.prepare(SEED_SQL).unwrap();
    twin.exec_prepared_batch(0, AccessKind::InsertTasks, &tins, &seed_rows()).unwrap();

    // steering scanners: read-only, run until the claims are done
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let mut scanners = Vec::new();
    for _ in 0..2 {
        let stop = stop.clone();
        scanners.push(std::thread::spawn(move || {
            let mut c = Client::connect(addr, 0, AccessKind::Steering).unwrap();
            let mut scans = 0usize;
            while !stop.load(std::sync::atomic::Ordering::SeqCst) {
                let rs = c
                    .query("SELECT status, COUNT(*) FROM workqueue GROUP BY status")
                    .unwrap();
                assert!(!rs.rows.is_empty());
                scans += 1;
            }
            c.close().unwrap();
            scans
        }));
    }

    // one client holds an open multi-statement txn concurrent with the
    // claims; it touches only `dur` (commutes with the status claims) so
    // the twin can apply it at any point in its sequential stream
    let txn_client = std::thread::spawn(move || {
        let mut c = Client::connect(addr, 0, AccessKind::Other).unwrap();
        // a rolled-back txn first: must leave no trace in the fingerprint
        c.begin().unwrap();
        c.txn_sql("UPDATE workqueue SET dur = 999.0 WHERE taskid = 0").unwrap();
        c.rollback().unwrap();
        c.begin().unwrap();
        let (bump, _) =
            c.prepare("UPDATE workqueue SET dur = dur + ? WHERE taskid = ?").unwrap();
        c.txn_prepared(bump, &[Value::Float(1.0), Value::Int(0)]).unwrap();
        c.txn_prepared(bump, &[Value::Float(2.0), Value::Int(1)]).unwrap();
        c.txn_sql("UPDATE workqueue SET dur = dur + 4.0 WHERE taskid = 2").unwrap();
        let results = c.commit(AccessKind::Other).unwrap();
        assert_eq!(results.len(), 3);
        c.close().unwrap();
    });

    // 8 concurrent claim workers, each draining its own partition
    let mut claimers = Vec::new();
    for w in 0..WORKERS {
        claimers.push(std::thread::spawn(move || {
            let mut c =
                Client::connect(addr, w as u32, AccessKind::UpdateToRunning).unwrap();
            let (claim, _) = c.prepare(CLAIM_SQL).unwrap();
            let mut claimed = 0usize;
            loop {
                match c.exec(claim, &[Value::Int(w as i64)]).unwrap() {
                    StatementResult::Rows(rs) if !rs.rows.is_empty() => claimed += 1,
                    _ => break,
                }
            }
            c.close().unwrap();
            claimed
        }));
    }
    let claimed: usize = claimers.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(claimed, WORKERS * TASKS_PER_WORKER);
    txn_client.join().unwrap();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    for s in scanners {
        assert!(s.join().unwrap() > 0, "scanner never completed a scan");
    }

    // the twin replays the same committed stream sequentially
    let tclaim = twin.prepare(CLAIM_SQL).unwrap();
    for w in 0..WORKERS {
        loop {
            let r = twin
                .exec_prepared(
                    w as u32,
                    AccessKind::UpdateToRunning,
                    &tclaim,
                    &[Value::Int(w as i64)],
                )
                .unwrap();
            match r {
                StatementResult::Rows(rs) if !rs.rows.is_empty() => {}
                _ => break,
            }
        }
    }
    let tbump = twin.prepare("UPDATE workqueue SET dur = dur + ? WHERE taskid = ?").unwrap();
    let tbump4 =
        twin.prepare("UPDATE workqueue SET dur = dur + 4.0 WHERE taskid = 2").unwrap();
    twin.exec_txn(
        0,
        AccessKind::Other,
        &[
            tbump.bind(&[Value::Float(1.0), Value::Int(0)]).unwrap(),
            tbump.bind(&[Value::Float(2.0), Value::Int(1)]).unwrap(),
            tbump4.bind(&[]).unwrap(),
        ],
    )
    .unwrap();

    // byte-equality, observed over the wire
    let stats = admin.stats(true, true).unwrap();
    assert_eq!(stats.fingerprint.as_deref(), Some(twin.fingerprint().unwrap().as_str()));
    assert_eq!(
        stats.table_rows,
        vec![("workqueue".to_string(), (WORKERS * TASKS_PER_WORKER) as u64)]
    );
    // adoption telemetry crossed the wire too: the remote claim loop must
    // have driven the compiled DML fast path
    assert!(stats.fast_dml > 0, "remote claims should take the fast path");
    assert!(stats.scatter > 0, "remote steering scans should scatter-gather");
    admin.close().unwrap();
}

/// Malformed and hostile frames: typed errors or clean closes, never a
/// panic, and the server keeps serving other clients afterwards.
#[test]
fn hostile_frames_never_kill_the_server() {
    let cluster = DbCluster::start(ClusterConfig::default()).unwrap();
    let server = Server::bind(any_addr(), cluster, ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    // (a) first frame with a corrupted checksum: the stream is
    // unsynchronized, the server just closes it
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let payload = Request::Hello {
            proto: wire::PROTO_VERSION,
            node: 0,
            kind: AccessKind::Other,
        }
        .encode();
        let mut buf = Vec::new();
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&(wire::checksum(&payload) ^ 0xdead_beef).to_le_bytes());
        buf.extend_from_slice(&payload);
        use std::io::Write as _;
        s.write_all(&buf).unwrap();
        // server closes without a panic: read drains to EOF
        let got = wire::read_frame(&mut s);
        assert!(matches!(got, Ok(None) | Err(_)), "got {got:?}");
    }

    // (b) a well-framed garbage payload after a valid handshake: typed
    // protocol error, connection stays usable
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let hello = Request::Hello {
            proto: wire::PROTO_VERSION,
            node: 0,
            kind: AccessKind::Other,
        };
        wire::write_frame(&mut s, &hello.encode()).unwrap();
        let resp = wire::read_frame(&mut s).unwrap().unwrap();
        assert!(matches!(
            Response::decode(&resp).unwrap(),
            Response::HelloOk { .. }
        ));
        wire::write_frame(&mut s, &[0x7f, 1, 2, 3]).unwrap(); // unknown tag
        let resp = Response::decode(&wire::read_frame(&mut s).unwrap().unwrap()).unwrap();
        assert!(matches!(resp, Response::Err { code: wire::ErrCode::Protocol, .. }));
        // same connection still serves real requests
        wire::write_frame(
            &mut s,
            &Request::Stats { fingerprint: false, tables: false }.encode(),
        )
        .unwrap();
        let resp = Response::decode(&wire::read_frame(&mut s).unwrap().unwrap()).unwrap();
        assert!(matches!(resp, Response::Stats(_)));
    }

    // (c) an oversize length prefix: one typed error frame, then close
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let hello = Request::Hello {
            proto: wire::PROTO_VERSION,
            node: 0,
            kind: AccessKind::Other,
        };
        wire::write_frame(&mut s, &hello.encode()).unwrap();
        wire::read_frame(&mut s).unwrap().unwrap();
        use std::io::Write as _;
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&buf).unwrap();
        let resp = Response::decode(&wire::read_frame(&mut s).unwrap().unwrap()).unwrap();
        assert!(matches!(resp, Response::Err { .. }));
        assert!(wire::read_frame(&mut s).unwrap().is_none(), "server must hang up");
    }

    // (d) wrong protocol version: typed error, not a hang
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let hello = Request::Hello { proto: 999, node: 0, kind: AccessKind::Other };
        wire::write_frame(&mut s, &hello.encode()).unwrap();
        let resp = Response::decode(&wire::read_frame(&mut s).unwrap().unwrap()).unwrap();
        match resp {
            Response::Err { code, message } => {
                assert_eq!(code, wire::ErrCode::Protocol);
                assert!(message.contains("version"));
            }
            other => panic!("{other:?}"),
        }
    }

    // after all of that, a normal client still gets served
    let mut c = Client::connect(addr, 0, AccessKind::Other).unwrap();
    c.exec_sql("CREATE TABLE t (id INT NOT NULL) PRIMARY KEY (id)").unwrap();
    c.exec_sql("INSERT INTO t (id) VALUES (1)").unwrap();
    let rs = c.query("SELECT COUNT(*) FROM t").unwrap();
    assert_eq!(rs.rows[0].values[0], Value::Int(1));
    c.close().unwrap();
}

/// Abrupt disconnect with an open transaction: the deferred queue dies
/// with the session and nothing was applied — rollback by construction.
#[test]
fn abrupt_disconnect_rolls_back_the_open_txn() {
    let cluster = DbCluster::start(ClusterConfig::default()).unwrap();
    let server = Server::bind(any_addr(), cluster.clone(), ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    let mut admin = Client::connect(addr, 0, AccessKind::Other).unwrap();
    admin
        .exec_sql("CREATE TABLE acct (id INT NOT NULL, bal INT NOT NULL) PRIMARY KEY (id)")
        .unwrap();
    admin.exec_sql("INSERT INTO acct (id, bal) VALUES (1, 100)").unwrap();

    let doomed = {
        let mut c = Client::connect(addr, 3, AccessKind::Other).unwrap();
        c.begin().unwrap();
        // acked by the server, so it is queued server-side before the drop
        c.txn_sql("UPDATE acct SET bal = 0 WHERE id = 1").unwrap();
        c.txn_sql("DELETE FROM acct WHERE id = 1").unwrap();
        c
    };
    drop(doomed); // vanish without Close, txn still open

    // nothing was applied (deferred execution): state is untouched,
    // regardless of how quickly the server notices the disconnect
    let rs = admin.query("SELECT bal FROM acct WHERE id = 1").unwrap();
    assert_eq!(rs.rows[0].values[0], Value::Int(100));

    // and the handler thread exits: the session count drains to 1 (admin)
    let mut drained = false;
    for _ in 0..500 {
        if admin.stats(false, false).unwrap().sessions <= 1 {
            drained = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(drained, "disconnected session never reaped");
    admin.close().unwrap();
}

/// The backpressure rule: beyond `max_conns` concurrent connections the
/// accept loop answers with a typed error frame instead of queueing.
#[test]
fn connections_beyond_max_conns_are_rejected_with_backpressure() {
    let cluster = DbCluster::start(ClusterConfig::default()).unwrap();
    let server = Server::bind(
        any_addr(),
        cluster,
        ServerConfig { max_conns: 1, ..ServerConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr();

    let held = Client::connect(addr, 0, AccessKind::Other).unwrap();
    let rejected = Client::connect(addr, 1, AccessKind::Other);
    match rejected {
        Err(schaladb::Error::Unavailable(msg)) => {
            assert!(msg.contains("backpressure"), "unexpected message: {msg}")
        }
        other => panic!("expected backpressure rejection, got {other:?}"),
    }

    // freeing the slot re-admits new clients
    held.close().unwrap();
    let mut ok = None;
    for _ in 0..500 {
        match Client::connect(addr, 1, AccessKind::Other) {
            Ok(c) => {
                ok = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
    ok.expect("slot never freed after close").close().unwrap();
}

/// `--conn-timeout-secs`: an idle connection is dropped once a frame read
/// outlives the per-connection deadline, and the drop is typed — counted
/// in `Counter::ConnTimeouts`, not lumped in with frame errors.
#[test]
fn idle_connections_are_dropped_after_the_conn_timeout() {
    let cluster = DbCluster::start(ClusterConfig::default()).unwrap();
    let server = Server::bind(
        any_addr(),
        cluster.clone(),
        ServerConfig { max_conns: 4, conn_timeout: Some(Duration::from_millis(150)) },
    )
    .unwrap();
    let addr = server.local_addr();

    // handshake succeeds, then the client goes quiet past the deadline
    let _idle = Client::connect(addr, 0, AccessKind::Other).unwrap();
    let mut dropped = false;
    for _ in 0..300 {
        if server.active_conns() == 0 {
            dropped = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(dropped, "idle connection outlived the read deadline");
    let timeouts = cluster.obs().counter(schaladb::obs::Counter::ConnTimeouts);
    assert!(timeouts >= 1, "deadline expiry was not counted (got {timeouts})");
}

/// Failover regression (the PR 1 guarantee, across the wire): a remote
/// session's prepared stmt ids keep working through data node kill →
/// backup promotion → process restart → online rejoin, and the surviving
/// state stays byte-equal to a never-killed twin.
#[test]
fn remote_prepared_handles_survive_node_kill_and_rejoin() {
    let dir = std::env::temp_dir()
        .join(format!("schaladb-server-failover-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cluster = DbCluster::start(
        ClusterConfig::builder()
            .durability(DurabilityConfig::new(dir.clone(), 8))
            .build()
            .unwrap(),
    )
    .unwrap();
    let am = AvailabilityManager::new(cluster.clone());
    let server = Server::bind(any_addr(), cluster.clone(), ServerConfig::default()).unwrap();
    let addr = server.local_addr();
    let twin = DbCluster::start(ClusterConfig::default()).unwrap();

    let mut admin = Client::connect(addr, 0, AccessKind::Other).unwrap();
    admin.exec_sql(&schema_sql()).unwrap();
    let (ins, _) = admin.prepare(SEED_SQL).unwrap();
    admin.exec_batch(ins, AccessKind::InsertTasks, &seed_rows()).unwrap();
    twin.exec(&schema_sql()).unwrap();
    let tins = twin.prepare(SEED_SQL).unwrap();
    twin.exec_prepared_batch(0, AccessKind::InsertTasks, &tins, &seed_rows()).unwrap();

    // the remote session prepares its claim ONCE; the same stmt id must
    // keep executing through every failover phase below
    let mut worker = Client::connect(addr, 1, AccessKind::UpdateToRunning).unwrap();
    let (claim, _) = worker.prepare(
        "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
         WHERE taskid = ? AND workerid = ? AND status = 'READY'",
    )
    .unwrap();
    let tclaim = twin
        .prepare(
            "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
             WHERE taskid = ? AND workerid = ? AND status = 'READY'",
        )
        .unwrap();
    let claim_on_both = |worker: &mut Client, tid: i64| {
        let params = [Value::Int(tid), Value::Int(tid % WORKERS as i64)];
        let n = worker.exec(claim, &params).unwrap().affected();
        assert_eq!(n, 1, "remote claim of task {tid} must hit exactly one row");
        twin.exec_prepared(1, AccessKind::UpdateToRunning, &tclaim, &params)
            .unwrap()
            .affected();
    };

    // healthy phase
    for tid in 0..8 {
        claim_on_both(&mut worker, tid);
    }

    // kill a data node, promote its backups; same remote stmt id
    let epoch0 = cluster.cluster_epoch();
    cluster.kill_node(1).unwrap();
    let r = am.sweep().unwrap();
    assert!(r.promoted > 0, "node 1 must have hosted primaries");
    assert!(cluster.cluster_epoch() > epoch0);
    for tid in 8..16 {
        claim_on_both(&mut worker, tid);
    }

    // restart the dead node from checkpoints + WAL tail, sweep to rejoin
    let start = cluster.restart_node(1).unwrap();
    assert!(start.partitions > 0);
    let mut rejoined = false;
    for _ in 0..200 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(rejoined, "node 1 never rejoined");
    for tid in 16..24 {
        claim_on_both(&mut worker, tid);
    }

    // byte-equality across kill → promote → restart → rejoin, observed
    // over the wire
    let stats = admin.stats(true, false).unwrap();
    assert_eq!(stats.fingerprint.as_deref(), Some(twin.fingerprint().unwrap().as_str()));
    assert!(stats.epoch > 0);

    worker.close().unwrap();
    admin.close().unwrap();
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}
