//! Observability property gate: the obs registry must *reconcile* with the
//! engine's own ground truth under concurrent load — obs counters equal the
//! route counters, paired histograms hold exactly one sample per counted
//! op, sharded per-partition totals equal their shard sums, and the
//! materialized `monitoring` table is internally consistent (each global
//! row equals the sum of its part rows within one SQL snapshot). Plus the
//! wire-level half: a remote client fetches the Prometheus-style
//! exposition, the slow-op ring with stage breakdowns, and SELECTs straight
//! from `monitoring` over TCP.

use schaladb::obs::{Counter, Hist, PartMetric, Stage, PART_SHARDS, SLOW_RING_K};
use schaladb::server::{Client, Server, ServerConfig};
use schaladb::storage::cluster::{ClusterConfig, ConcurrencyMode};
use schaladb::storage::{AccessKind, DbCluster, StatementResult, Value};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const PARTS: usize = 4;
const TASKS_PER_PART: usize = 30;

const CLAIM: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
                     WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
                     RETURNING taskid";
// OR predicates never classify for the compiled fast path (see
// tests/dml_fastpath.rs), so this shape is guaranteed interpreted DML.
const OR_BUMP: &str = "UPDATE workqueue SET dur = ? WHERE taskid = ? OR taskid = ?";

fn any_addr() -> std::net::SocketAddr {
    "127.0.0.1:0".parse().unwrap()
}

fn workload_cluster() -> Arc<DbCluster> {
    workload_cluster_with(ClusterConfig::default())
}

fn workload_cluster_with(cfg: ClusterConfig) -> Arc<DbCluster> {
    let c = DbCluster::start(cfg).unwrap();
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {PARTS} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE node (nodeid INT NOT NULL, hostname TEXT) PRIMARY KEY (nodeid)")
        .unwrap();
    // seed through the *text* path on purpose: text DML runs interpreted
    // without touching the prepared-DML obs counters, so the reconciliation
    // below accounts for every DmlFast/DmlInterp bump it observes
    for w in 0..PARTS {
        c.exec(&format!("INSERT INTO node (nodeid, hostname) VALUES ({w}, 'host{w}')")).unwrap();
        for t in 0..TASKS_PER_PART {
            let id = (w * TASKS_PER_PART + t) as i64;
            let sql = format!(
                "INSERT INTO workqueue (taskid, workerid, status, dur) \
                 VALUES ({id}, {w}, 'READY', 1.0)"
            );
            c.exec(&sql).unwrap();
        }
    }
    c
}

/// The tentpole property: run concurrent claim workers (compiled fast
/// path), interpreted DML, and steering scanners, then demand that the obs
/// registry reconciles *exactly* with the router's own counters and with
/// the per-call tally the threads kept themselves.
#[test]
fn obs_counters_reconcile_with_route_counters_under_concurrent_load() {
    let c = workload_cluster();
    let obs = c.obs().clone();

    // scanners: scatter aggregates + snapshot joins + centralized point
    // reads, continuously, while the claims churn underneath
    let stop = Arc::new(AtomicBool::new(false));
    let mut scanners = Vec::new();
    for _ in 0..2 {
        let c = c.clone();
        let stop = stop.clone();
        scanners.push(std::thread::spawn(move || {
            let mut n = 0u64;
            while !stop.load(Ordering::SeqCst) {
                let rs = c
                    .query("SELECT status, COUNT(*) FROM workqueue GROUP BY status")
                    .unwrap();
                assert!(!rs.rows.is_empty());
                c.query(
                    "SELECT n.hostname, COUNT(*) AS c FROM workqueue t \
                     JOIN node n ON t.workerid = n.nodeid \
                     GROUP BY n.hostname ORDER BY c DESC",
                )
                .unwrap();
                // prunes to one partition, no aggregate: centralized route
                c.query("SELECT status FROM workqueue WHERE workerid = 1").unwrap();
                n += 1;
            }
            n
        }));
    }

    // claim workers: every successful prepared DML call is tallied locally;
    // the drained-partition probe (empty claim) counts too — it still runs
    // the compiled plan
    let mut claimers = Vec::new();
    for w in 0..PARTS {
        let c = c.clone();
        claimers.push(std::thread::spawn(move || {
            let claim = c.prepare(CLAIM).unwrap();
            let bump = c.prepare(OR_BUMP).unwrap();
            let mut dml_calls = 0u64;
            let params = [Value::Int(w as i64)];
            loop {
                let r = c
                    .exec_prepared(w as u32, AccessKind::UpdateToRunning, &claim, &params)
                    .unwrap();
                dml_calls += 1;
                if r.rows().rows.is_empty() {
                    break;
                }
            }
            for i in 0..10i64 {
                let base = (w * TASKS_PER_PART) as i64;
                let params =
                    [Value::Float(2.0), Value::Int(base + i), Value::Int(base + i + 1)];
                c.exec_prepared(w as u32, AccessKind::Other, &bump, &params).unwrap();
                dml_calls += 1;
            }
            dml_calls
        }));
    }
    let dml_calls: u64 = claimers.into_iter().map(|h| h.join().unwrap()).sum();
    stop.store(true, Ordering::SeqCst);
    for s in scanners {
        assert!(s.join().unwrap() > 0, "scanner never completed a pass");
    }

    // quiesced: obs counters equal the router's ground truth, exactly
    let rc = c.route_counts();
    assert_eq!(obs.counter(Counter::DmlFast), rc.fast_dml);
    assert_eq!(obs.counter(Counter::SelectScatter), rc.scatter);
    assert_eq!(obs.counter(Counter::SelectSnapshotJoin), rc.snapshot_join);
    assert_eq!(obs.counter(Counter::SelectCentralized), rc.centralized);
    assert!(rc.scatter > 0, "steering aggregates must scatter");
    assert!(rc.snapshot_join > 0, "steering joins must snapshot-join");
    assert!(rc.centralized > 0, "point reads must run centralized");

    // every prepared DML call landed in exactly one of fast/interpreted
    let (fast, interp) = (obs.counter(Counter::DmlFast), obs.counter(Counter::DmlInterp));
    assert_eq!(fast + interp, dml_calls, "fast {fast} + interp {interp}");
    assert!(fast >= (PARTS * TASKS_PER_PART) as u64, "claims must run compiled");
    assert!(interp >= (PARTS * 10) as u64, "OR updates must interpret");

    // paired histograms: exactly one sample per counted op
    assert_eq!(obs.hist(Hist::ClaimFast).count(), fast);
    assert_eq!(obs.hist(Hist::ClaimInterp).count(), interp);
    assert_eq!(obs.hist(Hist::ScatterScan).count(), rc.scatter + rc.snapshot_join);
    assert!(obs.hist(Hist::LatchWait).count() > 0, "latch waits must be timed");

    // sharded per-partition counters: total equals the shard sum, and the
    // claim traffic landed on every partition (workerid hashes to itself)
    for m in [PartMetric::Claims, PartMetric::Scans, PartMetric::WalRecords] {
        let sum: u64 = (0..PART_SHARDS).map(|s| obs.part_shard(m, s)).sum();
        assert_eq!(obs.part_total(m), sum, "{}: total != shard sum", m.label());
    }
    for p in 0..PARTS {
        assert!(obs.part_shard(PartMetric::Claims, p) > 0, "no claims on part {p}");
        assert!(obs.part_shard(PartMetric::Scans, p) > 0, "no scans on part {p}");
    }

    // WAL accounting: the global counter, the per-partition ledger, and
    // the per-node ledger all describe the same committed stream
    let wal = obs.counter(Counter::WalRecords);
    assert!(wal > 0, "committed DML must append WAL records");
    assert_eq!(obs.part_total(PartMetric::WalRecords), wal);
    let node_sum: u64 = (0..obs.num_nodes()).map(|n| obs.node_wal_records(n)).sum();
    assert_eq!(node_sum, wal);
    let flushes = obs.counter(Counter::WalFlushes);
    assert!(flushes > 0, "group-commit boundaries must be observed");
    assert!(obs.counter(Counter::WalFlushedCommits) >= flushes);
    assert_eq!(obs.hist(Hist::WalFlush).count(), flushes);
}

/// The slow-op ring under real traffic: bounded, sorted, spans unique, and
/// every retained op's stage breakdown covers its total (the residual is
/// folded into `exec` when the span closes).
#[test]
fn slow_op_ring_retains_bounded_sorted_spans_with_stage_breakdowns() {
    let c = workload_cluster();
    let obs = c.obs().clone();
    let claim = c.prepare(CLAIM).unwrap();
    for w in 0..PARTS {
        let params = [Value::Int(w as i64)];
        loop {
            let r =
                c.exec_prepared(0, AccessKind::UpdateToRunning, &claim, &params).unwrap();
            if r.rows().rows.is_empty() {
                break;
            }
        }
    }
    c.query("SELECT status, COUNT(*) FROM workqueue GROUP BY status").unwrap();

    let ops = obs.slow_ops(SLOW_RING_K);
    assert!(!ops.is_empty(), "traced ops must populate the ring");
    assert!(ops.len() <= SLOW_RING_K);
    assert!(ops.windows(2).all(|w| w[0].total_nanos >= w[1].total_nanos));
    let mut spans: Vec<u64> = ops.iter().map(|o| o.span).collect();
    spans.sort_unstable();
    spans.dedup();
    assert_eq!(spans.len(), ops.len(), "span ids must be unique");
    for op in &ops {
        assert!(op.total_nanos > 0);
        assert!(!op.label.is_empty());
        let staged: u64 = op.stages.iter().sum();
        assert!(
            staged >= op.total_nanos,
            "{}: stages {staged} must cover total {}",
            op.label,
            op.total_nanos
        );
        // residual folding: exec absorbs whatever the timed stages missed
        assert!(op.stages[Stage::Exec as usize] > 0 || staged == op.total_nanos);
    }
}

/// The paper's "monitoring is just workflow data" claim, checked for
/// consistency: one SQL snapshot of the `monitoring` table must be
/// internally consistent (each sharded metric's global row equals the sum
/// of its part rows), stamped with the live cluster epoch, and re-reading
/// re-materializes a fresh — still consistent — snapshot.
#[test]
fn monitoring_table_snapshots_are_internally_consistent() {
    let c = workload_cluster();
    let claim = c.prepare(CLAIM).unwrap();
    for w in 0..PARTS {
        let params = [Value::Int(w as i64)];
        for _ in 0..5 {
            c.exec_prepared(0, AccessKind::UpdateToRunning, &claim, &params).unwrap();
        }
    }

    // ONE query per snapshot: each SELECT touching `monitoring` triggers a
    // fresh materialization, and the refresh's own writes move the very
    // counters being materialized — two queries see two snapshots
    let check = |ctx: &str| {
        let rs = c
            .query("SELECT part, cnt, epoch FROM monitoring WHERE metric = 'part_claims'")
            .unwrap();
        let mut global: Option<i64> = None;
        let mut part_sum = 0i64;
        for row in &rs.rows {
            let part = row.values[0].as_i64().unwrap();
            let cnt = row.values[1].as_i64().unwrap();
            assert_eq!(
                row.values[2].as_i64().unwrap(),
                c.cluster_epoch() as i64,
                "{ctx}: epoch stamp"
            );
            if part == -1 {
                assert!(global.is_none(), "{ctx}: exactly one global row");
                global = Some(cnt);
            } else {
                assert!((0..PART_SHARDS as i64).contains(&part), "{ctx}: part {part}");
                assert!(cnt > 0, "{ctx}: zero shards are omitted");
                part_sum += cnt;
            }
        }
        let global = global.unwrap_or_else(|| panic!("{ctx}: global row missing"));
        assert_eq!(global, part_sum, "{ctx}: global row != sum of part rows");
        assert!(global >= (PARTS * 5) as i64, "{ctx}: claims undercounted");
    };
    check("first snapshot");
    // the refresh between these two snapshots bumps the claim counters
    // itself (its INSERTs are prepared DML) — consistency must survive that
    check("second snapshot");

    let rs = c
        .query(
            "SELECT cnt FROM monitoring \
             WHERE metric = 'monitoring_refreshes' AND part = -1 AND node = -1",
        )
        .unwrap();
    assert_eq!(rs.rows.len(), 1);
    // the row describes the registry as of *before* this query's refresh
    assert!(rs.rows[0].values[0].as_i64().unwrap() >= 2);
}

/// The acceptance path: a remote client drives load over TCP, then reads
/// the telemetry three ways — the extended `Stats` reply, the `Metrics`
/// exposition + slow-op ring, and a plain SELECT on `monitoring`.
#[test]
fn remote_client_reads_metrics_and_monitoring_over_the_wire() {
    let cluster = DbCluster::start(ClusterConfig::default()).unwrap();
    let server = Server::bind(any_addr(), cluster, ServerConfig::default()).unwrap();
    let addr = server.local_addr();

    let mut c = Client::connect(addr, 0, AccessKind::Other).unwrap();
    c.exec_sql(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {PARTS} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    let (ins, _) = c
        .prepare("INSERT INTO workqueue (taskid, workerid, status, dur) VALUES (?, ?, 'READY', ?)")
        .unwrap();
    let rows: Vec<Vec<Value>> = (0..40i64)
        .map(|i| vec![Value::Int(i), Value::Int(i % PARTS as i64), Value::Float(1.0)])
        .collect();
    c.exec_batch(ins, AccessKind::InsertTasks, &rows).unwrap();
    let (claim, _) = c.prepare(CLAIM).unwrap();
    for w in 0..PARTS {
        loop {
            match c.exec(claim, &[Value::Int(w as i64)]).unwrap() {
                StatementResult::Rows(rs) if !rs.rows.is_empty() => {}
                _ => break,
            }
        }
    }
    c.query("SELECT status, COUNT(*) FROM workqueue GROUP BY status").unwrap();

    // (1) the extended Stats reply carries the obs counters
    let stats = c.stats(false, false).unwrap();
    assert!(stats.fast_dml >= 40, "claims crossed the wire on the fast path");
    assert!(stats.wal_records > 0);
    assert!(stats.wal_flushes > 0);
    assert!(stats.frames_in > 0 && stats.frames_out > 0);
    assert!(stats.bytes_in > stats.frames_in, "frames have headers");
    assert!(stats.bytes_out > stats.frames_out);
    assert_eq!(stats.frame_errors, 0);

    // (2) the Metrics reply: parseable exposition + slow ops with the
    // engine's stage vocabulary
    let m = c.metrics(8).unwrap();
    assert!(m.text.contains("schaladb_dml_fast_total"));
    assert!(m.text.contains("schaladb_server_frames_in_total"));
    for line in m.text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name, value) = line.rsplit_once(' ').expect("name value");
        assert!(name.starts_with("schaladb_"), "bad series name in {line:?}");
        assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
    }
    assert!(!m.slow_ops.is_empty(), "remote traffic must populate the ring");
    assert!(m.slow_ops.len() <= 8);
    for op in &m.slow_ops {
        assert!(op.total_nanos > 0);
        let labels: Vec<&str> = op.stages.iter().map(|(s, _)| s.as_str()).collect();
        assert_eq!(labels, ["latch", "exec", "wal", "scan"]);
    }

    // (3) the monitoring table is reachable through the ordinary remote
    // SQL path — telemetry really is just workflow data
    let rs = c
        .query(
            "SELECT metric, value, cnt FROM monitoring \
             WHERE part = -1 AND node = -1 ORDER BY metric",
        )
        .unwrap();
    assert!(!rs.rows.is_empty());
    let fast = rs
        .rows
        .iter()
        .find(|r| r.values[0] == Value::str("dml_fast"))
        .expect("dml_fast row");
    assert!(fast.values[2].as_i64().unwrap() >= 40);
    let frames = rs
        .rows
        .iter()
        .find(|r| r.values[0] == Value::str("server_frames_in"))
        .expect("server_frames_in row");
    assert!(frames.values[2].as_i64().unwrap() > 0);
    c.close().unwrap();
}

/// OCC telemetry end-to-end under `ConcurrencyMode::Occ`: racing PK-probe
/// claims move the OCC counters and their paired histograms with the
/// exact 1:1 pairing invariants, the router ledgers agree, the numbers
/// surface in the `monitoring` table — and the eligibility gate holds:
/// the index-probe `ORDER BY … LIMIT 1` claim shape never touches the
/// OCC path even in Occ mode (it keeps the 2PL fast path).
#[test]
fn occ_telemetry_reconciles_and_reaches_the_monitoring_table() {
    const PK_CLAIM: &str = "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
                            WHERE taskid = ? AND workerid = ? AND status = 'READY'";
    let c = workload_cluster_with(
        ClusterConfig::builder().concurrency(ConcurrencyMode::Occ).build().unwrap(),
    );
    let obs = c.obs().clone();

    // phase 1: two racers per partition claim every task by PK
    let mut handles = Vec::new();
    for t in 0..(PARTS * 2) as u32 {
        let c = c.clone();
        handles.push(std::thread::spawn(move || {
            let claim = c.prepare(PK_CLAIM).unwrap();
            let w = t as usize % PARTS;
            let mut won = 0u64;
            for i in 0..TASKS_PER_PART {
                let id = (w * TASKS_PER_PART + i) as i64;
                let n = c
                    .exec_prepared(
                        t,
                        AccessKind::UpdateToRunning,
                        &claim,
                        &[Value::Int(id), Value::Int(w as i64)],
                    )
                    .unwrap()
                    .affected();
                won += n as u64;
            }
            won
        }));
    }
    let won: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(won, (PARTS * TASKS_PER_PART) as u64, "each task claimed exactly once");

    // ledgers and pairing invariants, quiesced
    let rc = c.route_counts();
    assert!(rc.occ_dml > 0, "PK claims in Occ mode must commit through OCC");
    assert_eq!(obs.counter(Counter::OccDml), rc.occ_dml);
    assert_eq!(obs.counter(Counter::OccRetries), rc.occ_retries);
    assert_eq!(obs.counter(Counter::OccFallbacks), rc.occ_fallbacks);
    assert_eq!(
        obs.hist(Hist::OccValidate).count(),
        rc.occ_dml + rc.occ_retries,
        "one occ_validate sample per validation attempt"
    );
    assert_eq!(
        obs.hist(Hist::OccRetryDist).count(),
        rc.occ_dml + rc.occ_fallbacks,
        "one retry-distribution sample per OCC completion"
    );
    // OCC completions still count as fast DML (uniform adoption ledger)
    assert_eq!(obs.counter(Counter::DmlFast), rc.fast_dml);
    assert_eq!(obs.hist(Hist::ClaimFast).count(), rc.fast_dml);
    assert!(rc.fast_dml >= rc.occ_dml);

    // phase 2: the index-probe LIMIT 1 shape is OCC-ineligible — running
    // it (empty result: everything is RUNNING) must not move occ_*
    let before = (rc.occ_dml, rc.occ_retries, rc.occ_fallbacks);
    let drain = c.prepare(CLAIM).unwrap();
    for w in 0..PARTS {
        let r = c
            .exec_prepared(w as u32, AccessKind::UpdateToRunning, &drain, &[Value::Int(w as i64)])
            .unwrap();
        assert!(r.rows().rows.is_empty(), "everything was already claimed");
    }
    let rc2 = c.route_counts();
    assert_eq!(
        (rc2.occ_dml, rc2.occ_retries, rc2.occ_fallbacks),
        before,
        "the ORDER BY … LIMIT 1 claim shape must stay off the OCC path"
    );

    // phase 3: the numbers are queryable as workflow data
    let rs = c
        .query(
            "SELECT cnt FROM monitoring \
             WHERE metric = 'occ_dml' AND part = -1 AND node = -1",
        )
        .unwrap();
    assert_eq!(rs.rows[0].values[0].as_i64().unwrap() as u64, rc2.occ_dml);
    let rs = c
        .query("SELECT cnt FROM monitoring WHERE metric = 'occ_validate_p50_seconds'")
        .unwrap();
    assert_eq!(
        rs.rows[0].values[0].as_i64().unwrap() as u64,
        rc2.occ_dml + rc2.occ_retries,
        "occ_validate histogram must reach the monitoring table"
    );
    let rs = c
        .query("SELECT cnt FROM monitoring WHERE metric = 'occ_retry_dist_p50_seconds'")
        .unwrap();
    assert_eq!(
        rs.rows[0].values[0].as_i64().unwrap() as u64,
        rc2.occ_dml + rc2.occ_fallbacks,
        "the retry-count distribution must reach the monitoring table"
    );
    // and the Prometheus exposition carries the same ledger
    let text = obs.exposition();
    assert!(text.contains(&format!("schaladb_occ_dml_total {}", rc2.occ_dml)));
    assert!(text.contains("schaladb_occ_validate_seconds_count"));
}
