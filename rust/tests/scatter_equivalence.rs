//! Merge correctness for the scatter-gather engine: for every supported
//! SELECT shape — GROUP BY, HAVING, DISTINCT aggregates, AVG, top-k,
//! joins, left joins — the routed path (partial plans + coordinator merge
//! over partition snapshots) must return exactly what the centralized 2PL
//! executor returns, across 1..N partitions and under a dead primary
//! (backup reads).

use schaladb::storage::cluster::ClusterConfig;
use schaladb::storage::{DbCluster, ResultSet};
use schaladb::util::clock;
use std::sync::Arc;

/// Cluster with `parts` WQ partitions, deterministic data, frozen clock
/// (so `NOW()` is identical across both executions of a statement).
fn cluster(parts: usize) -> Arc<DbCluster> {
    let (shared, ctl) = clock::manual(1_000.0);
    let c = DbCluster::start(ClusterConfig {
        data_nodes: 2,
        replication: true,
        clock: shared,
        durability: None,
    })
    .unwrap();
    ctl.set(1_000.0);
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE workers (id INT NOT NULL, host TEXT) PRIMARY KEY (id)")
        .unwrap();
    let statuses = ["READY", "RUNNING", "FINISHED"];
    for i in 0..60i64 {
        // deterministic spread: statuses cycle, durations vary, one
        // workerid (parts+1) has no matching workers row (left-join case)
        c.execute(&format!(
            "INSERT INTO workqueue (taskid, actid, workerid, status, dur, starttime) \
             VALUES ({i}, {}, {}, '{}', {}.5, {}.0)",
            i % 3,
            i % (parts as i64 + 1),
            statuses[(i % 3) as usize],
            (i * 7) % 13,
            900 + i
        ))
        .unwrap();
    }
    for w in 0..parts as i64 {
        c.execute(&format!("INSERT INTO workers (id, host) VALUES ({w}, 'node{w:03}')"))
            .unwrap();
    }
    c
}

/// Queries whose result order is fully determined (ties broken) — compared
/// row-for-row.
const ORDERED: &[&str] = &[
    "SELECT status, COUNT(*) AS n FROM workqueue GROUP BY status ORDER BY status",
    "SELECT status FROM workqueue GROUP BY status ORDER BY status",
    "SELECT status FROM workqueue WHERE taskid > 9000 GROUP BY status ORDER BY status",
    "SELECT workerid, COUNT(*) AS n, AVG(dur) a, MIN(dur), MAX(dur), SUM(taskid) \
     FROM workqueue WHERE status != 'FAILED' GROUP BY workerid HAVING n >= 1 \
     ORDER BY workerid",
    "SELECT workerid, SUM(dur) s FROM workqueue GROUP BY workerid \
     ORDER BY s DESC, workerid LIMIT 2",
    "SELECT taskid, dur FROM workqueue WHERE dur > 2.0 \
     ORDER BY dur DESC, taskid ASC LIMIT 7",
    "SELECT taskid FROM workqueue ORDER BY taskid",
    "SELECT COUNT(*) FROM workqueue",
    "SELECT COUNT(DISTINCT status), COUNT(DISTINCT workerid), SUM(DISTINCT actid), \
     AVG(DISTINCT dur) FROM workqueue",
    "SELECT AVG(dur), MIN(starttime), COUNT(*) FROM workqueue WHERE status = 'NOPE'",
    "SELECT status, COUNT(*) n FROM workqueue WHERE starttime >= NOW() - 70 \
     GROUP BY status ORDER BY n DESC, status",
    "SELECT w.host, COUNT(*) AS n FROM workqueue t JOIN workers w \
     ON t.workerid = w.id GROUP BY w.host ORDER BY w.host",
    "SELECT t.taskid, w.host FROM workqueue t LEFT JOIN workers w \
     ON t.workerid = w.id ORDER BY t.taskid",
    "SELECT a.status, COUNT(*) FROM workqueue a JOIN workqueue b \
     ON a.taskid = b.taskid WHERE b.dur > 2.0 GROUP BY a.status ORDER BY a.status",
];

/// Queries with no (full) ORDER BY — compared as multisets.
const UNORDERED: &[&str] = &[
    "SELECT * FROM workqueue WHERE status = 'READY'",
    "SELECT taskid, actid FROM workqueue WHERE dur > 4.0 AND actid IN (0, 2)",
    "SELECT status, COUNT(*) FROM workqueue GROUP BY status",
];

fn sorted_rows(rs: &ResultSet) -> Vec<String> {
    let mut v: Vec<String> = rs.rows.iter().map(|r| format!("{:?}", r.values)).collect();
    v.sort();
    v
}

fn assert_equivalent(c: &DbCluster, label: &str) {
    for q in ORDERED {
        let routed = c.query(q).unwrap_or_else(|e| panic!("[{label}] routed {q}: {e}"));
        let central =
            c.query_centralized(q).unwrap_or_else(|e| panic!("[{label}] central {q}: {e}"));
        assert_eq!(routed, central, "[{label}] diverged on: {q}");
    }
    for q in UNORDERED {
        let routed = c.query(q).unwrap_or_else(|e| panic!("[{label}] routed {q}: {e}"));
        let central =
            c.query_centralized(q).unwrap_or_else(|e| panic!("[{label}] central {q}: {e}"));
        assert_eq!(routed.columns, central.columns, "[{label}] columns diverged on: {q}");
        assert_eq!(
            sorted_rows(&routed),
            sorted_rows(&central),
            "[{label}] row multiset diverged on: {q}"
        );
    }
}

#[test]
fn scatter_gather_equals_centralized_across_partition_counts() {
    for parts in [1usize, 2, 3, 4, 8] {
        let c = cluster(parts);
        assert_equivalent(&c, &format!("{parts} partitions"));
        if parts > 1 {
            let counts = c.route_counts();
            assert!(
                counts.scatter > 0,
                "aggregate queries must scatter at {parts} partitions"
            );
            assert!(
                counts.snapshot_join > 0,
                "join queries must snapshot-join at {parts} partitions"
            );
        }
    }
}

#[test]
fn scatter_gather_equals_centralized_under_dead_primary() {
    let c = cluster(4);
    // Kill a node *without* promoting: replica selection must fall back to
    // backups on both paths, and results must still agree.
    c.kill_node(0).unwrap();
    assert_equivalent(&c, "dead primary, backup reads");
    // ...and after promotion too.
    let promoted = c.promote_dead_primaries();
    assert!(promoted > 0, "node 0 hosted some primaries");
    assert_equivalent(&c, "promoted backups");
}

#[test]
fn error_shapes_match_on_both_paths() {
    let c = cluster(2);
    for q in [
        "SELECT nope FROM workqueue GROUP BY status",
        "SELECT status FROM workqueue ORDER BY nope_col LIMIT 3",
        "SELECT COUNT(*) FROM workqueue WHERE nope > 1",
    ] {
        assert!(c.query(q).is_err(), "routed path must reject: {q}");
        assert!(c.query_centralized(q).is_err(), "centralized path must reject: {q}");
    }
}
