//! Merge correctness for the scatter-gather engine: for every supported
//! SELECT shape — GROUP BY, HAVING, DISTINCT aggregates, AVG, top-k,
//! joins, left joins — the routed path (partial plans + coordinator merge
//! over partition snapshots) must return exactly what the centralized 2PL
//! executor returns, across 1..N partitions and under a dead primary
//! (backup reads).
//!
//! `SCATTER_MODE=occ` reruns the whole suite with point claims on the
//! optimistic path (the reference executions stay centralized/2PL), so
//! scan-vs-write equivalence holds under either write discipline.

use schaladb::storage::cluster::{ClusterConfig, ConcurrencyMode};
use schaladb::storage::replication::AvailabilityManager;
use schaladb::storage::{AccessKind, DbCluster, DurabilityConfig, ResultSet, Value};
use schaladb::util::clock;
use schaladb::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Point-DML discipline for the cluster under test, from `SCATTER_MODE`
/// (`2pl` | `occ`, default 2PL).
fn scatter_mode() -> ConcurrencyMode {
    std::env::var("SCATTER_MODE")
        .ok()
        .and_then(|s| ConcurrencyMode::from_name(&s))
        .unwrap_or_default()
}

/// Cluster with `parts` WQ partitions, deterministic data, frozen clock
/// (so `NOW()` is identical across both executions of a statement).
fn cluster(parts: usize) -> Arc<DbCluster> {
    let (shared, ctl) = clock::manual(1_000.0);
    let c = DbCluster::start(
        ClusterConfig::builder().clock(shared).concurrency(scatter_mode()).build().unwrap(),
    )
    .unwrap();
    ctl.set(1_000.0);
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE workers (id INT NOT NULL, host TEXT) PRIMARY KEY (id)")
        .unwrap();
    let statuses = ["READY", "RUNNING", "FINISHED"];
    for i in 0..60i64 {
        // deterministic spread: statuses cycle, durations vary, one
        // workerid (parts+1) has no matching workers row (left-join case)
        c.execute(&format!(
            "INSERT INTO workqueue (taskid, actid, workerid, status, dur, starttime) \
             VALUES ({i}, {}, {}, '{}', {}.5, {}.0)",
            i % 3,
            i % (parts as i64 + 1),
            statuses[(i % 3) as usize],
            (i * 7) % 13,
            900 + i
        ))
        .unwrap();
    }
    for w in 0..parts as i64 {
        c.execute(&format!("INSERT INTO workers (id, host) VALUES ({w}, 'node{w:03}')"))
            .unwrap();
    }
    c
}

/// Queries whose result order is fully determined (ties broken) — compared
/// row-for-row.
const ORDERED: &[&str] = &[
    "SELECT status, COUNT(*) AS n FROM workqueue GROUP BY status ORDER BY status",
    "SELECT status FROM workqueue GROUP BY status ORDER BY status",
    "SELECT status FROM workqueue WHERE taskid > 9000 GROUP BY status ORDER BY status",
    "SELECT workerid, COUNT(*) AS n, AVG(dur) a, MIN(dur), MAX(dur), SUM(taskid) \
     FROM workqueue WHERE status != 'FAILED' GROUP BY workerid HAVING n >= 1 \
     ORDER BY workerid",
    "SELECT workerid, SUM(dur) s FROM workqueue GROUP BY workerid \
     ORDER BY s DESC, workerid LIMIT 2",
    "SELECT taskid, dur FROM workqueue WHERE dur > 2.0 \
     ORDER BY dur DESC, taskid ASC LIMIT 7",
    "SELECT taskid FROM workqueue ORDER BY taskid",
    "SELECT COUNT(*) FROM workqueue",
    "SELECT COUNT(DISTINCT status), COUNT(DISTINCT workerid), SUM(DISTINCT actid), \
     AVG(DISTINCT dur) FROM workqueue",
    "SELECT AVG(dur), MIN(starttime), COUNT(*) FROM workqueue WHERE status = 'NOPE'",
    "SELECT status, COUNT(*) n FROM workqueue WHERE starttime >= NOW() - 70 \
     GROUP BY status ORDER BY n DESC, status",
    "SELECT w.host, COUNT(*) AS n FROM workqueue t JOIN workers w \
     ON t.workerid = w.id GROUP BY w.host ORDER BY w.host",
    "SELECT t.taskid, w.host FROM workqueue t LEFT JOIN workers w \
     ON t.workerid = w.id ORDER BY t.taskid",
    "SELECT a.status, COUNT(*) FROM workqueue a JOIN workqueue b \
     ON a.taskid = b.taskid WHERE b.dur > 2.0 GROUP BY a.status ORDER BY a.status",
];

/// Queries with no (full) ORDER BY — compared as multisets.
const UNORDERED: &[&str] = &[
    "SELECT * FROM workqueue WHERE status = 'READY'",
    "SELECT taskid, actid FROM workqueue WHERE dur > 4.0 AND actid IN (0, 2)",
    "SELECT status, COUNT(*) FROM workqueue GROUP BY status",
];

fn sorted_rows(rs: &ResultSet) -> Vec<String> {
    let mut v: Vec<String> = rs.rows.iter().map(|r| format!("{:?}", r.values)).collect();
    v.sort();
    v
}

fn assert_equivalent(c: &DbCluster, label: &str) {
    for q in ORDERED {
        let routed = c.query(q).unwrap_or_else(|e| panic!("[{label}] routed {q}: {e}"));
        let central =
            c.query_centralized(q).unwrap_or_else(|e| panic!("[{label}] central {q}: {e}"));
        assert_eq!(routed, central, "[{label}] diverged on: {q}");
    }
    for q in UNORDERED {
        let routed = c.query(q).unwrap_or_else(|e| panic!("[{label}] routed {q}: {e}"));
        let central =
            c.query_centralized(q).unwrap_or_else(|e| panic!("[{label}] central {q}: {e}"));
        assert_eq!(routed.columns, central.columns, "[{label}] columns diverged on: {q}");
        assert_eq!(
            sorted_rows(&routed),
            sorted_rows(&central),
            "[{label}] row multiset diverged on: {q}"
        );
    }
}

#[test]
fn scatter_gather_equals_centralized_across_partition_counts() {
    for parts in [1usize, 2, 3, 4, 8] {
        let c = cluster(parts);
        assert_equivalent(&c, &format!("{parts} partitions"));
        if parts > 1 {
            let counts = c.route_counts();
            assert!(
                counts.scatter > 0,
                "aggregate queries must scatter at {parts} partitions"
            );
            assert!(
                counts.snapshot_join > 0,
                "join queries must snapshot-join at {parts} partitions"
            );
        }
    }
}

#[test]
fn scatter_gather_equals_centralized_under_dead_primary() {
    let c = cluster(4);
    // Kill a node *without* promoting: replica selection must fall back to
    // backups on both paths, and results must still agree.
    c.kill_node(0).unwrap();
    assert_equivalent(&c, "dead primary, backup reads");
    // ...and after promotion too.
    let promoted = c.promote_dead_primaries();
    assert!(promoted > 0, "node 0 hosted some primaries");
    assert_equivalent(&c, "promoted backups");
}

/// Grow every partition past the chunk boundary (CHUNK_SLOTS = 256) so
/// the copy-on-write snapshots span multiple chunks per partition and
/// inserts/deletes exercise seal/reseal across boundaries.
fn grow(c: &DbCluster, parts: usize, base: i64, rows_per_part: usize) {
    let ins = c
        .prepare(
            "INSERT INTO workqueue (taskid, actid, workerid, status, dur, starttime) \
             VALUES (?, ?, ?, ?, ?, 950.0)",
        )
        .unwrap();
    let statuses = ["READY", "RUNNING", "FINISHED"];
    let batch: Vec<Vec<Value>> = (0..(rows_per_part * parts) as i64)
        .map(|i| {
            vec![
                Value::Int(base + i),
                Value::Int(i % 3),
                Value::Int(i % parts as i64),
                Value::str(statuses[(i % 3) as usize]),
                Value::Float((i % 13) as f64 + 0.5),
            ]
        })
        .collect();
    for chunk in batch.chunks(512) {
        c.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, chunk).unwrap();
    }
}

/// Mutate-while-scanning property stream: claim-loop writers race steering
/// scans over the chunked snapshots across 1..8 partitions, including
/// inserts/deletes that cross chunk boundaries; at every quiesce point the
/// routed results must be byte-equal to the centralized executor's.
#[test]
fn mutate_while_scanning_matches_centralized() {
    for parts in [1usize, 2, 4, 8] {
        let c = cluster(parts);
        let base = 100_000;
        grow(&c, parts, base, 300); // > CHUNK_SLOTS rows per partition

        for round in 0..2u64 {
            // the row population is invariant through Phase A (updates
            // only), so every consistent snapshot must sum to this
            let total = c.table_rows("workqueue").unwrap() as i64;
            // Phase A: status-flipping claim writers (updates only, so the
            // row population is invariant) racing a steering reader that
            // checks every scan stays internally consistent.
            let stop = Arc::new(AtomicBool::new(false));
            let reader = {
                let c = c.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    let mut scans = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        // one scatter aggregate runs over one consistent
                        // snapshot cut: group counts must sum to the fixed
                        // population even mid-claim-storm
                        let rs = c
                            .query("SELECT status, COUNT(*) FROM workqueue GROUP BY status")
                            .unwrap();
                        let sum: i64 = rs
                            .rows
                            .iter()
                            .map(|r| r.values[1].as_i64().unwrap())
                            .sum();
                        assert_eq!(sum, total, "snapshot scan saw a torn population");
                        // a selective scan (zone-prunable) must agree with
                        // the same cut's bounds
                        let rs = c
                            .query(&format!(
                                "SELECT COUNT(*) FROM workqueue WHERE taskid >= {base}"
                            ))
                            .unwrap();
                        assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), total - 60);
                        scans += 1;
                    }
                    scans
                })
            };
            let claim = c
                .prepare(
                    "UPDATE workqueue SET status = ?, starttime = NOW() \
                     WHERE taskid = ? AND workerid = ?",
                )
                .unwrap();
            let mut writers = Vec::new();
            for w in 0..parts {
                let c = c.clone();
                let claim = claim.clone();
                let mut rng = Rng::new(0xC0FFEE + round * 97 + w as u64);
                writers.push(std::thread::spawn(move || {
                    let statuses = ["READY", "RUNNING", "FINISHED"];
                    for _ in 0..150 {
                        let i = rng.range(0, 300 * parts as i64);
                        let tid = base + i;
                        let st = statuses[rng.index(3)];
                        c.exec_prepared(
                            w as u32,
                            AccessKind::UpdateToRunning,
                            &claim,
                            &[Value::str(st), Value::Int(tid), Value::Int(i % parts as i64)],
                        )
                        .unwrap();
                    }
                }));
            }
            for h in writers {
                h.join().unwrap();
            }
            stop.store(true, Ordering::Relaxed);
            let scans = reader.join().unwrap();
            assert!(scans > 0, "reader must have scanned during the claim storm");

            // quiesce: routed must be byte-equal to centralized
            assert_equivalent(&c, &format!("{parts} parts, round {round}, post-claims"));

            // Phase B: structural churn — delete and re-insert rows whose
            // canonical slots straddle the chunk boundary, plus brand-new
            // rows that grow the slab into fresh chunks.
            let del = c.prepare("DELETE FROM workqueue WHERE taskid = ?").unwrap();
            let mut rng = Rng::new(0xBEEF + round);
            let mut deleted: Vec<i64> = Vec::new();
            for _ in 0..120 {
                let tid = base + rng.range(0, 300 * parts as i64);
                let n = c
                    .exec_prepared(0, AccessKind::Other, &del, &[Value::Int(tid)])
                    .unwrap();
                if let schaladb::storage::StatementResult::Affected(1) = n {
                    deleted.push(tid);
                }
            }
            let ins = c
                .prepare(
                    "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                     VALUES (?, 0, ?, 'READY', 1.5)",
                )
                .unwrap();
            // re-insert half the deleted rows (slot reuse inside sealed
            // chunks) and add fresh ids (slab growth past the tail chunk)
            for (k, tid) in deleted.iter().enumerate() {
                if k % 2 == 0 {
                    let i = tid - base;
                    c.exec_prepared(
                        0,
                        AccessKind::InsertTasks,
                        &ins,
                        &[Value::Int(*tid), Value::Int(i % parts as i64)],
                    )
                    .unwrap();
                }
            }
            grow(&c, parts, base + 10_000 * (round as i64 + 1), 40);
            assert_equivalent(&c, &format!("{parts} parts, round {round}, post-churn"));
        }

        if parts > 1 {
            let counts = c.route_counts();
            assert!(counts.scatter > 0, "steering scans must have scattered");
            assert!(
                counts.chunks_scanned > 0,
                "multi-chunk partitions must report scanned chunks"
            );
        }
        // zone-map pruning is visible on a selective steering query (an
        // aggregate, so it scatters even on a single partition)
        let before = c.route_counts().chunks_pruned;
        c.query("SELECT COUNT(*), AVG(dur) FROM workqueue WHERE taskid > 99000000").unwrap();
        let after = c.route_counts().chunks_pruned;
        assert!(
            after > before,
            "selective scan must prune chunks via zone maps ({before} -> {after})"
        );
    }
}

/// The same racing stream, with a node kill + process restart + rejoin in
/// the middle: scans and claims keep running (retrying through the
/// unavailable window), and after the hand-off the routed path — now
/// partially served by the rejoined replicas — stays byte-equal to
/// centralized.
#[test]
fn mutate_while_scanning_survives_rejoin_mid_stream() {
    let parts = 4usize;
    let dir = std::env::temp_dir().join(format!(
        "schaladb-scatter-rejoin-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let (shared, ctl) = clock::manual(1_000.0);
    let c = DbCluster::start(
        ClusterConfig::builder()
            .clock(shared)
            .durability(DurabilityConfig::new(dir.clone(), 1))
            .concurrency(scatter_mode())
            .build()
            .unwrap(),
    )
    .unwrap();
    ctl.set(1_000.0);
    c.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {parts} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))
    .unwrap();
    c.exec("CREATE TABLE workers (id INT NOT NULL, host TEXT) PRIMARY KEY (id)")
        .unwrap();
    for w in 0..parts as i64 {
        c.execute(&format!("INSERT INTO workers (id, host) VALUES ({w}, 'node{w:03}')"))
            .unwrap();
    }
    grow(&c, parts, 0, 300);

    let am = AvailabilityManager::new(c.clone());
    let stop = Arc::new(AtomicBool::new(false));
    let mut threads = Vec::new();
    // claim writers: retry through the failover/rejoin windows
    for w in 0..parts {
        let c = c.clone();
        let stop = stop.clone();
        threads.push(std::thread::spawn(move || {
            let claim = c
                .prepare(
                    "UPDATE workqueue SET dur = dur + 1.0 \
                     WHERE taskid = ? AND workerid = ?",
                )
                .unwrap();
            let mut rng = Rng::new(0xABCD + w as u64);
            while !stop.load(Ordering::Relaxed) {
                let i = rng.range(0, 300 * parts as i64);
                match c.exec_prepared(
                    w as u32,
                    AccessKind::UpdateToRunning,
                    &claim,
                    &[Value::Int(i), Value::Int(i % parts as i64)],
                ) {
                    Ok(_) => {}
                    Err(schaladb::Error::Unavailable(_)) => {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                    Err(e) => panic!("writer failed mid-rejoin: {e}"),
                }
            }
        }));
    }
    // steering reader: scatter scans keep serving (replica failover)
    {
        let c = c.clone();
        let stop = stop.clone();
        threads.push(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                match c.query("SELECT status, COUNT(*), SUM(dur) FROM workqueue GROUP BY status")
                {
                    Ok(rs) => {
                        let sum: i64 = rs
                            .rows
                            .iter()
                            .map(|r| r.values[1].as_i64().unwrap())
                            .sum();
                        assert_eq!(sum, 300 * parts as i64);
                    }
                    Err(schaladb::Error::Unavailable(_)) => {
                        std::thread::sleep(std::time::Duration::from_micros(100));
                    }
                    Err(e) => panic!("reader failed mid-rejoin: {e}"),
                }
            }
        }));
    }

    // the outage: kill, promote, let the storm run degraded, then restart
    // and drive the rejoin while claims and scans keep racing
    std::thread::sleep(std::time::Duration::from_millis(30));
    c.kill_node(1).unwrap();
    am.sweep().unwrap();
    std::thread::sleep(std::time::Duration::from_millis(30));
    c.restart_node(1).unwrap();
    let mut rejoined = false;
    for _ in 0..200 {
        if am.sweep().unwrap().rejoined > 0 {
            rejoined = true;
            break;
        }
    }
    assert!(rejoined, "node 1 must rejoin under the racing stream");
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, Ordering::Relaxed);
    for h in threads {
        h.join().unwrap();
    }

    assert_equivalent(&c, "post-rejoin quiesce");
    // the rejoined node is a faithful serving replica: fail the survivor
    // over to it and the equivalence must still hold
    c.kill_node(0).unwrap();
    am.sweep().unwrap();
    assert_equivalent(&c, "served by the rejoined node");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn error_shapes_match_on_both_paths() {
    let c = cluster(2);
    for q in [
        "SELECT nope FROM workqueue GROUP BY status",
        "SELECT status FROM workqueue ORDER BY nope_col LIMIT 3",
        "SELECT COUNT(*) FROM workqueue WHERE nope > 1",
    ] {
        assert!(c.query(q).is_err(), "routed path must reject: {q}");
        assert!(c.query_centralized(q).is_err(), "centralized path must reject: {q}");
    }
}

/// Scatter–gather over a cold-started cluster: seed deterministically
/// (half before the checkpoint cut, half as WAL tail), stop the whole
/// cluster, `DbCluster::open` it, and every routed query must still match
/// its centralized execution — with the reopened state fingerprinting
/// byte-equal to a never-stopped twin.
#[test]
fn scatter_gather_equals_centralized_after_cold_start() {
    let parts = 4usize;
    let dir =
        std::env::temp_dir().join(format!("schaladb-scatter-coldstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk_config = || {
        let (shared, ctl) = clock::manual(1_000.0);
        ctl.set(1_000.0);
        ClusterConfig::builder()
            .clock(shared)
            .concurrency(scatter_mode())
            .durability(DurabilityConfig::new(dir.clone(), 4))
            .build()
            .unwrap()
    };
    let twin = cluster(parts);
    let insert_task = |c: &DbCluster, i: i64| {
        let statuses = ["READY", "RUNNING", "FINISHED"];
        c.execute(&format!(
            "INSERT INTO workqueue (taskid, actid, workerid, status, dur, starttime) \
             VALUES ({i}, {}, {}, '{}', {}.5, {}.0)",
            i % 3,
            i % (parts as i64 + 1),
            statuses[(i % 3) as usize],
            (i * 7) % 13,
            900 + i
        ))
        .unwrap();
    };
    {
        let a = DbCluster::start(mk_config()).unwrap();
        a.exec(&format!(
            "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
             status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
             PARTITION BY HASH(workerid) PARTITIONS {parts} \
             PRIMARY KEY (taskid) INDEX (status)"
        ))
        .unwrap();
        a.exec("CREATE TABLE workers (id INT NOT NULL, host TEXT) PRIMARY KEY (id)")
            .unwrap();
        for i in 0..30i64 {
            insert_task(&a, i);
        }
        for w in 0..parts as i64 {
            a.execute(&format!("INSERT INTO workers (id, host) VALUES ({w}, 'node{w:03}')"))
                .unwrap();
        }
        // cut checkpoints mid-dataset: rows 30..60 ride the WAL tail
        assert!(schaladb::storage::checkpoint::checkpoint_node(&a, 0).unwrap().written > 0);
        assert!(schaladb::storage::checkpoint::checkpoint_node(&a, 1).unwrap().written > 0);
        for i in 30..60i64 {
            insert_task(&a, i);
        }
        assert_equivalent(&a, "pre-stop");
        // scope end: Arcs drop, node WALs flush — clean whole-cluster stop
    }

    let a = DbCluster::open(mk_config()).unwrap();
    assert_eq!(
        a.fingerprint().unwrap(),
        twin.fingerprint().unwrap(),
        "cold-started state diverged from the never-stopped twin"
    );
    assert_equivalent(&a, "cold-start");
}
