//! Experiment-7-style hybrid workload: the steering analytics run
//! *concurrently* with transaction-oriented worker scheduling on the same
//! data. The scatter-gather engine serves the analytics off lock-free
//! partition snapshots, so (a) every analytical read is a consistent cut
//! and (b) monitoring does not serialize the claim/finish hot path.

use schaladb::coordinator::schema;
use schaladb::storage::cluster::ClusterConfig;
use schaladb::storage::{AccessKind, DbCluster, Value};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

fn hybrid_cluster(workers: usize, tasks: usize) -> Arc<DbCluster> {
    let db = DbCluster::start(ClusterConfig::default()).unwrap();
    schema::create_schema(&db, workers).unwrap();
    db.execute(
        "INSERT INTO workflow (wfid, name, status, starttime) \
         VALUES (1, 'hybrid', 'RUNNING', 0.0)",
    )
    .unwrap();
    db.execute(
        "INSERT INTO activity (actid, wfid, name, operator, ord, status, tasks_total, tasks_done) \
         VALUES (1, 1, 'analyze_risers', 'MAP', 0, 'RUNNING', 0, 0)",
    )
    .unwrap();
    for w in 0..workers {
        db.execute(&format!(
            "INSERT INTO node (nodeid, hostname, cores, role, status, heartbeat) \
             VALUES ({w}, 'node{w:03}', 2, 'worker', 'UP', 0.0)"
        ))
        .unwrap();
    }
    let ins = db
        .prepare(
            "INSERT INTO workqueue (taskid, actid, wfid, workerid, failtries, status, starttime) \
             VALUES (?, 1, 1, ?, 0, 'READY', ?)",
        )
        .unwrap();
    let rows: Vec<Vec<Value>> = (0..tasks)
        .map(|i| {
            vec![
                Value::Int(i as i64),
                Value::Int((i % workers) as i64),
                Value::Float(0.0),
            ]
        })
        .collect();
    db.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, &rows).unwrap();
    db
}

/// Claim-and-finish every READY task across `workers` writer threads;
/// returns (total claims, elapsed seconds).
fn drain(db: &Arc<DbCluster>, workers: usize) -> (usize, f64) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for w in 0..workers {
        let db = db.clone();
        handles.push(std::thread::spawn(move || {
            let claim = db
                .prepare(
                    "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                     WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
                     RETURNING taskid",
                )
                .unwrap();
            let fin = db
                .prepare("UPDATE workqueue SET status = 'FINISHED', endtime = NOW() WHERE taskid = ?")
                .unwrap();
            let mut n = 0usize;
            loop {
                let r = db
                    .exec_prepared(
                        w as u32,
                        AccessKind::UpdateToRunning,
                        &claim,
                        &[Value::Int(w as i64)],
                    )
                    .unwrap()
                    .rows();
                let Some(row) = r.rows.first() else { break };
                let tid = row.values[0].as_i64().unwrap();
                db.exec_prepared(
                    w as u32,
                    AccessKind::UpdateToFinished,
                    &fin,
                    &[Value::Int(tid)],
                )
                .unwrap();
                n += 1;
            }
            n
        }));
    }
    let claimed: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    (claimed, t0.elapsed().as_secs_f64())
}

#[test]
fn steering_reads_are_consistent_snapshots_under_writes() {
    let workers = 4;
    let tasks = 1500usize;
    let db = hybrid_cluster(workers, tasks);
    let stop = Arc::new(AtomicBool::new(false));

    // Steering loop: status histogram + total count + a Q1-style join,
    // continuously, while workers churn statuses underneath.
    let sdb = db.clone();
    let sstop = stop.clone();
    let steer = std::thread::spawn(move || {
        let mut iters = 0u64;
        while !sstop.load(Ordering::SeqCst) {
            let rs = sdb
                .query("SELECT status, COUNT(*) AS n FROM workqueue GROUP BY status")
                .unwrap();
            let total: i64 =
                rs.rows.iter().map(|r| r.values[1].as_i64().unwrap()).sum();
            assert_eq!(
                total, tasks as i64,
                "status histogram must be a consistent snapshot"
            );
            let rs = sdb.query("SELECT COUNT(*) FROM workqueue").unwrap();
            assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), tasks as i64);
            let rs = sdb
                .query(
                    "SELECT n.hostname, t.status, COUNT(*) AS c, SUM(t.failtries) \
                     FROM workqueue t JOIN node n ON t.workerid = n.nodeid \
                     GROUP BY n.hostname, t.status ORDER BY n.hostname, t.status",
                )
                .unwrap();
            let jtotal: i64 =
                rs.rows.iter().map(|r| r.values[2].as_i64().unwrap()).sum();
            assert_eq!(jtotal, tasks as i64, "join snapshot must cover every task");
            iters += 1;
        }
        iters
    });

    let (claimed, _) = drain(&db, workers);
    stop.store(true, Ordering::SeqCst);
    let steering_iters = steer.join().unwrap();

    assert_eq!(claimed, tasks, "every task claimed exactly once");
    assert!(steering_iters > 0, "steering ran concurrently");
    let rs = db
        .query("SELECT COUNT(*) FROM workqueue WHERE status = 'FINISHED'")
        .unwrap();
    assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), tasks as i64);
    let counts = db.route_counts();
    let (scatter, join) = (counts.scatter, counts.snapshot_join);
    assert!(
        scatter >= steering_iters * 2,
        "steering aggregates must take the scatter path ({scatter} < {steering_iters} * 2)"
    );
    assert!(join >= steering_iters, "steering joins must take the snapshot-join path");
}

#[test]
fn monitoring_does_not_serialize_scheduling() {
    let workers = 4;
    let tasks = 800usize;

    // Baseline: drain with no monitoring.
    let db = hybrid_cluster(workers, tasks);
    let (claimed, alone) = drain(&db, workers);
    assert_eq!(claimed, tasks);

    // Same workload with two aggressive steering threads hammering
    // full-table aggregates and joins the whole time.
    let db2 = hybrid_cluster(workers, tasks);
    let stop = Arc::new(AtomicBool::new(false));
    let mut monitors = Vec::new();
    for _ in 0..2 {
        let sdb = db2.clone();
        let sstop = stop.clone();
        monitors.push(std::thread::spawn(move || {
            let mut n = 0u64;
            while !sstop.load(Ordering::SeqCst) {
                sdb.query(
                    "SELECT status, COUNT(*), AVG(endtime - starttime) \
                     FROM workqueue GROUP BY status",
                )
                .unwrap();
                sdb.query(
                    "SELECT n.hostname, COUNT(*) AS c FROM workqueue t \
                     JOIN node n ON t.workerid = n.nodeid \
                     GROUP BY n.hostname ORDER BY c DESC",
                )
                .unwrap();
                n += 1;
            }
            n
        }));
    }
    let (claimed2, with_monitor) = drain(&db2, workers);
    stop.store(true, Ordering::SeqCst);
    let monitor_queries: u64 = monitors.into_iter().map(|h| h.join().unwrap()).sum();

    assert_eq!(claimed2, tasks, "scheduling must stay live under monitoring");
    assert!(monitor_queries > 0);
    // Snapshot reads hold no 2PL locks: scheduling must not be serialized
    // behind analytics. The bound is deliberately loose (shared CPU still
    // costs something) — serialization would blow past it by orders of
    // magnitude, CI jitter will not.
    assert!(
        with_monitor < alone * 10.0 + 2.0,
        "monitored drain {with_monitor:.3}s vs alone {alone:.3}s: scheduling serialized?"
    );
    println!(
        "hybrid drain: alone {alone:.3}s, with monitor {with_monitor:.3}s \
         ({monitor_queries} steering queries concurrent)"
    );
}
