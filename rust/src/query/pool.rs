//! The scan pool: a small fixed-size thread pool executing per-partition
//! partial plans concurrently.
//!
//! The paper's data nodes each own their partitions and scan them with
//! local CPU; in this in-process reproduction the pool plays that role —
//! one scatter task per partition replica, all running in parallel, with
//! the caller thread pitching in so a single-partition query pays no
//! dispatch latency at all. The pool is created lazily by the first
//! scatter-gather query and lives as long as its
//! [`DbCluster`](crate::storage::cluster::DbCluster).

use crate::{Error, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One scatter task: runs on a pool worker (or inline on the caller) and
/// returns its partial result.
pub type ScanTask<T> = Box<dyn FnOnce() -> Result<T> + Send + 'static>;

/// Fixed-size worker pool with a shared job queue. Dropping the pool closes
/// the queue and the workers exit.
pub struct ScanPool {
    tx: Mutex<Sender<Job>>,
    size: usize,
}

impl ScanPool {
    /// Pool sized for the machine: one worker per available core, clamped
    /// to a sane range (partition counts in the paper's deployments are
    /// single-digit to low-double-digit).
    pub fn with_default_size() -> ScanPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        ScanPool::new(n.clamp(2, 16))
    }

    pub fn new(size: usize) -> ScanPool {
        assert!(size > 0, "scan pool needs at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..size {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("schaladb-scan-{i}"))
                .spawn(move || loop {
                    // hold the queue lock only for the dequeue, not the job
                    let job = {
                        let g = rx.lock().unwrap();
                        g.recv()
                    };
                    match job {
                        Ok(j) => j(),
                        Err(_) => break, // pool dropped
                    }
                })
                .expect("spawn scan worker");
        }
        ScanPool { tx: Mutex::new(tx), size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Run every task, returning results in input order. All tasks but the
    /// last are dispatched to the pool; the last runs inline on the caller
    /// thread, so a one-task batch never crosses a thread boundary. Panics
    /// inside a task are caught and surfaced as `Error::Engine` so a bad
    /// task can't wedge the collector.
    pub fn run<T>(&self, tasks: Vec<ScanTask<T>>) -> Vec<Result<T>>
    where
        T: Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let mut tasks = tasks;
        let last = tasks.pop().expect("n > 0");
        let (rtx, rrx) = channel::<(usize, Result<T>)>();
        {
            let tx = self.tx.lock().unwrap();
            for (i, f) in tasks.into_iter().enumerate() {
                let rtx = rtx.clone();
                tx.send(Box::new(move || {
                    let r = catch_unwind(AssertUnwindSafe(f))
                        .unwrap_or_else(|_| Err(Error::Engine("scan task panicked".into())));
                    let _ = rtx.send((i, r));
                }))
                .expect("scan pool workers alive");
            }
        }
        let mut out: Vec<Option<Result<T>>> = (0..n).map(|_| None).collect();
        out[n - 1] = Some(
            catch_unwind(AssertUnwindSafe(last))
                .unwrap_or_else(|_| Err(Error::Engine("scan task panicked".into()))),
        );
        for _ in 0..n - 1 {
            let (i, r) = rrx.recv().expect("scan pool result");
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("every slot filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_tasks_and_preserves_order() {
        let pool = ScanPool::new(3);
        let tasks: Vec<ScanTask<usize>> = (0..10)
            .map(|i| {
                let f: ScanTask<usize> = Box::new(move || Ok(i * i));
                f
            })
            .collect();
        let got: Vec<usize> = pool.run(tasks).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn errors_and_panics_are_isolated_per_task() {
        let pool = ScanPool::new(2);
        let tasks: Vec<ScanTask<i32>> = vec![
            Box::new(|| Ok(1)),
            Box::new(|| Err(Error::Engine("boom".into()))),
            Box::new(|| panic!("scan bug")),
            Box::new(|| Ok(4)),
        ];
        let got = pool.run(tasks);
        assert_eq!(*got[0].as_ref().unwrap(), 1);
        assert!(got[1].is_err());
        assert!(got[2].is_err(), "panic must surface as an error, not a hang");
        assert_eq!(*got[3].as_ref().unwrap(), 4);
    }

    #[test]
    fn empty_and_single_batches() {
        let pool = ScanPool::new(2);
        let none: Vec<ScanTask<u8>> = vec![];
        assert!(pool.run(none).is_empty());
        let one: Vec<ScanTask<u8>> = vec![Box::new(|| Ok(7))];
        assert_eq!(*pool.run(one)[0].as_ref().unwrap(), 7);
    }
}
