//! Scatter-gather execution over lock-free chunked partition snapshots.
//!
//! Two shapes, both taken by every auto-commit SELECT that the router in
//! [`DbCluster`](crate::storage::cluster::DbCluster) deems eligible:
//!
//! - **scatter-gather** (`scatter_gather`): join-free SELECTs. Each
//!   (pruned) partition runs the partial plan on the scan pool — filter,
//!   then per-group [`AggState`] partials or a filtered/top-k row set —
//!   and the coordinator merges partials and finishes with the shared
//!   HAVING/ORDER BY/LIMIT/project tail. Only partial states cross the
//!   partition boundary, not rows.
//! - **snapshot-join** (`snapshot_join`): SELECTs with joins. Every
//!   involved partition is scanned in parallel with that table's
//!   single-table WHERE conjuncts pushed into the scan; the relational
//!   pipeline (`run_select`) then runs once at the coordinator.
//!
//! Either way the inputs are versioned copy-on-write chunk snapshots
//! acquired under a brief read latch (see `PartitionStore::snapshot` —
//! an `Arc` bump per clean chunk), so the steering analytics never hold
//! 2PL partition locks while executing — the paper's Experiment-7
//! requirement that monitoring not perturb scheduling.
//!
//! ## The compiled scan path
//!
//! Before the partials run, the WHERE clause is classified against the
//! table schema (see `ScanFilter`): every conjunct of the
//! `col <cmp> literal` shape compiles into the shared
//! [`Conjunct`](crate::storage::cexpr::Conjunct) evaluator from the DML
//! fast path. Compiled conjuncts serve two purposes:
//!
//! 1. **zone-map pruning** — a chunk whose per-column min/max bounds
//!    cannot satisfy some conjunct is skipped whole
//!    ([`Chunk::may_match`](crate::storage::partition::Chunk::may_match));
//!    sound for any compilable *subset* of the
//!    conjunction, since a chunk with no row matching one conjunct has no
//!    row matching the whole AND;
//! 2. **interpreter bypass** — when the *entire* WHERE compiles, the row
//!    filter runs on `Conjunct::matches` alone (`sql_cmp` three-valued
//!    logic, byte-for-byte the interpreter's `Bound::ColCmp` form) and
//!    `bind` is never called. Any uncompilable conjunct keeps the
//!    interpreted evaluator for row filtering (with subset pruning still
//!    active), and binding errors (unknown columns, unbound parameters)
//!    surface exactly as centralized raises them. Like the interpreter's
//!    left-to-right AND short-circuit, skipping a chunk also skips
//!    per-row *evaluation* errors a sibling conjunct would have raised on
//!    its rows — matched results are always identical.

use crate::query::plan::ScatterPlan;
use crate::query::pool::{ScanPool, ScanTask};
use crate::query::ScanMetrics;
use crate::storage::cexpr::{compile_conjunct, Conjunct, CVal};
use crate::storage::partition::ChunkSnapshot;
use crate::storage::sql::exec::{finish_groups, finish_select, run_select, AggState, TableInput};
use crate::storage::sql::expr::{bind, Bound, EvalCtx, Layout};
use crate::storage::sql::{AggFunc, Expr, Op, SelectStmt};
use crate::storage::table_def::TableDef;
use crate::storage::value::{Row, Value};
use crate::storage::ResultSet;
use crate::Result;
use rustc_hash::FxHashMap;
use std::sync::atomic::Ordering as AtomicOrdering;
use std::sync::Arc;

/// Snapshots of one table's target partitions: `(pidx, chunks)` in
/// ascending partition order, each an immutable shared view taken at a
/// single consistent cut (all latches held together during acquisition).
pub(crate) struct TableSnapshots {
    pub def: Arc<TableDef>,
    pub parts: Vec<(usize, ChunkSnapshot)>,
}

/// The compiled form of one table's scan predicate.
pub(crate) struct ScanFilter {
    /// Conjuncts of the `col <cmp> literal` shape — the zone-map pruning
    /// set (always a sound subset of the WHERE conjunction).
    preds: Vec<Conjunct>,
    /// True when `preds` covers the *whole* WHERE clause (or there is
    /// none): row filtering runs on the compiled conjuncts alone and the
    /// interpreter is never consulted.
    full: bool,
}

/// Classify a WHERE clause against `def` (bound as `binding`). Parameters
/// must have been substituted before the scan engine runs; a stray
/// `?`-conjunct is treated as uncompilable so the interpreted evaluator
/// raises its usual unbound-parameter error.
pub(crate) fn compile_scan_filter(
    where_: Option<&Expr>,
    def: &TableDef,
    binding: &str,
) -> ScanFilter {
    let Some(w) = where_ else {
        return ScanFilter { preds: Vec::new(), full: true };
    };
    let mut preds = Vec::new();
    let mut full = true;
    for c in w.conjuncts() {
        match compile_conjunct(c, def, binding) {
            Some(cj) if !matches!(cj.rhs, CVal::Param(_)) => preds.push(cj),
            _ => full = false,
        }
    }
    ScanFilter { preds, full }
}

/// Drive `per_row` over every matching live row of a chunk snapshot: skip
/// empty chunks, zone-prune on the compiled conjuncts (with the shared
/// scanned/pruned accounting), and apply the compiled-or-interpreted keep
/// test. This is the one scan preamble both partial shapes share — the
/// aggregate and scan partials must never diverge on what "matching"
/// means.
fn scan_matching_rows<F>(
    snap: &ChunkSnapshot,
    filter: &ScanFilter,
    wb: Option<&Bound>,
    metrics: &ScanMetrics,
    ectx: &EvalCtx,
    mut per_row: F,
) -> Result<()>
where
    F: FnMut(&Row) -> Result<()>,
{
    for chunk in snap.chunks() {
        if chunk.live == 0 {
            continue;
        }
        if !filter.preds.is_empty() && !chunk.may_match(&filter.preds, &[]) {
            metrics.chunks_pruned.fetch_add(1, AtomicOrdering::Relaxed);
            continue;
        }
        metrics.chunks_scanned.fetch_add(1, AtomicOrdering::Relaxed);
        for r in chunk.rows() {
            let keep = if filter.full {
                filter.preds.iter().all(|c| c.matches(&r.values, &[]))
            } else {
                match wb {
                    Some(b) => b.matches(&r.values, ectx)?,
                    None => true,
                }
            };
            if keep {
                per_row(r)?;
            }
        }
    }
    Ok(())
}

// ---------------- partial plans (run per partition, on the pool) ----------------

/// Shared context of an aggregate-shape partial plan.
struct AggPartialCtx {
    layout: Layout,
    where_: Option<Expr>,
    filter: ScanFilter,
    metrics: Arc<ScanMetrics>,
    group_by: Vec<Expr>,
    aggs: Vec<(AggFunc, bool, Option<Expr>)>,
    now: f64,
}

/// One partition's partial aggregation output: groups in first-seen order,
/// each with a representative row and one partial state per aggregate.
struct PartialGroups {
    order: Vec<Vec<u64>>,
    groups: FxHashMap<Vec<u64>, (Row, Vec<AggState>)>,
}

fn partial_aggregate(ctx: &AggPartialCtx, snap: &ChunkSnapshot) -> Result<PartialGroups> {
    let ectx = EvalCtx { now: ctx.now };
    // interpreted residual filter only when the compiled set is partial
    let wb = match (&ctx.where_, ctx.filter.full) {
        (Some(w), false) => Some(bind(w, &ctx.layout)?),
        _ => None,
    };
    let key_bound = ctx
        .group_by
        .iter()
        .map(|e| bind(e, &ctx.layout))
        .collect::<Result<Vec<_>>>()?;
    let arg_bound = ctx
        .aggs
        .iter()
        .map(|(_, _, arg)| match arg {
            Some(e) => bind(e, &ctx.layout).map(Some),
            None => Ok(None),
        })
        .collect::<Result<Vec<_>>>()?;
    let mut pg = PartialGroups { order: Vec::new(), groups: FxHashMap::default() };
    scan_matching_rows(snap, &ctx.filter, wb.as_ref(), &ctx.metrics, &ectx, |r| {
        let key: Vec<u64> = key_bound
            .iter()
            .map(|b| Ok(b.eval(&r.values, &ectx)?.hash_key()))
            .collect::<Result<Vec<_>>>()?;
        let g = match pg.groups.get_mut(&key) {
            Some(g) => g,
            None => {
                pg.order.push(key.clone());
                pg.groups.entry(key).or_insert_with(|| {
                    (
                        r.clone(),
                        ctx.aggs
                            .iter()
                            .map(|(f, d, _)| AggState::new(*f, *d))
                            .collect(),
                    )
                })
            }
        };
        for (st, arg) in g.1.iter_mut().zip(&arg_bound) {
            let v = match arg {
                Some(b) => Some(b.eval(&r.values, &ectx)?),
                None => None,
            };
            st.push(v)?;
        }
        Ok(())
    })?;
    Ok(pg)
}

/// Shared context of a scan-shape partial plan.
struct ScanPartialCtx {
    layout: Layout,
    where_: Option<Expr>,
    filter: ScanFilter,
    metrics: Arc<ScanMetrics>,
    /// `Some((order keys, k))`: keep only each partition's top-k under the
    /// final sort order (sound because the coordinator re-sorts stably and
    /// truncates to the same k; only pushed down when no HAVING runs).
    topk: Option<(Vec<(Expr, bool)>, usize)>,
    /// LIMIT without ORDER BY: first-k rows per partition suffice.
    limit_only: Option<usize>,
    now: f64,
}

fn partial_scan(ctx: &ScanPartialCtx, snap: &ChunkSnapshot) -> Result<Vec<Row>> {
    let ectx = EvalCtx { now: ctx.now };
    let wb = match (&ctx.where_, ctx.filter.full) {
        (Some(w), false) => Some(bind(w, &ctx.layout)?),
        _ => None,
    };
    let mut out = Vec::new();
    scan_matching_rows(snap, &ctx.filter, wb.as_ref(), &ctx.metrics, &ectx, |r| {
        out.push(r.clone());
        Ok(())
    })?;
    if let Some((keys, k)) = &ctx.topk {
        // bind failures fall through untruncated: the coordinator's ORDER
        // BY will surface the real error (or handle the alias case)
        if out.len() > *k {
            if let Ok(bound) = keys
                .iter()
                .map(|(e, asc)| Ok((bind(e, &ctx.layout)?, *asc)))
                .collect::<Result<Vec<_>>>()
            {
                let mut decorated: Vec<(Vec<Value>, Row)> = Vec::with_capacity(out.len());
                for r in out {
                    let key = bound
                        .iter()
                        .map(|(b, _)| b.eval(&r.values, &ectx))
                        .collect::<Result<Vec<_>>>()?;
                    decorated.push((key, r));
                }
                decorated.sort_by(|(ka, _), (kb, _)| {
                    for ((a, b), (_, asc)) in ka.iter().zip(kb.iter()).zip(bound.iter()) {
                        let o = a.total_cmp(b);
                        let o = if *asc { o } else { o.reverse() };
                        if o != std::cmp::Ordering::Equal {
                            return o;
                        }
                    }
                    std::cmp::Ordering::Equal
                });
                decorated.truncate(*k);
                return Ok(decorated.into_iter().map(|(_, r)| r).collect());
            }
        }
    } else if let Some(k) = ctx.limit_only {
        out.truncate(k);
    }
    Ok(out)
}

// ---------------- coordinator merge ----------------

/// Execute a split join-free SELECT: partials on the pool, merge inline.
pub(crate) fn scatter_gather(
    pool: &ScanPool,
    plan: &ScatterPlan,
    binding: &str,
    snaps: &TableSnapshots,
    metrics: &Arc<ScanMetrics>,
    now: f64,
) -> Result<ResultSet> {
    let layout = Layout::of_table(
        binding,
        snaps.def.schema.columns.iter().map(|c| c.name.clone()),
    );
    let ectx = EvalCtx { now };
    let filter = compile_scan_filter(plan.where_.as_ref(), &snaps.def, binding);

    if plan.aggregated {
        let ctx = Arc::new(AggPartialCtx {
            layout: layout.clone(),
            where_: plan.where_.clone(),
            filter,
            metrics: metrics.clone(),
            group_by: plan.group_by.clone(),
            aggs: plan.agg_specs(),
            now,
        });
        let tasks: Vec<ScanTask<PartialGroups>> = snaps
            .parts
            .iter()
            .map(|(_, snap)| -> ScanTask<PartialGroups> {
                let ctx = ctx.clone();
                let snap = snap.clone();
                Box::new(move || partial_aggregate(&ctx, &snap))
            })
            .collect();

        // Merge partials in ascending-partition order so group first-seen
        // order (and thus unordered output order) matches the centralized
        // single-pass scan exactly.
        let mut order: Vec<Vec<u64>> = Vec::new();
        let mut groups: FxHashMap<Vec<u64>, (Row, Vec<AggState>)> = FxHashMap::default();
        for partial in pool.run(tasks) {
            let mut partial = partial?;
            for key in partial.order.drain(..) {
                let (rep, states) = partial.groups.remove(&key).expect("ordered key present");
                match groups.get_mut(&key) {
                    Some((_, acc)) => {
                        for (a, s) in acc.iter_mut().zip(states) {
                            a.merge(s)?;
                        }
                    }
                    None => {
                        order.push(key.clone());
                        groups.insert(key, (rep, states));
                    }
                }
            }
        }
        // Shared epilogue: empty-group synthesis, `#.aggN` layout, output
        // rows — one implementation for both executors (see exec.rs).
        let spec_pairs: Vec<(AggFunc, bool)> =
            plan.agg_specs().iter().map(|(f, d, _)| (*f, *d)).collect();
        let (out_rows, ext) =
            finish_groups(order, groups, &spec_pairs, &layout, plan.group_by.is_empty());
        return finish_select(
            out_rows,
            &ext,
            &plan.items,
            plan.having.as_ref(),
            &plan.order_by,
            plan.limit,
            &ectx,
        );
    }

    // Scan shape: filter (+ top-k) partials, concatenate, shared tail.
    // Per-partition truncation is only sound when no HAVING re-filters.
    let pushdown_limit = plan.limit.filter(|_| plan.having.is_none()).map(|k| k as usize);
    let ctx = Arc::new(ScanPartialCtx {
        layout: layout.clone(),
        where_: plan.where_.clone(),
        filter,
        metrics: metrics.clone(),
        topk: match (&pushdown_limit, plan.order_by.is_empty()) {
            (Some(k), false) => Some((plan.order_by.clone(), *k)),
            _ => None,
        },
        limit_only: match (&pushdown_limit, plan.order_by.is_empty()) {
            (Some(k), true) => Some(*k),
            _ => None,
        },
        now,
    });
    let tasks: Vec<ScanTask<Vec<Row>>> = snaps
        .parts
        .iter()
        .map(|(_, snap)| -> ScanTask<Vec<Row>> {
            let ctx = ctx.clone();
            let snap = snap.clone();
            Box::new(move || partial_scan(&ctx, &snap))
        })
        .collect();
    let mut rows = Vec::new();
    for partial in pool.run(tasks) {
        rows.extend(partial?);
    }
    finish_select(
        rows,
        &layout,
        &plan.items,
        plan.having.as_ref(),
        &plan.order_by,
        plan.limit,
        &ectx,
    )
}

// ---------------- snapshot-join ----------------

/// The conjuncts of `where_` that resolve entirely against `layout` —
/// the single-table filter pushed into that table's scan. Mirrors the
/// centralized planner's pushdown (left-outer right sides get none).
pub(crate) fn single_table_filter(where_: Option<&Expr>, layout: &Layout) -> Option<Expr> {
    let w = where_?;
    let mut kept: Option<Expr> = None;
    for c in w.conjuncts() {
        if !c.has_aggregate() && bind(c, layout).is_ok() {
            kept = Some(match kept {
                None => c.clone(),
                Some(prev) => Expr::Binary(Op::And, Box::new(prev), Box::new(c.clone())),
            });
        }
    }
    kept
}

/// Execute a SELECT with joins: all partitions of all involved tables are
/// filtered in parallel over their snapshots, then the full relational
/// pipeline runs once at the coordinator. No 2PL locks are taken.
pub(crate) fn snapshot_join(
    pool: &ScanPool,
    s: &SelectStmt,
    snaps: &[TableSnapshots],
    metrics: &Arc<ScanMetrics>,
    now: f64,
) -> Result<ResultSet> {
    let ectx = EvalCtx { now };
    fn binding_of(s: &SelectStmt, ti: usize) -> &str {
        if ti == 0 {
            s.from.binding()
        } else {
            s.joins[ti - 1].table.binding()
        }
    }
    let mut specs: Vec<Arc<ScanPartialCtx>> = Vec::with_capacity(snaps.len());
    for (ti, snap) in snaps.iter().enumerate() {
        let binding = binding_of(s, ti);
        let layout = Layout::of_table(
            binding,
            snap.def.schema.columns.iter().map(|c| c.name.clone()),
        );
        // Pushing a filter into the right side of a LEFT JOIN would change
        // its padding semantics, so those scan full (as centralized does).
        let push = ti == 0 || !s.joins[ti - 1].left_outer;
        let filter = if push { single_table_filter(s.where_.as_ref(), &layout) } else { None };
        let compiled = compile_scan_filter(filter.as_ref(), &snap.def, binding);
        specs.push(Arc::new(ScanPartialCtx {
            layout,
            where_: filter,
            filter: compiled,
            metrics: metrics.clone(),
            topk: None,
            limit_only: None,
            now,
        }));
    }
    let mut tasks: Vec<ScanTask<Vec<Row>>> = Vec::new();
    for (ti, snap) in snaps.iter().enumerate() {
        for (_, part) in &snap.parts {
            let spec = specs[ti].clone();
            let part = part.clone();
            tasks.push(Box::new(move || partial_scan(&spec, &part)));
        }
    }
    let mut results = pool.run(tasks).into_iter();
    let mut inputs = Vec::with_capacity(snaps.len());
    for (ti, snap) in snaps.iter().enumerate() {
        let mut rows = Vec::new();
        for _ in &snap.parts {
            rows.extend(results.next().expect("one result per partition task")?);
        }
        inputs.push(TableInput {
            binding: binding_of(s, ti).to_string(),
            columns: snap.def.schema.columns.iter().map(|c| c.name.clone()).collect(),
            rows,
        });
    }
    run_select(s, inputs, &ectx)
}
