//! Plan split for the scatter-gather engine.
//!
//! A read-only, join-free `SELECT` splits into:
//!
//! - a **partial plan**, shipped to every (pruned) partition: evaluate the
//!   WHERE predicate against the partition snapshot, then either fold rows
//!   into per-group [`AggState`](crate::storage::sql::exec::AggState)
//!   partials (aggregate shape) or keep the filtered rows, truncated to a
//!   per-partition top-k when ORDER BY + LIMIT allow (scan shape);
//! - a **merge plan**, run once at the coordinator: combine partial
//!   aggregate states group by group (`AggState::merge`), then apply
//!   HAVING, ORDER BY, LIMIT and projection — the exact same tail stages
//!   the centralized pipeline runs
//!   ([`finish_select`](crate::storage::sql::exec::finish_select)), which
//!   is what makes the two paths result-identical by construction.
//!
//! Join shapes don't split (the coordinator joins over parallel snapshot
//! scans instead — see `crate::query::engine`), and DML never comes here.

use crate::storage::sql::exec::{rewrite_aggregates, substitute_aliases};
use crate::storage::sql::{AggFunc, Expr, Op, SelectItem, SelectStmt, Statement, TableRef};
use crate::storage::value::Value;

/// The split product for one join-free SELECT. Expressions in `items`,
/// `having` and `order_by` have aggregate calls rewritten to `#.aggN`
/// references into the merge layout; `aggs[N]` is the aggregate each
/// synthetic column stands for.
#[derive(Clone, Debug)]
pub struct ScatterPlan {
    /// Alias-substituted GROUP BY keys (bound per partition).
    pub group_by: Vec<Expr>,
    /// Distinct aggregate calls, in `#.aggN` order (the pushed-down part).
    pub aggs: Vec<Expr>,
    /// Select items with aggregates rewritten (the merge projection).
    pub items: Vec<SelectItem>,
    /// Alias-substituted, aggregate-rewritten HAVING (merge stage).
    pub having: Option<Expr>,
    /// Alias-substituted, aggregate-rewritten ORDER BY (merge stage).
    pub order_by: Vec<(Expr, bool)>,
    pub limit: Option<u64>,
    /// WHERE predicate, evaluated inside every partial (filter pushdown).
    pub where_: Option<Expr>,
    /// True when any GROUP BY/aggregate runs (partial-aggregate shape);
    /// false means pure filter/top-k scan partials.
    pub aggregated: bool,
}

impl ScatterPlan {
    /// Split a SELECT. Returns `None` for join shapes — those execute as
    /// parallel snapshot scans with the join at the coordinator instead.
    pub fn build(s: &SelectStmt) -> Option<ScatterPlan> {
        if !s.joins.is_empty() {
            return None;
        }
        // Mirror of run_select stages 3–4: alias substitution, then
        // aggregate rewrite. Any divergence here would break the
        // scatter == centralized equivalence the tests pin down.
        let aliases: Vec<(String, Expr)> = s
            .items
            .iter()
            .filter_map(|it| match it {
                SelectItem::Expr { expr, alias: Some(a) } => Some((a.clone(), expr.clone())),
                _ => None,
            })
            .collect();
        let subst = |e: &Expr| substitute_aliases(e, &aliases);
        let having = s.having.as_ref().map(&subst);
        let order_by: Vec<(Expr, bool)> =
            s.order_by.iter().map(|(e, asc)| (subst(e), *asc)).collect();
        let group_by: Vec<Expr> = s.group_by.iter().map(&subst).collect();

        let mut aggs: Vec<Expr> = Vec::new();
        let items: Vec<SelectItem> = s
            .items
            .iter()
            .map(|it| match it {
                SelectItem::Expr { expr, alias } => SelectItem::Expr {
                    expr: rewrite_aggregates(expr, &mut aggs),
                    alias: alias.clone(),
                },
                w => w.clone(),
            })
            .collect();
        let having = having.map(|h| rewrite_aggregates(&h, &mut aggs));
        let order_by: Vec<(Expr, bool)> = order_by
            .into_iter()
            .map(|(e, asc)| (rewrite_aggregates(&e, &mut aggs), asc))
            .collect();
        let aggregated = !group_by.is_empty() || !aggs.is_empty();
        Some(ScatterPlan {
            group_by,
            aggs,
            items,
            having,
            order_by,
            limit: s.limit,
            where_: s.where_.clone(),
            aggregated,
        })
    }

    /// (function, distinct, argument) triple per pushed-down aggregate.
    pub fn agg_specs(&self) -> Vec<(AggFunc, bool, Option<Expr>)> {
        self.aggs
            .iter()
            .map(|a| match a {
                Expr::Agg { func, arg, distinct } => {
                    (*func, *distinct, arg.as_deref().cloned())
                }
                _ => unreachable!("aggs only collects Agg nodes"),
            })
            .collect()
    }
}

/// Catalog facts `explain` needs about one table; the caller supplies a
/// lookup so the renderer works both with a live cluster catalog and
/// standalone (tests, offline plan inspection).
#[derive(Clone, Debug)]
pub struct TableInfo {
    pub partitions: usize,
    pub partition_col: Option<String>,
}

/// Render an EXPLAIN-style description of how the engine will execute
/// `stmt`: chosen path (scatter-gather aggregate / scatter scan /
/// snapshot-join / centralized), pushed-down aggregates, group keys, and
/// partition pruning. This is what `Prepared::describe()` returns.
pub fn explain<F>(stmt: &Statement, table_info: F) -> String
where
    F: Fn(&str) -> Option<TableInfo>,
{
    match stmt {
        Statement::Select(s) => explain_select(s, &table_info),
        Statement::Insert { table, .. } => format!(
            "plan: centralized transactional write (2PL + synchronous replica apply)\n  table: {}\n",
            table_label(table, &table_info)
        ),
        Statement::Update { table, .. } | Statement::Delete { table, .. } => format!(
            "plan: centralized transactional write (2PL + synchronous replica apply)\n  table: {}\n",
            table_label(&table.table, &table_info)
        ),
        Statement::CreateTable { name, .. } => {
            format!("plan: DDL (catalog update)\n  table: {name}\n")
        }
    }
}

fn table_label<F>(table: &str, info: &F) -> String
where
    F: Fn(&str) -> Option<TableInfo>,
{
    match info(table) {
        Some(ti) => format!("{table} ({} partitions)", ti.partitions),
        None => table.to_string(),
    }
}

fn explain_select<F>(s: &SelectStmt, info: &F) -> String
where
    F: Fn(&str) -> Option<TableInfo>,
{
    let mut out = String::new();
    if !s.joins.is_empty() {
        out.push_str(
            "plan: snapshot-join (lock-free parallel partition scans, join at coordinator)\n",
        );
        let mut tables = vec![table_label(&s.from.table, info)];
        for j in &s.joins {
            tables.push(table_label(&j.table.table, info));
        }
        out.push_str(&format!("  tables: {}\n", tables.join(", ")));
        out.push_str(
            "  pushdown: single-table WHERE conjuncts filter each scan (inner sides only)\n",
        );
        out.push_str(&pruning_line(s, &s.from, info));
        return out;
    }
    let plan = ScatterPlan::build(s).expect("join-free SELECT always splits");
    if plan.aggregated {
        out.push_str("plan: scatter-gather aggregate (partial aggregates merged at coordinator)\n");
        out.push_str(&format!("  table: {}\n", table_label(&s.from.table, info)));
        let rendered: Vec<String> = plan.aggs.iter().map(render_expr).collect();
        out.push_str(&format!("  pushdown: filter + partial [{}]\n", rendered.join(", ")));
        if !plan.group_by.is_empty() {
            let keys: Vec<String> = plan.group_by.iter().map(render_expr).collect();
            out.push_str(&format!("  group keys: [{}]\n", keys.join(", ")));
        }
        out.push_str("  merge: AggState::merge per group, then HAVING / ORDER BY / LIMIT / project\n");
    } else {
        out.push_str("plan: scatter scan (lock-free parallel filter");
        if plan.limit.is_some() && !plan.order_by.is_empty() {
            out.push_str(" + per-partition top-k");
        } else if plan.limit.is_some() {
            out.push_str(" + per-partition limit");
        }
        out.push_str(")\n");
        out.push_str(&format!("  table: {}\n", table_label(&s.from.table, info)));
        out.push_str(
            "  note: when pruning resolves to a single partition at bind time, the \
             centralized index-probe path runs instead\n",
        );
    }
    out.push_str(&pruning_line(s, &s.from, info));
    out.push_str("  reads: versioned partition snapshots, failover-aware, no 2PL locks\n");
    out
}

fn pruning_line<F>(s: &SelectStmt, from: &TableRef, info: &F) -> String
where
    F: Fn(&str) -> Option<TableInfo>,
{
    let Some(ti) = info(&from.table) else {
        return "  pruning: unknown (no catalog)\n".to_string();
    };
    let n = ti.partitions;
    let Some(pcol) = &ti.partition_col else {
        return format!("  pruning: none (table has {n} partition(s), no partition column)\n");
    };
    if let Some(w) = &s.where_ {
        for c in w.conjuncts() {
            if let Expr::Binary(Op::Eq, a, b) = c {
                let pair = match (a.as_ref(), b.as_ref()) {
                    (Expr::Col { name, .. }, Expr::Lit(Value::Int(k)))
                    | (Expr::Lit(Value::Int(k)), Expr::Col { name, .. }) => {
                        Some((name.as_str(), Some(*k), None))
                    }
                    (Expr::Col { name, .. }, Expr::Param(i))
                    | (Expr::Param(i), Expr::Col { name, .. }) => {
                        Some((name.as_str(), None, Some(*i)))
                    }
                    _ => None,
                };
                if let Some((name, lit, param)) = pair {
                    if name.eq_ignore_ascii_case(pcol) {
                        return match (lit, param) {
                            (Some(k), _) => format!(
                                "  pruning: {pcol} = {k} -> 1 of {n} partitions\n"
                            ),
                            (_, Some(i)) => format!(
                                "  pruning: {pcol} = ?{i} -> 1 of {n} partitions (resolved at bind)\n"
                            ),
                            _ => unreachable!("pair carries a literal or a param"),
                        };
                    }
                }
            }
        }
    }
    format!("  pruning: none (scatter across all {n} partitions)\n")
}

/// Compact SQL-ish rendering of an expression for plan output.
pub fn render_expr(e: &Expr) -> String {
    match e {
        Expr::Lit(v) => v.to_string(),
        Expr::Param(i) => format!("?{i}"),
        Expr::Col { table, name } => match table {
            Some(t) => format!("{t}.{name}"),
            None => name.clone(),
        },
        Expr::Agg { func, arg, distinct } => {
            let inner = match arg {
                Some(a) => render_expr(a),
                None => "*".to_string(),
            };
            if *distinct {
                format!("{}(DISTINCT {inner})", func.name())
            } else {
                format!("{}({inner})", func.name())
            }
        }
        Expr::Unary(op, x) => format!("{}{}", op_str(*op), render_expr(x)),
        Expr::Binary(op, a, b) => {
            format!("{} {} {}", render_expr(a), op_str(*op), render_expr(b))
        }
        Expr::Func { name, args } => {
            let rendered: Vec<String> = args.iter().map(render_expr).collect();
            format!("{}({})", name, rendered.join(", "))
        }
        other => format!("{other:?}"),
    }
}

fn op_str(op: Op) -> &'static str {
    match op {
        Op::Add => "+",
        Op::Sub => "-",
        Op::Mul => "*",
        Op::Div => "/",
        Op::Mod => "%",
        Op::Eq => "=",
        Op::Ne => "!=",
        Op::Lt => "<",
        Op::Le => "<=",
        Op::Gt => ">",
        Op::Ge => ">=",
        Op::And => "AND",
        Op::Or => "OR",
        Op::Not => "NOT ",
        Op::Neg => "-",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::sql::parse;

    fn select(sql: &str) -> SelectStmt {
        match parse(sql).unwrap() {
            Statement::Select(s) => s,
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn split_collects_aggregates_and_rewrites_references() {
        let s = select(
            "SELECT wid, COUNT(*) AS n, AVG(dur) FROM t WHERE status = 'F' \
             GROUP BY wid HAVING n > 1 ORDER BY n DESC, wid",
        );
        let p = ScatterPlan::build(&s).unwrap();
        assert!(p.aggregated);
        assert_eq!(p.aggs.len(), 2, "COUNT(*) and AVG(dur)");
        assert_eq!(p.group_by.len(), 1);
        assert!(p.where_.is_some());
        // HAVING `n > 1` resolved through the alias to the rewritten agg ref
        let h = p.having.as_ref().unwrap();
        assert!(
            matches!(h, Expr::Binary(Op::Gt, a, _)
                if matches!(a.as_ref(), Expr::Col { table: Some(t), name } if t == "#" && name == "agg0")),
            "alias-substituted HAVING must reference #.agg0, got {h:?}"
        );
    }

    #[test]
    fn scan_shape_has_no_aggregates() {
        let s = select("SELECT taskid FROM t WHERE wid = 3 ORDER BY taskid LIMIT 5");
        let p = ScatterPlan::build(&s).unwrap();
        assert!(!p.aggregated);
        assert!(p.aggs.is_empty());
        assert_eq!(p.limit, Some(5));
    }

    #[test]
    fn joins_do_not_split() {
        let s = select("SELECT COUNT(*) FROM t JOIN u ON t.a = u.a");
        assert!(ScatterPlan::build(&s).is_none());
    }

    #[test]
    fn explain_renders_each_shape() {
        let info = |t: &str| {
            Some(TableInfo {
                partitions: if t == "t" { 8 } else { 1 },
                partition_col: if t == "t" { Some("wid".into()) } else { None },
            })
        };
        let agg = parse("SELECT status, COUNT(*) FROM t GROUP BY status").unwrap();
        let txt = explain(&agg, info);
        assert!(txt.contains("scatter-gather aggregate"), "{txt}");
        assert!(txt.contains("COUNT(*)"), "{txt}");
        assert!(txt.contains("all 8 partitions"), "{txt}");

        let pruned = parse("SELECT COUNT(*) FROM t WHERE wid = ?").unwrap();
        let txt = explain(&pruned, info);
        assert!(txt.contains("wid = ?0"), "{txt}");
        assert!(txt.contains("resolved at bind"), "{txt}");

        let join = parse("SELECT COUNT(*) FROM t JOIN u ON t.a = u.a").unwrap();
        let txt = explain(&join, info);
        assert!(txt.contains("snapshot-join"), "{txt}");

        let dml = parse("UPDATE t SET a = 1 WHERE wid = 2").unwrap();
        let txt = explain(&dml, info);
        assert!(txt.contains("centralized transactional write"), "{txt}");
    }
}
