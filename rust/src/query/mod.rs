//! Parallel scatter-gather query engine for read-only SELECTs.
//!
//! The paper's central claim (§4–5, Experiment 7) is that transaction-
//! oriented scheduling and online-analytical steering can share one
//! in-memory database with negligible interference. The centralized
//! executor undermines that in-process: every SELECT took 2PL read locks
//! on its partitions and ran single-threaded at the coordinator, so the
//! steering `Monitor` contended head-on with worker claims. This subsystem
//! restores the paper's property:
//!
//! - [`plan`]: splits a join-free SELECT into a per-partition **partial
//!   plan** (filter + partial aggregates + top-k) and a coordinator
//!   **merge plan** (combine `AggState` partials, then HAVING/ORDER
//!   BY/LIMIT/project), plus the EXPLAIN renderer behind
//!   `Prepared::describe()`.
//! - [`engine`]: executes partials concurrently on the scan pool over
//!   **versioned copy-on-write chunk snapshots** — acquired under a brief
//!   read latch (an `Arc` bump per clean chunk), released before any work
//!   runs — honoring failover replica selection. Scans compile eligible
//!   WHERE conjuncts into the shared [`Conjunct`](crate::storage::cexpr)
//!   form and consult per-chunk **zone maps** to skip whole chunks that
//!   cannot match; [`ScanMetrics`] counts scanned vs pruned chunks
//!   (surfaced through `DbCluster::route_counts`). Join shapes run as
//!   parallel snapshot scans with the join at the coordinator.
//! - [`pool`]: the fixed-size scan pool standing in for data-node-local
//!   query threads.
//!
//! Routing lives in `DbCluster::exec_stmt`: auto-commit SELECTs go through
//! this engine unless they prune to a single partition without aggregates
//! (the `getREADYtasks` point pattern, where the centralized index-probe
//! path is faster). SELECTs inside multi-statement transactions always
//! stay on the 2PL path so they read their own writes.

pub mod engine;
pub mod plan;
pub mod pool;

pub use plan::{explain, ScatterPlan, TableInfo};
pub use pool::ScanPool;

use std::sync::atomic::AtomicU64;

/// Chunk-granularity scan telemetry, shared by every partial task of a
/// cluster's scatter/snapshot-join executions. `chunks_pruned` counts
/// chunks a zone map excluded before any row was touched; `chunks_scanned`
/// counts chunks whose rows actually ran through the filter. Exposed via
/// `DbCluster::route_counts` so tests (and steering dashboards) can see
/// pruning take effect.
#[derive(Default)]
pub struct ScanMetrics {
    pub chunks_scanned: AtomicU64,
    pub chunks_pruned: AtomicU64,
}
