//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component of the system (workload generation, simulated
//! task durations, property tests) takes an explicit seed so experiments are
//! reproducible bit-for-bit. The generator is `xoshiro256**` seeded through
//! SplitMix64 — small, fast, and good enough statistically for workload
//! synthesis; we deliberately avoid external crates (none are available
//! offline).

/// `xoshiro256**` PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread the seed over the full state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`. Panics if `lo >= hi`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[0, n)`. Panics if `n == 0`.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index into empty collection");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/σ, truncated below at `min`.
    ///
    /// This is the paper's "mean task duration of X seconds" model: task
    /// durations cluster around the mean with mild dispersion and are never
    /// negative.
    pub fn task_duration(&mut self, mean: f64, min: f64) -> f64 {
        let sd = mean * 0.15;
        (mean + sd * self.normal()).max(min)
    }

    /// Exponential with the given mean (inter-arrival synthesis).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * (1.0 - self.f64()).ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Split off an independently-seeded child generator (for per-thread use).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c = Rng::new(43).next_u64();
        assert_ne!(a[0], c);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.range(-5, 5);
            assert!((-5..5).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn task_duration_positive_and_near_mean() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mean = 60.0;
        let xs: Vec<f64> = (0..n).map(|_| r.task_duration(mean, 0.01)).collect();
        assert!(xs.iter().all(|&x| x >= 0.01));
        let m = xs.iter().sum::<f64>() / n as f64;
        assert!((m - mean).abs() < 1.0, "sample mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<i32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle left input unchanged");
    }

    #[test]
    fn split_streams_diverge() {
        let mut r = Rng::new(11);
        let mut a = r.split();
        let mut b = r.split();
        let xs: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
