//! Clock abstraction shared by the real engine and the simulator.
//!
//! The WQ relation stores task start/end times and the steering queries use
//! predicates like "started in the last minute" (`NOW() - 60`). To keep one
//! SQL code path for both the real engine (wall clock) and the
//! discrete-event simulator (virtual clock), time is always `f64` seconds
//! since an epoch chosen by the clock implementation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// A monotonic source of seconds-since-epoch.
pub trait Clock: Send + Sync {
    /// Current time in seconds.
    fn now(&self) -> f64;
}

/// Wall clock measured from process-local epoch (first use).
pub struct WallClock {
    start: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { start: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Manually-advanced clock used by the discrete-event simulator and by
/// deterministic tests. Stores seconds as an `f64` bit pattern in an atomic.
pub struct ManualClock {
    bits: AtomicU64,
}

impl ManualClock {
    pub fn new(start: f64) -> Self {
        ManualClock { bits: AtomicU64::new(start.to_bits()) }
    }

    /// Jump to an absolute time. Panics when moving backwards, which would
    /// indicate a broken event loop.
    pub fn set(&self, t: f64) {
        let prev = f64::from_bits(self.bits.swap(t.to_bits(), Ordering::SeqCst));
        assert!(t + 1e-12 >= prev, "clock moved backwards: {prev} -> {t}");
    }

    /// Advance by a delta and return the new time.
    pub fn advance(&self, dt: f64) -> f64 {
        assert!(dt >= 0.0);
        let t = self.now() + dt;
        self.set(t);
        t
    }
}

impl Clock for ManualClock {
    fn now(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::SeqCst))
    }
}

/// Shared, dyn-erased clock handle used throughout the storage engine.
pub type SharedClock = Arc<dyn Clock>;

/// Convenience constructor for a shared wall clock.
pub fn wall() -> SharedClock {
    Arc::new(WallClock::new())
}

/// Convenience constructor for a shared manual clock starting at `t0`.
pub fn manual(t0: f64) -> (SharedClock, Arc<ManualClock>) {
    let c = Arc::new(ManualClock::new(t0));
    (c.clone() as SharedClock, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotonic() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_set_advance() {
        let c = ManualClock::new(10.0);
        assert_eq!(c.now(), 10.0);
        c.advance(5.5);
        assert_eq!(c.now(), 15.5);
        c.set(20.0);
        assert_eq!(c.now(), 20.0);
    }

    #[test]
    #[should_panic(expected = "clock moved backwards")]
    fn manual_clock_rejects_backwards() {
        let c = ManualClock::new(10.0);
        c.set(5.0);
    }

    #[test]
    fn shared_handles() {
        let (shared, ctl) = manual(0.0);
        ctl.advance(3.0);
        assert_eq!(shared.now(), 3.0);
    }
}
