//! Deterministic fault injection for crash-surface tests.
//!
//! A *failpoint* is a named hook compiled into a durability-critical seam
//! (WAL append, checkpoint rename, rejoin cut, …). In production every
//! hook is a single relaxed atomic load — the registry is empty and
//! `hit()` returns immediately. Tests (or the `DCHIRON_FAILPOINTS`
//! environment variable) arm individual points with an [`Action`]:
//!
//! - `Err` — the seam returns an injected `Error::Io`, modelling a failed
//!   syscall (write/rename/fsync).
//! - `Panic` — the seam panics, modelling a crash mid-operation. Only
//!   safe at seams that hold no poisonable locks (e.g. the server frame
//!   pump, whose handler threads are isolated per connection).
//! - `Delay(ms)` — the seam sleeps, widening race windows.
//! - `OneShot(inner)` — fires `inner` exactly once, then disarms. The
//!   workhorse for recovery tests: inject one fault, then let the
//!   recovery path run clean.
//!
//! Env syntax (`;`-separated, first match wins):
//!
//! ```text
//! DCHIRON_FAILPOINTS='wal-append-before-flush=panic;ckpt-after-tmp-write=err'
//! DCHIRON_FAILPOINTS='rejoin-final-cut=oneshot(err);wal-flush=delay(5)'
//! ```
//!
//! The registry is process-global; tests that arm points must call
//! [`reset`] when done (and serialize with other failpoint tests — the
//! chaos suites run their schedules sequentially for this reason).

use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// What an armed failpoint does when its seam is hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Disarmed — `hit` is a no-op.
    Off,
    /// Panic with the failpoint's name, modelling a crash mid-seam.
    Panic,
    /// Return an injected `Error::Io`, modelling a failed syscall.
    Err,
    /// Sleep for the given number of milliseconds, widening races.
    Delay(u64),
    /// Fire the inner action exactly once, then disarm.
    OneShot(Box<Action>),
}

impl Action {
    /// Parse one action spec: `off | panic | err | delay(MS) |
    /// oneshot(ACTION)`.
    fn parse(spec: &str) -> Result<Action> {
        let spec = spec.trim();
        if let Some(rest) = spec.strip_prefix("oneshot(").and_then(|s| s.strip_suffix(')')) {
            let inner = Action::parse(rest)?;
            if matches!(inner, Action::Off | Action::OneShot(_)) {
                return Err(Error::Parse(format!("failpoint: invalid oneshot inner {rest:?}")));
            }
            return Ok(Action::OneShot(Box::new(inner)));
        }
        if let Some(rest) = spec.strip_prefix("delay(").and_then(|s| s.strip_suffix(')')) {
            let ms: u64 = rest
                .trim()
                .parse()
                .map_err(|_| Error::Parse(format!("failpoint: invalid delay {rest:?}")))?;
            return Ok(Action::Delay(ms));
        }
        match spec {
            "off" => Ok(Action::Off),
            "panic" => Ok(Action::Panic),
            "err" => Ok(Action::Err),
            _ => Err(Error::Parse(format!("failpoint: unknown action {spec:?}"))),
        }
    }
}

struct Registry {
    points: HashMap<String, Action>,
    hits: HashMap<String, u64>,
}

/// Count of currently armed (non-`Off`) points. `hit()`'s fast path is a
/// single relaxed load of this — zero means no lock, no lookup.
static ARMED: AtomicUsize = AtomicUsize::new(0);

fn registry() -> &'static Mutex<Registry> {
    static REG: OnceLock<Mutex<Registry>> = OnceLock::new();
    REG.get_or_init(|| {
        let mut reg = Registry { points: HashMap::new(), hits: HashMap::new() };
        if let Ok(spec) = std::env::var("DCHIRON_FAILPOINTS") {
            match parse_spec(&spec) {
                Ok(parsed) => {
                    for (name, action) in parsed {
                        arm(&mut reg, &name, action);
                    }
                }
                Err(e) => eprintln!("[failpoint] ignoring DCHIRON_FAILPOINTS: {e}"),
            }
        }
        Mutex::new(reg)
    })
}

fn parse_spec(spec: &str) -> Result<Vec<(String, Action)>> {
    let mut out = Vec::new();
    for entry in spec.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, action) = entry
            .split_once('=')
            .ok_or_else(|| Error::Parse(format!("failpoint: missing '=' in {entry:?}")))?;
        let name = name.trim();
        if name.is_empty() {
            return Err(Error::Parse(format!("failpoint: empty name in {entry:?}")));
        }
        out.push((name.to_string(), Action::parse(action)?));
    }
    Ok(out)
}

/// Install `action` for `name` inside a held registry, maintaining the
/// ARMED count that gates the fast path.
fn arm(reg: &mut Registry, name: &str, action: Action) {
    let was_armed = reg.points.get(name).is_some_and(|a| *a != Action::Off);
    let now_armed = action != Action::Off;
    match action {
        Action::Off => {
            reg.points.remove(name);
        }
        a => {
            reg.points.insert(name.to_string(), a);
        }
    }
    match (was_armed, now_armed) {
        (false, true) => {
            ARMED.fetch_add(1, Ordering::SeqCst);
        }
        (true, false) => {
            ARMED.fetch_sub(1, Ordering::SeqCst);
        }
        _ => {}
    }
}

/// Arm (or disarm, with [`Action::Off`]) a failpoint programmatically.
pub fn set(name: &str, action: Action) {
    let mut reg = registry().lock().unwrap();
    arm(&mut reg, name, action);
}

/// Disarm a single failpoint.
pub fn clear(name: &str) {
    set(name, Action::Off);
}

/// Disarm every failpoint and zero the hit counters. Tests that arm
/// points must call this when done.
pub fn reset() {
    let mut reg = registry().lock().unwrap();
    let armed = reg.points.len();
    reg.points.clear();
    reg.hits.clear();
    if armed > 0 {
        ARMED.fetch_sub(armed, Ordering::SeqCst);
    }
}

/// Parse and apply an env-style spec (`name=action;name=action`).
/// Returns how many points were configured.
pub fn configure(spec: &str) -> Result<usize> {
    let parsed = parse_spec(spec)?;
    let n = parsed.len();
    let mut reg = registry().lock().unwrap();
    for (name, action) in parsed {
        arm(&mut reg, &name, action);
    }
    Ok(n)
}

/// How many times `name` has been hit *while armed* (OneShot consumption
/// counts). Lets tests assert an injected fault actually fired.
pub fn hits(name: &str) -> u64 {
    registry().lock().unwrap().hits.get(name).copied().unwrap_or(0)
}

/// Evaluate the failpoint `name`. The overwhelmingly common case — no
/// failpoint armed anywhere in the process — is a single relaxed atomic
/// load and an immediate `Ok(())`.
#[inline]
pub fn hit(name: &str) -> Result<()> {
    if ARMED.load(Ordering::Relaxed) == 0 {
        // Touch the registry once so DCHIRON_FAILPOINTS is parsed even if
        // nothing ever calls set(); OnceLock makes repeats free.
        if !env_checked() {
            let _ = registry();
            return hit(name);
        }
        return Ok(());
    }
    hit_slow(name)
}

/// Whether the env spec has been folded into the registry yet.
fn env_checked() -> bool {
    static CHECKED: AtomicUsize = AtomicUsize::new(0);
    if CHECKED.load(Ordering::Relaxed) == 1 {
        return true;
    }
    CHECKED.store(1, Ordering::Relaxed);
    false
}

#[cold]
fn hit_slow(name: &str) -> Result<()> {
    let action = {
        let mut reg = registry().lock().unwrap();
        let Some(action) = reg.points.get(name).cloned() else {
            return Ok(());
        };
        *reg.hits.entry(name.to_string()).or_insert(0) += 1;
        if let Action::OneShot(inner) = action {
            arm(&mut reg, name, Action::Off);
            *inner
        } else {
            action
        }
    };
    match action {
        Action::Off => Ok(()),
        Action::Panic => panic!("failpoint '{name}' (injected panic)"),
        Action::Err => {
            Err(Error::Io(std::io::Error::other(format!("failpoint '{name}' (injected error)"))))
        }
        Action::Delay(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        Action::OneShot(_) => unreachable!("oneshot unwrapped above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global, so these tests serialize on a local
    // mutex and reset() on every path.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn off_by_default_and_after_reset() {
        let _g = serial();
        reset();
        assert!(hit("nothing-armed").is_ok());
        set("x", Action::Err);
        assert!(hit("x").is_err());
        reset();
        assert!(hit("x").is_ok());
        assert_eq!(hits("x"), 0);
    }

    #[test]
    fn err_action_is_io_error_and_counts_hits() {
        let _g = serial();
        reset();
        set("wal-append-before-flush", Action::Err);
        let e = hit("wal-append-before-flush").unwrap_err();
        assert!(matches!(e, Error::Io(_)), "got {e:?}");
        assert!(e.to_string().contains("wal-append-before-flush"));
        assert_eq!(hits("wal-append-before-flush"), 1);
        assert!(hit("some-other-point").is_ok());
        reset();
    }

    #[test]
    fn oneshot_fires_once_then_disarms() {
        let _g = serial();
        reset();
        set("cut", Action::OneShot(Box::new(Action::Err)));
        assert!(hit("cut").is_err());
        assert!(hit("cut").is_ok());
        assert!(hit("cut").is_ok());
        assert_eq!(hits("cut"), 1);
        // Disarmed oneshot returns the fast path to zero-cost.
        assert_eq!(ARMED.load(Ordering::SeqCst), 0);
        reset();
    }

    #[test]
    fn panic_action_panics_with_name() {
        let _g = serial();
        reset();
        set("boom", Action::Panic);
        let r = std::panic::catch_unwind(|| {
            let _ = hit("boom");
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(msg.contains("boom"), "panic message: {msg}");
        reset();
    }

    #[test]
    fn delay_sleeps() {
        let _g = serial();
        reset();
        set("slow", Action::Delay(10));
        let t0 = std::time::Instant::now();
        assert!(hit("slow").is_ok());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(8));
        reset();
    }

    #[test]
    fn configure_parses_env_syntax() {
        let _g = serial();
        reset();
        let n = configure("a=err; b=delay(3) ;c=oneshot(panic);d=off").unwrap();
        assert_eq!(n, 4);
        assert!(hit("a").is_err());
        assert!(hit("b").is_ok());
        assert_eq!(hits("b"), 1);
        assert!(hit("d").is_ok());
        assert!(std::panic::catch_unwind(|| {
            let _ = hit("c");
        })
        .is_err());
        assert!(hit("c").is_ok(), "oneshot consumed");
        reset();
    }

    #[test]
    fn configure_rejects_garbage() {
        let _g = serial();
        reset();
        assert!(configure("a").is_err());
        assert!(configure("=err").is_err());
        assert!(configure("a=explode").is_err());
        assert!(configure("a=delay(x)").is_err());
        assert!(configure("a=oneshot(off)").is_err());
        assert!(configure("a=oneshot(oneshot(err))").is_err());
        // Failed parses must not leave partial arms behind.
        reset();
        assert_eq!(ARMED.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn rearming_same_point_does_not_leak_armed_count() {
        let _g = serial();
        reset();
        set("p", Action::Err);
        set("p", Action::Delay(1));
        set("p", Action::Err);
        assert_eq!(ARMED.load(Ordering::SeqCst), 1);
        clear("p");
        assert_eq!(ARMED.load(Ordering::SeqCst), 0);
        reset();
    }
}
