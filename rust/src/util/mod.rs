//! Small self-contained utilities: deterministic RNG, a clock abstraction
//! shared by the real engine and the discrete-event simulator, a mini
//! property-testing harness (stand-in for `proptest`, which is not available
//! offline), a tiny JSON writer for machine-readable bench reports, and the
//! deterministic failpoint registry used by the crash-surface tests.

pub mod clock;
pub mod failpoint;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a duration in seconds with adaptive units, e.g. `1.50ms`, `39.0min`.
pub fn fmt_secs(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", fmt_secs(-s));
    }
    if s < 1e-6 {
        format!("{:.0}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2}s", s)
    } else if s < 7200.0 {
        format!("{:.1}min", s / 60.0)
    } else {
        format!("{:.2}h", s / 3600.0)
    }
}

/// Render an aligned text table (used by the bench harness to print the
/// paper-style rows). `rows` must all have `header.len()` cells.
pub fn render_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        assert_eq!(r.len(), ncol, "row arity mismatch");
        for (i, c) in r.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: Vec<&str>| {
        for (i, c) in cells.iter().enumerate() {
            out.push_str(&format!("{:<w$}", c, w = widths[i] + 2));
        }
        while out.ends_with(' ') {
            out.pop();
        }
        out.push('\n');
    };
    line(&mut out, header.to_vec());
    let total: usize = widths.iter().map(|w| w + 2).sum();
    out.push_str(&"-".repeat(total.saturating_sub(2)));
    out.push('\n');
    for r in rows {
        line(&mut out, r.iter().map(|s| s.as_str()).collect());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(0.0000000005), "0ns");
        assert_eq!(fmt_secs(0.0000025), "2.50us");
        assert_eq!(fmt_secs(0.0015), "1.50ms");
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(180.0), "3.0min");
        assert_eq!(fmt_secs(7200.0), "2.00h");
    }

    #[test]
    fn fmt_secs_negative() {
        assert_eq!(fmt_secs(-1.5), "-1.50s");
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["a", "longer"],
            &[vec!["xx".into(), "y".into()], vec!["1".into(), "2".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a"));
        assert!(lines[0].contains("longer"));
    }
}
