//! Mini property-based testing harness (offline stand-in for `proptest`).
//!
//! Provides seeded case generation with a fixed case count and greedy
//! shrinking for integer-vector inputs. Failure messages include the seed so
//! a failing case can be replayed exactly.
//!
//! Usage (doctest disabled: rustdoc test binaries don't inherit the
//! xla_extension rpath on this image — the same snippet runs as a unit
//! test below):
//! ```text
//! use schaladb::util::prop::check;
//! check("sum is commutative", 200, |g| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Per-case generator handle; draws primitive values from the seeded RNG.
pub struct Gen {
    rng: Rng,
    /// The seed used for this case, surfaced on failure.
    pub case_seed: u64,
}

impl Gen {
    fn new(case_seed: u64) -> Self {
        Gen { rng: Rng::new(case_seed), case_seed }
    }

    /// Integer in `[lo, hi]` (inclusive).
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        self.rng.range(lo, hi + 1)
    }

    /// usize in `[lo, hi]` (inclusive).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as i64, hi as i64 + 1) as usize
    }

    /// Float in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Vector of integers with random length in `[0, max_len]`.
    pub fn vec_i64(&mut self, max_len: usize, lo: i64, hi: i64) -> Vec<i64> {
        let n = self.usize(0, max_len);
        (0..n).map(|_| self.i64(lo, hi)).collect()
    }

    /// ASCII identifier-ish string of length `[1, max_len]`.
    pub fn ident(&mut self, max_len: usize) -> String {
        let n = self.usize(1, max_len.max(1));
        const ALPHA: &[u8] = b"abcdefghijklmnopqrstuvwxyz_0123456789";
        (0..n)
            .map(|i| {
                let set = if i == 0 { &ALPHA[..27] } else { ALPHA };
                set[self.rng.index(set.len())] as char
            })
            .collect()
    }

    /// Direct access to the underlying RNG for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `f`. Panics (with replay seed) on the first
/// failing case. The master seed is derived from the property name so runs
/// are deterministic without global state.
pub fn check(name: &str, cases: u64, f: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let master = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    for i in 0..cases {
        let case_seed = master.wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(case_seed);
            f(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (for debugging a failure printed by `check`).
pub fn replay(case_seed: u64, f: impl Fn(&mut Gen)) {
    let mut g = Gen::new(case_seed);
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut hits = 0u64;
        // Can't capture &mut through RefUnwindSafe closure; use a cell.
        let counter = std::sync::atomic::AtomicU64::new(0);
        check("addition commutes", 64, |g| {
            let a = g.i64(-100, 100);
            let b = g.i64(-100, 100);
            assert_eq!(a + b, b + a);
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        hits += counter.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(hits, 64);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 10, |_g| {
                panic!("boom");
            });
        });
        let msg = match r {
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn generators_respect_bounds() {
        check("gen bounds", 128, |g| {
            let v = g.i64(3, 9);
            assert!((3..=9).contains(&v));
            let u = g.usize(0, 4);
            assert!(u <= 4);
            let s = g.ident(8);
            assert!(!s.is_empty() && s.len() <= 8);
            assert!(s.chars().next().unwrap().is_ascii_lowercase() || s.starts_with('_'));
        });
    }
}
