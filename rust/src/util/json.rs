//! Minimal JSON *writer* for machine-readable bench/experiment reports.
//!
//! `serde` is unavailable offline, and we only need serialization (reports
//! are consumed by humans or plotting scripts), so this is a small
//! build-by-hand tree with escaping and stable key order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object builder entry point.
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert a key (object values only; panics otherwise — builder misuse).
    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested_roundtrip_shape() {
        let j = Json::obj()
            .set("name", "exp1")
            .set("cores", vec![120i64, 240, 480, 960])
            .set("ok", true)
            .set("ratio", 1.25)
            .set("none", Json::Null);
        let s = j.to_string();
        assert_eq!(
            s,
            r#"{"cores":[120,240,480,960],"name":"exp1","none":null,"ok":true,"ratio":1.25}"#
        );
    }

    #[test]
    fn string_escaping() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into()).to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn integral_floats_print_as_ints() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
