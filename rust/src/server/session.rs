//! The transport-agnostic session layer.
//!
//! A [`Session`] owns everything that used to live implicitly in each
//! caller of `Connector`/`WorkerLink`: the prepared-handle table mapping
//! small client statement ids onto [`DbCluster::prepare`] handles, the
//! open-transaction state (a deferred statement queue, the `TxnBuilder`
//! model — nothing touches the data until commit, so rollback and abrupt
//! disconnect are both "drop the queue"), and the session's default
//! [`AccessKind`]. The engine is reached through a [`SessionTransport`]
//! object, implemented both by [`Arc<DbCluster>`] (direct, in-process) and
//! by [`WorkerLink`] (in-process with connector failover) — so the TCP
//! server and an embedded caller drive the *same* session object over
//! different transports, and byte-equality tests can run the identical
//! statement stream down both paths.
//!
//! Failover: prepared handles are plan-only (no connection state), so a
//! handle stays valid across connector failover and data-node promotion.
//! The session adds one more layer of resilience on top: if a prepared
//! execution returns [`Error::Unavailable`] (e.g. the failover window),
//! it re-prepares the statement from its stored SQL text and retries once
//! — the wire client's stmt id never changes, which is the PR 1
//! failover-surviving-handle guarantee extended across the network.

use crate::obs::span;
use crate::storage::cluster::DbCluster;
use crate::storage::connector::WorkerLink;
use crate::storage::prepared::Prepared;
use crate::storage::sql::{self, Statement};
use crate::storage::stats::AccessKind;
use crate::storage::value::Value;
use crate::storage::StatementResult;
use crate::{Error, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// The engine surface a session needs, abstracted over how statements
/// reach the cluster. This is also the seam where a future async or
/// remote-forwarding transport would slot in: implement these seven
/// methods and every session behavior (handle table, txn queue,
/// re-resolve) comes along for free.
pub trait SessionTransport: Send + Sync {
    /// Parse + catalog-check once, yielding a plan-only handle.
    fn prepare(&self, sql: &str) -> Result<Prepared>;
    /// Execute one pre-parsed statement (auto-commit).
    fn exec_stmt(&self, node: u32, kind: AccessKind, stmt: &Statement)
        -> Result<StatementResult>;
    /// Parse and execute one SQL text (auto-commit; DDL goes this way).
    fn exec_sql(&self, node: u32, kind: AccessKind, sql: &str) -> Result<StatementResult>;
    /// Execute a prepared handle (compiled fast path when available).
    fn exec_prepared(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult>;
    /// Execute a prepared single-row INSERT template over many rows.
    fn exec_prepared_batch(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult>;
    /// Execute a statement batch atomically (union 2PL lock set).
    fn exec_txn(
        &self,
        node: u32,
        kind: AccessKind,
        stmts: &[Statement],
    ) -> Result<Vec<StatementResult>>;
    /// The cluster behind this transport (introspection: stats frames).
    fn cluster(&self) -> &Arc<DbCluster>;
}

/// Direct in-process transport: the session talks straight to the cluster.
impl SessionTransport for Arc<DbCluster> {
    fn prepare(&self, sql: &str) -> Result<Prepared> {
        DbCluster::prepare(self, sql)
    }

    fn exec_stmt(
        &self,
        node: u32,
        kind: AccessKind,
        stmt: &Statement,
    ) -> Result<StatementResult> {
        DbCluster::exec_stmt(self, node, kind, stmt)
    }

    fn exec_sql(&self, node: u32, kind: AccessKind, sql: &str) -> Result<StatementResult> {
        self.exec_tagged(node, kind, sql)
    }

    fn exec_prepared(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        DbCluster::exec_prepared(self, node, kind, prepared, params)
    }

    fn exec_prepared_batch(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        DbCluster::exec_prepared_batch(self, node, kind, prepared, rows)
    }

    fn exec_txn(
        &self,
        node: u32,
        kind: AccessKind,
        stmts: &[Statement],
    ) -> Result<Vec<StatementResult>> {
        DbCluster::exec_txn(self, node, kind, stmts)
    }

    fn cluster(&self) -> &Arc<DbCluster> {
        self
    }
}

/// Connector-fabric transport: every statement brokers through the
/// worker's primary connector with failover to its secondary (the `node`
/// argument is ignored — a link is pinned to its worker node).
impl SessionTransport for WorkerLink {
    fn prepare(&self, sql: &str) -> Result<Prepared> {
        WorkerLink::prepare(self, sql)
    }

    fn exec_stmt(
        &self,
        _node: u32,
        kind: AccessKind,
        stmt: &Statement,
    ) -> Result<StatementResult> {
        WorkerLink::exec_stmt(self, kind, stmt)
    }

    fn exec_sql(&self, _node: u32, kind: AccessKind, sql: &str) -> Result<StatementResult> {
        WorkerLink::exec(self, kind, sql)
    }

    fn exec_prepared(
        &self,
        _node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        WorkerLink::exec_prepared(self, kind, prepared, params)
    }

    fn exec_prepared_batch(
        &self,
        _node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        WorkerLink::exec_prepared_batch(self, kind, prepared, rows)
    }

    fn exec_txn(
        &self,
        _node: u32,
        kind: AccessKind,
        stmts: &[Statement],
    ) -> Result<Vec<StatementResult>> {
        WorkerLink::exec_txn(self, kind, stmts)
    }

    fn cluster(&self) -> &Arc<DbCluster> {
        WorkerLink::cluster(self)
    }
}

struct PreparedEntry {
    /// Statement text, kept for failover re-resolve.
    sql: String,
    handle: Prepared,
}

/// One statement queued in an open transaction (the `TxnBuilder` model:
/// binding of prepared statements is deferred to commit so a
/// single-prepared-statement transaction takes the compiled fast path).
enum QueuedStmt {
    Prepared { stmt: u32, params: Vec<Value> },
    Sql(Statement),
}

/// Per-client session state over any [`SessionTransport`].
pub struct Session {
    transport: Box<dyn SessionTransport>,
    node: u32,
    kind: AccessKind,
    stmts: HashMap<u32, PreparedEntry>,
    next_stmt: u32,
    txn: Option<Vec<QueuedStmt>>,
}

impl Session {
    pub fn new(transport: Box<dyn SessionTransport>, node: u32, kind: AccessKind) -> Session {
        Session { transport, node, kind, stmts: HashMap::new(), next_stmt: 1, txn: None }
    }

    /// Session over the direct in-process transport.
    pub fn for_cluster(cluster: Arc<DbCluster>, node: u32, kind: AccessKind) -> Session {
        Session::new(Box::new(cluster), node, kind)
    }

    /// The worker node this session speaks for (stats attribution).
    pub fn node(&self) -> u32 {
        self.node
    }

    /// The session's default access kind (from the handshake).
    pub fn kind(&self) -> AccessKind {
        self.kind
    }

    /// Number of live prepared handles (introspection).
    pub fn stmt_count(&self) -> usize {
        self.stmts.len()
    }

    /// Is a transaction open?
    pub fn txn_open(&self) -> bool {
        self.txn.is_some()
    }

    fn no_open_txn(&self, what: &str) -> Result<()> {
        if self.txn.is_some() {
            return Err(Error::Engine(format!(
                "{what} while a transaction is open (commit or roll back first)"
            )));
        }
        Ok(())
    }

    /// Prepare a statement, returning its session-scoped id and the number
    /// of `?` placeholders to bind.
    pub fn prepare(&mut self, sql: &str) -> Result<(u32, usize)> {
        let handle = self.transport.prepare(sql)?;
        let params = handle.param_count();
        let id = self.next_stmt;
        self.next_stmt += 1;
        self.stmts.insert(id, PreparedEntry { sql: sql.to_string(), handle });
        Ok((id, params))
    }

    /// EXPLAIN-style plan summary of a prepared handle.
    pub fn describe(&self, stmt: u32) -> Result<String> {
        Ok(self.entry(stmt)?.handle.describe().to_string())
    }

    /// Drop a prepared handle from the session table.
    pub fn close_stmt(&mut self, stmt: u32) -> Result<()> {
        self.stmts
            .remove(&stmt)
            .map(|_| ())
            .ok_or_else(|| Error::Engine(format!("no prepared statement #{stmt}")))
    }

    fn entry(&self, stmt: u32) -> Result<&PreparedEntry> {
        self.stmts
            .get(&stmt)
            .ok_or_else(|| Error::Engine(format!("no prepared statement #{stmt}")))
    }

    /// Run `op` against a prepared handle; on [`Error::Unavailable`]
    /// (failover window) re-prepare from the stored SQL text and retry
    /// once, keeping the client's stmt id stable.
    fn with_reresolve<T>(
        &mut self,
        stmt: u32,
        op: impl Fn(&dyn SessionTransport, &Prepared) -> Result<T>,
    ) -> Result<T> {
        let handle = self.entry(stmt)?.handle.clone();
        match op(self.transport.as_ref(), &handle) {
            Err(Error::Unavailable(_)) => {
                let sql = self.entry(stmt)?.sql.clone();
                let fresh = self.transport.prepare(&sql)?;
                let r = op(self.transport.as_ref(), &fresh);
                if r.is_ok() {
                    self.stmts.insert(stmt, PreparedEntry { sql, handle: fresh });
                }
                r
            }
            other => other,
        }
    }

    /// Bind + execute a prepared handle (auto-commit).
    pub fn exec(
        &mut self,
        stmt: u32,
        kind: AccessKind,
        params: &[Value],
    ) -> Result<StatementResult> {
        self.no_open_txn("exec")?;
        // Session-level span: the guard outlives the cluster call, so the
        // slow-op ring attributes the whole request to this entry point
        // (inner cluster spans are inert while this one owns the thread).
        let _span = span::begin(self.transport.cluster().obs(), "session_exec");
        let node = self.node;
        self.with_reresolve(stmt, move |t, p| t.exec_prepared(node, kind, p, params))
    }

    /// Bind + execute a prepared INSERT template over many rows.
    pub fn exec_batch(
        &mut self,
        stmt: u32,
        kind: AccessKind,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        self.no_open_txn("exec_batch")?;
        let _span = span::begin(self.transport.cluster().obs(), "session_exec_batch");
        let node = self.node;
        self.with_reresolve(stmt, move |t, p| t.exec_prepared_batch(node, kind, p, rows))
    }

    /// Parse + execute one SQL text (auto-commit).
    pub fn exec_sql(&mut self, kind: AccessKind, sql: &str) -> Result<StatementResult> {
        self.no_open_txn("exec_sql")?;
        let _span = span::begin(self.transport.cluster().obs(), "session_exec_sql");
        self.transport.exec_sql(self.node, kind, sql)
    }

    /// Open a deferred transaction. Statements queue until
    /// [`Session::commit`]; nothing touches the data before that, so
    /// dropping the session (abrupt disconnect) rolls back by discarding.
    pub fn begin(&mut self) -> Result<()> {
        self.no_open_txn("begin")?;
        self.txn = Some(Vec::new());
        Ok(())
    }

    /// Queue a prepared statement into the open transaction (arity checked
    /// now, bound at commit).
    pub fn queue_prepared(&mut self, stmt: u32, params: &[Value]) -> Result<()> {
        let entry = self.entry(stmt)?;
        if params.len() != entry.handle.param_count() {
            // surface the same arity error bind would raise
            entry.handle.bind(params)?;
        }
        let q = self
            .txn
            .as_mut()
            .ok_or_else(|| Error::Engine("no open transaction".into()))?;
        q.push(QueuedStmt::Prepared { stmt, params: params.to_vec() });
        Ok(())
    }

    /// Queue a SQL text statement (parsed now so syntax errors surface at
    /// the call, not at commit).
    pub fn queue_sql(&mut self, sql_text: &str) -> Result<()> {
        let parsed = sql::parse(sql_text)?;
        let q = self
            .txn
            .as_mut()
            .ok_or_else(|| Error::Engine("no open transaction".into()))?;
        q.push(QueuedStmt::Sql(parsed));
        Ok(())
    }

    /// Atomically execute the queued statements. A queue of exactly one
    /// prepared statement routes through the prepared entry point (compiled
    /// fast path); anything else binds and runs under the union 2PL lock
    /// set via `exec_txn`.
    pub fn commit(&mut self, kind: AccessKind) -> Result<Vec<StatementResult>> {
        let queue =
            self.txn.take().ok_or_else(|| Error::Engine("no open transaction".into()))?;
        let _span = span::begin(self.transport.cluster().obs(), "session_commit");
        if queue.len() == 1 {
            if let QueuedStmt::Prepared { stmt, params } = &queue[0] {
                let (stmt, params) = (*stmt, params.clone());
                let node = self.node;
                return self
                    .with_reresolve(stmt, move |t, p| {
                        t.exec_prepared(node, kind, p, &params)
                    })
                    .map(|r| vec![r]);
            }
        }
        let mut bound = Vec::with_capacity(queue.len());
        for q in queue {
            bound.push(match q {
                QueuedStmt::Sql(s) => s,
                QueuedStmt::Prepared { stmt, params } => {
                    self.entry(stmt)?.handle.bind(&params)?
                }
            });
        }
        self.transport.exec_txn(self.node, kind, &bound)
    }

    /// Discard the open transaction's queue (nothing was applied).
    pub fn rollback(&mut self) -> Result<()> {
        self.txn
            .take()
            .map(|_| ())
            .ok_or_else(|| Error::Engine("no open transaction".into()))
    }

    /// The cluster behind this session (introspection: stats frames).
    pub fn cluster(&self) -> &Arc<DbCluster> {
        self.transport.cluster()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::ClusterConfig;
    use crate::storage::connector::{assign_links, Connector};

    fn cluster() -> Arc<DbCluster> {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE acct (id INT NOT NULL, bal INT NOT NULL) \
             PARTITION BY HASH(id) PARTITIONS 4 PRIMARY KEY (id)",
        )
        .unwrap();
        for i in 0..8 {
            c.execute(&format!("INSERT INTO acct (id, bal) VALUES ({i}, 100)")).unwrap();
        }
        c
    }

    #[test]
    fn prepare_exec_roundtrip_and_handle_table() {
        let c = cluster();
        let mut s = Session::for_cluster(c.clone(), 0, AccessKind::Other);
        let (id1, n1) = s.prepare("SELECT bal FROM acct WHERE id = ?").unwrap();
        let (id2, n2) = s.prepare("UPDATE acct SET bal = ? WHERE id = ?").unwrap();
        assert_ne!(id1, id2);
        assert_eq!((n1, n2), (1, 2));
        assert_eq!(s.stmt_count(), 2);
        let r = s.exec(id1, AccessKind::Steering, &[Value::Int(3)]).unwrap();
        assert_eq!(r.rows().rows[0].values[0], Value::Int(100));
        s.exec(id2, AccessKind::Other, &[Value::Int(55), Value::Int(3)]).unwrap();
        let r = s.exec(id1, AccessKind::Steering, &[Value::Int(3)]).unwrap();
        assert_eq!(r.rows().rows[0].values[0], Value::Int(55));
        assert!(s.describe(id1).unwrap().contains("acct"));
        s.close_stmt(id1).unwrap();
        assert!(s.exec(id1, AccessKind::Steering, &[Value::Int(3)]).is_err());
        assert!(s.close_stmt(id1).is_err());
    }

    #[test]
    fn txn_commits_atomically_and_rollback_discards() {
        let c = cluster();
        let mut s = Session::for_cluster(c.clone(), 0, AccessKind::Other);
        let (debit, _) = s.prepare("UPDATE acct SET bal = bal - ? WHERE id = ?").unwrap();
        s.begin().unwrap();
        assert!(s.exec(debit, AccessKind::Other, &[Value::Int(1), Value::Int(0)]).is_err());
        s.queue_prepared(debit, &[Value::Int(25), Value::Int(1)]).unwrap();
        s.queue_sql("UPDATE acct SET bal = bal + 25 WHERE id = 2").unwrap();
        let r = s.commit(AccessKind::Other).unwrap();
        assert_eq!(r.len(), 2);
        let rs = c.query("SELECT SUM(bal) FROM acct").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(800));

        s.begin().unwrap();
        s.queue_sql("UPDATE acct SET bal = 0 WHERE id = 5").unwrap();
        s.rollback().unwrap();
        let rs = c.query("SELECT bal FROM acct WHERE id = 5").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(100));
        assert!(s.rollback().is_err());
        assert!(s.commit(AccessKind::Other).is_err());
    }

    #[test]
    fn single_prepared_txn_takes_fast_path_and_counts_fast_dml() {
        let c = cluster();
        let mut s = Session::for_cluster(c.clone(), 0, AccessKind::Other);
        let (upd, _) =
            s.prepare("UPDATE acct SET bal = ? WHERE id = ?").unwrap();
        let before = c.route_counts().fast_dml;
        s.begin().unwrap();
        s.queue_prepared(upd, &[Value::Int(7), Value::Int(4)]).unwrap();
        let r = s.commit(AccessKind::Other).unwrap();
        assert_eq!(r.len(), 1);
        assert!(
            c.route_counts().fast_dml > before,
            "single-prepared txn should take the compiled fast path"
        );
    }

    #[test]
    fn worker_link_transport_fails_over() {
        let c = cluster();
        let conns =
            vec![Connector::new(0, 0, c.clone()), Connector::new(1, 1, c.clone())];
        let links = assign_links(&[0], &conns).unwrap();
        let link = links.into_iter().next().unwrap();
        let mut s = Session::new(Box::new(link), 0, AccessKind::Other);
        let (id, _) = s.prepare("SELECT bal FROM acct WHERE id = ?").unwrap();
        s.exec(id, AccessKind::Steering, &[Value::Int(1)]).unwrap();
        conns[0].kill();
        // primary connector down: the link fails over, same handle, same id
        let r = s.exec(id, AccessKind::Steering, &[Value::Int(1)]).unwrap();
        assert_eq!(r.rows().rows[0].values[0], Value::Int(100));
        // and an atomic batch brokered through the surviving connector
        s.begin().unwrap();
        s.queue_sql("UPDATE acct SET bal = bal - 5 WHERE id = 1").unwrap();
        s.queue_sql("UPDATE acct SET bal = bal + 5 WHERE id = 2").unwrap();
        s.commit(AccessKind::Other).unwrap();
        let rs = c.query("SELECT SUM(bal) FROM acct").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(800));
    }

    #[test]
    fn queue_checks_arity_and_syntax_up_front() {
        let c = cluster();
        let mut s = Session::for_cluster(c, 0, AccessKind::Other);
        let (id, _) = s.prepare("UPDATE acct SET bal = ? WHERE id = ?").unwrap();
        s.begin().unwrap();
        assert!(s.queue_prepared(id, &[Value::Int(1)]).is_err());
        assert!(s.queue_sql("UPDATE acct SET SET").is_err());
        // the failed queues left nothing behind; commit of empty queue is a no-op
        let r = s.commit(AccessKind::Other).unwrap();
        assert!(r.is_empty());
    }
}
