//! The TCP front-end: a bounded thread-per-connection accept loop.
//!
//! Why threads, not async: the build environment is offline, so tokio is
//! unavailable — and the paper's workload shape doesn't need it. The two
//! remote audiences are a few hundred worker tasks (each with one
//! long-lived connection running short point transactions) and a handful
//! of steering analysts; both are well inside what blocking threads
//! handle, and a thread per connection keeps the engine's existing
//! synchronous call tree unchanged. The async seam is the
//! [`SessionTransport`](super::session::SessionTransport) trait plus this
//! module: an async transport would replace only the accept loop and the
//! frame pump, reusing `Session` and `wire` unchanged.
//!
//! Backpressure: the accept loop admits at most `max_conns` concurrent
//! connections. Beyond that it *rejects* — one typed `Backpressure` error
//! frame, then close — rather than queueing silently, so a saturated
//! server is observable at the client instead of looking like latency.
//!
//! Shutdown: there is no signal handling in a pure-std build, so the
//! SIGTERM-equivalent is the wire-level `Shutdown` frame (`dchiron
//! shutdown --addr ...`). It flips the shutdown flag, wakes the accept
//! loop with a loopback connect, closes every live connection's stream,
//! and joins all threads — `dchiron serve` then exits 0.

use super::session::Session;
use super::wire::{
    self, read_frame, write_frame, AdminCmd, ErrCode, MetricsReply, Request, Response,
    SlowOpWire, StatsReply, TopologyReply, PROTO_VERSION,
};
use crate::obs::{Counter, Stage};
use crate::storage::cluster::DbCluster;
use crate::util::failpoint;
use crate::{Error, Result};
use std::net::{Shutdown as NetShutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Server tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Concurrent-connection bound; connection N+1 gets a typed
    /// `Backpressure` error frame and is closed.
    pub max_conns: usize,
    /// Per-connection read/write deadline. A frame read or write that
    /// blocks longer than this gets a typed `Timeout` error frame (best
    /// effort) and the connection is closed; open transactions discard
    /// with the session. `None` (the default) keeps the pre-existing
    /// block-forever behavior.
    pub conn_timeout: Option<Duration>,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { max_conns: 64, conn_timeout: None }
    }
}

/// State shared between the accept loop, connection handlers, and the
/// [`Server`] handle.
struct Shared {
    cluster: Arc<DbCluster>,
    addr: SocketAddr,
    max_conns: usize,
    conn_timeout: Option<Duration>,
    /// Live connection count (backpressure bound, `Stats.sessions`).
    active: AtomicUsize,
    shutdown: AtomicBool,
    next_session: AtomicU64,
}

impl Shared {
    fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the accept loop out of its blocking accept(): a loopback
        // connect is accepted, sees the flag, and the loop exits.
        let _ = TcpStream::connect(self.addr);
    }
}

/// Decrements the live-connection count when a handler exits by any path
/// (clean close, protocol error, panic unwinding through the frame pump).
struct ActiveGuard(Arc<Shared>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.active.fetch_sub(1, Ordering::SeqCst);
    }
}

/// One registered connection: a clone of its stream (so shutdown can
/// force-close it out from under a blocking read) and its handler thread.
struct Conn {
    stream: Option<TcpStream>,
    handle: JoinHandle<()>,
}

/// A running wire-protocol server. Dropping it shuts it down.
pub struct Server {
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<Conn>>>,
}

impl Server {
    /// Bind `addr` and start accepting. Port 0 picks a free port — read it
    /// back with [`Server::local_addr`].
    pub fn bind(
        addr: SocketAddr,
        cluster: Arc<DbCluster>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let shared = Arc::new(Shared {
            cluster,
            addr: local,
            max_conns: cfg.max_conns.max(1),
            conn_timeout: cfg.conn_timeout,
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            next_session: AtomicU64::new(1),
        });
        let conns: Arc<Mutex<Vec<Conn>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("dchiron-accept".into())
                .spawn(move || accept_loop(listener, shared, conns))
                .map_err(|e| Error::Engine(format!("spawn accept thread: {e}")))?
        };
        Ok(Server { shared, accept: Some(accept), conns })
    }

    /// The address actually bound (resolves `--addr host:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Live connection count.
    pub fn active_conns(&self) -> usize {
        self.shared.active.load(Ordering::SeqCst)
    }

    /// Block until the server shuts down (via a wire `Shutdown` frame or
    /// a concurrent [`Server::shutdown`]), then reap every thread.
    pub fn wait(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.reap_conns();
    }

    /// Stop accepting, force-close live connections, join every thread.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.request_shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.reap_conns();
    }

    /// Reaping only happens once the accept loop has exited, i.e. the
    /// server is shutting down — so live streams are force-closed to get
    /// handlers out of blocking reads, then every thread is joined.
    fn reap_conns(&self) {
        let drained: Vec<Conn> = {
            let mut g = self.conns.lock().unwrap();
            g.drain(..).collect()
        };
        for c in drained {
            if let Some(s) = &c.stream {
                let _ = s.shutdown(NetShutdown::Both);
            }
            let _ = c.handle.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, conns: Arc<Mutex<Vec<Conn>>>) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(a) => a,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connect (or a straggler) during shutdown
        }
        // Backpressure: reject above the bound with a typed error frame so
        // the client sees "server full", not a mystery hangup.
        let prior = shared.active.fetch_add(1, Ordering::SeqCst);
        if prior >= shared.max_conns {
            shared.active.fetch_sub(1, Ordering::SeqCst);
            let mut stream = stream;
            let resp = Response::Err {
                code: ErrCode::Backpressure,
                message: format!(
                    "connection limit reached ({} active, max {})",
                    prior, shared.max_conns
                ),
            };
            send(&mut stream, &shared, &resp);
            continue;
        }
        let guard = ActiveGuard(shared.clone());
        let peer_stream = stream.try_clone().ok();
        let handler = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("dchiron-conn".into())
                .spawn(move || {
                    let _guard = guard;
                    handle_conn(stream, &shared);
                })
        };
        match handler {
            Ok(handle) => {
                let mut g = conns.lock().unwrap();
                // prune finished handlers so the registry doesn't grow
                // unboundedly across many short-lived connections
                let mut kept: Vec<Conn> = Vec::with_capacity(g.len() + 1);
                for c in g.drain(..) {
                    if c.handle.is_finished() {
                        let _ = c.handle.join();
                    } else {
                        kept.push(c);
                    }
                }
                kept.push(Conn { stream: peer_stream, handle });
                *g = kept;
            }
            // spawn failure drops the closure, and with it the guard —
            // the active count stays correct
            Err(_) => {}
        }
    }
}

/// Map an engine error into a typed error frame.
fn err_response(e: &Error) -> Response {
    let (code, message) = wire::encode_error(e);
    Response::Err { code, message }
}

/// Write one response frame, counting it (payload + 8-byte header) in the
/// observability registry. Returns `false` when the peer is gone.
fn send(stream: &mut TcpStream, shared: &Shared, resp: &Response) -> bool {
    let obs = shared.cluster.obs();
    let payload = resp.encode();
    let write = failpoint::hit("server-frame-write").and_then(|()| write_frame(stream, &payload));
    if let Err(e) = write {
        if matches!(&e, Error::Io(io) if wire::is_timeout_io(io)) {
            obs.inc(Counter::ConnTimeouts);
        }
        return false;
    }
    obs.inc(Counter::FramesOut);
    obs.addc(Counter::BytesOut, (payload.len() + 8) as u64);
    true
}

/// Read one request frame, counting traffic, malformed frames, and
/// deadline expiries.
fn recv(stream: &mut TcpStream, shared: &Shared) -> Result<Option<Vec<u8>>> {
    let obs = shared.cluster.obs();
    match failpoint::hit("server-frame-read").and_then(|()| read_frame(stream)) {
        Ok(Some(p)) => {
            obs.inc(Counter::FramesIn);
            obs.addc(Counter::BytesIn, (p.len() + 8) as u64);
            Ok(Some(p))
        }
        Ok(None) => Ok(None),
        Err(e) => {
            if matches!(&e, Error::Io(io) if wire::is_timeout_io(io)) {
                obs.inc(Counter::ConnTimeouts);
            } else {
                obs.inc(Counter::FrameErrors);
            }
            Err(e)
        }
    }
}

/// Drive one connection: handshake, then a frame pump over one
/// [`Session`]. Returning (for any reason) drops the session, which
/// discards any open transaction — abrupt-disconnect rollback for free.
fn handle_conn(mut stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true); // claim loops are latency-bound
    if let Some(t) = shared.conn_timeout {
        let _ = stream.set_read_timeout(Some(t));
        let _ = stream.set_write_timeout(Some(t));
    }
    // Handshake: the first frame must be a version-matched Hello.
    let (node, kind) = match recv(&mut stream, shared) {
        Ok(Some(payload)) => match Request::decode(&payload) {
            Ok(Request::Hello { proto, node, kind }) => {
                if proto != PROTO_VERSION {
                    let resp = Response::Err {
                        code: ErrCode::Protocol,
                        message: format!(
                            "protocol version mismatch: client {proto}, server {PROTO_VERSION}"
                        ),
                    };
                    send(&mut stream, shared, &resp);
                    return;
                }
                (node, kind)
            }
            Ok(_) | Err(_) => {
                let resp = Response::Err {
                    code: ErrCode::Protocol,
                    message: "expected Hello as the first frame".into(),
                };
                send(&mut stream, shared, &resp);
                return;
            }
        },
        _ => return, // closed or torn before the handshake
    };
    let session_id = shared.next_session.fetch_add(1, Ordering::SeqCst);
    let hello = Response::HelloOk { proto: PROTO_VERSION, session: session_id };
    if !send(&mut stream, shared, &hello) {
        return;
    }

    let mut session = Session::for_cluster(shared.cluster.clone(), node, kind);
    loop {
        let payload = match recv(&mut stream, shared) {
            Ok(Some(p)) => p,
            Ok(None) => return, // clean disconnect; open txn discards with the session
            Err(e) => {
                // torn frame / checksum mismatch / oversize: the stream is
                // unsynchronized — report once (best effort) and close
                send(&mut stream, shared, &err_response(&e));
                return;
            }
        };
        // A well-framed but undecodable payload leaves the stream
        // synchronized: answer with a typed error and keep serving.
        let req = match Request::decode(&payload) {
            Ok(r) => r,
            Err(e) => {
                shared.cluster.obs().inc(Counter::FrameErrors);
                let resp = Response::Err {
                    code: ErrCode::Protocol,
                    message: e.to_string(),
                };
                if !send(&mut stream, shared, &resp) {
                    return;
                }
                continue;
            }
        };
        let (resp, hangup) = respond(req, &mut session, shared);
        if !send(&mut stream, shared, &resp) {
            return;
        }
        if hangup {
            return;
        }
    }
}

/// Execute one decoded request against the session. Returns the response
/// and whether the connection should close afterwards.
fn respond(req: Request, session: &mut Session, shared: &Arc<Shared>) -> (Response, bool) {
    let resp = match req {
        Request::Hello { .. } => Response::Err {
            code: ErrCode::Protocol,
            message: "Hello is only valid as the first frame".into(),
        },
        Request::Prepare { sql } => match session.prepare(&sql) {
            Ok((stmt, params)) => Response::PrepareOk { stmt, params: params as u16 },
            Err(e) => err_response(&e),
        },
        Request::BindExec { stmt, kind, params } => {
            match session.exec(stmt, kind, &params) {
                Ok(r) => Response::Result(r),
                Err(e) => err_response(&e),
            }
        }
        Request::BindExecBatch { stmt, kind, rows } => {
            match session.exec_batch(stmt, kind, &rows) {
                Ok(r) => Response::Result(r),
                Err(e) => err_response(&e),
            }
        }
        Request::ExecSql { kind, sql } => match session.exec_sql(kind, &sql) {
            Ok(r) => Response::Result(r),
            Err(e) => err_response(&e),
        },
        Request::DescribeStmt { stmt } => match session.describe(stmt) {
            Ok(text) => Response::Describe(text),
            Err(e) => err_response(&e),
        },
        Request::CloseStmt { stmt } => match session.close_stmt(stmt) {
            Ok(()) => Response::Result(crate::storage::StatementResult::Ok),
            Err(e) => err_response(&e),
        },
        Request::Stats { fingerprint, tables } => {
            match stats_reply(shared, fingerprint, tables) {
                Ok(s) => Response::Stats(Box::new(s)),
                Err(e) => err_response(&e),
            }
        }
        Request::TxnBegin => match session.begin() {
            Ok(()) => Response::Result(crate::storage::StatementResult::Ok),
            Err(e) => err_response(&e),
        },
        Request::TxnPrepared { stmt, params } => {
            match session.queue_prepared(stmt, &params) {
                Ok(()) => Response::Result(crate::storage::StatementResult::Ok),
                Err(e) => err_response(&e),
            }
        }
        Request::TxnSql { sql } => match session.queue_sql(&sql) {
            Ok(()) => Response::Result(crate::storage::StatementResult::Ok),
            Err(e) => err_response(&e),
        },
        Request::TxnCommit { kind } => match session.commit(kind) {
            Ok(rs) => Response::TxnResults(rs),
            Err(e) => err_response(&e),
        },
        Request::TxnRollback => match session.rollback() {
            Ok(()) => Response::Result(crate::storage::StatementResult::Ok),
            Err(e) => err_response(&e),
        },
        Request::Close => {
            return (Response::Result(crate::storage::StatementResult::Ok), true)
        }
        Request::Shutdown => {
            shared.request_shutdown();
            return (Response::ShutdownOk, true);
        }
        Request::Metrics { top_k } => {
            let obs = shared.cluster.obs();
            let slow_ops = obs
                .slow_ops(top_k as usize)
                .into_iter()
                .map(|op| SlowOpWire {
                    span: op.span,
                    label: op.label.to_string(),
                    total_nanos: op.total_nanos,
                    stages: Stage::ALL
                        .iter()
                        .map(|s| (s.label().to_string(), op.stages[*s as usize]))
                        .collect(),
                })
                .collect();
            Response::Metrics(Box::new(MetricsReply { text: obs.exposition(), slow_ops }))
        }
        Request::Topology => {
            let t = shared.cluster.topology();
            Response::Topology(Box::new(TopologyReply::from(&t)))
        }
        Request::Admin(cmd) => match admin_reply(shared, cmd) {
            Ok(r) => r,
            Err(e) => err_response(&e),
        },
    };
    (resp, false)
}

/// Execute one admin command against the cluster. Admin ops serialize on
/// the cluster's admin mutex, so concurrent commands from different
/// connections queue rather than interleave.
fn admin_reply(shared: &Arc<Shared>, cmd: AdminCmd) -> Result<Response> {
    let c = &shared.cluster;
    let (message, value) = match cmd {
        AdminCmd::AddNode => {
            let id = c.add_node()?;
            (format!("node {id} joined (empty; rebalance onto it)"), u64::from(id))
        }
        AdminCmd::Rebalance { table, pidx, to_node } => {
            c.rebalance_partition(&table, pidx as usize, to_node)?;
            (format!("partition {table}[{pidx}] now primary on node {to_node}"), 0)
        }
        AdminCmd::Split { table, pidx } => {
            let new_pidx = c.split_partition(&table, pidx as usize)?;
            let msg = format!("partition {table}[{pidx}] split; new partition {new_pidx}");
            (msg, new_pidx as u64)
        }
    };
    Ok(Response::AdminOk { message, value, epoch: c.cluster_epoch() })
}

fn stats_reply(shared: &Arc<Shared>, fingerprint: bool, tables: bool) -> Result<StatsReply> {
    let c = &shared.cluster;
    let rc = c.route_counts();
    let obs = c.obs();
    let mut reply = StatsReply {
        scatter: rc.scatter,
        snapshot_join: rc.snapshot_join,
        centralized: rc.centralized,
        fast_dml: rc.fast_dml,
        chunks_scanned: rc.chunks_scanned,
        chunks_pruned: rc.chunks_pruned,
        cached_plans: c.cached_plans() as u64,
        epoch: c.cluster_epoch(),
        sessions: shared.active.load(Ordering::SeqCst) as u64,
        dml_interp: obs.counter(Counter::DmlInterp),
        wal_records: obs.counter(Counter::WalRecords),
        wal_flushes: obs.counter(Counter::WalFlushes),
        frames_in: obs.counter(Counter::FramesIn),
        frames_out: obs.counter(Counter::FramesOut),
        bytes_in: obs.counter(Counter::BytesIn),
        bytes_out: obs.counter(Counter::BytesOut),
        frame_errors: obs.counter(Counter::FrameErrors),
        occ_dml: rc.occ_dml,
        occ_retries: rc.occ_retries,
        occ_fallbacks: rc.occ_fallbacks,
        fingerprint: None,
        table_rows: Vec::new(),
    };
    if fingerprint {
        reply.fingerprint = Some(c.fingerprint()?);
    }
    if tables {
        for t in c.tables() {
            reply.table_rows.push((t.clone(), c.table_rows(&t)? as u64));
        }
    }
    Ok(reply)
}
