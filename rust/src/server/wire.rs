//! The wire protocol: checksummed length-prefixed binary frames.
//!
//! Every frame on the socket is
//!
//! ```text
//! +----------+--------------+-----------------------+
//! | len: u32 | checksum:u32 | payload (len bytes)   |  all integers LE
//! +----------+--------------+-----------------------+
//! payload = tag: u8 + tag-specific body
//! ```
//!
//! The checksum is FNV-1a over the payload — the same discipline the WAL
//! applies to its record lines (`wal.rs`), for the same reason: a torn or
//! corrupted frame must fail loudly as a checksum mismatch, never parse as
//! a plausible shorter message. Frames above [`MAX_FRAME`] are rejected
//! before the payload is read (the stream is then unsynchronized, so the
//! connection must close). Values travel in a compact binary encoding of
//! the engine's own [`Value`] type; result sets and errors are typed
//! frames, so a protocol error is distinguishable from a SQL error and
//! both are distinguishable from a dead peer.

use crate::storage::cluster::Topology;
use crate::storage::stats::AccessKind;
use crate::storage::value::{Row, Value};
use crate::storage::{NodeState, ResultSet, StatementResult};
use crate::{Error, Result};
use std::io::{Read, Write};

/// Protocol version carried in `Hello`/`HelloOk`. Bump on any frame-format
/// change; the server rejects mismatched clients with a typed error.
/// v2: `Metrics` request/response and the observability fields appended to
/// `StatsReply`.
/// v4: cluster-admin surface — `Topology` introspection and `Admin`
/// (add-node / rebalance / split) requests with their replies.
pub const PROTO_VERSION: u16 = 4;

/// Upper bound on one frame's payload. Large enough for any steering
/// result set we produce, small enough that a hostile or corrupt length
/// prefix cannot make the server allocate unbounded memory.
pub const MAX_FRAME: usize = 16 * 1024 * 1024;

/// FNV-1a over a frame payload (mirrors the WAL's record checksum).
pub fn checksum(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= u32::from(b);
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Write one frame (header + payload).
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(Error::Engine(format!(
            "outgoing frame of {} bytes exceeds MAX_FRAME ({MAX_FRAME})",
            payload.len()
        )));
    }
    let mut header = [0u8; 8];
    header[..4].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[4..].copy_from_slice(&checksum(payload).to_le_bytes());
    w.write_all(&header)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload. `Ok(None)` means the peer closed the
/// connection cleanly *between* frames; a close mid-frame, a checksum
/// mismatch, or an oversize length prefix is an error (and the stream is
/// no longer synchronized — the caller must drop the connection).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut header = [0u8; 8];
    let mut got = 0;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None), // clean EOF at a frame boundary
            Ok(0) => {
                return Err(Error::Engine("connection closed mid-frame header".into()))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
    let want = u32::from_le_bytes(header[4..].try_into().unwrap());
    if len == 0 {
        return Err(Error::Engine("empty frame (no tag byte)".into()));
    }
    if len > MAX_FRAME {
        return Err(Error::Engine(format!(
            "frame of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})"
        )));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|_| Error::Engine("connection closed mid-frame payload".into()))?;
    let got_sum = checksum(&payload);
    if got_sum != want {
        return Err(Error::Engine(format!(
            "frame checksum mismatch ({got_sum:08x} != {want:08x})"
        )));
    }
    Ok(Some(payload))
}

// ---------- primitive encoding ----------

/// Sequential reader over a frame payload with typed, bounds-checked
/// getters (a malformed body becomes `Error::Engine`, never a panic).
pub struct Buf<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Buf<'a> {
    pub fn new(data: &'a [u8]) -> Buf<'a> {
        Buf { data, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.data.len() {
            return Err(Error::Engine(format!(
                "truncated frame body (wanted {n} bytes at offset {}, have {})",
                self.pos,
                self.data.len()
            )));
        }
        let s = &self.data[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::Engine("non-UTF-8 string in frame".into()))
    }

    /// All bytes consumed? (trailing garbage is a protocol error)
    pub fn finish(&self) -> Result<()> {
        if self.pos != self.data.len() {
            return Err(Error::Engine(format!(
                "{} trailing bytes after frame body",
                self.data.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

// ---------- Value / Row / ResultSet encoding ----------

/// Binary encode one [`Value`] (tag byte + payload).
pub fn put_value(out: &mut Vec<u8>, v: &Value) {
    match v {
        Value::Null => out.push(0),
        Value::Int(i) => {
            out.push(1);
            out.extend_from_slice(&i.to_le_bytes());
        }
        Value::Float(f) => {
            out.push(2);
            out.extend_from_slice(&f.to_bits().to_le_bytes());
        }
        Value::Str(s) => {
            out.push(3);
            put_str(out, s);
        }
        Value::Bool(b) => {
            out.push(4);
            out.push(u8::from(*b));
        }
    }
}

/// Decode one [`Value`].
pub fn get_value(b: &mut Buf) -> Result<Value> {
    Ok(match b.u8()? {
        0 => Value::Null,
        1 => Value::Int(b.i64()?),
        2 => Value::Float(b.f64()?),
        3 => Value::Str(b.str()?.into()),
        4 => Value::Bool(b.u8()? != 0),
        t => return Err(Error::Engine(format!("bad value tag {t}"))),
    })
}

fn put_params(out: &mut Vec<u8>, params: &[Value]) {
    out.extend_from_slice(&(params.len() as u16).to_le_bytes());
    for v in params {
        put_value(out, v);
    }
}

fn get_params(b: &mut Buf) -> Result<Vec<Value>> {
    let n = b.u16()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(get_value(b)?);
    }
    Ok(out)
}

fn put_result_set(out: &mut Vec<u8>, rs: &ResultSet) {
    out.extend_from_slice(&(rs.columns.len() as u16).to_le_bytes());
    for c in &rs.columns {
        put_str(out, c);
    }
    out.extend_from_slice(&(rs.rows.len() as u32).to_le_bytes());
    for r in &rs.rows {
        put_params(out, &r.values);
    }
}

fn get_result_set(b: &mut Buf) -> Result<ResultSet> {
    let ncols = b.u16()? as usize;
    let mut columns = Vec::with_capacity(ncols);
    for _ in 0..ncols {
        columns.push(b.str()?);
    }
    let nrows = b.u32()? as usize;
    let mut rows = Vec::with_capacity(nrows.min(65_536));
    for _ in 0..nrows {
        rows.push(Row::new(get_params(b)?));
    }
    Ok(ResultSet { columns, rows })
}

fn put_statement_result(out: &mut Vec<u8>, r: &StatementResult) {
    match r {
        StatementResult::Rows(rs) => {
            out.push(0);
            put_result_set(out, rs);
        }
        StatementResult::Affected(n) => {
            out.push(1);
            out.extend_from_slice(&(*n as u64).to_le_bytes());
        }
        StatementResult::Ok => out.push(2),
    }
}

fn get_statement_result(b: &mut Buf) -> Result<StatementResult> {
    Ok(match b.u8()? {
        0 => StatementResult::Rows(get_result_set(b)?),
        1 => StatementResult::Affected(b.u64()? as usize),
        2 => StatementResult::Ok,
        t => return Err(Error::Engine(format!("bad statement-result tag {t}"))),
    })
}

// ---------- AccessKind encoding ----------

/// Wire index of an access kind (position in [`AccessKind::all`]).
pub fn kind_to_u8(kind: AccessKind) -> u8 {
    AccessKind::all().iter().position(|k| *k == kind).expect("kind in all()") as u8
}

/// Access kind from its wire index.
pub fn kind_from_u8(i: u8) -> Result<AccessKind> {
    AccessKind::all()
        .get(i as usize)
        .copied()
        .ok_or_else(|| Error::Engine(format!("bad access-kind index {i}")))
}

/// Wire index of a node state (carried by [`Response::Topology`]).
pub fn state_to_u8(s: NodeState) -> u8 {
    match s {
        NodeState::Alive => 0,
        NodeState::Dead => 1,
        NodeState::Rejoining => 2,
        NodeState::Joining => 3,
    }
}

/// Node state from its wire index.
pub fn state_from_u8(i: u8) -> Result<NodeState> {
    Ok(match i {
        0 => NodeState::Alive,
        1 => NodeState::Dead,
        2 => NodeState::Rejoining,
        3 => NodeState::Joining,
        t => return Err(Error::Engine(format!("bad node-state index {t}"))),
    })
}

// ---------- error codes ----------

/// Typed error codes so every [`Error`] variant round-trips the wire.
/// `Backpressure` is server-only: the accept loop sends it when the
/// connection limit is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    Parse = 1,
    Catalog = 2,
    Type = 3,
    Constraint = 4,
    TxnAborted = 5,
    Unavailable = 6,
    Engine = 7,
    Runtime = 8,
    Io = 9,
    Protocol = 10,
    Backpressure = 11,
    /// Server-only: the per-connection read/write deadline expired
    /// (`--conn-timeout-secs`). Older clients decode it through the
    /// `Engine` fallback arm, so no protocol-version bump is needed.
    Timeout = 12,
}

/// Split an engine error into its wire code + message.
pub fn encode_error(e: &Error) -> (ErrCode, String) {
    match e {
        Error::Parse(m) => (ErrCode::Parse, m.clone()),
        Error::Catalog(m) => (ErrCode::Catalog, m.clone()),
        Error::Type(m) => (ErrCode::Type, m.clone()),
        Error::Constraint(m) => (ErrCode::Constraint, m.clone()),
        Error::TxnAborted(m) => (ErrCode::TxnAborted, m.clone()),
        Error::Unavailable(m) => (ErrCode::Unavailable, m.clone()),
        Error::Engine(m) => (ErrCode::Engine, m.clone()),
        Error::Runtime(m) => (ErrCode::Runtime, m.clone()),
        Error::Io(m) if is_timeout_io(m) => (ErrCode::Timeout, m.to_string()),
        Error::Io(m) => (ErrCode::Io, m.to_string()),
        // Recovery failures never reach a live connection (they abort
        // startup), but the match must stay exhaustive.
        Error::Recovery(m) => (ErrCode::Engine, format!("recovery error: {m}")),
    }
}

/// `true` for the two kinds a blocking socket read/write deadline surfaces
/// as (`TimedOut` on most platforms, `WouldBlock` on some Unixes).
pub fn is_timeout_io(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
    )
}

/// Rebuild a client-side [`Error`] from a wire code + message.
pub fn decode_error(code: u8, message: String) -> Error {
    match code {
        1 => Error::Parse(message),
        2 => Error::Catalog(message),
        3 => Error::Type(message),
        4 => Error::Constraint(message),
        5 => Error::TxnAborted(message),
        6 => Error::Unavailable(message),
        8 => Error::Runtime(message),
        9 => Error::Io(std::io::Error::other(message)),
        10 => Error::Engine(format!("protocol error: {message}")),
        11 => Error::Unavailable(format!("server backpressure: {message}")),
        12 => Error::Io(std::io::Error::new(std::io::ErrorKind::TimedOut, message)),
        _ => Error::Engine(message),
    }
}

// ---------- requests ----------

/// Body of [`Request::Admin`] — the elastic-topology operations exposed
/// over the wire (v4). Each maps 1:1 onto a `DbCluster` admin method and
/// is serialized server-side by the cluster's admin mutex.
#[derive(Clone, Debug, PartialEq)]
pub enum AdminCmd {
    /// Register a fresh, empty data node. It joins in `Joining` state,
    /// hosts nothing, and becomes an eligible rebalance target.
    AddNode,
    /// Move one partition's primary onto `to_node` (live redo-ship seed,
    /// catch-up rounds, then a latched final cut).
    Rebalance { table: String, pidx: u32, to_node: u32 },
    /// Split one partition in two by doubling its congruence class.
    Split { table: String, pidx: u32 },
}

/// Client → server frames.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Handshake: protocol version, the worker node id this session speaks
    /// for (stats attribution), and its default access kind.
    Hello { proto: u16, node: u32, kind: AccessKind },
    /// Prepare a statement; the reply carries the session-scoped stmt id.
    Prepare { sql: String },
    /// Bind params to a prepared stmt id and execute (auto-commit).
    BindExec { stmt: u32, kind: AccessKind, params: Vec<Value> },
    /// Bind a prepared single-row INSERT template over many rows and
    /// execute as one atomic multi-row insert.
    BindExecBatch { stmt: u32, kind: AccessKind, rows: Vec<Vec<Value>> },
    /// Parse and execute one SQL text (auto-commit; DDL goes this way).
    ExecSql { kind: AccessKind, sql: String },
    /// EXPLAIN-style plan summary of a prepared stmt id.
    DescribeStmt { stmt: u32 },
    /// Drop a prepared stmt id from the session's handle table.
    CloseStmt { stmt: u32 },
    /// Cluster introspection: route counts, plan cache, epoch, sessions;
    /// optionally the full state fingerprint and per-table row counts.
    Stats { fingerprint: bool, tables: bool },
    /// Open a deferred multi-statement transaction.
    TxnBegin,
    /// Queue a prepared statement into the open transaction.
    TxnPrepared { stmt: u32, params: Vec<Value> },
    /// Queue a SQL text statement into the open transaction.
    TxnSql { sql: String },
    /// Atomically execute the queued statements.
    TxnCommit { kind: AccessKind },
    /// Discard the queued statements.
    TxnRollback,
    /// Graceful session close.
    Close,
    /// Ask the server process to shut down (the SIGTERM-equivalent for
    /// environments without signal handling).
    Shutdown,
    /// Telemetry snapshot: the Prometheus-style exposition text plus the
    /// `top_k` slowest traced ops with their stage breakdowns.
    Metrics { top_k: u16 },
    /// Cluster topology snapshot: nodes, per-partition placement and sizes.
    Topology,
    /// A cluster-admin command (add-node / rebalance / split).
    Admin(AdminCmd),
}

const REQ_HELLO: u8 = 0x01;
const REQ_PREPARE: u8 = 0x02;
const REQ_BIND_EXEC: u8 = 0x03;
const REQ_EXEC_SQL: u8 = 0x04;
const REQ_DESCRIBE: u8 = 0x05;
const REQ_STATS: u8 = 0x06;
const REQ_CLOSE: u8 = 0x07;
const REQ_BIND_EXEC_BATCH: u8 = 0x08;
const REQ_TXN_BEGIN: u8 = 0x09;
const REQ_TXN_PREPARED: u8 = 0x0a;
const REQ_TXN_SQL: u8 = 0x0b;
const REQ_TXN_COMMIT: u8 = 0x0c;
const REQ_TXN_ROLLBACK: u8 = 0x0d;
const REQ_CLOSE_STMT: u8 = 0x0e;
const REQ_SHUTDOWN: u8 = 0x0f;
const REQ_METRICS: u8 = 0x10;
const REQ_TOPOLOGY: u8 = 0x11;
const REQ_ADMIN: u8 = 0x12;

// Subtags inside a REQ_ADMIN body.
const ADMIN_ADD_NODE: u8 = 0;
const ADMIN_REBALANCE: u8 = 1;
const ADMIN_SPLIT: u8 = 2;

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        match self {
            Request::Hello { proto, node, kind } => {
                out.push(REQ_HELLO);
                out.extend_from_slice(&proto.to_le_bytes());
                out.extend_from_slice(&node.to_le_bytes());
                out.push(kind_to_u8(*kind));
            }
            Request::Prepare { sql } => {
                out.push(REQ_PREPARE);
                put_str(&mut out, sql);
            }
            Request::BindExec { stmt, kind, params } => {
                out.push(REQ_BIND_EXEC);
                out.extend_from_slice(&stmt.to_le_bytes());
                out.push(kind_to_u8(*kind));
                put_params(&mut out, params);
            }
            Request::BindExecBatch { stmt, kind, rows } => {
                out.push(REQ_BIND_EXEC_BATCH);
                out.extend_from_slice(&stmt.to_le_bytes());
                out.push(kind_to_u8(*kind));
                out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
                for r in rows {
                    put_params(&mut out, r);
                }
            }
            Request::ExecSql { kind, sql } => {
                out.push(REQ_EXEC_SQL);
                out.push(kind_to_u8(*kind));
                put_str(&mut out, sql);
            }
            Request::DescribeStmt { stmt } => {
                out.push(REQ_DESCRIBE);
                out.extend_from_slice(&stmt.to_le_bytes());
            }
            Request::CloseStmt { stmt } => {
                out.push(REQ_CLOSE_STMT);
                out.extend_from_slice(&stmt.to_le_bytes());
            }
            Request::Stats { fingerprint, tables } => {
                out.push(REQ_STATS);
                let mut flags = 0u8;
                if *fingerprint {
                    flags |= 1;
                }
                if *tables {
                    flags |= 2;
                }
                out.push(flags);
            }
            Request::TxnBegin => out.push(REQ_TXN_BEGIN),
            Request::TxnPrepared { stmt, params } => {
                out.push(REQ_TXN_PREPARED);
                out.extend_from_slice(&stmt.to_le_bytes());
                put_params(&mut out, params);
            }
            Request::TxnSql { sql } => {
                out.push(REQ_TXN_SQL);
                put_str(&mut out, sql);
            }
            Request::TxnCommit { kind } => {
                out.push(REQ_TXN_COMMIT);
                out.push(kind_to_u8(*kind));
            }
            Request::TxnRollback => out.push(REQ_TXN_ROLLBACK),
            Request::Close => out.push(REQ_CLOSE),
            Request::Shutdown => out.push(REQ_SHUTDOWN),
            Request::Metrics { top_k } => {
                out.push(REQ_METRICS);
                out.extend_from_slice(&top_k.to_le_bytes());
            }
            Request::Topology => out.push(REQ_TOPOLOGY),
            Request::Admin(cmd) => {
                out.push(REQ_ADMIN);
                match cmd {
                    AdminCmd::AddNode => out.push(ADMIN_ADD_NODE),
                    AdminCmd::Rebalance { table, pidx, to_node } => {
                        out.push(ADMIN_REBALANCE);
                        put_str(&mut out, table);
                        out.extend_from_slice(&pidx.to_le_bytes());
                        out.extend_from_slice(&to_node.to_le_bytes());
                    }
                    AdminCmd::Split { table, pidx } => {
                        out.push(ADMIN_SPLIT);
                        put_str(&mut out, table);
                        out.extend_from_slice(&pidx.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Request> {
        let mut b = Buf::new(payload);
        let req = match b.u8()? {
            REQ_HELLO => Request::Hello {
                proto: b.u16()?,
                node: b.u32()?,
                kind: kind_from_u8(b.u8()?)?,
            },
            REQ_PREPARE => Request::Prepare { sql: b.str()? },
            REQ_BIND_EXEC => Request::BindExec {
                stmt: b.u32()?,
                kind: kind_from_u8(b.u8()?)?,
                params: get_params(&mut b)?,
            },
            REQ_BIND_EXEC_BATCH => {
                let stmt = b.u32()?;
                let kind = kind_from_u8(b.u8()?)?;
                let n = b.u32()? as usize;
                let mut rows = Vec::with_capacity(n.min(65_536));
                for _ in 0..n {
                    rows.push(get_params(&mut b)?);
                }
                Request::BindExecBatch { stmt, kind, rows }
            }
            REQ_EXEC_SQL => {
                Request::ExecSql { kind: kind_from_u8(b.u8()?)?, sql: b.str()? }
            }
            REQ_DESCRIBE => Request::DescribeStmt { stmt: b.u32()? },
            REQ_CLOSE_STMT => Request::CloseStmt { stmt: b.u32()? },
            REQ_STATS => {
                let flags = b.u8()?;
                Request::Stats { fingerprint: flags & 1 != 0, tables: flags & 2 != 0 }
            }
            REQ_TXN_BEGIN => Request::TxnBegin,
            REQ_TXN_PREPARED => {
                Request::TxnPrepared { stmt: b.u32()?, params: get_params(&mut b)? }
            }
            REQ_TXN_SQL => Request::TxnSql { sql: b.str()? },
            REQ_TXN_COMMIT => Request::TxnCommit { kind: kind_from_u8(b.u8()?)? },
            REQ_TXN_ROLLBACK => Request::TxnRollback,
            REQ_CLOSE => Request::Close,
            REQ_SHUTDOWN => Request::Shutdown,
            REQ_METRICS => Request::Metrics { top_k: b.u16()? },
            REQ_TOPOLOGY => Request::Topology,
            REQ_ADMIN => Request::Admin(match b.u8()? {
                ADMIN_ADD_NODE => AdminCmd::AddNode,
                ADMIN_REBALANCE => AdminCmd::Rebalance {
                    table: b.str()?,
                    pidx: b.u32()?,
                    to_node: b.u32()?,
                },
                ADMIN_SPLIT => AdminCmd::Split { table: b.str()?, pidx: b.u32()? },
                t => return Err(Error::Engine(format!("bad admin subtag {t}"))),
            }),
            t => return Err(Error::Engine(format!("bad request tag 0x{t:02x}"))),
        };
        b.finish()?;
        Ok(req)
    }
}

// ---------- responses ----------

/// Cluster introspection payload of [`Response::Stats`] — `route_counts()`,
/// plan cache, epoch and session count, plus the optional byte-equality
/// fingerprint and per-table row counts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsReply {
    pub scatter: u64,
    pub snapshot_join: u64,
    pub centralized: u64,
    pub fast_dml: u64,
    pub chunks_scanned: u64,
    pub chunks_pruned: u64,
    pub cached_plans: u64,
    pub epoch: u64,
    pub sessions: u64,
    /// Claims that fell back to the interpreted 2PL executor (obs).
    pub dml_interp: u64,
    /// Redo records appended across all node WALs (obs).
    pub wal_records: u64,
    /// Group-commit flush boundaries hit across all node WALs (obs).
    pub wal_flushes: u64,
    /// Request frames read by the server since start (obs).
    pub frames_in: u64,
    /// Response frames written by the server since start (obs).
    pub frames_out: u64,
    /// Bytes read off client sockets, headers included (obs).
    pub bytes_in: u64,
    /// Bytes written to client sockets, headers included (obs).
    pub bytes_out: u64,
    /// Malformed / failed frames observed by the server (obs).
    pub frame_errors: u64,
    /// Point claims committed by the optimistic (OCC) path.
    pub occ_dml: u64,
    /// OCC validation conflicts (each one is a retry of the claim).
    pub occ_retries: u64,
    /// OCC claims that exhausted their retry budget and fell back to the
    /// 2PL fast path.
    pub occ_fallbacks: u64,
    pub fingerprint: Option<String>,
    pub table_rows: Vec<(String, u64)>,
}

/// One slow-op ring entry as shipped by [`Response::Metrics`]: a traced
/// request with its span id, total latency, and per-stage breakdown.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SlowOpWire {
    pub span: u64,
    pub label: String,
    pub total_nanos: u64,
    /// `(stage label, nanos)` pairs in the engine's stage order.
    pub stages: Vec<(String, u64)>,
}

/// Telemetry payload of [`Response::Metrics`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsReply {
    /// Prometheus-style text exposition of the whole registry.
    pub text: String,
    /// The slowest traced ops, worst first.
    pub slow_ops: Vec<SlowOpWire>,
}

/// One data node in a [`Response::Topology`] snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeWire {
    pub id: u32,
    pub state: NodeState,
    /// Partition replicas hosted (primary and backup roles both count).
    pub partitions: u32,
}

/// One partition's placement and size in a [`Response::Topology`] snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PartWire {
    pub pidx: u32,
    pub primary: u32,
    pub backup: Option<u32>,
    pub rows: u64,
    pub bytes: u64,
    /// Partition LSN and epoch fence of the serving replica.
    pub version: u64,
    pub store_epoch: u64,
    /// Congruence class `(modulus, residue)` owning this partition's keys
    /// (`None` for single-partition tables).
    pub class: Option<(i64, i64)>,
}

/// Cluster-topology payload of [`Response::Topology`] — the wire mirror of
/// the engine's [`Topology`] snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TopologyReply {
    /// Cluster epoch at the time of the snapshot.
    pub epoch: u64,
    pub nodes: Vec<NodeWire>,
    /// `(table, partitions)` placement maps, sorted by table name.
    pub tables: Vec<(String, Vec<PartWire>)>,
}

impl From<&Topology> for TopologyReply {
    fn from(t: &Topology) -> TopologyReply {
        TopologyReply {
            epoch: t.epoch,
            nodes: t
                .nodes
                .iter()
                .map(|n| NodeWire {
                    id: n.id,
                    state: n.state,
                    partitions: n.partitions as u32,
                })
                .collect(),
            tables: t
                .tables
                .iter()
                .map(|tt| {
                    let parts = tt
                        .partitions
                        .iter()
                        .map(|p| PartWire {
                            pidx: p.pidx as u32,
                            primary: p.primary,
                            backup: p.backup,
                            rows: p.rows as u64,
                            bytes: p.bytes as u64,
                            version: p.version,
                            store_epoch: p.store_epoch,
                            class: p.class,
                        })
                        .collect();
                    (tt.table.clone(), parts)
                })
                .collect(),
        }
    }
}

/// Server → client frames.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    HelloOk { proto: u16, session: u64 },
    PrepareOk { stmt: u32, params: u16 },
    Result(StatementResult),
    Describe(String),
    Stats(Box<StatsReply>),
    TxnResults(Vec<StatementResult>),
    Err { code: ErrCode, message: String },
    ShutdownOk,
    Metrics(Box<MetricsReply>),
    Topology(Box<TopologyReply>),
    /// Ack for [`Request::Admin`]. `value` is the operation's product —
    /// the new node id for `AddNode`, the new partition index for `Split`,
    /// `0` for `Rebalance`; `epoch` is the cluster epoch after the op.
    AdminOk { message: String, value: u64, epoch: u64 },
}

const RESP_HELLO_OK: u8 = 0x81;
const RESP_PREPARE_OK: u8 = 0x82;
const RESP_RESULT: u8 = 0x83;
const RESP_DESCRIBE: u8 = 0x84;
const RESP_STATS: u8 = 0x85;
const RESP_TXN_RESULTS: u8 = 0x86;
const RESP_ERR: u8 = 0x87;
const RESP_SHUTDOWN_OK: u8 = 0x88;
const RESP_METRICS: u8 = 0x89;
const RESP_TOPOLOGY: u8 = 0x8a;
const RESP_ADMIN_OK: u8 = 0x8b;

impl Response {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(32);
        match self {
            Response::HelloOk { proto, session } => {
                out.push(RESP_HELLO_OK);
                out.extend_from_slice(&proto.to_le_bytes());
                out.extend_from_slice(&session.to_le_bytes());
            }
            Response::PrepareOk { stmt, params } => {
                out.push(RESP_PREPARE_OK);
                out.extend_from_slice(&stmt.to_le_bytes());
                out.extend_from_slice(&params.to_le_bytes());
            }
            Response::Result(r) => {
                out.push(RESP_RESULT);
                put_statement_result(&mut out, r);
            }
            Response::Describe(text) => {
                out.push(RESP_DESCRIBE);
                put_str(&mut out, text);
            }
            Response::Stats(s) => {
                out.push(RESP_STATS);
                for v in [
                    s.scatter,
                    s.snapshot_join,
                    s.centralized,
                    s.fast_dml,
                    s.chunks_scanned,
                    s.chunks_pruned,
                    s.cached_plans,
                    s.epoch,
                    s.sessions,
                    s.dml_interp,
                    s.wal_records,
                    s.wal_flushes,
                    s.frames_in,
                    s.frames_out,
                    s.bytes_in,
                    s.bytes_out,
                    s.frame_errors,
                    s.occ_dml,
                    s.occ_retries,
                    s.occ_fallbacks,
                ] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                match &s.fingerprint {
                    Some(f) => {
                        out.push(1);
                        put_str(&mut out, f);
                    }
                    None => out.push(0),
                }
                out.extend_from_slice(&(s.table_rows.len() as u16).to_le_bytes());
                for (t, n) in &s.table_rows {
                    put_str(&mut out, t);
                    out.extend_from_slice(&n.to_le_bytes());
                }
            }
            Response::TxnResults(rs) => {
                out.push(RESP_TXN_RESULTS);
                out.extend_from_slice(&(rs.len() as u32).to_le_bytes());
                for r in rs {
                    put_statement_result(&mut out, r);
                }
            }
            Response::Err { code, message } => {
                out.push(RESP_ERR);
                out.push(*code as u8);
                put_str(&mut out, message);
            }
            Response::ShutdownOk => out.push(RESP_SHUTDOWN_OK),
            Response::Metrics(m) => {
                out.push(RESP_METRICS);
                put_str(&mut out, &m.text);
                out.extend_from_slice(&(m.slow_ops.len() as u16).to_le_bytes());
                for op in &m.slow_ops {
                    out.extend_from_slice(&op.span.to_le_bytes());
                    put_str(&mut out, &op.label);
                    out.extend_from_slice(&op.total_nanos.to_le_bytes());
                    out.push(op.stages.len() as u8);
                    for (stage, nanos) in &op.stages {
                        put_str(&mut out, stage);
                        out.extend_from_slice(&nanos.to_le_bytes());
                    }
                }
            }
            Response::Topology(t) => {
                out.push(RESP_TOPOLOGY);
                out.extend_from_slice(&t.epoch.to_le_bytes());
                out.extend_from_slice(&(t.nodes.len() as u16).to_le_bytes());
                for n in &t.nodes {
                    out.extend_from_slice(&n.id.to_le_bytes());
                    out.push(state_to_u8(n.state));
                    out.extend_from_slice(&n.partitions.to_le_bytes());
                }
                out.extend_from_slice(&(t.tables.len() as u16).to_le_bytes());
                for (name, parts) in &t.tables {
                    put_str(&mut out, name);
                    out.extend_from_slice(&(parts.len() as u32).to_le_bytes());
                    for p in parts {
                        out.extend_from_slice(&p.pidx.to_le_bytes());
                        out.extend_from_slice(&p.primary.to_le_bytes());
                        match p.backup {
                            Some(bk) => {
                                out.push(1);
                                out.extend_from_slice(&bk.to_le_bytes());
                            }
                            None => out.push(0),
                        }
                        for v in [p.rows, p.bytes, p.version, p.store_epoch] {
                            out.extend_from_slice(&v.to_le_bytes());
                        }
                        match p.class {
                            Some((m, r)) => {
                                out.push(1);
                                out.extend_from_slice(&m.to_le_bytes());
                                out.extend_from_slice(&r.to_le_bytes());
                            }
                            None => out.push(0),
                        }
                    }
                }
            }
            Response::AdminOk { message, value, epoch } => {
                out.push(RESP_ADMIN_OK);
                put_str(&mut out, message);
                out.extend_from_slice(&value.to_le_bytes());
                out.extend_from_slice(&epoch.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(payload: &[u8]) -> Result<Response> {
        let mut b = Buf::new(payload);
        let resp = match b.u8()? {
            RESP_HELLO_OK => Response::HelloOk { proto: b.u16()?, session: b.u64()? },
            RESP_PREPARE_OK => Response::PrepareOk { stmt: b.u32()?, params: b.u16()? },
            RESP_RESULT => Response::Result(get_statement_result(&mut b)?),
            RESP_DESCRIBE => Response::Describe(b.str()?),
            RESP_STATS => {
                // struct fields evaluate in source order, matching encode()
                let mut s = StatsReply {
                    scatter: b.u64()?,
                    snapshot_join: b.u64()?,
                    centralized: b.u64()?,
                    fast_dml: b.u64()?,
                    chunks_scanned: b.u64()?,
                    chunks_pruned: b.u64()?,
                    cached_plans: b.u64()?,
                    epoch: b.u64()?,
                    sessions: b.u64()?,
                    dml_interp: b.u64()?,
                    wal_records: b.u64()?,
                    wal_flushes: b.u64()?,
                    frames_in: b.u64()?,
                    frames_out: b.u64()?,
                    bytes_in: b.u64()?,
                    bytes_out: b.u64()?,
                    frame_errors: b.u64()?,
                    occ_dml: b.u64()?,
                    occ_retries: b.u64()?,
                    occ_fallbacks: b.u64()?,
                    fingerprint: None,
                    table_rows: Vec::new(),
                };
                if b.u8()? != 0 {
                    s.fingerprint = Some(b.str()?);
                }
                let nt = b.u16()? as usize;
                for _ in 0..nt {
                    let t = b.str()?;
                    let n = b.u64()?;
                    s.table_rows.push((t, n));
                }
                Response::Stats(Box::new(s))
            }
            RESP_TXN_RESULTS => {
                let n = b.u32()? as usize;
                let mut rs = Vec::with_capacity(n.min(65_536));
                for _ in 0..n {
                    rs.push(get_statement_result(&mut b)?);
                }
                Response::TxnResults(rs)
            }
            RESP_ERR => {
                let code = b.u8()?;
                let message = b.str()?;
                // decode through the error mapper and back so unknown codes
                // degrade to Engine instead of failing the decode
                let e = decode_error(code, message);
                let (code, message) = encode_error(&e);
                Response::Err { code, message }
            }
            RESP_SHUTDOWN_OK => Response::ShutdownOk,
            RESP_METRICS => {
                let text = b.str()?;
                let n = b.u16()? as usize;
                let mut slow_ops = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    let span = b.u64()?;
                    let label = b.str()?;
                    let total_nanos = b.u64()?;
                    let ns = b.u8()? as usize;
                    let mut stages = Vec::with_capacity(ns);
                    for _ in 0..ns {
                        let stage = b.str()?;
                        let nanos = b.u64()?;
                        stages.push((stage, nanos));
                    }
                    slow_ops.push(SlowOpWire { span, label, total_nanos, stages });
                }
                Response::Metrics(Box::new(MetricsReply { text, slow_ops }))
            }
            RESP_TOPOLOGY => {
                let epoch = b.u64()?;
                let nn = b.u16()? as usize;
                let mut nodes = Vec::with_capacity(nn.min(1024));
                for _ in 0..nn {
                    let id = b.u32()?;
                    let state = state_from_u8(b.u8()?)?;
                    let partitions = b.u32()?;
                    nodes.push(NodeWire { id, state, partitions });
                }
                let nt = b.u16()? as usize;
                let mut tables = Vec::with_capacity(nt.min(1024));
                for _ in 0..nt {
                    let name = b.str()?;
                    let np = b.u32()? as usize;
                    let mut parts = Vec::with_capacity(np.min(65_536));
                    for _ in 0..np {
                        let pidx = b.u32()?;
                        let primary = b.u32()?;
                        let backup = if b.u8()? != 0 { Some(b.u32()?) } else { None };
                        let rows = b.u64()?;
                        let bytes = b.u64()?;
                        let version = b.u64()?;
                        let store_epoch = b.u64()?;
                        let class =
                            if b.u8()? != 0 { Some((b.i64()?, b.i64()?)) } else { None };
                        parts.push(PartWire {
                            pidx,
                            primary,
                            backup,
                            rows,
                            bytes,
                            version,
                            store_epoch,
                            class,
                        });
                    }
                    tables.push((name, parts));
                }
                Response::Topology(Box::new(TopologyReply { epoch, nodes, tables }))
            }
            RESP_ADMIN_OK => Response::AdminOk {
                message: b.str()?,
                value: b.u64()?,
                epoch: b.u64()?,
            },
            t => return Err(Error::Engine(format!("bad response tag 0x{t:02x}"))),
        };
        b.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        let enc = r.encode();
        assert_eq!(Request::decode(&enc).unwrap(), r);
    }

    fn roundtrip_resp(r: Response) {
        let enc = r.encode();
        assert_eq!(Response::decode(&enc).unwrap(), r);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(Request::Hello {
            proto: PROTO_VERSION,
            node: 7,
            kind: AccessKind::Steering,
        });
        roundtrip_req(Request::Prepare { sql: "SELECT * FROM t WHERE a = ?".into() });
        roundtrip_req(Request::BindExec {
            stmt: 3,
            kind: AccessKind::UpdateToRunning,
            params: vec![
                Value::Int(-5),
                Value::Float(2.5),
                Value::str("it's a \t string\n"),
                Value::Bool(true),
                Value::Null,
            ],
        });
        roundtrip_req(Request::BindExecBatch {
            stmt: 9,
            kind: AccessKind::InsertTasks,
            rows: vec![vec![Value::Int(1)], vec![Value::Int(2)]],
        });
        roundtrip_req(Request::ExecSql {
            kind: AccessKind::Other,
            sql: "CREATE TABLE t (id INT NOT NULL) PRIMARY KEY (id)".into(),
        });
        roundtrip_req(Request::DescribeStmt { stmt: 1 });
        roundtrip_req(Request::CloseStmt { stmt: 2 });
        roundtrip_req(Request::Stats { fingerprint: true, tables: false });
        roundtrip_req(Request::Stats { fingerprint: false, tables: true });
        roundtrip_req(Request::TxnBegin);
        roundtrip_req(Request::TxnPrepared { stmt: 4, params: vec![Value::Int(1)] });
        roundtrip_req(Request::TxnSql { sql: "DELETE FROM t".into() });
        roundtrip_req(Request::TxnCommit { kind: AccessKind::Other });
        roundtrip_req(Request::TxnRollback);
        roundtrip_req(Request::Close);
        roundtrip_req(Request::Shutdown);
        roundtrip_req(Request::Metrics { top_k: 16 });
        roundtrip_req(Request::Topology);
        roundtrip_req(Request::Admin(AdminCmd::AddNode));
        roundtrip_req(Request::Admin(AdminCmd::Rebalance {
            table: "workqueue".into(),
            pidx: 3,
            to_node: 2,
        }));
        roundtrip_req(Request::Admin(AdminCmd::Split {
            table: "workqueue".into(),
            pidx: 1,
        }));
    }

    #[test]
    fn responses_roundtrip() {
        roundtrip_resp(Response::HelloOk { proto: 1, session: 42 });
        roundtrip_resp(Response::PrepareOk { stmt: 8, params: 2 });
        roundtrip_resp(Response::Result(StatementResult::Affected(11)));
        roundtrip_resp(Response::Result(StatementResult::Ok));
        roundtrip_resp(Response::Result(StatementResult::Rows(ResultSet {
            columns: vec!["a".into(), "b".into()],
            rows: vec![
                Row::new(vec![Value::Int(1), Value::str("x")]),
                Row::new(vec![Value::Null, Value::Float(f64::NAN)]),
            ],
        })));
        roundtrip_resp(Response::Describe("scatter-gather: ...".into()));
        roundtrip_resp(Response::Stats(Box::new(StatsReply {
            scatter: 1,
            fast_dml: 9,
            fingerprint: Some("workqueue\nI1\tSREADY\n".into()),
            table_rows: vec![("workqueue".into(), 100)],
            ..Default::default()
        })));
        roundtrip_resp(Response::TxnResults(vec![
            StatementResult::Affected(1),
            StatementResult::Ok,
        ]));
        roundtrip_resp(Response::Err {
            code: ErrCode::Constraint,
            message: "column 'id' is NOT NULL".into(),
        });
        roundtrip_resp(Response::ShutdownOk);
        roundtrip_resp(Response::Stats(Box::new(StatsReply {
            dml_interp: 3,
            wal_records: 400,
            wal_flushes: 50,
            frames_in: 6,
            frames_out: 6,
            bytes_in: 7_000,
            bytes_out: 8_000,
            frame_errors: 1,
            occ_dml: 250,
            occ_retries: 12,
            occ_fallbacks: 2,
            ..Default::default()
        })));
        roundtrip_resp(Response::Metrics(Box::new(MetricsReply {
            text: "# TYPE schaladb_dml_fast_total counter\n\
                   schaladb_dml_fast_total 12\n"
                .into(),
            slow_ops: vec![
                SlowOpWire {
                    span: 9,
                    label: "exec_prepared".into(),
                    total_nanos: 1_234_567,
                    stages: vec![("latch".into(), 1_000), ("exec".into(), 1_233_567)],
                },
                SlowOpWire::default(),
            ],
        })));
        roundtrip_resp(Response::Metrics(Box::new(MetricsReply::default())));
        roundtrip_resp(Response::Topology(Box::new(TopologyReply {
            epoch: 7,
            nodes: vec![
                NodeWire { id: 0, state: NodeState::Alive, partitions: 4 },
                NodeWire { id: 2, state: NodeState::Joining, partitions: 0 },
            ],
            tables: vec![(
                "workqueue".into(),
                vec![
                    PartWire {
                        pidx: 0,
                        primary: 0,
                        backup: Some(1),
                        rows: 25,
                        bytes: 1_600,
                        version: 25,
                        store_epoch: 3,
                        class: Some((4, 0)),
                    },
                    PartWire::default(),
                ],
            )],
        })));
        roundtrip_resp(Response::Topology(Box::new(TopologyReply::default())));
        roundtrip_resp(Response::AdminOk {
            message: "partition workqueue[1] split".into(),
            value: 4,
            epoch: 9,
        });
    }

    #[test]
    fn node_state_index_roundtrips() {
        for s in [
            NodeState::Alive,
            NodeState::Dead,
            NodeState::Rejoining,
            NodeState::Joining,
        ] {
            assert_eq!(state_from_u8(state_to_u8(s)).unwrap(), s);
        }
        assert!(state_from_u8(9).is_err());
    }

    #[test]
    fn nan_float_roundtrips_by_bits() {
        // Value::PartialEq uses total_cmp, under which NaN == NaN — but make
        // sure the bits really survive, not just the comparison.
        let mut out = Vec::new();
        put_value(&mut out, &Value::Float(f64::NAN));
        let v = get_value(&mut Buf::new(&out)).unwrap();
        match v {
            Value::Float(f) => assert!(f.is_nan()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frame_roundtrip_over_a_pipe() {
        let payload = Request::Prepare { sql: "SELECT 1".into() }.encode();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let got = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(got, payload);
        // clean EOF after the frame
        assert!(read_frame(&mut cursor).unwrap().is_none());
    }

    #[test]
    fn corrupt_payload_fails_checksum() {
        let payload = Request::Prepare { sql: "SELECT 1".into() }.encode();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0xff;
        let e = read_frame(&mut std::io::Cursor::new(buf));
        assert!(matches!(e, Err(Error::Engine(m)) if m.contains("checksum")));
    }

    #[test]
    fn torn_frame_is_an_error_not_a_hang_or_panic() {
        let payload = Request::Prepare { sql: "SELECT 1".into() }.encode();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        // cut mid-payload
        buf.truncate(buf.len() - 3);
        let e = read_frame(&mut std::io::Cursor::new(buf));
        assert!(matches!(e, Err(Error::Engine(m)) if m.contains("mid-frame")));
        // cut mid-header
        let e = read_frame(&mut std::io::Cursor::new(vec![1u8, 2, 3]));
        assert!(matches!(e, Err(Error::Engine(m)) if m.contains("mid-frame")));
    }

    #[test]
    fn oversize_length_prefix_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        buf.extend_from_slice(&0u32.to_le_bytes());
        let e = read_frame(&mut std::io::Cursor::new(buf));
        assert!(matches!(e, Err(Error::Engine(m)) if m.contains("MAX_FRAME")));
    }

    #[test]
    fn trailing_garbage_is_a_decode_error() {
        let mut enc = Request::TxnBegin.encode();
        enc.push(0x99);
        assert!(Request::decode(&enc).is_err());
    }

    #[test]
    fn error_codes_roundtrip_every_variant() {
        let cases: Vec<Error> = vec![
            Error::Parse("p".into()),
            Error::Catalog("c".into()),
            Error::Type("t".into()),
            Error::Constraint("n".into()),
            Error::TxnAborted("a".into()),
            Error::Unavailable("u".into()),
            Error::Engine("e".into()),
            Error::Runtime("r".into()),
        ];
        for e in cases {
            let (code, msg) = encode_error(&e);
            let back = decode_error(code as u8, msg);
            assert_eq!(std::mem::discriminant(&e), std::mem::discriminant(&back));
        }
    }

    #[test]
    fn timeout_io_gets_its_own_wire_code() {
        let e = Error::Io(std::io::Error::new(std::io::ErrorKind::TimedOut, "read deadline"));
        let (code, msg) = encode_error(&e);
        assert_eq!(code, ErrCode::Timeout);
        let back = decode_error(code as u8, msg);
        assert!(matches!(back, Error::Io(ref io) if io.kind() == std::io::ErrorKind::TimedOut));
        // Recovery degrades to Engine: it never reaches a live connection.
        let (code, _) = encode_error(&Error::Recovery("x".into()));
        assert_eq!(code, ErrCode::Engine);
    }

    #[test]
    fn kind_index_roundtrips() {
        for &k in AccessKind::all() {
            assert_eq!(kind_from_u8(kind_to_u8(k)).unwrap(), k);
        }
        assert!(kind_from_u8(200).is_err());
    }
}
