//! The network front-end: a wire protocol, a transport-agnostic session
//! layer, and a TCP server exposing the full prepared-statement API to
//! remote clients.
//!
//! The paper's premise is that a WMS database must serve *two remote
//! audiences at once*: hundreds of worker tasks hammering the task-claim
//! transactions, and human analysts running steering queries against the
//! same data mid-execution. Until this module existed, `DbCluster` was an
//! in-process library — no socket anywhere. The front-end splits into
//! three layers so neither audience is coupled to the transport:
//!
//! - [`wire`]: a hand-rolled length-prefixed binary protocol. Every frame
//!   is `u32 len + u32 FNV-1a checksum + payload` (the same checksum
//!   discipline the WAL applies to its record lines), and values reuse the
//!   engine's [`Value`](crate::storage::Value) type with a compact binary
//!   encoding. Errors travel as typed frames, never as closed sockets.
//! - [`session`]: per-session state — the prepared-handle table mapping
//!   client statement ids onto [`DbCluster::prepare`], open-transaction
//!   state (deferred statement queue, the `TxnBuilder` model), and the
//!   default [`AccessKind`](crate::storage::AccessKind) — behind a
//!   [`SessionTransport`](session::SessionTransport) trait, so the
//!   in-process path (`DbCluster` direct, or a `WorkerLink` with connector
//!   failover) and the TCP path are two transports over one session object.
//! - [`serve`]: `std::net::TcpListener` with a **bounded thread-per-
//!   connection** accept loop (the build environment is offline — no tokio,
//!   no async runtime). Connections beyond `--max-conns` are rejected with
//!   a typed `Backpressure` error frame: that is the backpressure story.
//! - [`client`]: a blocking Rust client speaking the same frames, used by
//!   `dchiron stats`/`dchiron drive`, the multi-client benchmark driver,
//!   and the round-trip tests.
//!
//! See DESIGN.md §"Network front-end & session layer" for the frame format
//! table and the session state machine.

pub mod client;
pub mod serve;
pub mod session;
pub mod wire;

pub use client::{Client, RemoteStats};
pub use serve::{Server, ServerConfig};
pub use session::{Session, SessionTransport};
pub use wire::{AdminCmd, MetricsReply, NodeWire, PartWire, SlowOpWire, TopologyReply};

use crate::{Error, Result};
use std::net::{SocketAddr, ToSocketAddrs};

/// Parse and validate a `--addr HOST:PORT` flag value. Accepts literal
/// socket addresses (`127.0.0.1:7878`, `[::1]:7878`) and resolvable host
/// names (`localhost:7878`); shared by every network subcommand (`serve`,
/// `stats`, `shutdown`, `drive`, `query`, `metrics`, `top`, `topology`,
/// `rebalance`) so they all reject bad input with one consistent message.
pub fn parse_addr(s: &str) -> Result<SocketAddr> {
    if let Ok(a) = s.parse::<SocketAddr>() {
        return Ok(a);
    }
    match s.to_socket_addrs() {
        Ok(mut addrs) => addrs.next().ok_or_else(|| {
            Error::Parse(format!("--addr '{s}' resolved to no addresses"))
        }),
        Err(e) => Err(Error::Parse(format!(
            "bad --addr '{s}': {e} (expected HOST:PORT, e.g. 127.0.0.1:7878)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_addr_accepts_literals() {
        assert_eq!(parse_addr("127.0.0.1:7878").unwrap().port(), 7878);
        assert_eq!(parse_addr("0.0.0.0:0").unwrap().port(), 0);
        assert!(parse_addr("[::1]:9000").unwrap().is_ipv6());
    }

    #[test]
    fn parse_addr_resolves_hostnames() {
        // loopback is resolvable everywhere CI runs
        let a = parse_addr("localhost:7979").unwrap();
        assert_eq!(a.port(), 7979);
        assert!(a.ip().is_loopback());
    }

    #[test]
    fn parse_addr_rejects_garbage() {
        for bad in ["", "7878", "127.0.0.1", "no spaces here", "host:notaport"] {
            let e = parse_addr(bad);
            assert!(e.is_err(), "'{bad}' should not parse");
        }
    }
}
