//! Blocking wire-protocol client.
//!
//! Speaks the [`wire`](super::wire) frames over one `TcpStream`: every
//! call writes a request frame and blocks for the matching response
//! (strict request/response alternation — the protocol has no pipelining,
//! which keeps the server's frame pump trivially correct). Used by
//! `dchiron stats`/`dchiron drive`/`dchiron shutdown`, the multi-client
//! benchmark driver, and the round-trip tests; it is the reference
//! implementation a non-Rust client would be written against.

use super::wire::{
    decode_error, read_frame, write_frame, AdminCmd, MetricsReply, Request, Response,
    StatsReply, TopologyReply, PROTO_VERSION,
};
use crate::storage::stats::AccessKind;
use crate::storage::value::Value;
use crate::storage::{ResultSet, StatementResult};
use crate::{Error, Result};
use std::net::{SocketAddr, TcpStream};

/// Cluster introspection as observed over the wire (the decoded
/// `Stats` response).
pub type RemoteStats = StatsReply;

/// One connection to a `dchiron serve` endpoint.
pub struct Client {
    stream: TcpStream,
    session: u64,
    node: u32,
    kind: AccessKind,
}

impl Client {
    /// Connect and handshake. `node` is the worker node this session
    /// speaks for (stats attribution); `kind` is the default access kind
    /// used by the untagged convenience calls.
    pub fn connect(addr: SocketAddr, node: u32, kind: AccessKind) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let mut client = Client { stream, session: 0, node, kind };
        let resp =
            client.call(&Request::Hello { proto: PROTO_VERSION, node, kind })?;
        match resp {
            Response::HelloOk { proto, session } => {
                if proto != PROTO_VERSION {
                    return Err(Error::Engine(format!(
                        "protocol version mismatch: server {proto}, client {PROTO_VERSION}"
                    )));
                }
                client.session = session;
                Ok(client)
            }
            other => Err(unexpected("HelloOk", &other)),
        }
    }

    /// Server-assigned session id.
    pub fn session_id(&self) -> u64 {
        self.session
    }

    /// The worker node declared at connect.
    pub fn node(&self) -> u32 {
        self.node
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &req.encode())?;
        let payload = read_frame(&mut self.stream)?.ok_or_else(|| {
            Error::Unavailable("server closed the connection".into())
        })?;
        match Response::decode(&payload)? {
            Response::Err { code, message } => Err(decode_error(code as u8, message)),
            ok => Ok(ok),
        }
    }

    /// Prepare a statement, returning `(stmt id, placeholder count)`.
    pub fn prepare(&mut self, sql: &str) -> Result<(u32, usize)> {
        match self.call(&Request::Prepare { sql: sql.to_string() })? {
            Response::PrepareOk { stmt, params } => Ok((stmt, params as usize)),
            other => Err(unexpected("PrepareOk", &other)),
        }
    }

    /// Bind + execute a prepared stmt under the session's default kind.
    pub fn exec(&mut self, stmt: u32, params: &[Value]) -> Result<StatementResult> {
        self.exec_tagged(stmt, self.kind, params)
    }

    /// Bind + execute a prepared stmt under an explicit access kind.
    pub fn exec_tagged(
        &mut self,
        stmt: u32,
        kind: AccessKind,
        params: &[Value],
    ) -> Result<StatementResult> {
        let req = Request::BindExec { stmt, kind, params: params.to_vec() };
        match self.call(&req)? {
            Response::Result(r) => Ok(r),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Execute a prepared single-row INSERT template over many rows.
    pub fn exec_batch(
        &mut self,
        stmt: u32,
        kind: AccessKind,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        let req = Request::BindExecBatch { stmt, kind, rows: rows.to_vec() };
        match self.call(&req)? {
            Response::Result(r) => Ok(r),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Parse + execute one SQL text under the session's default kind.
    pub fn exec_sql(&mut self, sql: &str) -> Result<StatementResult> {
        self.exec_sql_tagged(self.kind, sql)
    }

    /// Parse + execute one SQL text under an explicit access kind.
    pub fn exec_sql_tagged(
        &mut self,
        kind: AccessKind,
        sql: &str,
    ) -> Result<StatementResult> {
        let req = Request::ExecSql { kind, sql: sql.to_string() };
        match self.call(&req)? {
            Response::Result(r) => Ok(r),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Convenience: execute a SELECT and unwrap its rows.
    pub fn query(&mut self, sql: &str) -> Result<ResultSet> {
        match self.exec_sql_tagged(AccessKind::Steering, sql)? {
            StatementResult::Rows(r) => Ok(r),
            other => Err(Error::Engine(format!("expected rows, got {other:?}"))),
        }
    }

    /// EXPLAIN-style plan summary of a prepared stmt.
    pub fn describe(&mut self, stmt: u32) -> Result<String> {
        match self.call(&Request::DescribeStmt { stmt })? {
            Response::Describe(text) => Ok(text),
            other => Err(unexpected("Describe", &other)),
        }
    }

    /// Drop a prepared stmt from the server-side session table.
    pub fn close_stmt(&mut self, stmt: u32) -> Result<()> {
        match self.call(&Request::CloseStmt { stmt })? {
            Response::Result(_) => Ok(()),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Fetch cluster stats; `fingerprint`/`tables` opt into the expensive
    /// extras (full-state fingerprint, per-table row counts).
    pub fn stats(&mut self, fingerprint: bool, tables: bool) -> Result<RemoteStats> {
        match self.call(&Request::Stats { fingerprint, tables })? {
            Response::Stats(s) => Ok(*s),
            other => Err(unexpected("Stats", &other)),
        }
    }

    /// Fetch the telemetry snapshot: the Prometheus-style exposition text
    /// plus the `top_k` slowest traced ops with stage breakdowns.
    pub fn metrics(&mut self, top_k: u16) -> Result<MetricsReply> {
        match self.call(&Request::Metrics { top_k })? {
            Response::Metrics(m) => Ok(*m),
            other => Err(unexpected("Metrics", &other)),
        }
    }

    /// Fetch the cluster topology snapshot: nodes, per-partition placement
    /// and sizes, and the cluster epoch.
    pub fn topology(&mut self) -> Result<TopologyReply> {
        match self.call(&Request::Topology)? {
            Response::Topology(t) => Ok(*t),
            other => Err(unexpected("Topology", &other)),
        }
    }

    fn admin(&mut self, cmd: AdminCmd) -> Result<(String, u64, u64)> {
        match self.call(&Request::Admin(cmd))? {
            Response::AdminOk { message, value, epoch } => Ok((message, value, epoch)),
            other => Err(unexpected("AdminOk", &other)),
        }
    }

    /// Register a fresh, empty data node; returns its id. The node joins
    /// in `Joining` state and becomes a rebalance target.
    pub fn add_node(&mut self) -> Result<u32> {
        let (_, id, _) = self.admin(AdminCmd::AddNode)?;
        Ok(id as u32)
    }

    /// Move one partition's primary onto `to_node` (live hand-off).
    /// Returns the server's human-readable ack message.
    pub fn rebalance(&mut self, table: &str, pidx: u32, to_node: u32) -> Result<String> {
        let cmd = AdminCmd::Rebalance { table: table.to_string(), pidx, to_node };
        let (message, _, _) = self.admin(cmd)?;
        Ok(message)
    }

    /// Split one partition in two; returns the new partition's index.
    pub fn split(&mut self, table: &str, pidx: u32) -> Result<u32> {
        let cmd = AdminCmd::Split { table: table.to_string(), pidx };
        let (_, new_pidx, _) = self.admin(cmd)?;
        Ok(new_pidx as u32)
    }

    /// Open a deferred transaction on the server-side session.
    pub fn begin(&mut self) -> Result<()> {
        match self.call(&Request::TxnBegin)? {
            Response::Result(_) => Ok(()),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Queue a prepared statement into the open transaction.
    pub fn txn_prepared(&mut self, stmt: u32, params: &[Value]) -> Result<()> {
        let req = Request::TxnPrepared { stmt, params: params.to_vec() };
        match self.call(&req)? {
            Response::Result(_) => Ok(()),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Queue a SQL text statement into the open transaction.
    pub fn txn_sql(&mut self, sql: &str) -> Result<()> {
        match self.call(&Request::TxnSql { sql: sql.to_string() })? {
            Response::Result(_) => Ok(()),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Atomically execute the queued statements.
    pub fn commit(&mut self, kind: AccessKind) -> Result<Vec<StatementResult>> {
        match self.call(&Request::TxnCommit { kind })? {
            Response::TxnResults(rs) => Ok(rs),
            other => Err(unexpected("TxnResults", &other)),
        }
    }

    /// Discard the open transaction's queue.
    pub fn rollback(&mut self) -> Result<()> {
        match self.call(&Request::TxnRollback)? {
            Response::Result(_) => Ok(()),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Graceful close: tell the server, then drop the stream.
    pub fn close(mut self) -> Result<()> {
        match self.call(&Request::Close)? {
            Response::Result(_) => Ok(()),
            other => Err(unexpected("Result", &other)),
        }
    }

    /// Ask the server process to shut down (the SIGTERM-equivalent).
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShutdownOk => Ok(()),
            other => Err(unexpected("ShutdownOk", &other)),
        }
    }
}

fn unexpected(wanted: &str, got: &Response) -> Error {
    Error::Engine(format!("expected {wanted} response, got {got:?}"))
}
