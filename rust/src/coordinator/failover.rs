//! Supervisor failover: heartbeats and secondary takeover — plus the
//! engine-side availability loop for the data tier.
//!
//! The primary supervisor updates its heartbeat row on every poll. The
//! secondary watches that row; when it goes stale past the timeout it
//! rebuilds the dependency graph from the database
//! (`Supervisor::rebuild_from_db`) and becomes the active supervisor — the
//! paper's "secondary supervisor eliminates the single point of failure".
//!
//! [`run_availability_loop`] is the data-tier counterpart: a background
//! sweeper that promotes backups of dead data nodes, heals stale replicas,
//! and drives restarted nodes through the rejoin state machine while the
//! workflow keeps executing.

use crate::coordinator::supervisor::{IdGen, Supervisor};
use crate::coordinator::workflow::WorkflowSpec;
use crate::storage::replication::AvailabilityManager;
use crate::storage::{AccessKind, DbCluster, Value};
use crate::Result;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// node-table row ids for the two supervisors.
pub const PRIMARY_NODE_ROW: i64 = 100_000;
pub const SECONDARY_NODE_ROW: i64 = 100_001;

/// Which supervisor a loop is running as.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SupervisorRole {
    Primary,
    Secondary,
}

/// Register the supervisor and secondary-supervisor rows in `node`.
pub fn register_supervisor_nodes(db: &DbCluster) -> Result<()> {
    let now = db.clock.now();
    let ins = db.prepare(
        "INSERT INTO node (nodeid, hostname, cores, role, status, heartbeat) \
         VALUES (?, ?, 1, ?, 'UP', ?)",
    )?;
    db.exec_prepared_batch(
        0,
        AccessKind::Other,
        &ins,
        &[
            vec![
                Value::Int(PRIMARY_NODE_ROW),
                Value::str("supervisor"),
                Value::str("supervisor"),
                Value::Float(now),
            ],
            vec![
                Value::Int(SECONDARY_NODE_ROW),
                Value::str("secondary-supervisor"),
                Value::str("secondary_supervisor"),
                Value::Float(now),
            ],
        ],
    )?;
    Ok(())
}

/// Primary (or promoted secondary) supervisor loop: poll readiness, beat the
/// heart, exit when the workflow completes or `alive` is flipped off
/// (failure injection).
pub fn run_supervisor_loop(
    sup: &mut Supervisor,
    role: SupervisorRole,
    done: Arc<AtomicBool>,
    alive: Arc<AtomicBool>,
    poll_secs: f64,
) {
    let node_row = match role {
        SupervisorRole::Primary => PRIMARY_NODE_ROW,
        SupervisorRole::Secondary => SECONDARY_NODE_ROW,
    };
    while !done.load(Ordering::SeqCst) {
        if role == SupervisorRole::Primary && !alive.load(Ordering::SeqCst) {
            // crashed: stop polling AND stop heartbeating
            return;
        }
        match sup.poll() {
            Ok(r) => {
                if r.workflow_done {
                    return;
                }
            }
            Err(e) => log::error!("supervisor poll: {e}"),
        }
        let _ = sup.heartbeat(node_row);
        std::thread::sleep(std::time::Duration::from_secs_f64(poll_secs));
    }
}

/// Secondary supervisor loop: watch the primary's heartbeat; on timeout,
/// rebuild state from the database and take over as the active supervisor.
#[allow(clippy::too_many_arguments)]
pub fn run_secondary_loop(
    db: Arc<DbCluster>,
    wf: WorkflowSpec,
    workers: usize,
    ids: Arc<IdGen>,
    seed: u64,
    done: Arc<AtomicBool>,
    primary_alive: Arc<AtomicBool>,
    failovers: Arc<AtomicUsize>,
    poll_secs: f64,
    timeout_secs: f64,
) {
    loop {
        if done.load(Ordering::SeqCst) {
            return;
        }
        // Heartbeat staleness check against DB time (prepared point read;
        // this fires every watch interval for the whole run).
        let stale = match db
            .prepare("SELECT heartbeat FROM node WHERE nodeid = ?")
            .and_then(|p| db.query_prepared(&p, &[Value::Int(PRIMARY_NODE_ROW)]))
        {
            Ok(rs) => {
                let hb = rs
                    .rows
                    .first()
                    .and_then(|r| r.values[0].as_f64())
                    .unwrap_or(0.0);
                db.clock.now() - hb > timeout_secs
            }
            Err(_) => false,
        };
        // Heartbeat staleness is the trigger (a genuinely crashed primary
        // cannot flip any flag); `primary_alive` only makes the injected-kill
        // tests deterministic by letting the secondary react immediately.
        if stale || !primary_alive.load(Ordering::SeqCst) {
            failovers.fetch_add(1, Ordering::SeqCst);
            log::warn!("secondary supervisor taking over");
            let _ = db
                .prepare("UPDATE node SET status = 'DOWN' WHERE nodeid = ?")
                .and_then(|p| {
                    db.exec_prepared(0, AccessKind::Other, &p, &[Value::Int(PRIMARY_NODE_ROW)])
                });
            let mut sup = Supervisor::new(db.clone(), wf.clone(), workers, ids.clone(), seed);
            sup.done = done.clone();
            if let Err(e) = sup.rebuild_from_db() {
                log::error!("secondary rebuild failed: {e}");
                continue;
            }
            run_supervisor_loop(
                &mut sup,
                SupervisorRole::Secondary,
                done.clone(),
                Arc::new(AtomicBool::new(true)),
                poll_secs,
            );
            return;
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(poll_secs * 2.0));
    }
}

/// Background availability sweeper for the data tier: periodically
/// promote / heal / rejoin until `done` flips. Returns the join handle so
/// the engine can collect it with its other threads.
pub fn run_availability_loop(
    db: Arc<DbCluster>,
    interval_secs: f64,
    done: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("availability".into())
        .spawn(move || {
            let am = AvailabilityManager::new(db);
            while !done.load(Ordering::SeqCst) {
                match am.sweep() {
                    Ok(r) => {
                        if r.promoted > 0 || r.healed > 0 || r.rejoined > 0 {
                            log::info!("availability sweep: {r:?}");
                        }
                    }
                    Err(e) => log::warn!("availability sweep: {e}"),
                }
                std::thread::sleep(std::time::Duration::from_secs_f64(interval_secs));
            }
        })
        .expect("spawn availability loop")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{DChironEngine, EngineConfig};
    use crate::coordinator::payload::Payload;
    use crate::coordinator::workflow::{ActivitySpec, Operator};
    use crate::storage::value::Value;

    /// Kill the primary supervisor mid-run: the secondary must take over and
    /// the workflow must still complete.
    #[test]
    fn secondary_takes_over_and_finishes() {
        let wf = WorkflowSpec::new("failover", 30)
            .activity(ActivitySpec::new("a1", Operator::Map, Payload::Sleep { mean_secs: 2.0 }))
            .activity(ActivitySpec::new("a2", Operator::Map, Payload::Sleep { mean_secs: 2.0 }));
        let engine = DChironEngine::new(EngineConfig {
            workers: 2,
            threads_per_worker: 2,
            time_scale: 0.005, // 10ms tasks
            supervisor_poll_secs: 0.002,
            heartbeat_timeout_secs: 0.05,
            ..Default::default()
        });
        let running = engine.start(wf, vec![vec![]; 30]).unwrap();
        // let activity 1 get going, then kill the primary
        std::thread::sleep(std::time::Duration::from_millis(30));
        running.kill_primary_supervisor();
        let db = running.db.clone();
        let report = running.join().unwrap();
        assert_eq!(report.supervisor_failovers, 1);
        assert_eq!(report.executed_tasks, 60);
        let rs = db.query("SELECT status FROM workflow").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("FINISHED"));
        // primary marked DOWN in the node table
        let rs = db
            .query(&format!("SELECT status FROM node WHERE nodeid = {PRIMARY_NODE_ROW}"))
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("DOWN"));
    }

    /// Kill a data node mid-run with the background availability loop on:
    /// the sweeper promotes its backups and the workflow still completes.
    #[test]
    fn availability_loop_repairs_data_node_failure_mid_run() {
        let tasks = 24;
        let wf = WorkflowSpec::new("av_loop", tasks)
            .activity(ActivitySpec::new("a1", Operator::Map, Payload::Sleep { mean_secs: 2.0 }))
            .activity(ActivitySpec::new("a2", Operator::Map, Payload::Sleep { mean_secs: 2.0 }));
        let engine = DChironEngine::new(EngineConfig {
            workers: 2,
            threads_per_worker: 2,
            time_scale: 0.005, // 10ms tasks
            supervisor_poll_secs: 0.002,
            availability_sweep_secs: 0.002,
            ..Default::default()
        });
        let running = engine.start(wf, vec![vec![]; tasks]).unwrap();
        let db = running.db.clone();
        std::thread::sleep(std::time::Duration::from_millis(25));
        db.kill_node(1).unwrap();
        let report = running.join().unwrap();
        assert_eq!(report.executed_tasks, tasks as u64 * 2);
        let rs = db.query("SELECT status FROM workflow").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("FINISHED"));
        // the loop promoted node 1's primaries while workers kept claiming
        assert!(db.cluster_epoch() > 0, "promotion must have opened a new epoch");
    }
}
