//! The supervisor: task generation, dependency-driven readiness, completion
//! detection, heartbeats.
//!
//! Paper §3.1: "*Supervisor* is responsible for adding tasks to the WQ.
//! *Secondary supervisor* eliminates the single point of failure by becoming
//! the main supervisor in case the original main supervisor crashes."
//!
//! The supervisor generates the whole task graph up front (so the WQ shows
//! WAITING/READY rows for downstream activities while earlier ones run,
//! exactly like the paper's Figure 3 excerpt), assigns `worker_id`
//! circularly (§4 "the supervisor circularly assigns a worker id to each
//! task"), and then drives readiness: when a task finishes, its dependents'
//! counters drop; at zero the dependent's inputs are ingested (producer
//! outputs become consumer inputs in `taskfield`) and its WQ row flips to
//! READY. All of that state is *also* persisted (`taskdep`), so a secondary
//! supervisor can rebuild the graph from the database and take over.

use crate::coordinator::payload::Payload;
use crate::coordinator::status;
use crate::coordinator::workflow::{Operator, WorkflowSpec};
use crate::storage::prepared::{in_placeholders, padded_chunks, IN_CHUNK};
use crate::storage::{AccessKind, DbCluster, StatementResult, Value};
use crate::util::rng::Rng;
use crate::Result;
use rustc_hash::{FxHashMap, FxHashSet};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Arc, OnceLock};

/// Single-row templates the supervisor binds per task/row; prepared once
/// per cluster via the shared plan cache, values never pass through SQL
/// text. Most of these classify into compiled fast plans at prepare time
/// (`storage::dml_plan`): the INSERT templates apply rows directly with the
/// batch landing partitions write-locked (siblings only read-latched for
/// the PK probe), and `WORKFLOW_FINISH`/`HEARTBEAT` are point updates by
/// primary key. `SELECT_DONE` (an OR predicate) and the `IN (...)` chunk
/// statements stay on the interpreted path by design.
const INSERT_WORKFLOW: &str =
    "INSERT INTO workflow (wfid, name, status, starttime) VALUES (?, ?, 'RUNNING', ?)";
const INSERT_ACTIVITY: &str =
    "INSERT INTO activity (actid, wfid, name, operator, ord, status, tasks_total, tasks_done) \
     VALUES (?, ?, ?, ?, ?, ?, ?, 0)";
const INSERT_TASK: &str =
    "INSERT INTO workqueue (taskid, actid, wfid, workerid, coreid, cmd, workspace, failtries, \
     stdout, status, duration, starttime, endtime) \
     VALUES (?, ?, ?, ?, NULL, ?, ?, 0, NULL, ?, ?, NULL, NULL)";
const INSERT_DEP: &str = "INSERT INTO taskdep (depid, taskid, dep) VALUES (?, ?, ?)";
const INSERT_FIELD_IN: &str =
    "INSERT INTO taskfield (fieldid, taskid, actid, field, value, direction) \
     VALUES (?, ?, ?, ?, ?, 'in')";
const SELECT_DONE: &str =
    "SELECT taskid FROM workqueue WHERE status = 'FINISHED' OR status = 'FAILED'";
const ACTIVITY_TO_RUNNING: &str =
    "UPDATE activity SET status = 'RUNNING' WHERE status = 'WAITING'";
const WORKFLOW_FINISH: &str =
    "UPDATE workflow SET status = 'FINISHED', endtime = ? WHERE wfid = ?";
const ACTIVITY_FINISH_ALL: &str = "UPDATE activity SET status = 'FINISHED'";
const HEARTBEAT: &str = "UPDATE node SET heartbeat = ? WHERE nodeid = ?";

/// Fixed-width IN-clause texts, rendered once per process (the skeleton is
/// invariant; only the bound ids change per call).
fn select_out_fields_in_sql() -> &'static str {
    static SQL: OnceLock<String> = OnceLock::new();
    SQL.get_or_init(|| {
        format!(
            "SELECT taskid, field, value FROM taskfield \
             WHERE direction = 'out' AND taskid IN ({})",
            in_placeholders(IN_CHUNK)
        )
    })
}

fn flip_ready_in_sql() -> &'static str {
    static SQL: OnceLock<String> = OnceLock::new();
    SQL.get_or_init(|| {
        format!(
            "UPDATE workqueue SET status = '{}' WHERE taskid IN ({})",
            status::READY,
            in_placeholders(IN_CHUNK)
        )
    })
}

fn flip_filtered_in_sql() -> &'static str {
    static SQL: OnceLock<String> = OnceLock::new();
    SQL.get_or_init(|| {
        format!(
            "UPDATE workqueue SET status = '{}', stdout = 'filtered-out', \
             starttime = NOW(), endtime = NOW() WHERE taskid IN ({})",
            status::FINISHED,
            in_placeholders(IN_CHUNK)
        )
    })
}

/// Monotone id generators shared by supervisor and workers.
#[derive(Default)]
pub struct IdGen {
    pub task: AtomicI64,
    pub field: AtomicI64,
    pub file: AtomicI64,
    pub prov: AtomicI64,
    pub dep: AtomicI64,
}

impl IdGen {
    pub fn next(counter: &AtomicI64) -> i64 {
        counter.fetch_add(1, Ordering::Relaxed)
    }
}

/// In-memory dependency graph (rebuildable from `taskdep`).
#[derive(Default)]
struct DepGraph {
    /// task -> number of unfinished dependencies
    remaining: FxHashMap<i64, usize>,
    /// task -> dependents
    dependents: FxHashMap<i64, Vec<i64>>,
    /// task -> its dependencies (for input ingestion)
    deps: FxHashMap<i64, Vec<i64>>,
    /// task -> activity index (0-based)
    task_act: FxHashMap<i64, usize>,
    finished: FxHashSet<i64>,
}

/// The supervisor. Drive it with [`Supervisor::bootstrap`] (primary only)
/// then repeated [`Supervisor::poll`] calls until it reports completion.
pub struct Supervisor {
    db: Arc<DbCluster>,
    wf: WorkflowSpec,
    workers: usize,
    node_id: u32,
    rng: Rng,
    graph: DepGraph,
    wfid: i64,
    ids: Arc<IdGen>,
    /// Flipped when the workflow reaches a terminal state.
    pub done: Arc<AtomicBool>,
    /// Tasks that finished but whose dependents' bookkeeping isn't flushed.
    batch_limit: usize,
}

/// Per-poll progress summary.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PollReport {
    pub newly_finished: usize,
    pub newly_ready: usize,
    pub filtered_out: usize,
    pub workflow_done: bool,
}

impl Supervisor {
    pub fn new(
        db: Arc<DbCluster>,
        wf: WorkflowSpec,
        workers: usize,
        ids: Arc<IdGen>,
        seed: u64,
    ) -> Supervisor {
        Supervisor {
            db,
            wf,
            workers: workers.max(1),
            node_id: u32::MAX, // supervisor's stat bucket
            rng: Rng::new(seed),
            graph: DepGraph::default(),
            wfid: 1,
            ids,
            done: Arc::new(AtomicBool::new(false)),
            batch_limit: 256,
        }
    }

    pub fn wfid(&self) -> i64 {
        self.wfid
    }

    /// Prepare (plan-cache hit after the first call) and execute with bound
    /// parameters under this supervisor's stats bucket.
    fn exec_p(&self, kind: AccessKind, sql: &str, params: &[Value]) -> Result<StatementResult> {
        let p = self.db.prepare(sql)?;
        self.db.exec_prepared(self.node_id, kind, &p, params)
    }

    /// Execute a prepared single-row INSERT template over `rows`, chunked
    /// into atomic multi-row inserts of at most `batch_limit`.
    fn exec_batch(&self, kind: AccessKind, sql: &str, rows: &[Vec<Value>]) -> Result<()> {
        if rows.is_empty() {
            return Ok(());
        }
        let p = self.db.prepare(sql)?;
        for chunk in rows.chunks(self.batch_limit) {
            self.db.exec_prepared_batch(self.node_id, kind, &p, chunk)?;
        }
        Ok(())
    }

    /// Run `sql_of_chunk` (a statement ending in `IN (<IN_CHUNK> ?s)`) over
    /// every padded chunk of `ids`.
    fn exec_in_chunks(&self, kind: AccessKind, sql: &str, ids: &[i64]) -> Result<()> {
        let p = self.db.prepare(sql)?;
        for chunk in padded_chunks(ids, IN_CHUNK) {
            self.db.exec_prepared(self.node_id, kind, &p, &chunk)?;
        }
        Ok(())
    }

    /// Mean nominal duration for tasks of an activity.
    fn activity_mean(&self, act: usize) -> f64 {
        match self.wf.activities[act].payload {
            Payload::Sleep { mean_secs } | Payload::Busy { mean_secs } => mean_secs,
            _ => 0.0,
        }
    }

    /// Generate the workflow, activity, task, dependency, and input rows.
    ///
    /// `inputs` are the parameter tuples of activity 1 (may be empty vecs
    /// for purely synthetic duration workloads); its length must equal the
    /// spec's input cardinality.
    pub fn bootstrap(&mut self, inputs: &[Vec<(String, f64)>]) -> Result<()> {
        self.wf.validate()?;
        assert_eq!(
            inputs.len(),
            self.wf.input_cardinality,
            "input tuples must match the spec cardinality"
        );
        let now = self.db.clock.now();
        self.exec_p(
            AccessKind::Other,
            INSERT_WORKFLOW,
            &[Value::Int(self.wfid), Value::str(&self.wf.name), Value::Float(now)],
        )?;

        // Activity rows.
        let counts = self.wf.planned_task_counts();
        let mut act_rows: Vec<Vec<Value>> = Vec::new();
        for (i, a) in self.wf.activities.iter().enumerate() {
            act_rows.push(vec![
                Value::Int(i as i64 + 1),
                Value::Int(self.wfid),
                Value::str(&a.name),
                Value::str(a.operator.name()),
                Value::Int(i as i64 + 1),
                Value::str(if i == 0 { "RUNNING" } else { "WAITING" }),
                Value::Int(counts[i] as i64),
            ]);
        }
        self.exec_batch(AccessKind::Other, INSERT_ACTIVITY, &act_rows)?;

        // Task graph, activity by activity.
        let mut worker_cursor = 0usize;
        let mut prev_tasks: Vec<i64> = Vec::new();
        for (ai, act) in self.wf.activities.iter().enumerate().collect::<Vec<_>>() {
            let n_tasks = counts[ai];
            let mean = self.activity_mean(ai);
            let mut tids = Vec::with_capacity(n_tasks);
            let mut task_rows: Vec<Vec<Value>> = Vec::with_capacity(n_tasks);
            let mut dep_rows: Vec<Vec<Value>> = Vec::new();
            for j in 0..n_tasks {
                let tid = IdGen::next(&self.ids.task);
                tids.push(tid);
                let wid = worker_cursor % self.workers;
                worker_cursor += 1;
                let dur = if mean > 0.0 { self.rng.task_duration(mean, 0.05) } else { 0.0 };
                let st = if ai == 0 { status::READY } else { status::WAITING };
                task_rows.push(vec![
                    Value::Int(tid),
                    Value::Int(ai as i64 + 1),
                    Value::Int(self.wfid),
                    Value::Int(wid as i64),
                    Value::str(format!("./run {} id={tid}", act.name)),
                    Value::str(format!("/data/{}", act.name)),
                    Value::str(st),
                    Value::Float(dur),
                ]);
                // dependencies on the previous activity
                let deps: Vec<i64> = if ai == 0 {
                    vec![]
                } else {
                    match act.operator {
                        Operator::Map | Operator::Filter { .. } => {
                            vec![prev_tasks[j.min(prev_tasks.len() - 1)]]
                        }
                        Operator::SplitMap { fanout } => {
                            vec![prev_tasks[(j / fanout).min(prev_tasks.len() - 1)]]
                        }
                        Operator::Reduce { fanin } => {
                            let lo = j * fanin;
                            let hi = ((j + 1) * fanin).min(prev_tasks.len());
                            prev_tasks[lo..hi].to_vec()
                        }
                        Operator::MrQuery => prev_tasks.clone(),
                    }
                };
                for d in &deps {
                    let depid = IdGen::next(&self.ids.dep);
                    dep_rows.push(vec![Value::Int(depid), Value::Int(tid), Value::Int(*d)]);
                }
                self.graph.remaining.insert(tid, deps.len());
                for d in &deps {
                    self.graph.dependents.entry(*d).or_default().push(tid);
                }
                self.graph.deps.insert(tid, deps);
                self.graph.task_act.insert(tid, ai);
            }
            self.exec_batch(AccessKind::InsertTasks, INSERT_TASK, &task_rows)?;
            self.exec_batch(AccessKind::InsertTasks, INSERT_DEP, &dep_rows)?;
            prev_tasks = tids;
        }

        // Activity-1 input fields.
        let mut field_rows: Vec<Vec<Value>> = Vec::new();
        let first_act_tasks: Vec<i64> = self
            .graph
            .task_act
            .iter()
            .filter(|(_, a)| **a == 0)
            .map(|(t, _)| *t)
            .collect();
        let mut sorted_first = first_act_tasks;
        sorted_first.sort();
        for (tid, tuple) in sorted_first.iter().zip(inputs.iter()) {
            for (name, val) in tuple {
                let fid = IdGen::next(&self.ids.field);
                field_rows.push(vec![
                    Value::Int(fid),
                    Value::Int(*tid),
                    Value::Int(1),
                    Value::str(name),
                    Value::Float(*val),
                ]);
            }
        }
        self.exec_batch(AccessKind::InsertDomainData, INSERT_FIELD_IN, &field_rows)?;
        Ok(())
    }

    /// Rebuild the in-memory graph from the database — the secondary
    /// supervisor's takeover path. Tasks whose dependencies all completed
    /// during the takeover gap (still WAITING with zero remaining deps) are
    /// promoted immediately so no readiness is lost.
    pub fn rebuild_from_db(&mut self) -> Result<()> {
        self.graph = DepGraph::default();
        let tasks = self.db.query("SELECT taskid, actid, status FROM workqueue")?;
        let (ti, ai, si) = (
            tasks.col("taskid").unwrap(),
            tasks.col("actid").unwrap(),
            tasks.col("status").unwrap(),
        );
        let mut waiting: FxHashSet<i64> = FxHashSet::default();
        for r in &tasks.rows {
            let tid = r.values[ti].as_i64().unwrap();
            let act = r.values[ai].as_i64().unwrap() as usize - 1;
            self.graph.task_act.insert(tid, act);
            self.graph.remaining.insert(tid, 0);
            self.graph.deps.insert(tid, vec![]);
            let st = r.values[si].as_str().unwrap_or("");
            if st == status::FINISHED || st == status::FAILED {
                self.graph.finished.insert(tid);
            } else if st == status::WAITING {
                waiting.insert(tid);
            }
        }
        let deps = self.db.query("SELECT taskid, dep FROM taskdep")?;
        for r in &deps.rows {
            let tid = r.values[0].as_i64().unwrap();
            let dep = r.values[1].as_i64().unwrap();
            self.graph.deps.get_mut(&tid).unwrap().push(dep);
            self.graph.dependents.entry(dep).or_default().push(tid);
            if !self.graph.finished.contains(&dep) {
                *self.graph.remaining.get_mut(&tid).unwrap() += 1;
            }
        }
        // keep the task-id allocator ahead of everything persisted
        let max_tid = self
            .graph
            .task_act
            .keys()
            .max()
            .copied()
            .unwrap_or(0);
        self.ids.task.fetch_max(max_tid + 1, Ordering::Relaxed);

        // close the takeover gap: WAITING tasks with no unfinished deps
        let mut stranded: Vec<i64> = waiting
            .into_iter()
            .filter(|t| self.graph.remaining.get(t).copied() == Some(0))
            .collect();
        stranded.sort_unstable();
        if !stranded.is_empty() {
            let (_, filtered) = self.promote(stranded)?;
            // filtered-out stranded tasks may unlock further tasks
            self.cascade(filtered)?;
        }
        Ok(())
    }

    /// One readiness/completion sweep.
    pub fn poll(&mut self) -> Result<PollReport> {
        let mut report = PollReport::default();

        // 1. who finished since last poll?
        let rs = self.exec_p(AccessKind::UpdateActivityStatus, SELECT_DONE, &[])?;
        let rs = match rs {
            StatementResult::Rows(r) => r,
            _ => unreachable!(),
        };
        let mut newly: Vec<i64> = Vec::new();
        for r in &rs.rows {
            let tid = r.values[0].as_i64().unwrap();
            if self.graph.finished.insert(tid) {
                newly.push(tid);
            }
        }
        report.newly_finished = newly.len();

        // 2. decrement dependents, collect newly-ready, and promote them.
        let (n_ready, n_filtered) = self.cascade(newly)?;
        report.newly_ready = n_ready;
        report.filtered_out = n_filtered;

        // 6. activity + workflow bookkeeping.
        if report.newly_finished > 0 || report.filtered_out > 0 {
            self.exec_p(AccessKind::UpdateActivityStatus, ACTIVITY_TO_RUNNING, &[])?;
        }
        let total: usize = self.graph.task_act.len();
        if self.graph.finished.len() == total && total > 0 {
            let now = self.db.clock.now();
            self.exec_p(
                AccessKind::Other,
                WORKFLOW_FINISH,
                &[Value::Float(now), Value::Int(self.wfid)],
            )?;
            self.exec_p(AccessKind::Other, ACTIVITY_FINISH_ALL, &[])?;
            self.done.store(true, Ordering::SeqCst);
            report.workflow_done = true;
        }
        Ok(report)
    }

    /// Propagate completion of `frontier` through the dependency graph:
    /// decrement dependents, promote the ones that become ready, and keep
    /// cascading — filtered-out tasks complete instantly, which can unlock
    /// tasks further down the chain within the same sweep. Returns
    /// `(newly_ready, filtered_out)` totals.
    fn cascade(&mut self, mut frontier: Vec<i64>) -> Result<(usize, usize)> {
        let mut total_ready = 0;
        let mut total_filtered = 0;
        while !frontier.is_empty() {
            let mut ready: Vec<i64> = Vec::new();
            for tid in &frontier {
                let Some(deps) = self.graph.dependents.get(tid) else { continue };
                for d in deps.clone() {
                    let rem = self.graph.remaining.get_mut(&d).expect("dependent tracked");
                    if *rem > 0 {
                        *rem -= 1;
                        if *rem == 0 {
                            ready.push(d);
                        }
                    }
                }
            }
            if ready.is_empty() {
                break;
            }
            let (n_ready, filtered) = self.promote(ready)?;
            total_ready += n_ready;
            total_filtered += filtered.len();
            frontier = filtered;
        }
        Ok((total_ready, total_filtered))
    }

    /// Promote dependency-satisfied tasks: apply Filter predicates, ingest
    /// producer outputs as inputs, flip WQ statuses. Returns the count of
    /// newly READY tasks and the list of filtered-out (auto-finished) ones.
    fn promote(&mut self, ready: Vec<i64>) -> Result<(usize, Vec<i64>)> {
        {
            // 3. ingest inputs: producer 'out' fields become consumer 'in'.
            let mut all_deps: Vec<i64> = ready
                .iter()
                .flat_map(|t| self.graph.deps.get(t).cloned().unwrap_or_default())
                .collect();
            all_deps.sort_unstable();
            all_deps.dedup();
            let mut outputs: FxHashMap<i64, Vec<(String, f64)>> = FxHashMap::default();
            if !all_deps.is_empty() {
                // Fixed-width IN probe: one cached plan covers every list
                // length (padding duplicates the last id, harmless in IN).
                let p = self.db.prepare(select_out_fields_in_sql())?;
                for chunk in padded_chunks(&all_deps, IN_CHUNK) {
                    let rs = self
                        .db
                        .exec_prepared(self.node_id, AccessKind::Other, &p, &chunk)?
                        .rows();
                    for r in &rs.rows {
                        let tid = r.values[0].as_i64().unwrap();
                        let f = r.values[1].as_str().unwrap_or("").to_string();
                        let v = r.values[2].as_f64().unwrap_or(0.0);
                        outputs.entry(tid).or_default().push((f, v));
                    }
                }
            }

            // 4. apply Filter operators: drop tasks whose producer output
            // fails the predicate — they finish instantly, unexecuted.
            let mut to_ready: Vec<i64> = Vec::new();
            let mut filtered: Vec<i64> = Vec::new();
            for t in ready {
                let act = self.graph.task_act[&t];
                let keep = match self.wf.activities.get(act).map(|a| a.operator) {
                    Some(Operator::Filter { field, min }) => {
                        let deps = &self.graph.deps[&t];
                        deps.iter().any(|d| {
                            outputs
                                .get(d)
                                .map(|fs| {
                                    fs.iter().any(|(n, v)| n == field && *v >= min)
                                })
                                .unwrap_or(false)
                        })
                    }
                    _ => true,
                };
                if keep {
                    to_ready.push(t);
                } else {
                    filtered.push(t);
                }
            }
            // input ingestion rows for kept tasks
            let mut field_rows: Vec<Vec<Value>> = Vec::new();
            for t in &to_ready {
                let act = self.graph.task_act[&t] as i64 + 1;
                for d in &self.graph.deps[t] {
                    if let Some(fs) = outputs.get(d) {
                        for (name, val) in fs {
                            let fid = IdGen::next(&self.ids.field);
                            field_rows.push(vec![
                                Value::Int(fid),
                                Value::Int(*t),
                                Value::Int(act),
                                Value::str(name),
                                Value::Float(*val),
                            ]);
                        }
                    }
                }
            }
            self.exec_batch(AccessKind::InsertDomainData, INSERT_FIELD_IN, &field_rows)?;

            // 5. flip statuses (fixed-width IN updates; padding repeats an
            // id, which an UPDATE applies once).
            if !to_ready.is_empty() {
                self.exec_in_chunks(
                    AccessKind::UpdateActivityStatus,
                    flip_ready_in_sql(),
                    &to_ready,
                )?;
            }
            if !filtered.is_empty() {
                self.exec_in_chunks(
                    AccessKind::UpdateActivityStatus,
                    flip_filtered_in_sql(),
                    &filtered,
                )?;
                // filtered tasks count as finished for dependency purposes;
                // they propagate on the next poll
                for t in filtered.iter() {
                    self.graph.finished.insert(*t);
                }
            }
            Ok((to_ready.len(), filtered))
        }
    }

    /// Touch this supervisor's heartbeat row.
    pub fn heartbeat(&self, node_row: i64) -> Result<()> {
        let now = self.db.clock.now();
        self.exec_p(
            AccessKind::UpdateWorkerHeartbeat,
            HEARTBEAT,
            &[Value::Float(now), Value::Int(node_row)],
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::Payload;
    use crate::coordinator::schema;
    use crate::coordinator::workflow::ActivitySpec;
    use crate::storage::cluster::ClusterConfig;
    use crate::storage::value::Value;

    fn setup(wf: WorkflowSpec, workers: usize) -> (Arc<DbCluster>, Supervisor) {
        let db = DbCluster::start(ClusterConfig::default()).unwrap();
        schema::create_schema(&db, workers).unwrap();
        let ids = Arc::new(IdGen::default());
        ids.task.store(1, Ordering::Relaxed);
        let sup = Supervisor::new(db.clone(), wf, workers, ids, 7);
        (db, sup)
    }

    fn chain2(n: usize) -> WorkflowSpec {
        WorkflowSpec::new("t", n)
            .activity(ActivitySpec::new("a1", Operator::Map, Payload::Sleep { mean_secs: 1.0 }))
            .activity(ActivitySpec::new("a2", Operator::Map, Payload::Sleep { mean_secs: 1.0 }))
    }

    fn finish_all_running_or_ready(db: &DbCluster, act: i64) {
        let p = db
            .prepare(
                "UPDATE workqueue SET status = 'FINISHED', endtime = NOW() \
                 WHERE actid = ? AND status = 'READY'",
            )
            .unwrap();
        db.exec_prepared(0, AccessKind::Other, &p, &[Value::Int(act)]).unwrap();
    }

    #[test]
    fn bootstrap_generates_figure3_shape() {
        let (db, mut sup) = setup(chain2(6), 2);
        sup.bootstrap(&vec![vec![]; 6]).unwrap();
        // 12 tasks total; act1 READY, act2 WAITING
        assert_eq!(db.table_rows("workqueue").unwrap(), 12);
        let rs = db
            .query("SELECT status, COUNT(*) AS n FROM workqueue GROUP BY status ORDER BY status")
            .unwrap();
        let m: Vec<(String, i64)> = rs
            .rows
            .iter()
            .map(|r| {
                (r.values[0].as_str().unwrap().to_string(), r.values[1].as_i64().unwrap())
            })
            .collect();
        assert_eq!(m, vec![("READY".to_string(), 6), ("WAITING".to_string(), 6)]);
        // circular worker assignment: 6 tasks per worker over 2 workers
        let rs = db
            .query("SELECT workerid, COUNT(*) n FROM workqueue GROUP BY workerid ORDER BY workerid")
            .unwrap();
        assert_eq!(rs.rows[0].values[1], Value::Int(6));
        assert_eq!(rs.rows[1].values[1], Value::Int(6));
        // dependencies persisted
        assert_eq!(db.table_rows("taskdep").unwrap(), 6);
    }

    #[test]
    fn poll_propagates_readiness_and_completion() {
        let (db, mut sup) = setup(chain2(4), 2);
        sup.bootstrap(&vec![vec![]; 4]).unwrap();
        // nothing finished -> nothing changes
        let r = sup.poll().unwrap();
        assert_eq!(r, PollReport::default());

        finish_all_running_or_ready(&db, 1);
        let r = sup.poll().unwrap();
        assert_eq!(r.newly_finished, 4);
        assert_eq!(r.newly_ready, 4);
        assert!(!r.workflow_done);
        let rs = db
            .query("SELECT COUNT(*) FROM workqueue WHERE actid = 2 AND status = 'READY'")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(4));

        finish_all_running_or_ready(&db, 2);
        let r = sup.poll().unwrap();
        assert!(r.workflow_done);
        assert!(sup.done.load(Ordering::SeqCst));
        let rs = db.query("SELECT status FROM workflow").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("FINISHED"));
    }

    #[test]
    fn input_ingestion_copies_producer_outputs() {
        let (db, mut sup) = setup(chain2(2), 1);
        sup.bootstrap(&[vec![("a".into(), 1.5)], vec![("a".into(), 2.5)]]).unwrap();
        // activity-1 inputs present
        let rs = db
            .query("SELECT COUNT(*) FROM taskfield WHERE direction = 'in' AND actid = 1")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(2));
        // simulate act-1 tasks producing outputs, then finishing
        db.execute(
            "INSERT INTO taskfield (fieldid, taskid, actid, field, value, direction) \
             VALUES (1000, 1, 1, 'y', 42.0, 'out'), (1001, 2, 1, 'y', 43.0, 'out')",
        )
        .unwrap();
        finish_all_running_or_ready(&db, 1);
        sup.poll().unwrap();
        // act-2 tasks received 'y' as input
        let rs = db
            .query(
                "SELECT COUNT(*) FROM taskfield WHERE direction = 'in' AND actid = 2 AND field = 'y'",
            )
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(2));
    }

    #[test]
    fn filter_operator_drops_failing_tuples() {
        let wf = WorkflowSpec::new("t", 2)
            .activity(ActivitySpec::new("gen", Operator::Map, Payload::Sleep { mean_secs: 1.0 }))
            .activity(ActivitySpec::new(
                "filt",
                Operator::Filter { field: "y", min: 10.0 },
                Payload::Sleep { mean_secs: 1.0 },
            ));
        let (db, mut sup) = setup(wf, 1);
        sup.bootstrap(&vec![vec![]; 2]).unwrap();
        db.execute(
            "INSERT INTO taskfield (fieldid, taskid, actid, field, value, direction) \
             VALUES (1000, 1, 1, 'y', 5.0, 'out'), (1001, 2, 1, 'y', 15.0, 'out')",
        )
        .unwrap();
        finish_all_running_or_ready(&db, 1);
        let r = sup.poll().unwrap();
        assert_eq!(r.newly_ready, 1);
        assert_eq!(r.filtered_out, 1);
        let rs = db
            .query("SELECT stdout FROM workqueue WHERE actid = 2 AND stdout IS NOT NULL")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("filtered-out"));
        // finish the surviving task; the next poll completes the workflow
        // (the filtered task already counts as done)
        finish_all_running_or_ready(&db, 2);
        let r2 = sup.poll().unwrap();
        assert!(r2.workflow_done, "{r2:?}");
    }

    #[test]
    fn secondary_rebuilds_graph_from_db() {
        let (db, mut sup) = setup(chain2(4), 2);
        sup.bootstrap(&vec![vec![]; 4]).unwrap();
        finish_all_running_or_ready(&db, 1);
        // a fresh supervisor (the secondary) rebuilds from the database
        let ids = Arc::new(IdGen::default());
        let mut sec = Supervisor::new(db.clone(), chain2(4), 2, ids, 8);
        sec.rebuild_from_db().unwrap();
        // rebuild itself closes the takeover gap: the stranded WAITING tasks
        // of activity 2 are promoted without waiting for a poll
        let rs = db
            .query("SELECT COUNT(*) FROM workqueue WHERE actid = 2 AND status = 'READY'")
            .unwrap();
        assert_eq!(
            rs.rows[0].values[0],
            Value::Int(4),
            "secondary must resume readiness propagation"
        );
        finish_all_running_or_ready(&db, 2);
        let r = sec.poll().unwrap();
        assert!(r.workflow_done);
    }

    #[test]
    fn reduce_waits_for_all_inputs() {
        let wf = WorkflowSpec::new("t", 4)
            .activity(ActivitySpec::new("gen", Operator::Map, Payload::Sleep { mean_secs: 1.0 }))
            .activity(ActivitySpec::new(
                "red",
                Operator::Reduce { fanin: 4 },
                Payload::Sleep { mean_secs: 1.0 },
            ));
        let (db, mut sup) = setup(wf, 2);
        sup.bootstrap(&vec![vec![]; 4]).unwrap();
        // finish 3 of 4 producers: reducer must stay WAITING
        db.execute(
            "UPDATE workqueue SET status = 'FINISHED' WHERE actid = 1 AND taskid IN (1, 2, 3)",
        )
        .unwrap();
        let r = sup.poll().unwrap();
        assert_eq!(r.newly_ready, 0);
        db.execute("UPDATE workqueue SET status = 'FINISHED' WHERE actid = 1 AND taskid = 4")
            .unwrap();
        let r = sup.poll().unwrap();
        assert_eq!(r.newly_ready, 1);
    }
}
