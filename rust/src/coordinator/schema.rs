//! The d-Chiron database schema.
//!
//! One database integrates execution, domain, and provenance data — the
//! paper's central design point. The `workqueue` relation mirrors Figure 3;
//! `taskfield` carries extracted domain values (the paper's "registering
//! pointers to raw data files with some relevant raw data"); `file` holds
//! the raw-file pointers; `provenance` is the W3C-PROV-style activity/entity
//! record; `node` powers the monitoring queries (Q1–Q3).

use crate::storage::{AccessKind, DbCluster, Value};
use crate::Result;

/// Create all d-Chiron relations for a deployment with `workers` worker
/// nodes. The WQ is hash-partitioned on `workerid` into exactly `workers`
/// partitions (paper §3.2: "WQ has W partitions").
pub fn create_schema(db: &DbCluster, workers: usize) -> Result<()> {
    let w = workers.max(1);
    db.exec(
        "CREATE TABLE workflow (wfid INT NOT NULL, name TEXT, status TEXT, \
         starttime FLOAT, endtime FLOAT) PRIMARY KEY (wfid)",
    )?;
    db.exec(
        "CREATE TABLE activity (actid INT NOT NULL, wfid INT NOT NULL, name TEXT, \
         operator TEXT, ord INT, status TEXT, tasks_total INT, tasks_done INT) \
         PRIMARY KEY (actid)",
    )?;
    db.exec(&format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT NOT NULL, \
         wfid INT NOT NULL, workerid INT NOT NULL, coreid INT, cmd TEXT, \
         workspace TEXT, failtries INT, stdout TEXT, status TEXT, \
         duration FLOAT, starttime FLOAT, endtime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {w} \
         PRIMARY KEY (taskid) INDEX (status)"
    ))?;
    // Domain data: field values consumed/produced by tasks. Partitioned by
    // taskid so ingestion from many workers spreads across data nodes.
    db.exec(&format!(
        "CREATE TABLE taskfield (fieldid INT NOT NULL, taskid INT NOT NULL, \
         actid INT, field TEXT, value FLOAT, direction TEXT) \
         PARTITION BY HASH(taskid) PARTITIONS {w} \
         PRIMARY KEY (fieldid) INDEX (taskid)"
    ))?;
    // Raw data file pointers (paper §2.3).
    db.exec(&format!(
        "CREATE TABLE file (fileid INT NOT NULL, taskid INT NOT NULL, path TEXT, \
         size_bytes INT, direction TEXT) \
         PARTITION BY HASH(taskid) PARTITIONS {w} \
         PRIMARY KEY (fileid) INDEX (taskid)"
    ))?;
    // W3C-PROV-style records: used / wasGeneratedBy / wasDerivedFrom edges.
    db.exec(&format!(
        "CREATE TABLE provenance (pid INT NOT NULL, taskid INT NOT NULL, \
         actid INT, kind TEXT, entity TEXT, at FLOAT) \
         PARTITION BY HASH(taskid) PARTITIONS {w} \
         PRIMARY KEY (pid) INDEX (taskid)"
    ))?;
    // Computing nodes + heartbeats (availability + monitoring queries).
    db.exec(
        "CREATE TABLE node (nodeid INT NOT NULL, hostname TEXT, cores INT, \
         role TEXT, status TEXT, heartbeat FLOAT) PRIMARY KEY (nodeid)",
    )?;
    // Task dependency edges (fan-in > 1 needs more than `dependson`).
    db.exec(&format!(
        "CREATE TABLE taskdep (depid INT NOT NULL, taskid INT NOT NULL, dep INT NOT NULL) \
         PARTITION BY HASH(taskid) PARTITIONS {w} \
         PRIMARY KEY (depid) INDEX (taskid)"
    ))?;
    Ok(())
}

/// Register the computing nodes of the deployment in the `node` relation.
pub fn register_nodes(db: &DbCluster, workers: usize, threads_per_worker: usize) -> Result<()> {
    let now = db.clock.now();
    let ins = db.prepare(
        "INSERT INTO node (nodeid, hostname, cores, role, status, heartbeat) \
         VALUES (?, ?, ?, 'worker', 'UP', ?)",
    )?;
    let rows: Vec<Vec<Value>> = (0..workers)
        .map(|wid| {
            vec![
                Value::Int(wid as i64),
                Value::str(format!("node{wid:03}")),
                Value::Int(threads_per_worker as i64),
                Value::Float(now),
            ]
        })
        .collect();
    if !rows.is_empty() {
        db.exec_prepared_batch(0, AccessKind::Other, &ins, &rows)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::cluster::ClusterConfig;
    use crate::storage::value::Value;

    #[test]
    fn schema_creates_all_relations_with_w_partitions() {
        let db = DbCluster::start(ClusterConfig::default()).unwrap();
        create_schema(&db, 8).unwrap();
        let tables = db.tables();
        for t in ["workflow", "activity", "workqueue", "taskfield", "file", "provenance", "node", "taskdep"] {
            assert!(tables.contains(&t.to_string()), "missing table {t}");
        }
        assert_eq!(db.table_def("workqueue").unwrap().num_partitions(), 8);
        assert_eq!(db.table_def("workflow").unwrap().num_partitions(), 1);
    }

    #[test]
    fn node_registration() {
        let db = DbCluster::start(ClusterConfig::default()).unwrap();
        create_schema(&db, 3).unwrap();
        register_nodes(&db, 3, 24).unwrap();
        let rs = db.query("SELECT COUNT(*), MIN(cores) FROM node WHERE status = 'UP'").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(3));
        assert_eq!(rs.rows[0].values[1], Value::Int(24));
    }

    #[test]
    fn zero_workers_clamps_to_one_partition() {
        let db = DbCluster::start(ClusterConfig::default()).unwrap();
        create_schema(&db, 0).unwrap();
        assert_eq!(db.table_def("workqueue").unwrap().num_partitions(), 1);
    }
}
