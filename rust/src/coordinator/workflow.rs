//! Workflow specifications: Chiron's algebraic activity model.
//!
//! A workflow is a chain of activities; each activity applies an algebraic
//! operator to its input relation (Ogasawara et al., PVLDB 2011 — the
//! algebra Chiron executes) and carries a payload describing the actual
//! scientific computation of each task.

use crate::coordinator::payload::Payload;
use crate::{Error, Result};

/// Chiron's algebraic operators, defining how task counts map across an
/// activity boundary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Operator {
    /// 1:1 — each input tuple yields one task depending on its producer.
    Map,
    /// 1:N — each input tuple yields `fanout` tasks.
    SplitMap { fanout: usize },
    /// N:1 — groups of `fanin` consecutive tuples reduce into one task.
    Reduce { fanin: usize },
    /// 1:{0,1} — tasks whose predecessor output fails the predicate are
    /// dropped. The predicate is evaluated by the supervisor on the
    /// producer's domain outputs: `field >= threshold` keeps the tuple.
    Filter { field: &'static str, min: f64 },
    /// Query over the task relation itself (used by monitoring activities);
    /// scheduled as a single task regardless of input cardinality.
    MrQuery,
}

impl Operator {
    pub fn name(&self) -> &'static str {
        match self {
            Operator::Map => "MAP",
            Operator::SplitMap { .. } => "SPLIT_MAP",
            Operator::Reduce { .. } => "REDUCE",
            Operator::Filter { .. } => "FILTER",
            Operator::MrQuery => "MRQUERY",
        }
    }
}

/// One activity of a workflow.
#[derive(Clone, Debug)]
pub struct ActivitySpec {
    pub name: String,
    pub operator: Operator,
    /// What each task computes.
    pub payload: Payload,
    /// Names of the domain fields this activity's tasks produce (ingested
    /// into `taskfield` with direction 'out').
    pub out_fields: Vec<String>,
}

impl ActivitySpec {
    pub fn new(name: &str, operator: Operator, payload: Payload) -> ActivitySpec {
        ActivitySpec {
            name: name.to_string(),
            operator,
            payload,
            out_fields: vec![],
        }
    }

    pub fn with_fields(mut self, fields: &[&str]) -> ActivitySpec {
        self.out_fields = fields.iter().map(|s| s.to_string()).collect();
        self
    }
}

/// A workflow: named chain of activities plus the cardinality of the first
/// activity's input (the parameter sweep size).
#[derive(Clone, Debug)]
pub struct WorkflowSpec {
    pub name: String,
    pub activities: Vec<ActivitySpec>,
    /// Number of input tuples feeding activity 1.
    pub input_cardinality: usize,
}

impl WorkflowSpec {
    pub fn new(name: &str, input_cardinality: usize) -> WorkflowSpec {
        WorkflowSpec { name: name.to_string(), activities: vec![], input_cardinality }
    }

    pub fn activity(mut self, a: ActivitySpec) -> WorkflowSpec {
        self.activities.push(a);
        self
    }

    pub fn validate(&self) -> Result<()> {
        if self.activities.is_empty() {
            return Err(Error::Engine("workflow has no activities".into()));
        }
        if self.input_cardinality == 0 {
            return Err(Error::Engine("workflow input cardinality is 0".into()));
        }
        for a in &self.activities {
            match a.operator {
                Operator::SplitMap { fanout } if fanout == 0 => {
                    return Err(Error::Engine(format!("activity '{}' fanout 0", a.name)))
                }
                Operator::Reduce { fanin } if fanin == 0 => {
                    return Err(Error::Engine(format!("activity '{}' fanin 0", a.name)))
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Task count of each activity given the input cardinality and the
    /// operator chain (Filter counted at full cardinality — the filter is
    /// applied at runtime on produced values).
    pub fn planned_task_counts(&self) -> Vec<usize> {
        let mut n = self.input_cardinality;
        let mut counts = Vec::with_capacity(self.activities.len());
        for a in &self.activities {
            n = match a.operator {
                Operator::Map | Operator::Filter { .. } => n,
                Operator::SplitMap { fanout } => n * fanout,
                Operator::Reduce { fanin } => n.div_ceil(fanin),
                Operator::MrQuery => 1,
            };
            counts.push(n.max(1));
        }
        counts
    }

    /// Total planned tasks across activities.
    pub fn planned_total_tasks(&self) -> usize {
        self.planned_task_counts().iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::Payload;

    fn map(name: &str) -> ActivitySpec {
        ActivitySpec::new(name, Operator::Map, Payload::Sleep { mean_secs: 1.0 })
    }

    #[test]
    fn task_count_planning_across_operators() {
        let wf = WorkflowSpec::new("t", 100)
            .activity(map("a1"))
            .activity(ActivitySpec::new(
                "a2",
                Operator::SplitMap { fanout: 3 },
                Payload::Sleep { mean_secs: 1.0 },
            ))
            .activity(ActivitySpec::new(
                "a3",
                Operator::Reduce { fanin: 10 },
                Payload::Sleep { mean_secs: 1.0 },
            ))
            .activity(ActivitySpec::new("a4", Operator::MrQuery, Payload::Sleep { mean_secs: 1.0 }));
        assert_eq!(wf.planned_task_counts(), vec![100, 300, 30, 1]);
        assert_eq!(wf.planned_total_tasks(), 431);
        wf.validate().unwrap();
    }

    #[test]
    fn validation_catches_degenerate_specs() {
        assert!(WorkflowSpec::new("x", 10).validate().is_err());
        assert!(WorkflowSpec::new("x", 0).activity(map("a")).validate().is_err());
        let bad = WorkflowSpec::new("x", 10).activity(ActivitySpec::new(
            "a",
            Operator::SplitMap { fanout: 0 },
            Payload::Sleep { mean_secs: 1.0 },
        ));
        assert!(bad.validate().is_err());
    }

    #[test]
    fn reduce_rounds_up() {
        let wf = WorkflowSpec::new("t", 25).activity(ActivitySpec::new(
            "r",
            Operator::Reduce { fanin: 10 },
            Payload::Sleep { mean_secs: 1.0 },
        ));
        assert_eq!(wf.planned_task_counts(), vec![3]);
    }
}
