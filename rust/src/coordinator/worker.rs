//! Worker nodes: DBMS-driven task execution.
//!
//! A worker node runs `T` threads. Each thread pulls from *its own* WQ
//! partition (`where worker_id = i`, paper §3.2), claims a task with an
//! atomic conditional update, fetches the task's domain inputs, executes the
//! payload, then writes outputs, files, provenance, and the FINISHED status
//! back — all directly against the DBMS, with no master in the path
//! (Figure 6-A).

use crate::coordinator::payload::{self, Payload, RunnerRegistry, TaskCtx};
use crate::coordinator::supervisor::IdGen;
use crate::storage::connector::WorkerLink;
use crate::storage::prepared::Prepared;
use crate::storage::{AccessKind, Value};
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// The worker's per-task statement set, prepared once per node through its
/// [`WorkerLink`] (plan-only handles: they keep executing through the
/// secondary connector after the primary dies, and against promoted
/// backups after a data-node failure). Values are always bound — stdout
/// and field names never touch SQL text, so embedded quotes are inert.
struct WorkerStmts {
    /// `getREADYtasks`: candidates from this worker's WQ partition.
    get_ready: Prepared,
    /// `updateToRUNNING`: the atomic claim.
    claim: Prepared,
    /// `getFileFields`: the task's domain inputs.
    get_inputs: Prepared,
    /// Domain outputs (single-row template, bound per field).
    insert_field: Prepared,
    /// Raw file pointers.
    insert_file: Prepared,
    /// W3C-PROV edges.
    insert_prov: Prepared,
    /// `updateToFINISHED`.
    finish: Prepared,
    /// Retry-or-fail bookkeeping.
    fail: Prepared,
}

impl WorkerStmts {
    fn prepare(link: &WorkerLink, claim_batch: usize) -> Result<WorkerStmts> {
        // LIMIT is not a parameter position in the dialect; the batch size
        // is fixed per worker config, so it is rendered once here at
        // prepare time (never per call, and never a value).
        let get_ready_sql = format!(
            "SELECT taskid, actid, duration FROM workqueue \
             WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT {}",
            claim_batch.max(1)
        );
        // The claim/finish/fail transitions pin `workerid` alongside the
        // task id. Tasks never change workers mid-flight, so the predicate
        // is redundant for correctness — but it pins the statement to this
        // worker's WQ partition, which lets the compiled DML fast path
        // route each transition to exactly one partition lock instead of
        // the whole table (the paper's §3.2 partition-locality argument).
        Ok(WorkerStmts {
            get_ready: link.prepare(&get_ready_sql)?,
            claim: link.prepare(
                "UPDATE workqueue SET status = 'RUNNING', starttime = NOW(), coreid = ? \
                 WHERE taskid = ? AND status = 'READY' AND workerid = ?",
            )?,
            get_inputs: link.prepare(
                "SELECT field, value FROM taskfield WHERE taskid = ? AND direction = 'in'",
            )?,
            insert_field: link.prepare(
                "INSERT INTO taskfield (fieldid, taskid, actid, field, value, direction) \
                 VALUES (?, ?, ?, ?, ?, 'out')",
            )?,
            insert_file: link.prepare(
                "INSERT INTO file (fileid, taskid, path, size_bytes, direction) \
                 VALUES (?, ?, ?, ?, 'out')",
            )?,
            insert_prov: link.prepare(
                "INSERT INTO provenance (pid, taskid, actid, kind, entity, at) \
                 VALUES (?, ?, ?, ?, ?, NOW())",
            )?,
            finish: link.prepare(
                "UPDATE workqueue SET status = 'FINISHED', endtime = NOW(), stdout = ? \
                 WHERE taskid = ? AND workerid = ?",
            )?,
            fail: link.prepare(
                "UPDATE workqueue SET failtries = failtries + 1, stdout = ?, \
                 status = CASE WHEN failtries + 1 >= ? THEN 'FAILED' ELSE 'READY' END \
                 WHERE taskid = ? AND workerid = ?",
            )?,
        })
    }
}

/// Worker configuration (per worker node).
#[derive(Clone)]
pub struct WorkerConfig {
    pub worker_id: u32,
    pub threads: usize,
    /// How many candidate tasks one `getREADYtasks` fetches.
    pub claim_batch: usize,
    /// Multiplier applied to nominal task durations (1.0 = real time).
    pub time_scale: f64,
    /// Idle backoff between empty polls, in (already scaled) seconds.
    pub idle_backoff_secs: f64,
    /// Retries before a failing task is marked FAILED.
    pub max_failtries: i64,
    pub seed: u64,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            worker_id: 0,
            threads: 2,
            claim_batch: 4,
            time_scale: 1.0,
            idle_backoff_secs: 0.002,
            max_failtries: 3,
            seed: 0,
        }
    }
}

/// Shared worker-side counters (monitoring / reports).
#[derive(Default)]
pub struct WorkerCounters {
    pub executed: AtomicU64,
    pub claim_races_lost: AtomicU64,
    pub failures: AtomicU64,
}

/// One worker node. [`WorkerNode::run_thread`] is the body each of its `T`
/// threads executes until `done` flips.
pub struct WorkerNode {
    pub cfg: WorkerConfig,
    link: Arc<WorkerLink>,
    /// Payload per activity (index = actid - 1).
    payloads: Arc<Vec<Payload>>,
    registry: Arc<RunnerRegistry>,
    ids: Arc<IdGen>,
    done: Arc<AtomicBool>,
    pub counters: Arc<WorkerCounters>,
    /// Prepared per-task statements, initialized lazily on the first step
    /// (the schema must exist by then; node construction stays infallible).
    stmts: OnceLock<WorkerStmts>,
}

impl WorkerNode {
    pub fn new(
        cfg: WorkerConfig,
        link: Arc<WorkerLink>,
        payloads: Arc<Vec<Payload>>,
        registry: Arc<RunnerRegistry>,
        ids: Arc<IdGen>,
        done: Arc<AtomicBool>,
    ) -> WorkerNode {
        WorkerNode {
            cfg,
            link,
            payloads,
            registry,
            ids,
            done,
            counters: Arc::new(WorkerCounters::default()),
            stmts: OnceLock::new(),
        }
    }

    /// The node's prepared statement set (prepared on first use; a losing
    /// racer's set is dropped — the plan cache makes re-preparation a
    /// lookup, not a parse).
    fn stmts(&self) -> Result<&WorkerStmts> {
        if self.stmts.get().is_none() {
            let prepared = WorkerStmts::prepare(&self.link, self.cfg.claim_batch)?;
            let _ = self.stmts.set(prepared);
        }
        Ok(self.stmts.get().expect("statement set just initialized"))
    }

    /// Spawn this node's threads; returns their join handles.
    pub fn spawn(self: Arc<Self>) -> Vec<std::thread::JoinHandle<()>> {
        (0..self.cfg.threads)
            .map(|t| {
                let me = self.clone();
                std::thread::Builder::new()
                    .name(format!("worker{}-t{t}", me.cfg.worker_id))
                    .spawn(move || me.run_thread(t as i64))
                    .expect("spawn worker thread")
            })
            .collect()
    }

    /// Thread body: claim → run → record, until the engine signals done.
    pub fn run_thread(&self, core: i64) {
        while !self.done.load(Ordering::SeqCst) {
            match self.step(core) {
                Ok(did_work) => {
                    if !did_work {
                        std::thread::sleep(std::time::Duration::from_secs_f64(
                            self.cfg.idle_backoff_secs,
                        ));
                    }
                }
                Err(Error::Unavailable(_)) => {
                    // connector/data-node outage: back off and retry; the
                    // availability manager will repair placement
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        self.cfg.idle_backoff_secs * 5.0,
                    ));
                }
                Err(e) => {
                    log::error!("worker {} thread {core}: {e}", self.cfg.worker_id);
                    std::thread::sleep(std::time::Duration::from_secs_f64(
                        self.cfg.idle_backoff_secs * 5.0,
                    ));
                }
            }
        }
    }

    /// One scheduling step. Returns whether a task was executed.
    pub fn step(&self, core: i64) -> Result<bool> {
        let w = self.cfg.worker_id;
        let stmts = self.stmts()?;

        // getREADYtasks: candidates from this worker's partition.
        let cands = self
            .link
            .exec_prepared(
                AccessKind::GetReadyTasks,
                &stmts.get_ready,
                &[Value::Int(w as i64)],
            )?
            .rows();
        if cands.rows.is_empty() {
            return Ok(false);
        }

        for cand in &cands.rows {
            let taskid = cand.values[0].as_i64().unwrap();
            let actid = cand.values[1].as_i64().unwrap();
            let duration = cand.values[2].as_f64().unwrap_or(0.0);

            // updateToRUNNING: atomic claim (threads of this node race).
            let claimed = self
                .link
                .exec_prepared(
                    AccessKind::UpdateToRunning,
                    &stmts.claim,
                    &[Value::Int(core), Value::Int(taskid), Value::Int(w as i64)],
                )?
                .affected();
            if claimed == 0 {
                self.counters.claim_races_lost.fetch_add(1, Ordering::Relaxed);
                continue;
            }

            self.execute_claimed(core, taskid, actid, duration)?;
            return Ok(true);
        }
        Ok(false)
    }

    /// Run a claimed task to completion (or failure/retry).
    fn execute_claimed(&self, _core: i64, taskid: i64, actid: i64, duration: f64) -> Result<()> {
        let w = self.cfg.worker_id;
        let stmts = self.stmts()?;

        // getFileFields: the task's domain inputs.
        let inputs = self
            .link
            .exec_prepared(AccessKind::GetFileFields, &stmts.get_inputs, &[Value::Int(taskid)])?
            .rows();
        let inputs: Vec<(String, f64)> = inputs
            .rows
            .iter()
            .map(|r| {
                (
                    r.values[0].as_str().unwrap_or("").to_string(),
                    r.values[1].as_f64().unwrap_or(0.0),
                )
            })
            .collect();

        let payload = self
            .payloads
            .get((actid - 1) as usize)
            .cloned()
            .ok_or_else(|| Error::Engine(format!("no payload for activity {actid}")))?;
        let ctx = TaskCtx {
            taskid,
            actid,
            workerid: w as i64,
            inputs: inputs.clone(),
            seed: self.cfg.seed ^ (taskid as u64).wrapping_mul(0x9E3779B97F4A7C15),
            duration,
            time_scale: self.cfg.time_scale,
        };

        match payload::execute(&payload, &ctx, &self.registry) {
            Ok(out) => {
                // Domain outputs (one batched insert, values bound).
                if !out.fields.is_empty() {
                    let rows: Vec<Vec<Value>> = out
                        .fields
                        .iter()
                        .map(|(f, v)| {
                            let fid = IdGen::next(&self.ids.field);
                            vec![
                                Value::Int(fid),
                                Value::Int(taskid),
                                Value::Int(actid),
                                Value::str(f),
                                Value::Float(*v),
                            ]
                        })
                        .collect();
                    self.link.exec_prepared_batch(
                        AccessKind::InsertDomainData,
                        &stmts.insert_field,
                        &rows,
                    )?;
                }
                // Raw file pointers.
                if !out.files.is_empty() {
                    let rows: Vec<Vec<Value>> = out
                        .files
                        .iter()
                        .map(|(p, sz)| {
                            let fid = IdGen::next(&self.ids.file);
                            vec![
                                Value::Int(fid),
                                Value::Int(taskid),
                                Value::str(p),
                                Value::Int(*sz),
                            ]
                        })
                        .collect();
                    self.link.exec_prepared_batch(
                        AccessKind::InsertDomainData,
                        &stmts.insert_file,
                        &rows,
                    )?;
                }
                // Provenance: used(inputs) + wasGeneratedBy(outputs).
                let mut prov_rows: Vec<Vec<Value>> = Vec::new();
                let prov =
                    |ids: &Arc<IdGen>, kind: &str, entity: &str, rows: &mut Vec<Vec<Value>>| {
                        let pid = IdGen::next(&ids.prov);
                        rows.push(vec![
                            Value::Int(pid),
                            Value::Int(taskid),
                            Value::Int(actid),
                            Value::str(kind),
                            Value::str(entity),
                        ]);
                    };
                for (f, _) in &inputs {
                    prov(&self.ids, "used", f, &mut prov_rows);
                }
                for (f, _) in &out.fields {
                    prov(&self.ids, "wasGeneratedBy", f, &mut prov_rows);
                }
                for (p, _) in &out.files {
                    prov(&self.ids, "wasGeneratedBy", p, &mut prov_rows);
                }
                if !prov_rows.is_empty() {
                    self.link.exec_prepared_batch(
                        AccessKind::InsertProvenance,
                        &stmts.insert_prov,
                        &prov_rows,
                    )?;
                }
                // updateToFINISHED: stdout is bound, so quotes and any other
                // payload output are inert data, not SQL.
                self.link.exec_prepared(
                    AccessKind::UpdateToFinished,
                    &stmts.finish,
                    &[Value::str(&out.stdout), Value::Int(taskid), Value::Int(w as i64)],
                )?;
                self.counters.executed.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                self.counters.failures.fetch_add(1, Ordering::Relaxed);
                // retry or fail permanently
                self.link.exec_prepared(
                    AccessKind::UpdateTaskOutput,
                    &stmts.fail,
                    &[
                        Value::str(e.to_string()),
                        Value::Int(self.cfg.max_failtries),
                        Value::Int(taskid),
                        Value::Int(w as i64),
                    ],
                )?;
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::{SyntheticKind, TaskOutput, TaskRunner};
    use crate::coordinator::schema;
    use crate::coordinator::supervisor::Supervisor;
    use crate::coordinator::workflow::{ActivitySpec, Operator, WorkflowSpec};
    use crate::storage::cluster::ClusterConfig;
    use crate::storage::connector::{assign_links, Connector};
    use crate::storage::value::Value;
    use crate::storage::DbCluster;

    fn setup(wf: WorkflowSpec, workers: usize) -> (Arc<DbCluster>, Supervisor, Arc<IdGen>) {
        let db = DbCluster::start(ClusterConfig::default()).unwrap();
        schema::create_schema(&db, workers).unwrap();
        let ids = Arc::new(IdGen::default());
        ids.task.store(1, std::sync::atomic::Ordering::Relaxed);
        ids.field.store(100_000, std::sync::atomic::Ordering::Relaxed);
        let sup = Supervisor::new(db.clone(), wf.clone(), workers, ids.clone(), 7);
        (db, sup, ids)
    }

    fn node(
        db: &Arc<DbCluster>,
        w: u32,
        payloads: Vec<Payload>,
        ids: Arc<IdGen>,
        done: Arc<AtomicBool>,
    ) -> WorkerNode {
        let conn = Connector::new(0, 0, db.clone());
        let links = assign_links(&[w], &[conn]).unwrap();
        let link = Arc::new(links.into_iter().next().unwrap());
        WorkerNode::new(
            WorkerConfig { worker_id: w, time_scale: 0.0, ..Default::default() },
            link,
            Arc::new(payloads),
            Arc::new(RunnerRegistry::new()),
            ids,
            done,
        )
    }

    #[test]
    fn step_claims_runs_and_finishes_a_task() {
        let wf = WorkflowSpec::new("t", 3).activity(ActivitySpec::new(
            "a1",
            Operator::Map,
            Payload::Synthetic { kind: SyntheticKind::Quadratic },
        ));
        let (db, mut sup, ids) = setup(wf.clone(), 1);
        sup.bootstrap(&vec![vec![("a".into(), 1.0), ("b".into(), 2.0), ("c".into(), 3.0)]; 3])
            .unwrap();
        let done = Arc::new(AtomicBool::new(false));
        let wn = node(&db, 0, vec![wf.activities[0].payload.clone()], ids, done);

        assert!(wn.step(0).unwrap());
        let rs = db
            .query("SELECT COUNT(*) FROM workqueue WHERE status = 'FINISHED'")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(1));
        // outputs + provenance landed
        let rs = db
            .query("SELECT COUNT(*) FROM taskfield WHERE direction = 'out'")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(2)); // x and y
        let rs = db
            .query("SELECT COUNT(*) FROM provenance WHERE kind = 'wasGeneratedBy'")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(2));
        let rs = db
            .query("SELECT COUNT(*) FROM provenance WHERE kind = 'used'")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(3)); // a, b, c

        // two more steps drain the queue; a fourth finds nothing
        assert!(wn.step(0).unwrap());
        assert!(wn.step(1).unwrap());
        assert!(!wn.step(0).unwrap());
    }

    #[test]
    fn workers_only_see_their_partition() {
        let wf = WorkflowSpec::new("t", 4).activity(ActivitySpec::new(
            "a1",
            Operator::Map,
            Payload::Sleep { mean_secs: 1.0 },
        ));
        let (db, mut sup, ids) = setup(wf.clone(), 2);
        sup.bootstrap(&vec![vec![]; 4]).unwrap();
        let done = Arc::new(AtomicBool::new(false));
        let w1 = node(&db, 1, vec![wf.activities[0].payload.clone()], ids, done);
        // worker 1 executes its 2 tasks then stalls, leaving worker 0's alone
        assert!(w1.step(0).unwrap());
        assert!(w1.step(0).unwrap());
        assert!(!w1.step(0).unwrap());
        let rs = db
            .query("SELECT COUNT(*) FROM workqueue WHERE status = 'READY' AND workerid = 0")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(2));
    }

    struct AlwaysFails;
    impl TaskRunner for AlwaysFails {
        fn run(&self, _ctx: &TaskCtx) -> crate::Result<TaskOutput> {
            Err(Error::Engine("injected failure".into()))
        }
    }

    #[test]
    fn failing_tasks_retry_then_fail_permanently() {
        let wf = WorkflowSpec::new("t", 1).activity(ActivitySpec::new(
            "a1",
            Operator::Map,
            Payload::Artifact { runner: "boom".into() },
        ));
        let (db, mut sup, ids) = setup(wf.clone(), 1);
        sup.bootstrap(&vec![vec![]; 1]).unwrap();
        let done = Arc::new(AtomicBool::new(false));
        let conn = Connector::new(0, 0, db.clone());
        let links = assign_links(&[0], &[conn]).unwrap();
        let mut reg = RunnerRegistry::new();
        reg.register("boom", Arc::new(AlwaysFails));
        let wn = WorkerNode::new(
            WorkerConfig { worker_id: 0, max_failtries: 2, time_scale: 0.0, ..Default::default() },
            Arc::new(links.into_iter().next().unwrap()),
            Arc::new(vec![wf.activities[0].payload.clone()]),
            Arc::new(reg),
            ids,
            done,
        );
        // failtries: 0 -> 1 (back to READY) -> 2 (FAILED)
        wn.step(0).unwrap();
        let rs = db.query("SELECT status, failtries FROM workqueue").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("READY"));
        assert_eq!(rs.rows[0].values[1], Value::Int(1));
        wn.step(0).unwrap();
        let rs = db.query("SELECT status, failtries FROM workqueue").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("FAILED"));
        assert_eq!(wn.counters.failures.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn concurrent_threads_never_double_execute() {
        let wf = WorkflowSpec::new("t", 40).activity(ActivitySpec::new(
            "a1",
            Operator::Map,
            Payload::Sleep { mean_secs: 1.0 },
        ));
        let (db, mut sup, ids) = setup(wf.clone(), 1);
        sup.bootstrap(&vec![vec![]; 40]).unwrap();
        let done = Arc::new(AtomicBool::new(false));
        let wn = Arc::new(node(&db, 0, vec![wf.activities[0].payload.clone()], ids, done));
        let mut handles = Vec::new();
        for t in 0..4 {
            let wn = wn.clone();
            handles.push(std::thread::spawn(move || {
                while wn.step(t).unwrap() {}
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(wn.counters.executed.load(Ordering::Relaxed), 40);
        let rs = db
            .query("SELECT COUNT(*) FROM workqueue WHERE status = 'FINISHED'")
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(40));
    }
}
