//! The d-Chiron workflow engine: SchalaDB's coordinator layer.
//!
//! Everything the paper's §4 describes lives here:
//!
//! - [`schema`]: the d-Chiron database (workqueue per Figure 3, activity and
//!   workflow catalogs, domain `taskfield`s, file pointers, provenance, node
//!   heartbeats), created with `PARTITION BY HASH(workerid) PARTITIONS W`.
//! - [`workflow`]: workflow specifications — chained activities with
//!   Chiron's algebraic operators (Map / SplitMap / Reduce / Filter) and a
//!   per-activity *payload* describing the actual scientific computation.
//! - [`supervisor`]: generates tasks, assigns `worker_id` circularly,
//!   propagates readiness along the dependency graph, detects activity and
//!   workflow completion; the *secondary supervisor* takes over on
//!   heartbeat loss ([`failover`]).
//! - [`worker`]: worker nodes — `T` threads each pulling tasks straight from
//!   the DBMS (`getREADYtasks` → claim → run → `updateToFINISHED`), with
//!   domain-data and provenance capture on the way.
//! - [`engine`]: wires cluster + connectors + supervisor + workers into a
//!   run-to-completion driver and produces a [`engine::RunReport`].

pub mod engine;
pub mod failover;
pub mod payload;
pub mod schema;
pub mod supervisor;
pub mod worker;
pub mod workflow;

pub use engine::{DChironEngine, EngineConfig, RunReport};
pub use payload::{Payload, TaskOutput};
pub use workflow::{ActivitySpec, Operator, WorkflowSpec};

/// Task lifecycle states as stored in `workqueue.status`.
pub mod status {
    /// Dependencies not yet satisfied.
    pub const WAITING: &str = "WAITING";
    /// Eligible to be claimed by its worker.
    pub const READY: &str = "READY";
    /// Claimed and executing.
    pub const RUNNING: &str = "RUNNING";
    /// Completed successfully.
    pub const FINISHED: &str = "FINISHED";
    /// Failed after exhausting retries.
    pub const FAILED: &str = "FAILED";
}
