//! The d-Chiron engine: wires cluster, connectors, supervisors, and workers
//! into a run-to-completion driver.

use crate::coordinator::failover::{self, SupervisorRole};
use crate::coordinator::payload::{Payload, RunnerRegistry};
use crate::coordinator::supervisor::{IdGen, Supervisor};
use crate::coordinator::worker::{WorkerConfig, WorkerCounters, WorkerNode};
use crate::coordinator::{schema, workflow::WorkflowSpec};
use crate::storage::cluster::{ClusterConfig, ConcurrencyMode, DurabilityConfig};
use crate::storage::connector::{assign_links, Connector};
use crate::storage::stats::{AccessKind, AccessStat};
use crate::storage::DbCluster;
use crate::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Engine deployment parameters (the paper's component-to-node allocation).
#[derive(Clone)]
pub struct EngineConfig {
    /// Worker nodes (W). The WQ gets W partitions.
    pub workers: usize,
    /// Threads per worker node (the paper sweeps 12/24/48).
    pub threads_per_worker: usize,
    /// SchalaDB data nodes (the paper uses 2).
    pub data_nodes: usize,
    /// One backup replica per partition.
    pub replication: bool,
    /// Connectors brokering worker↔DBMS traffic.
    pub connectors: usize,
    /// Scales nominal task durations to wall time (1.0 = real time; tests
    /// and examples use ~1e-3 so "60-second tasks" take 60 ms).
    pub time_scale: f64,
    /// Tasks fetched per `getREADYtasks`.
    pub claim_batch: usize,
    /// Supervisor poll cadence in wall seconds.
    pub supervisor_poll_secs: f64,
    /// Secondary supervisor heartbeat timeout in wall seconds.
    pub heartbeat_timeout_secs: f64,
    pub seed: u64,
    /// When > 0, the engine runs a background availability sweeper at this
    /// cadence: dead-primary promotion, replica healing, and rejoin
    /// catch-up all happen automatically while the workflow runs. 0
    /// disables it (tests that drive sweeps explicitly).
    pub availability_sweep_secs: f64,
    /// Durable-logging configuration passed through to the cluster
    /// (per-partition WAL segments + checkpoints; `None` = in-memory).
    pub durability: Option<DurabilityConfig>,
    /// Concurrency control for the claim loop's compiled point DML,
    /// passed through to the cluster (default: 2PL latches).
    pub concurrency: ConcurrencyMode,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 2,
            threads_per_worker: 2,
            data_nodes: 2,
            replication: true,
            connectors: 2,
            time_scale: 1.0,
            claim_batch: 4,
            supervisor_poll_secs: 0.002,
            heartbeat_timeout_secs: 0.5,
            seed: 42,
            availability_sweep_secs: 0.0,
            durability: None,
            concurrency: ConcurrencyMode::default(),
        }
    }
}

/// Result of a workflow run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Wall-clock makespan in seconds.
    pub makespan_secs: f64,
    pub total_tasks: usize,
    pub executed_tasks: u64,
    pub failed_tasks: u64,
    pub claim_races_lost: u64,
    /// Sum of all DBMS access times across nodes.
    pub dbms_total_secs: f64,
    /// The paper's Experiment-5 metric: max per-node sum of access times.
    pub dbms_max_node_secs: f64,
    /// Per-kind access stats (Figure 12).
    pub access_stats: Vec<(AccessKind, AccessStat)>,
    /// Database resident size at completion.
    pub db_bytes: usize,
    /// Whether the primary supervisor was failed over during the run.
    pub supervisor_failovers: usize,
}

impl RunReport {
    /// Percentage of total DBMS time spent in `kind`.
    pub fn pct(&self, kind: AccessKind) -> f64 {
        if self.dbms_total_secs <= 0.0 {
            return 0.0;
        }
        self.access_stats
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, s)| 100.0 * s.total_secs / self.dbms_total_secs)
            .unwrap_or(0.0)
    }
}

/// A running workflow: join it for the report, or query `db` live for
/// steering while it executes.
pub struct RunningWorkflow {
    pub db: Arc<DbCluster>,
    pub done: Arc<AtomicBool>,
    primary_alive: Arc<AtomicBool>,
    failovers: Arc<std::sync::atomic::AtomicUsize>,
    worker_counters: Vec<Arc<WorkerCounters>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    total_tasks: usize,
    t0: Instant,
}

impl RunningWorkflow {
    /// Kill the primary supervisor (failure injection for Experiment-style
    /// failover demos). The secondary takes over on heartbeat timeout.
    pub fn kill_primary_supervisor(&self) {
        self.primary_alive.store(false, Ordering::SeqCst);
    }

    /// Block until the workflow completes; collect the report.
    pub fn join(self) -> Result<RunReport> {
        for h in self.threads {
            h.join().map_err(|_| crate::Error::Engine("engine thread panicked".into()))?;
        }
        let makespan = self.t0.elapsed().as_secs_f64();
        let executed: u64 = self
            .worker_counters
            .iter()
            .map(|c| c.executed.load(Ordering::Relaxed))
            .sum();
        let races: u64 = self
            .worker_counters
            .iter()
            .map(|c| c.claim_races_lost.load(Ordering::Relaxed))
            .sum();
        let failures: u64 = self
            .worker_counters
            .iter()
            .map(|c| c.failures.load(Ordering::Relaxed))
            .sum();
        Ok(RunReport {
            makespan_secs: makespan,
            total_tasks: self.total_tasks,
            executed_tasks: executed,
            failed_tasks: failures,
            claim_races_lost: races,
            dbms_total_secs: self.db.stats.total_secs(),
            dbms_max_node_secs: self.db.stats.max_node_secs(),
            access_stats: self.db.stats.snapshot(),
            db_bytes: self.db.total_bytes(),
            supervisor_failovers: self.failovers.load(Ordering::Relaxed),
        })
    }
}

/// The engine itself.
pub struct DChironEngine {
    pub config: EngineConfig,
    pub registry: Arc<RunnerRegistry>,
}

impl DChironEngine {
    pub fn new(config: EngineConfig) -> DChironEngine {
        DChironEngine { config, registry: Arc::new(RunnerRegistry::new()) }
    }

    pub fn with_registry(config: EngineConfig, registry: RunnerRegistry) -> DChironEngine {
        DChironEngine { config, registry: Arc::new(registry) }
    }

    /// Start `wf` with the given activity-1 input tuples; returns a handle
    /// for live steering plus joining.
    pub fn start(
        &self,
        wf: WorkflowSpec,
        inputs: Vec<Vec<(String, f64)>>,
    ) -> Result<RunningWorkflow> {
        wf.validate()?;
        let cfg = &self.config;

        // DBManager --start: cluster + schema.
        let mut b = ClusterConfig::builder()
            .data_nodes(cfg.data_nodes)
            .replication(cfg.replication)
            .concurrency(cfg.concurrency);
        if let Some(d) = cfg.durability.clone() {
            b = b.durability(d);
        }
        let db = DbCluster::start(b.build()?)?;
        schema::create_schema(&db, cfg.workers)?;
        schema::register_nodes(&db, cfg.workers, cfg.threads_per_worker)?;
        failover::register_supervisor_nodes(&db)?;

        // Connectors + worker links (paper's co-location + round-robin).
        let connectors: Vec<_> = (0..cfg.connectors.max(1) as u32)
            .map(|i| Connector::new(i, i, db.clone()))
            .collect();
        let worker_ids: Vec<u32> = (0..cfg.workers as u32).collect();
        let links = assign_links(&worker_ids, &connectors)?;

        // Shared state.
        let ids = Arc::new(IdGen::default());
        ids.task.store(1, Ordering::Relaxed);
        ids.field.store(1, Ordering::Relaxed);
        ids.file.store(1, Ordering::Relaxed);
        ids.prov.store(1, Ordering::Relaxed);
        ids.dep.store(1, Ordering::Relaxed);
        let done = Arc::new(AtomicBool::new(false));
        let primary_alive = Arc::new(AtomicBool::new(true));
        let failovers = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let payloads: Arc<Vec<Payload>> =
            Arc::new(wf.activities.iter().map(|a| a.payload.clone()).collect());
        let total_tasks = wf.planned_total_tasks();

        // Primary supervisor bootstraps before workers start. It shares the
        // engine-wide `done` flag so workers stop when it declares
        // completion.
        let mut sup = Supervisor::new(db.clone(), wf.clone(), cfg.workers, ids.clone(), cfg.seed);
        sup.done = done.clone();
        sup.bootstrap(&inputs)?;

        let t0 = Instant::now();
        let mut threads = Vec::new();

        // Primary supervisor loop.
        {
            let done = done.clone();
            let alive = primary_alive.clone();
            let poll = cfg.supervisor_poll_secs;
            threads.push(
                std::thread::Builder::new()
                    .name("supervisor".into())
                    .spawn(move || {
                        failover::run_supervisor_loop(
                            &mut sup,
                            SupervisorRole::Primary,
                            done,
                            alive,
                            poll,
                        );
                    })
                    .expect("spawn supervisor"),
            );
        }
        // Secondary supervisor: watches the heartbeat, takes over on loss.
        {
            let db2 = db.clone();
            let wf2 = wf.clone();
            let ids2 = ids.clone();
            let done = done.clone();
            let alive = primary_alive.clone();
            let failovers = failovers.clone();
            let workers = cfg.workers;
            let seed = cfg.seed ^ 0x5EC0_5EC0;
            let poll = cfg.supervisor_poll_secs;
            let timeout = cfg.heartbeat_timeout_secs;
            threads.push(
                std::thread::Builder::new()
                    .name("secondary-supervisor".into())
                    .spawn(move || {
                        failover::run_secondary_loop(
                            db2, wf2, workers, ids2, seed, done, alive, failovers, poll, timeout,
                        );
                    })
                    .expect("spawn secondary supervisor"),
            );
        }

        // Availability sweeper: promotes, heals, and drives rejoins in the
        // background so data-node failures self-repair mid-run.
        if cfg.availability_sweep_secs > 0.0 {
            threads.push(failover::run_availability_loop(
                db.clone(),
                cfg.availability_sweep_secs,
                done.clone(),
            ));
        }

        // Worker nodes.
        let mut worker_counters = Vec::new();
        for (w, link) in links.into_iter().enumerate() {
            let wn = Arc::new(WorkerNode::new(
                WorkerConfig {
                    worker_id: w as u32,
                    threads: cfg.threads_per_worker,
                    claim_batch: cfg.claim_batch,
                    time_scale: cfg.time_scale,
                    idle_backoff_secs: (cfg.supervisor_poll_secs / 2.0).max(0.0005),
                    max_failtries: 3,
                    seed: cfg.seed.wrapping_add(w as u64),
                },
                Arc::new(link),
                payloads.clone(),
                self.registry.clone(),
                ids.clone(),
                done.clone(),
            ));
            worker_counters.push(wn.counters.clone());
            threads.extend(wn.spawn());
        }

        Ok(RunningWorkflow {
            db,
            done,
            primary_alive,
            failovers,
            worker_counters,
            threads,
            total_tasks,
            t0,
        })
    }

    /// Run to completion (start + join).
    pub fn run(&self, wf: WorkflowSpec, inputs: Vec<Vec<(String, f64)>>) -> Result<RunReport> {
        self.start(wf, inputs)?.join()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::payload::SyntheticKind;
    use crate::coordinator::workflow::{ActivitySpec, Operator};
    use crate::storage::value::Value;

    fn fast_cfg(workers: usize, threads: usize) -> EngineConfig {
        EngineConfig {
            workers,
            threads_per_worker: threads,
            time_scale: 0.001,
            supervisor_poll_secs: 0.001,
            ..Default::default()
        }
    }

    #[test]
    fn end_to_end_sleep_workflow() {
        let wf = WorkflowSpec::new("sleepy", 24)
            .activity(ActivitySpec::new("a1", Operator::Map, Payload::Sleep { mean_secs: 1.0 }))
            .activity(ActivitySpec::new("a2", Operator::Map, Payload::Sleep { mean_secs: 1.0 }));
        let engine = DChironEngine::new(fast_cfg(3, 2));
        let report = engine.run(wf, vec![vec![]; 24]).unwrap();
        assert_eq!(report.total_tasks, 48);
        assert_eq!(report.executed_tasks, 48);
        assert_eq!(report.failed_tasks, 0);
        assert_eq!(report.supervisor_failovers, 0);
        assert!(report.dbms_total_secs > 0.0);
        assert!(report.db_bytes > 0);
    }

    #[test]
    fn end_to_end_domain_dataflow() {
        // quadratic sweep -> filter on y -> reduce
        let wf = WorkflowSpec::new("quad", 12)
            .activity(
                ActivitySpec::new(
                    "sweep",
                    Operator::Map,
                    Payload::Synthetic { kind: SyntheticKind::Quadratic },
                )
                .with_fields(&["x", "y"]),
            )
            .activity(ActivitySpec::new(
                "gather",
                Operator::Reduce { fanin: 4 },
                Payload::Sleep { mean_secs: 0.5 },
            ));
        let engine = DChironEngine::new(fast_cfg(2, 2));
        let running = engine
            .start(
                wf,
                (0..12)
                    .map(|i| vec![("a".into(), 1.0), ("b".into(), i as f64), ("c".into(), 0.0)])
                    .collect(),
            )
            .unwrap();
        let db = running.db.clone();
        let report = running.join().unwrap();
        assert_eq!(report.executed_tasks, 15); // 12 + 3 reducers
        // every sweep task produced x and y
        let rs = db
            .query(
                "SELECT COUNT(*) FROM taskfield WHERE direction = 'out' AND actid = 1",
            )
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(24));
        // reducers received inputs from all 4 producers
        let rs = db
            .query(
                "SELECT taskid, COUNT(*) n FROM taskfield WHERE direction = 'in' AND actid = 2 \
                 GROUP BY taskid ORDER BY taskid",
            )
            .unwrap();
        assert_eq!(rs.rows.len(), 3);
        for r in &rs.rows {
            assert_eq!(r.values[1], Value::Int(8)); // 4 producers x (x, y)
        }
        // provenance chain is queryable
        let rs = db
            .query(
                "SELECT COUNT(*) FROM provenance p JOIN workqueue t ON p.taskid = t.taskid \
                 WHERE p.kind = 'wasGeneratedBy' AND t.actid = 1",
            )
            .unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(24));
    }

    #[test]
    fn live_steering_queries_during_run() {
        let wf = WorkflowSpec::new("live", 32).activity(ActivitySpec::new(
            "a1",
            Operator::Map,
            Payload::Sleep { mean_secs: 5.0 },
        ));
        let engine = DChironEngine::new(EngineConfig {
            time_scale: 0.004, // 20ms tasks
            ..fast_cfg(2, 2)
        });
        let running = engine.start(wf, vec![vec![]; 32]).unwrap();
        // monitor while running (Q4-style: how many tasks left?)
        let mut saw_inflight = false;
        for _ in 0..200 {
            let rs = running
                .db
                .query(
                    "SELECT COUNT(*) FROM workqueue WHERE status != 'FINISHED'",
                )
                .unwrap();
            let left = rs.rows[0].values[0].as_i64().unwrap();
            if left > 0 && left < 32 {
                saw_inflight = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let report = running.join().unwrap();
        assert!(saw_inflight, "steering query never observed the run in flight");
        assert_eq!(report.executed_tasks, 32);
    }
}
