//! Task payloads: what a task actually computes.
//!
//! The paper's synthetic workloads only need controllable durations
//! ([`Payload::Sleep`], [`Payload::Busy`]); the real Risers case study runs
//! the AOT-compiled JAX/Pallas fatigue computation through a
//! [`TaskRunner`] registered by the runtime layer (keeps `coordinator`
//! decoupled from PJRT so unit tests never need artifacts).

use crate::util::rng::Rng;
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::sync::Arc;
use std::time::Instant;

/// What each task of an activity computes.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Sleep for ~`mean_secs` (scaled by the engine's `time_scale`). This is
    /// how the paper's synthetic workloads model "application computation".
    Sleep { mean_secs: f64 },
    /// Spin the CPU for ~`mean_secs` (scaled): contention-realistic variant.
    Busy { mean_secs: f64 },
    /// Pure-Rust analytic payload: evaluates a deterministic function of the
    /// task's numeric inputs and produces named outputs. Used for workflows
    /// exercising steering on domain values without PJRT.
    Synthetic { kind: SyntheticKind },
    /// Run an AOT-compiled artifact through a registered [`TaskRunner`]
    /// (the riser fatigue kernel in the end-to-end example).
    Artifact { runner: String },
}

/// Built-in synthetic computations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SyntheticKind {
    /// Copies inputs to outputs unchanged (staging/gathering activities
    /// that must preserve the dataflow).
    PassThrough,
    /// y = a*x^2 + b*x + c over the inputs (quickstart-style sweep).
    Quadratic,
    /// Cheap stand-in for the riser stress response: combines environment
    /// inputs (wind, wave, depth) into curvature components cx, cy, cz.
    RiserStress,
    /// Wear-and-tear factor f1 from curvature components.
    WearTear,
}

/// Inputs handed to a runner: the task row basics plus its domain inputs.
#[derive(Clone, Debug)]
pub struct TaskCtx {
    pub taskid: i64,
    pub actid: i64,
    pub workerid: i64,
    /// Input fields (from `taskfield` rows with direction 'in').
    pub inputs: Vec<(String, f64)>,
    /// Deterministic per-task seed.
    pub seed: u64,
    /// Nominal duration from the workqueue row (seconds, unscaled).
    pub duration: f64,
    /// Engine time scale (1.0 = real time).
    pub time_scale: f64,
}

impl TaskCtx {
    pub fn input(&self, name: &str) -> Option<f64> {
        self.inputs.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}

/// What a task produced.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TaskOutput {
    /// Named numeric outputs (ingested into `taskfield`, direction 'out').
    pub fields: Vec<(String, f64)>,
    /// Raw output files (path, bytes) registered in `file`.
    pub files: Vec<(String, i64)>,
    /// One-line stdout summary stored in the WQ row (paper Figure 3).
    pub stdout: String,
}

/// Executes one task. Implementations must be thread-safe: every worker
/// thread calls into the same runner.
pub trait TaskRunner: Send + Sync {
    fn run(&self, ctx: &TaskCtx) -> Result<TaskOutput>;
}

/// Registry mapping runner names to implementations.
#[derive(Default, Clone)]
pub struct RunnerRegistry {
    runners: FxHashMap<String, Arc<dyn TaskRunner>>,
}

impl RunnerRegistry {
    pub fn new() -> RunnerRegistry {
        RunnerRegistry::default()
    }

    pub fn register(&mut self, name: &str, runner: Arc<dyn TaskRunner>) {
        self.runners.insert(name.to_string(), runner);
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn TaskRunner>> {
        self.runners
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Engine(format!("no task runner registered as '{name}'")))
    }
}

/// Execute a payload. `Sleep`/`Busy`/`Synthetic` are handled inline;
/// `Artifact` dispatches through the registry.
pub fn execute(payload: &Payload, ctx: &TaskCtx, registry: &RunnerRegistry) -> Result<TaskOutput> {
    match payload {
        Payload::Sleep { .. } => {
            let secs = (ctx.duration * ctx.time_scale).max(0.0);
            if secs > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(secs));
            }
            Ok(TaskOutput {
                fields: vec![],
                files: vec![],
                stdout: format!("slept {:.3}s (nominal {:.1}s)", secs, ctx.duration),
            })
        }
        Payload::Busy { .. } => {
            let secs = (ctx.duration * ctx.time_scale).max(0.0);
            let t0 = Instant::now();
            let mut acc = ctx.seed;
            while t0.elapsed().as_secs_f64() < secs {
                // branch-free mixing loop; cheap but not optimizable away
                for _ in 0..512 {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                }
                std::hint::black_box(acc);
            }
            Ok(TaskOutput {
                fields: vec![],
                files: vec![],
                stdout: format!("burned {:.3}s", secs),
            })
        }
        Payload::Synthetic { kind } => run_synthetic(*kind, ctx),
        Payload::Artifact { runner } => registry.get(runner)?.run(ctx),
    }
}

fn run_synthetic(kind: SyntheticKind, ctx: &TaskCtx) -> Result<TaskOutput> {
    let mut rng = Rng::new(ctx.seed);
    match kind {
        SyntheticKind::PassThrough => Ok(TaskOutput {
            fields: ctx.inputs.clone(),
            files: vec![],
            stdout: format!("passed {} fields", ctx.inputs.len()),
        }),
        SyntheticKind::Quadratic => {
            let a = ctx.input("a").unwrap_or_else(|| rng.uniform(0.0, 3.0));
            let b = ctx.input("b").unwrap_or_else(|| rng.uniform(0.0, 40.0));
            let c = ctx.input("c").unwrap_or_else(|| rng.uniform(0.0, 30.0));
            let x = rng.uniform(0.0, 10.0);
            let y = a * x * x + b * x + c;
            Ok(TaskOutput {
                fields: vec![("x".into(), x), ("y".into(), y)],
                files: vec![],
                stdout: format!("x={x:.2} y={y:.2}"),
            })
        }
        SyntheticKind::RiserStress => {
            let wind = ctx.input("wind").unwrap_or_else(|| rng.uniform(0.0, 30.0));
            let wave = ctx.input("wave").unwrap_or_else(|| rng.uniform(0.05, 0.4));
            let depth = ctx.input("depth").unwrap_or_else(|| rng.uniform(500.0, 2500.0));
            // toy mode-superposition: curvature components from the inputs
            let cx = (wind * wave).sin().abs() * depth.sqrt() / 50.0;
            let cy = (wind + 1.0).ln() * wave * 2.0;
            let cz = (depth / 1000.0) * wave.powi(2) * 10.0;
            Ok(TaskOutput {
                fields: vec![("cx".into(), cx), ("cy".into(), cy), ("cz".into(), cz)],
                files: vec![(
                    format!("/data/riser/stress_{:06}.seg", ctx.taskid),
                    (4096.0 + depth) as i64,
                )],
                stdout: format!("cx={cx:.3} cy={cy:.3} cz={cz:.3}"),
            })
        }
        SyntheticKind::WearTear => {
            let cx = ctx.input("cx").unwrap_or(0.1);
            let cy = ctx.input("cy").unwrap_or(0.1);
            let cz = ctx.input("cz").unwrap_or(0.1);
            let f1 = 1.0 - (-(cx * cx + cy * cy + cz * cz)).exp();
            Ok(TaskOutput {
                fields: vec![("f1".into(), f1)],
                files: vec![],
                stdout: format!("f1={f1:.4}"),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(inputs: Vec<(String, f64)>) -> TaskCtx {
        TaskCtx {
            taskid: 1,
            actid: 1,
            workerid: 0,
            inputs,
            seed: 42,
            duration: 0.01,
            time_scale: 1.0,
        }
    }

    #[test]
    fn sleep_payload_sleeps_scaled() {
        let mut c = ctx(vec![]);
        c.duration = 0.05;
        c.time_scale = 0.1; // 5ms
        let t0 = Instant::now();
        let out =
            execute(&Payload::Sleep { mean_secs: 0.05 }, &c, &RunnerRegistry::new()).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.004);
        assert!(out.stdout.contains("slept"));
    }

    #[test]
    fn busy_payload_burns_cpu() {
        let mut c = ctx(vec![]);
        c.duration = 0.01;
        let t0 = Instant::now();
        execute(&Payload::Busy { mean_secs: 0.01 }, &c, &RunnerRegistry::new()).unwrap();
        assert!(t0.elapsed().as_secs_f64() >= 0.009);
    }

    #[test]
    fn quadratic_uses_inputs() {
        let c = ctx(vec![("a".into(), 1.0), ("b".into(), 0.0), ("c".into(), 0.0)]);
        let out = execute(
            &Payload::Synthetic { kind: SyntheticKind::Quadratic },
            &c,
            &RunnerRegistry::new(),
        )
        .unwrap();
        let x = out.fields.iter().find(|(n, _)| n == "x").unwrap().1;
        let y = out.fields.iter().find(|(n, _)| n == "y").unwrap().1;
        assert!((y - x * x).abs() < 1e-9);
    }

    #[test]
    fn riser_chain_produces_expected_fields() {
        let c = ctx(vec![("wind".into(), 10.0), ("wave".into(), 0.2), ("depth".into(), 1000.0)]);
        let stress = execute(
            &Payload::Synthetic { kind: SyntheticKind::RiserStress },
            &c,
            &RunnerRegistry::new(),
        )
        .unwrap();
        let names: Vec<&str> = stress.fields.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["cx", "cy", "cz"]);
        assert_eq!(stress.files.len(), 1);

        let c2 = ctx(stress.fields.clone());
        let wear = execute(
            &Payload::Synthetic { kind: SyntheticKind::WearTear },
            &c2,
            &RunnerRegistry::new(),
        )
        .unwrap();
        let f1 = wear.fields[0].1;
        assert!((0.0..=1.0).contains(&f1));
    }

    #[test]
    fn determinism_by_seed() {
        let c = ctx(vec![]);
        let a = execute(
            &Payload::Synthetic { kind: SyntheticKind::Quadratic },
            &c,
            &RunnerRegistry::new(),
        )
        .unwrap();
        let b = execute(
            &Payload::Synthetic { kind: SyntheticKind::Quadratic },
            &c,
            &RunnerRegistry::new(),
        )
        .unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn missing_runner_is_an_error() {
        let c = ctx(vec![]);
        let e = execute(
            &Payload::Artifact { runner: "riser".into() },
            &c,
            &RunnerRegistry::new(),
        );
        assert!(e.is_err());
    }

    struct Echo;
    impl TaskRunner for Echo {
        fn run(&self, ctx: &TaskCtx) -> Result<TaskOutput> {
            Ok(TaskOutput {
                fields: vec![("echo".into(), ctx.taskid as f64)],
                files: vec![],
                stdout: "echo".into(),
            })
        }
    }

    #[test]
    fn registry_dispatch() {
        let mut reg = RunnerRegistry::new();
        reg.register("echo", Arc::new(Echo));
        let c = ctx(vec![]);
        let out = execute(&Payload::Artifact { runner: "echo".into() }, &c, &reg).unwrap();
        assert_eq!(out.fields[0].1, 1.0);
    }
}
