//! # SchalaDB — distributed in-memory data management for workflow executions
//!
//! Reproduction of Souza et al., *Distributed In-memory Data Management for
//! Workflow Executions* (PeerJ CS, 2021). The crate provides:
//!
//! - [`storage`]: a from-scratch distributed in-memory relational engine
//!   (partitioned, replicated, transactional, SQL-subset) standing in for
//!   MySQL Cluster — the substrate SchalaDB assumes.
//! - [`query`]: the parallel scatter-gather executor for read-only
//!   SELECTs — partial-aggregate pushdown to partitions, lock-free
//!   versioned snapshot reads, merge at the coordinator — so steering
//!   analytics never contend with scheduling transactions.
//! - [`coordinator`]: the d-Chiron workflow engine built on SchalaDB
//!   principles — supervisor/secondary-supervisor, DBMS-driven worker
//!   scheduling, provenance + domain data capture.
//! - [`steering`]: runtime analytical queries (Table 2, Q1–Q8) and dynamic
//!   workflow adaptation.
//! - [`baseline`]: centralized Chiron (master–worker over message passing
//!   with a centralized DBMS) used as the Experiment-8 comparator.
//! - [`server`]: the network front-end — a hand-rolled length-prefixed
//!   wire protocol, a transport-agnostic session layer, a bounded
//!   thread-per-connection TCP server (`dchiron serve`), and a blocking
//!   client for remote workers and steering analysts.
//! - [`obs`]: always-on observability — a sharded lock-free metrics
//!   registry instrumented at every hot layer, per-request span tracing
//!   with a bounded slow-op ring, a Prometheus-style text exposition, and
//!   the system `monitoring` table that makes telemetry queryable through
//!   the normal SQL path (the paper's "monitoring is just workflow data").
//! - [`sim`]: a calibrated discrete-event simulator of the paper's
//!   960-core Grid5000 testbed, used by the `exp*` benches.
//! - [`runtime`]: PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   riser-fatigue payload (`artifacts/*.hlo.txt`).
//! - [`workload`]: the Risers Fatigue Analysis workflow and synthetic
//!   workload generators.
//!
//! See `DESIGN.md` for the substitution table and the per-experiment index.

pub mod baseline;
pub mod coordinator;
pub mod metrics;
pub mod obs;
pub mod query;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod steering;
pub mod storage;
pub mod util;
pub mod workload;

pub use storage::cluster::DbCluster;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// SQL lexing/parsing failure with position information.
    #[error("sql parse error: {0}")]
    Parse(String),
    /// Catalog-level failure (unknown table/column, duplicate create, ...).
    #[error("catalog error: {0}")]
    Catalog(String),
    /// Type mismatch or unsupported operation during evaluation.
    #[error("type error: {0}")]
    Type(String),
    /// Constraint violation (primary key, not-null, ...).
    #[error("constraint violation: {0}")]
    Constraint(String),
    /// Transaction aborted (conflict, explicit rollback, node failure).
    #[error("transaction aborted: {0}")]
    TxnAborted(String),
    /// A data node (or all replicas of a partition) is unavailable.
    #[error("node unavailable: {0}")]
    Unavailable(String),
    /// Workflow-engine level failure.
    #[error("engine error: {0}")]
    Engine(String),
    /// PJRT runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// I/O failure (WAL, checkpoints, artifacts).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// Durable-state recovery failure (irreconcilable replica divergence,
    /// unusable durability directory). Cold start refuses rather than
    /// guessing — see `DbCluster::open`.
    #[error("recovery error: {0}")]
    Recovery(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
