//! `dchiron` — the d-Chiron launcher CLI.
//!
//! Args are `--key value` pairs; no external CLI crate is available
//! offline, so parsing is hand-rolled. The subcommand list lives in one
//! place — the [`USAGE`] table — and `dchiron help` (or any unknown
//! command) renders from it, so the help text cannot drift from the
//! dispatch table the way a hand-written usage string can.
//!
//! Run `dchiron help` for the full list; highlights:
//!
//! - `run` / `risers` / `bench-sim` / `sql` — in-process workloads.
//! - `serve` — the wire-protocol server (`dchiron shutdown` stops it).
//! - `stats` / `query` / `metrics` / `top` — remote introspection.
//! - `drive` — remote multi-client claim + steering workload.
//! - `topology` / `rebalance` — elastic-topology admin: inspect
//!   placement, add a data node, move a partition's primary, or split a
//!   hot partition, all against a live server.

use schaladb::coordinator::payload::RunnerRegistry;
use schaladb::coordinator::{DChironEngine, EngineConfig};
use schaladb::metrics;
use schaladb::runtime::{self, riser, PjrtService};
use schaladb::server::{parse_addr, Client, Server, ServerConfig};
use schaladb::sim::experiments;
use schaladb::storage::{AccessKind, ClusterConfig, ConcurrencyMode, DurabilityConfig, Value};
use schaladb::util::json::Json;
use schaladb::workload::{self, SyntheticWorkload};
use schaladb::DbCluster;
use std::collections::HashMap;
use std::io::Write as _;

/// One row per subcommand: `(name, flag summary, one-line description)`.
/// The single source of truth for the CLI surface — `main`'s dispatch
/// arms, the help output, and the module doc above all follow this table,
/// so a new subcommand is added here first.
const USAGE: &[(&str, &str, &str)] = &[
    (
        "run",
        "[--tasks N] [--duration SECS] [--workers W] [--threads T] [--time-scale S] \
         [--engine dchiron|chiron] [--seed S]",
        "run a synthetic workload on the real engine and print the report",
    ),
    (
        "risers",
        "[--conditions N] [--pjrt] [--workers W] [--threads T]",
        "run the Risers Fatigue Analysis workflow (--pjrt uses the AOT artifacts)",
    ),
    (
        "bench-sim",
        "[--experiment expN] [--json FILE]",
        "regenerate the paper's tables/figures on the calibrated simulator",
    ),
    ("sql", "", "run the steering SQL demo on a seeded risers database"),
    (
        "serve",
        "[--addr HOST:PORT] [--max-conns N] [--data-nodes N] [--concurrency 2pl|occ] \
         [--data-dir PATH] [--group-commit N] [--reopen] [--conn-timeout-secs S]",
        "start the wire-protocol server (blocks until `dchiron shutdown`); \
         --reopen cold-starts from an existing --data-dir",
    ),
    (
        "stats",
        "[--addr HOST:PORT] [--fingerprint] [--tables]",
        "query a running server for route counts, plan cache, epoch, sessions",
    ),
    ("shutdown", "[--addr HOST:PORT]", "ask a running server to shut down cleanly"),
    (
        "drive",
        "[--addr HOST:PORT] [--clients N] [--scanners M] [--tasks T]",
        "remote multi-client workload: N claim workers + M steering scanners",
    ),
    (
        "query",
        "[--addr HOST:PORT] [--sql \"SELECT ...\"]",
        "run one steering SQL statement over the wire and print the rows",
    ),
    (
        "metrics",
        "[--addr HOST:PORT] [--top K]",
        "dump the telemetry registry (Prometheus text) and the K slowest ops",
    ),
    (
        "top",
        "[--addr HOST:PORT] [--interval SECS] [--iterations N]",
        "live terminal view of claim/scan/WAL/frame rates and slowest ops",
    ),
    (
        "topology",
        "[--addr HOST:PORT]",
        "print node states and each table's per-partition placement and size",
    ),
    (
        "rebalance",
        "[--addr HOST:PORT] (--add-node | --table T --partition P [--split | --to-node N])",
        "elastic-topology admin: add a node, move a partition's primary, or split it",
    ),
];

fn print_usage() {
    println!("dchiron — SchalaDB / d-Chiron reproduction");
    println!("usage: dchiron <command> [--key value ...]");
    println!();
    for (name, flags, desc) in USAGE {
        if flags.is_empty() {
            println!("  dchiron {name}");
        } else {
            println!("  dchiron {name} {flags}");
        }
        println!("      {desc}");
    }
    println!();
    println!("see README.md for details");
}

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.len() > 1 { &args[1..] } else { &[] };
    let (flags, _pos) = parse_flags(rest);

    match cmd {
        "run" => cmd_run(&flags),
        "risers" => cmd_risers(&flags),
        "bench-sim" => cmd_bench_sim(&flags),
        "sql" => cmd_sql(),
        "serve" => cmd_serve(&flags),
        "stats" => cmd_stats(&flags),
        "shutdown" => cmd_shutdown(&flags),
        "drive" => cmd_drive(&flags),
        "query" => cmd_query(&flags),
        "metrics" => cmd_metrics(&flags),
        "top" => cmd_top(&flags),
        "topology" => cmd_topology(&flags),
        "rebalance" => cmd_rebalance(&flags),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn cmd_run(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let tasks: usize = get(flags, "tasks", 300);
    let duration: f64 = get(flags, "duration", 1.0);
    let workers: usize = get(flags, "workers", 4);
    let threads: usize = get(flags, "threads", 2);
    let time_scale: f64 = get(flags, "time-scale", 0.01);
    let seed: u64 = get(flags, "seed", 42);
    let engine_kind = flags.get("engine").map(|s| s.as_str()).unwrap_or("dchiron");

    let w = SyntheticWorkload { total_tasks: tasks, mean_task_secs: duration, activities: 3, seed };
    println!(
        "synthetic workload: {} tasks @ {duration}s mean (scaled x{time_scale}), engine={engine_kind}",
        w.planned_tasks()
    );
    let report = match engine_kind {
        "chiron" => {
            use schaladb::baseline::{ChironConfig, ChironEngine};
            ChironEngine::new(ChironConfig {
                workers,
                threads_per_worker: threads,
                time_scale,
                seed,
                ..Default::default()
            })
            .run(w.workflow(), w.inputs())?
        }
        _ => DChironEngine::new(EngineConfig {
            workers,
            threads_per_worker: threads,
            time_scale,
            seed,
            ..Default::default()
        })
        .run(w.workflow(), w.inputs())?,
    };
    println!("{}", metrics::format_report("synthetic run", &report));
    Ok(())
}

fn cmd_risers(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let conditions: usize = get(flags, "conditions", 64);
    let workers: usize = get(flags, "workers", 4);
    let threads: usize = get(flags, "threads", 2);
    let use_pjrt = flags.contains_key("pjrt");

    let mut registry = RunnerRegistry::new();
    let wf = if use_pjrt {
        if !runtime::artifacts_available() {
            anyhow::bail!("--pjrt needs artifacts; run `make artifacts`");
        }
        let svc = PjrtService::start(runtime::default_artifact_dir())?;
        riser::register_riser_runners(&mut registry, &svc);
        workload::risers_workflow_with(conditions, Some("riser"))
    } else {
        workload::risers_workflow(conditions)
    };
    let engine = DChironEngine::with_registry(
        EngineConfig {
            workers,
            threads_per_worker: threads,
            time_scale: 0.01,
            ..Default::default()
        },
        registry,
    );
    let inputs = workload::risers_inputs(conditions, get(flags, "seed", 42));
    let report = engine.run(wf, inputs)?;
    println!("{}", metrics::format_report("risers", &report));
    Ok(())
}

fn cmd_bench_sim(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let which = flags.get("experiment").cloned();
    let mut outputs = Vec::new();
    match which {
        Some(id) => outputs.push(experiments::run(&id)?),
        None => {
            for f in experiments::all() {
                outputs.push(f()?);
            }
        }
    }
    let mut all_json = Vec::new();
    for out in &outputs {
        out.print();
        all_json.push(out.json.clone());
    }
    if let Some(path) = flags.get("json") {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", Json::Arr(all_json).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sql() -> anyhow::Result<()> {
    use schaladb::steering::SteeringClient;
    // Seed a small risers database, then run the Table-2 query set.
    let engine = DChironEngine::new(EngineConfig {
        workers: 2,
        threads_per_worker: 2,
        time_scale: 0.0,
        ..Default::default()
    });
    let running =
        engine.start(workload::risers_workflow(24), workload::risers_inputs(24, 3))?;
    let db = running.db.clone();
    running.join()?;
    let client = SteeringClient::new(db);
    println!("Q1:\n{}", client.q1_recent_status_by_node()?.render());
    println!("Q6:\n{}", client.q6_activity_times()?.render());
    println!("Q7:\n{}", client.q7_wear_outliers("calculate_wear_and_tear", 0.2)?.render());
    Ok(())
}

/// Resolve the shared `--addr` flag (default loopback:7878) through the
/// one validation helper every network subcommand uses.
fn flag_addr(flags: &HashMap<String, String>) -> anyhow::Result<std::net::SocketAddr> {
    let raw = flags.get("addr").map(String::as_str).unwrap_or("127.0.0.1:7878");
    Ok(parse_addr(raw)?)
}

fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let max_conns: usize = get(flags, "max-conns", 64);
    let data_nodes: usize = get(flags, "data-nodes", 2);
    let group_commit: usize = get(flags, "group-commit", 64);
    let conn_timeout_secs: u64 = get(flags, "conn-timeout-secs", 0);
    let reopen = flags.contains_key("reopen");
    let concurrency = match flags.get("concurrency") {
        None => ConcurrencyMode::default(),
        Some(name) => ConcurrencyMode::from_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown --concurrency mode {name:?} (expected 2pl or occ)")
        })?,
    };
    let mut builder = ClusterConfig::builder()
        .data_nodes(data_nodes)
        .replication(data_nodes >= 2)
        .concurrency(concurrency);
    if let Some(dir) = flags.get("data-dir") {
        builder = builder
            .durability(DurabilityConfig::new(dir.into(), group_commit.max(1)));
    } else if reopen {
        anyhow::bail!("--reopen needs --data-dir PATH (the durability dir to recover)");
    }
    let config = builder.build()?;
    let cluster = if reopen {
        let c = DbCluster::open(config)?;
        println!(
            "dchiron serve: cold start recovered {} tables at epoch {}",
            c.tables().len(),
            c.cluster_epoch()
        );
        c
    } else {
        DbCluster::start(config)?
    };
    let conn_timeout = (conn_timeout_secs > 0)
        .then(|| std::time::Duration::from_secs(conn_timeout_secs));
    let mut server =
        Server::bind(addr, cluster.clone(), ServerConfig { max_conns, conn_timeout })?;
    println!(
        "dchiron serve: listening on {} ({data_nodes} data nodes, {concurrency:?} point DML, \
         max {max_conns} connections)",
        server.local_addr()
    );
    println!("stop with: dchiron shutdown --addr {}", server.local_addr());
    server.wait();
    // Clean shutdown: cut a final checkpoint on every node so a later
    // `--reopen` cold-starts from checkpoints instead of long WAL replays.
    if cluster.durability().is_some() {
        for id in 0..cluster.num_nodes() as u32 {
            if let Err(e) = schaladb::storage::checkpoint::checkpoint_node(&cluster, id) {
                eprintln!("warning: shutdown checkpoint for node {id} failed: {e}");
            }
        }
    }
    println!("dchiron serve: shut down cleanly");
    Ok(())
}

fn cmd_stats(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let mut client = Client::connect(addr, 0, AccessKind::Steering)?;
    let want_fp = flags.contains_key("fingerprint");
    let want_tables = flags.contains_key("tables");
    let s = client.stats(want_fp, want_tables)?;
    let header = ["metric", "value"];
    let rows: Vec<Vec<String>> = vec![
        vec!["routes.scatter".into(), s.scatter.to_string()],
        vec!["routes.snapshot_join".into(), s.snapshot_join.to_string()],
        vec!["routes.centralized".into(), s.centralized.to_string()],
        vec!["routes.fast_dml".into(), s.fast_dml.to_string()],
        vec!["chunks.scanned".into(), s.chunks_scanned.to_string()],
        vec!["chunks.pruned".into(), s.chunks_pruned.to_string()],
        vec!["plan_cache.entries".into(), s.cached_plans.to_string()],
        vec!["cluster.epoch".into(), s.epoch.to_string()],
        vec!["server.sessions".into(), s.sessions.to_string()],
        vec!["obs.dml_interp".into(), s.dml_interp.to_string()],
        vec!["obs.wal_records".into(), s.wal_records.to_string()],
        vec!["obs.wal_flushes".into(), s.wal_flushes.to_string()],
        vec!["obs.frames_in".into(), s.frames_in.to_string()],
        vec!["obs.frames_out".into(), s.frames_out.to_string()],
        vec!["obs.bytes_in".into(), s.bytes_in.to_string()],
        vec!["obs.bytes_out".into(), s.bytes_out.to_string()],
        vec!["obs.frame_errors".into(), s.frame_errors.to_string()],
        vec!["occ.dml".into(), s.occ_dml.to_string()],
        vec!["occ.retries".into(), s.occ_retries.to_string()],
        vec!["occ.fallbacks".into(), s.occ_fallbacks.to_string()],
    ];
    println!("{}", schaladb::util::render_table(&header, &rows));
    if let Some(fp) = &s.fingerprint {
        // the full canonical serialization is large; the checksum is what
        // byte-equality comparisons need at a glance
        println!(
            "fingerprint: {} bytes, fnv1a={:08x}",
            fp.len(),
            schaladb::server::wire::checksum(fp.as_bytes())
        );
    }
    if want_tables {
        let trows: Vec<Vec<String>> =
            s.table_rows.iter().map(|(t, n)| vec![t.clone(), n.to_string()]).collect();
        println!("{}", schaladb::util::render_table(&["table", "rows"], &trows));
    }
    client.close()?;
    Ok(())
}

fn cmd_shutdown(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let mut client = Client::connect(addr, 0, AccessKind::Other)?;
    client.shutdown_server()?;
    println!("server at {addr} acknowledged shutdown");
    Ok(())
}

/// Remote multi-client workload driver: N claim workers + M steering
/// scanners against an already-running `dchiron serve` (the CI smoke job
/// points this at a freshly started server).
fn cmd_drive(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::Arc;

    let addr = flag_addr(flags)?;
    let clients: usize = get(flags, "clients", 8);
    let scanners: usize = get(flags, "scanners", 2);
    let tasks: usize = get(flags, "tasks", clients * 50);
    let clients = clients.max(1);

    let mut admin = Client::connect(addr, 0, AccessKind::Other)?;
    let create = format!(
        "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
         status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
         PARTITION BY HASH(workerid) PARTITIONS {clients} \
         PRIMARY KEY (taskid) INDEX (status)"
    );
    let base = match admin.exec_sql(&create) {
        Ok(_) => 0i64,
        // table exists from a previous drive against the same server:
        // keep going, seeding above the current maximum task id
        Err(schaladb::Error::Catalog(_)) => {
            let rs = admin.query("SELECT MAX(taskid) FROM workqueue")?;
            match rs.rows.first().and_then(|r| r.values.first()) {
                Some(Value::Int(m)) => m + 1,
                _ => 0,
            }
        }
        Err(e) => return Err(e.into()),
    };
    let (ins, _) = admin.prepare(
        "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
         VALUES (?, ?, ?, 'READY', ?)",
    )?;
    for chunk_start in (0..tasks).step_by(256) {
        let rows: Vec<Vec<Value>> = (chunk_start..(chunk_start + 256).min(tasks))
            .map(|i| {
                vec![
                    Value::Int(base + i as i64),
                    Value::Int((i % 3) as i64),
                    Value::Int((i % clients) as i64),
                    Value::Float(1.0),
                ]
            })
            .collect();
        admin.exec_batch(ins, AccessKind::InsertTasks, &rows)?;
    }
    println!("seeded {tasks} READY tasks (taskid {base}..) across {clients} partitions");

    let stop = Arc::new(AtomicBool::new(false));
    let scans = Arc::new(AtomicUsize::new(0));
    let mut scan_handles = Vec::new();
    for _ in 0..scanners {
        let stop = stop.clone();
        let scans = scans.clone();
        scan_handles.push(std::thread::spawn(move || -> schaladb::Result<()> {
            let mut c = Client::connect(addr, 0, AccessKind::Steering)?;
            while !stop.load(Ordering::SeqCst) {
                c.query("SELECT status, COUNT(*) FROM workqueue GROUP BY status")?;
                scans.fetch_add(1, Ordering::Relaxed);
            }
            c.close()
        }));
    }

    let t0 = std::time::Instant::now();
    let mut claim_handles = Vec::new();
    for w in 0..clients {
        claim_handles.push(std::thread::spawn(move || -> schaladb::Result<usize> {
            let mut c = Client::connect(addr, w as u32, AccessKind::UpdateToRunning)?;
            let (claim, _) = c.prepare(
                "UPDATE workqueue SET status = 'RUNNING', starttime = 0.0 \
                 WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 1 \
                 RETURNING taskid",
            )?;
            let mut claimed = 0;
            loop {
                match c.exec(claim, &[Value::Int(w as i64)])? {
                    schaladb::storage::StatementResult::Rows(rs) if !rs.rows.is_empty() => {
                        claimed += 1;
                    }
                    _ => break, // this worker's partition is drained
                }
            }
            c.close()?;
            Ok(claimed)
        }));
    }
    let mut claimed = 0;
    for h in claim_handles {
        claimed += h.join().expect("claim worker panicked")?;
    }
    let dt = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);
    let mut scan_total = 0;
    for h in scan_handles {
        h.join().expect("scanner panicked")?;
        scan_total = scans.load(Ordering::Relaxed);
    }

    println!(
        "claimed {claimed} tasks over TCP with {clients} workers in {dt:.2}s \
         -> {:.0} claims/s; {scan_total} steering scans from {scanners} scanners",
        claimed as f64 / dt.max(1e-9)
    );
    let s = admin.stats(false, true)?;
    for (t, n) in &s.table_rows {
        println!("table {t}: {n} rows");
    }
    admin.close()?;
    Ok(())
}

/// Run one steering SQL statement over the wire and print the rows. The
/// default statement reads the global rows of the system `monitoring`
/// table — telemetry through the same SQL path as workflow data.
fn cmd_query(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let sql = flags.get("sql").cloned().unwrap_or_else(|| {
        "SELECT metric, value, cnt FROM monitoring WHERE part = -1 AND node = -1".into()
    });
    let mut client = Client::connect(addr, 0, AccessKind::Steering)?;
    let rs = client.query(&sql)?;
    let header: Vec<&str> = rs.columns.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = rs
        .rows
        .iter()
        .map(|r| r.values.iter().map(|v| v.to_string()).collect())
        .collect();
    println!("{}", schaladb::util::render_table(&header, &rows));
    println!("{} rows", rows.len());
    client.close()?;
    Ok(())
}

/// Render a slow-op list as table rows (shared by `metrics` and `top`).
fn slow_op_rows(ops: &[schaladb::server::SlowOpWire]) -> Vec<Vec<String>> {
    ops.iter()
        .map(|op| {
            let stages = op
                .stages
                .iter()
                .filter(|(_, n)| *n > 0)
                .map(|(s, n)| format!("{s}={:.2}ms", *n as f64 / 1e6))
                .collect::<Vec<_>>()
                .join(" ");
            vec![
                op.span.to_string(),
                op.label.clone(),
                format!("{:.2}", op.total_nanos as f64 / 1e6),
                stages,
            ]
        })
        .collect()
}

fn cmd_metrics(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let top_k: u16 = get(flags, "top", 10);
    let mut client = Client::connect(addr, 0, AccessKind::Steering)?;
    let m = client.metrics(top_k)?;
    print!("{}", m.text);
    if !m.slow_ops.is_empty() {
        println!();
        println!(
            "{}",
            schaladb::util::render_table(
                &["span", "op", "total_ms", "stages"],
                &slow_op_rows(&m.slow_ops),
            )
        );
    }
    client.close()?;
    Ok(())
}

/// Live terminal view of a running server: per-interval rates computed
/// from successive `Stats` snapshots, plus the current slowest ops.
fn cmd_top(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    use std::io::IsTerminal;

    let addr = flag_addr(flags)?;
    let interval: f64 = get::<f64>(flags, "interval", 1.0).max(0.05);
    let iterations: usize = get(flags, "iterations", 0);
    let clear = std::io::stdout().is_terminal();
    let mut client = Client::connect(addr, 0, AccessKind::Steering)?;
    let mut prev: Option<schaladb::server::RemoteStats> = None;
    let mut tick = 0usize;
    loop {
        let s = client.stats(false, false)?;
        let m = client.metrics(5)?;
        // first tick has no baseline: rates start at zero, totals are live
        let p = prev.unwrap_or_else(|| s.clone());
        // `saturating_sub`, not `-`: counters restart at zero when the
        // registry is quiesced and re-enabled (`set_enabled(false)` →
        // `true` resets the observation window), so a snapshot taken
        // across that boundary can be *smaller* than the previous one. A
        // negative delta is not a rate — clamp it to zero and let the
        // next tick re-baseline.
        let rate = |cur: u64, old: u64| cur.saturating_sub(old) as f64 / interval;
        let row = |name: &str, cur: u64, old: u64| {
            vec![name.to_string(), cur.to_string(), format!("{:.0}", rate(cur, old))]
        };
        let rows = vec![
            row("claims.fast", s.fast_dml, p.fast_dml),
            row("claims.occ", s.occ_dml, p.occ_dml),
            row("claims.interpreted", s.dml_interp, p.dml_interp),
            row("occ.retries", s.occ_retries, p.occ_retries),
            row("occ.fallbacks", s.occ_fallbacks, p.occ_fallbacks),
            row("selects.scatter", s.scatter, p.scatter),
            row("selects.snapshot_join", s.snapshot_join, p.snapshot_join),
            row("selects.centralized", s.centralized, p.centralized),
            row("chunks.scanned", s.chunks_scanned, p.chunks_scanned),
            row("chunks.pruned", s.chunks_pruned, p.chunks_pruned),
            row("wal.records", s.wal_records, p.wal_records),
            row("wal.flushes", s.wal_flushes, p.wal_flushes),
            row("server.frames_in", s.frames_in, p.frames_in),
            row("server.frames_out", s.frames_out, p.frames_out),
            row("server.bytes_in", s.bytes_in, p.bytes_in),
            row("server.bytes_out", s.bytes_out, p.bytes_out),
            row("server.frame_errors", s.frame_errors, p.frame_errors),
        ];
        if clear {
            print!("\x1b[2J\x1b[H");
        }
        println!(
            "dchiron top — {addr} | epoch {} | {} sessions | every {interval}s",
            s.epoch, s.sessions
        );
        println!("{}", schaladb::util::render_table(&["metric", "total", "per-sec"], &rows));
        if !m.slow_ops.is_empty() {
            println!("slowest ops:");
            println!(
                "{}",
                schaladb::util::render_table(
                    &["span", "op", "total_ms", "stages"],
                    &slow_op_rows(&m.slow_ops),
                )
            );
        }
        prev = Some(s);
        tick += 1;
        if iterations > 0 && tick >= iterations {
            break;
        }
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
    client.close()?;
    Ok(())
}

/// Print the cluster topology: node states, then each table's
/// per-partition placement, size and congruence class.
fn cmd_topology(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let mut client = Client::connect(addr, 0, AccessKind::Steering)?;
    let t = client.topology()?;
    println!("cluster epoch {}", t.epoch);
    let nrows: Vec<Vec<String>> = t
        .nodes
        .iter()
        .map(|n| vec![n.id.to_string(), format!("{:?}", n.state), n.partitions.to_string()])
        .collect();
    println!("{}", schaladb::util::render_table(&["node", "state", "replicas"], &nrows));
    for (table, parts) in &t.tables {
        let prows: Vec<Vec<String>> = parts
            .iter()
            .map(|p| {
                let class = match p.class {
                    Some((m, r)) => format!("{r} mod {m}"),
                    None => "-".into(),
                };
                vec![
                    p.pidx.to_string(),
                    class,
                    p.primary.to_string(),
                    p.backup.map_or_else(|| "-".into(), |b| b.to_string()),
                    p.rows.to_string(),
                    p.bytes.to_string(),
                    p.version.to_string(),
                    p.store_epoch.to_string(),
                ]
            })
            .collect();
        println!("table {table}:");
        println!(
            "{}",
            schaladb::util::render_table(
                &["part", "class", "primary", "backup", "rows", "bytes", "lsn", "epoch"],
                &prows,
            )
        );
    }
    client.close()?;
    Ok(())
}

/// Elastic-topology admin against a running server: `--add-node`
/// registers a fresh data node; `--table T --partition P --to-node N`
/// moves a partition's primary live; `--table T --partition P --split`
/// splits a hot partition in two.
fn cmd_rebalance(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let addr = flag_addr(flags)?;
    let mut client = Client::connect(addr, 0, AccessKind::Other)?;
    if flags.contains_key("add-node") {
        let id = client.add_node()?;
        println!(
            "node {id} joined (empty); move work onto it with: \
             dchiron rebalance --addr {addr} --table T --partition P --to-node {id}"
        );
    } else {
        let table = flags.get("table").ok_or_else(|| {
            anyhow::anyhow!(
                "rebalance needs --add-node, or --table with --partition and \
                 either --split or --to-node"
            )
        })?;
        let pidx: u32 = flags
            .get("partition")
            .and_then(|v| v.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("rebalance needs --partition INDEX"))?;
        if flags.contains_key("split") {
            let new_pidx = client.split(table, pidx)?;
            println!("partition {table}[{pidx}] split; new partition {new_pidx}");
        } else {
            let to_node: u32 = flags
                .get("to-node")
                .and_then(|v| v.parse().ok())
                .ok_or_else(|| {
                    anyhow::anyhow!("rebalance needs --to-node NODE (or --split/--add-node)")
                })?;
            println!("{}", client.rebalance(table, pidx, to_node)?);
        }
    }
    client.close()?;
    Ok(())
}
