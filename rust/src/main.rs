//! `dchiron` — the d-Chiron launcher CLI.
//!
//! Subcommands (args are `--key value` pairs; no external CLI crate is
//! available offline, so parsing is hand-rolled):
//!
//! ```text
//! dchiron run      [--tasks N] [--duration SECS] [--workers W] [--threads T]
//!                  [--time-scale S] [--engine dchiron|chiron] [--seed S]
//!     run a synthetic workload on the real engine and print the report
//! dchiron risers   [--conditions N] [--pjrt] [--workers W] [--threads T]
//!     run the Risers Fatigue Analysis workflow (--pjrt uses the AOT
//!     artifacts; otherwise synthetic physics)
//! dchiron bench-sim [--experiment expN] [--json FILE]
//!     regenerate the paper's tables/figures on the calibrated simulator
//! dchiron sql
//!     run the steering SQL demo on a seeded risers database
//! ```

use schaladb::coordinator::payload::RunnerRegistry;
use schaladb::coordinator::{DChironEngine, EngineConfig};
use schaladb::metrics;
use schaladb::runtime::{self, riser, PjrtService};
use schaladb::sim::experiments;
use schaladb::util::json::Json;
use schaladb::workload::{self, SyntheticWorkload};
use std::collections::HashMap;
use std::io::Write as _;

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positional)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.len() > 1 { &args[1..] } else { &[] };
    let (flags, _pos) = parse_flags(rest);

    match cmd {
        "run" => cmd_run(&flags),
        "risers" => cmd_risers(&flags),
        "bench-sim" => cmd_bench_sim(&flags),
        "sql" => cmd_sql(),
        _ => {
            println!("dchiron — SchalaDB / d-Chiron reproduction");
            println!("commands: run | risers | bench-sim | sql (see README.md)");
            Ok(())
        }
    }
}

fn cmd_run(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let tasks: usize = get(flags, "tasks", 300);
    let duration: f64 = get(flags, "duration", 1.0);
    let workers: usize = get(flags, "workers", 4);
    let threads: usize = get(flags, "threads", 2);
    let time_scale: f64 = get(flags, "time-scale", 0.01);
    let seed: u64 = get(flags, "seed", 42);
    let engine_kind = flags.get("engine").map(|s| s.as_str()).unwrap_or("dchiron");

    let w = SyntheticWorkload { total_tasks: tasks, mean_task_secs: duration, activities: 3, seed };
    println!(
        "synthetic workload: {} tasks @ {duration}s mean (scaled x{time_scale}), engine={engine_kind}",
        w.planned_tasks()
    );
    let report = match engine_kind {
        "chiron" => {
            use schaladb::baseline::{ChironConfig, ChironEngine};
            ChironEngine::new(ChironConfig {
                workers,
                threads_per_worker: threads,
                time_scale,
                seed,
                ..Default::default()
            })
            .run(w.workflow(), w.inputs())?
        }
        _ => DChironEngine::new(EngineConfig {
            workers,
            threads_per_worker: threads,
            time_scale,
            seed,
            ..Default::default()
        })
        .run(w.workflow(), w.inputs())?,
    };
    println!("{}", metrics::format_report("synthetic run", &report));
    Ok(())
}

fn cmd_risers(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let conditions: usize = get(flags, "conditions", 64);
    let workers: usize = get(flags, "workers", 4);
    let threads: usize = get(flags, "threads", 2);
    let use_pjrt = flags.contains_key("pjrt");

    let mut registry = RunnerRegistry::new();
    let wf = if use_pjrt {
        if !runtime::artifacts_available() {
            anyhow::bail!("--pjrt needs artifacts; run `make artifacts`");
        }
        let svc = PjrtService::start(runtime::default_artifact_dir())?;
        riser::register_riser_runners(&mut registry, &svc);
        workload::risers_workflow_with(conditions, Some("riser"))
    } else {
        workload::risers_workflow(conditions)
    };
    let engine = DChironEngine::with_registry(
        EngineConfig {
            workers,
            threads_per_worker: threads,
            time_scale: 0.01,
            ..Default::default()
        },
        registry,
    );
    let inputs = workload::risers_inputs(conditions, get(flags, "seed", 42));
    let report = engine.run(wf, inputs)?;
    println!("{}", metrics::format_report("risers", &report));
    Ok(())
}

fn cmd_bench_sim(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let which = flags.get("experiment").cloned();
    let mut outputs = Vec::new();
    match which {
        Some(id) => outputs.push(experiments::run(&id)?),
        None => {
            for f in experiments::all() {
                outputs.push(f()?);
            }
        }
    }
    let mut all_json = Vec::new();
    for out in &outputs {
        out.print();
        all_json.push(out.json.clone());
    }
    if let Some(path) = flags.get("json") {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", Json::Arr(all_json).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sql() -> anyhow::Result<()> {
    use schaladb::steering::SteeringClient;
    // Seed a small risers database, then run the Table-2 query set.
    let engine = DChironEngine::new(EngineConfig {
        workers: 2,
        threads_per_worker: 2,
        time_scale: 0.0,
        ..Default::default()
    });
    let running =
        engine.start(workload::risers_workflow(24), workload::risers_inputs(24, 3))?;
    let db = running.db.clone();
    running.join()?;
    let client = SteeringClient::new(db);
    println!("Q1:\n{}", client.q1_recent_status_by_node()?.render());
    println!("Q6:\n{}", client.q6_activity_times()?.render());
    println!("Q7:\n{}", client.q7_wear_outliers("calculate_wear_and_tear", 0.2)?.render());
    Ok(())
}
