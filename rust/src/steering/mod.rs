//! User steering: the Table-2 analytical queries (Q1–Q8) and runtime
//! workflow adaptation, issued against the live d-Chiron database.
//!
//! These run *while the workflow executes* — the integration the paper
//! argues for: execution, domain, and provenance data in one DBMS means a
//! monitoring query can join the scheduler's workqueue with domain values
//! and provenance edges with no export step.
//!
//! All of Q1–Q7 execute on the scatter-gather engine (`crate::query`):
//! lock-free partition snapshots, parallel partial plans, merge at the
//! coordinator — so a monitor polling every few seconds never holds 2PL
//! partition locks against the scheduler's claim transactions
//! (Experiment 7's "negligible steering overhead").

use crate::storage::prepared::{in_placeholders, padded_chunks, IN_CHUNK};
use crate::storage::{AccessKind, DbCluster, ResultSet, Value};
use crate::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Stats bucket for steering clients.
const STEERING_NODE: u32 = u32::MAX - 1;

/// A steering client bound to a (possibly running) d-Chiron database.
///
/// Every query goes through the cluster's prepared-statement API: the
/// monitor loop re-issues Q1–Q7 every interval, so each query text is
/// parsed once per cluster and user-supplied values (hostnames, activity
/// names, thresholds) are bound, never interpolated — a hostname like
/// `o'brien-03` steers, it does not break the lexer.
pub struct SteeringClient {
    db: Arc<DbCluster>,
}

impl SteeringClient {
    pub fn new(db: Arc<DbCluster>) -> SteeringClient {
        SteeringClient { db }
    }

    fn q(&self, sql: &str) -> Result<ResultSet> {
        self.q_params(sql, &[])
    }

    /// Prepare (cache-hit after the first call), bind, and execute one
    /// steering query.
    fn q_params(&self, sql: &str, params: &[Value]) -> Result<ResultSet> {
        let p = self.db.prepare(sql)?;
        match self.db.exec_prepared(STEERING_NODE, AccessKind::Steering, &p, params)? {
            crate::storage::StatementResult::Rows(r) => Ok(r),
            other => Err(Error::Engine(format!("steering query returned {other:?}"))),
        }
    }

    /// Q1: per node, task status counts and failure tries for tasks started
    /// in the last minute.
    pub fn q1_recent_status_by_node(&self) -> Result<ResultSet> {
        self.q(
            "SELECT n.hostname, t.status, COUNT(*) AS tasks, SUM(t.failtries) AS failure_tries \
             FROM workqueue t JOIN node n ON t.workerid = n.nodeid \
             WHERE t.starttime >= NOW() - 60 \
             GROUP BY n.hostname, t.status \
             ORDER BY n.hostname, t.status",
        )
    }

    /// Q2: for one node, per task finished in the last minute: status and
    /// total bytes of its files, heaviest first.
    pub fn q2_bytes_by_task(&self, hostname: &str) -> Result<ResultSet> {
        self.q_params(
            "SELECT t.taskid, t.status, SUM(f.size_bytes) AS bytes \
             FROM workqueue t \
             JOIN file f ON f.taskid = t.taskid \
             JOIN node n ON t.workerid = n.nodeid \
             WHERE n.hostname = ? AND t.endtime >= NOW() - 60 \
             GROUP BY t.taskid, t.status \
             ORDER BY bytes DESC, t.status ASC",
            &[Value::str(hostname)],
        )
    }

    /// Q3: node(s) with the most aborted/failed tasks in the last minute.
    pub fn q3_worst_nodes(&self) -> Result<ResultSet> {
        self.q(
            "SELECT n.hostname, COUNT(*) AS failed \
             FROM workqueue t JOIN node n ON t.workerid = n.nodeid \
             WHERE t.status = 'FAILED' AND t.endtime >= NOW() - 60 \
             GROUP BY n.hostname ORDER BY failed DESC, n.hostname LIMIT 3",
        )
    }

    /// Q4: tasks left to execute for a workflow.
    pub fn q4_tasks_left(&self, wfid: i64) -> Result<i64> {
        let rs = self.q_params(
            "SELECT COUNT(*) AS remaining FROM workqueue \
             WHERE wfid = ? AND status != 'FINISHED' AND status != 'FAILED'",
            &[Value::Int(wfid)],
        )?;
        Ok(rs.rows[0].values[0].as_i64().unwrap_or(0))
    }

    /// Q5: for workflows running > 1 minute, the activity with the most
    /// unfinished tasks.
    pub fn q5_busiest_activity(&self) -> Result<ResultSet> {
        self.q(
            "SELECT a.name, COUNT(*) AS unfinished \
             FROM workqueue t \
             JOIN activity a ON t.actid = a.actid \
             JOIN workflow w ON t.wfid = w.wfid \
             WHERE w.status = 'RUNNING' AND w.starttime <= NOW() - 60 \
               AND t.status != 'FINISHED' AND t.status != 'FAILED' \
             GROUP BY a.name ORDER BY unfinished DESC LIMIT 1",
        )
    }

    /// Q6: average and maximum execution time of finished tasks per
    /// unfinished activity.
    pub fn q6_activity_times(&self) -> Result<ResultSet> {
        self.q(
            "SELECT a.name, AVG(t.endtime - t.starttime) AS avg_secs, \
                    MAX(t.endtime - t.starttime) AS max_secs \
             FROM workqueue t JOIN activity a ON t.actid = a.actid \
             WHERE t.status = 'FINISHED' AND a.status != 'FINISHED' \
               AND t.starttime IS NOT NULL AND t.endtime IS NOT NULL \
             GROUP BY a.name ORDER BY avg_secs DESC, max_secs DESC",
        )
    }

    /// Q7: cross activity dataflow query — curvature components (produced by
    /// the pre-processing activity and consumed downstream) plus the raw
    /// stress file path, for wear-and-tear tasks whose `f1 > threshold` and
    /// whose runtime exceeded their activity's average. Assembled from three
    /// statements, as a steering client would.
    pub fn q7_wear_outliers(&self, wear_activity: &str, threshold: f64) -> Result<ResultSet> {
        // average runtime of the wear activity's finished tasks
        let avg = self.q_params(
            "SELECT AVG(t.endtime - t.starttime) AS a FROM workqueue t \
             JOIN activity ac ON t.actid = ac.actid \
             WHERE ac.name = ? AND t.status = 'FINISHED'",
            &[Value::str(wear_activity)],
        )?;
        let avg_secs = avg
            .rows
            .first()
            .and_then(|r| r.values[0].as_f64())
            .unwrap_or(f64::INFINITY);
        // wear tasks over both thresholds, with their consumed curvature
        // (note: a non-finite avg_secs is only representable as a bound
        // value — rendered into SQL text it would not even lex)
        self.q_params(
            "SELECT t.taskid, fx.value AS cx, fy.value AS cy, fz.value AS cz, \
                    ff.value AS f1, rf.path \
             FROM workqueue t \
             JOIN activity ac ON t.actid = ac.actid \
             JOIN taskfield ff ON ff.taskid = t.taskid \
             JOIN taskfield fx ON fx.taskid = t.taskid \
             JOIN taskfield fy ON fy.taskid = t.taskid \
             JOIN taskfield fz ON fz.taskid = t.taskid \
             LEFT JOIN taskdep d ON d.taskid = t.taskid \
             LEFT JOIN file rf ON rf.taskid = d.dep \
             WHERE ac.name = ? AND t.status = 'FINISHED' \
               AND ff.field = 'f1' AND ff.direction = 'out' AND ff.value > ? \
               AND fx.field = 'cx' AND fx.direction = 'in' \
               AND fy.field = 'cy' AND fy.direction = 'in' \
               AND fz.field = 'cz' AND fz.direction = 'in' \
               AND t.endtime - t.starttime > ? \
             ORDER BY f1 DESC",
            &[
                Value::str(wear_activity),
                Value::Float(threshold),
                Value::Float(avg_secs),
            ],
        )
    }

    /// Q8: steering *adaptation* — rewrite an input field of the next READY
    /// tasks of an activity (the paper's "modify the input data for the next
    /// ready tasks for Analyze Risers"). Returns how many fields changed.
    /// Runs as one atomic transaction so workers never see half an update.
    pub fn q8_adapt_ready_inputs(
        &self,
        activity: &str,
        field: &str,
        new_value: f64,
        limit: usize,
    ) -> Result<usize> {
        // find target tasks (READY, of the activity). LIMIT is not a
        // parameter position in the dialect, so only the bound count is
        // rendered into the (cached) statement skeleton; the activity name
        // stays a bound value.
        let sel = format!(
            "SELECT t.taskid FROM workqueue t JOIN activity a ON t.actid = a.actid \
             WHERE a.name = ? AND t.status = 'READY' \
             ORDER BY t.taskid LIMIT {limit}"
        );
        let rs = self.q_params(&sel, &[Value::str(activity)])?;
        if rs.rows.is_empty() {
            return Ok(0);
        }
        let ids: Vec<i64> =
            rs.rows.iter().map(|r| r.values[0].as_i64().unwrap()).collect();
        let upd = self.db.prepare(&format!(
            "UPDATE taskfield SET value = ? \
             WHERE field = ? AND direction = 'in' AND taskid IN ({})",
            in_placeholders(IN_CHUNK)
        ))?;
        let mut n = 0;
        for chunk in padded_chunks(&ids, IN_CHUNK) {
            let mut params = Vec::with_capacity(2 + IN_CHUNK);
            params.push(Value::Float(new_value));
            params.push(Value::str(field));
            params.extend(chunk);
            n += self
                .db
                .exec_prepared(STEERING_NODE, AccessKind::Steering, &upd, &params)?
                .affected();
        }
        Ok(n)
    }

    /// Provenance derivation query: everything a task used and generated.
    pub fn provenance_of(&self, taskid: i64) -> Result<ResultSet> {
        self.q_params(
            "SELECT kind, entity, at FROM provenance WHERE taskid = ? ORDER BY at, kind, entity",
            &[Value::Int(taskid)],
        )
    }

    /// Database footprint summary (the paper's "tens of MB" observation).
    pub fn db_footprint(&self) -> (usize, Vec<(String, usize)>) {
        let tables = self.db.tables();
        let per: Vec<(String, usize)> = tables
            .iter()
            .map(|t| (t.clone(), self.db.table_bytes(t).unwrap_or(0)))
            .collect();
        (per.iter().map(|(_, b)| b).sum(), per)
    }
}

/// A monitoring loop issuing the steering query mix every `interval_secs`
/// until stopped — Experiment 7's "running each query in intervals of 15 s".
pub struct Monitor {
    pub queries_run: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Monitor {
    /// Spawn a monitor thread over `db` firing the full Q1–Q7 mix each
    /// interval (Q8 is an adaptation, not monitoring).
    pub fn spawn(db: Arc<DbCluster>, interval_secs: f64, wfid: i64) -> Monitor {
        let stop = Arc::new(AtomicBool::new(false));
        let queries_run = Arc::new(AtomicU64::new(0));
        let s2 = stop.clone();
        let q2 = queries_run.clone();
        let handle = std::thread::Builder::new()
            .name("steering-monitor".into())
            .spawn(move || {
                let client = SteeringClient::new(db);
                while !s2.load(Ordering::SeqCst) {
                    let _ = client.q1_recent_status_by_node();
                    let _ = client.q2_bytes_by_task("node000");
                    let _ = client.q3_worst_nodes();
                    let _ = client.q4_tasks_left(wfid);
                    let _ = client.q5_busiest_activity();
                    let _ = client.q6_activity_times();
                    let _ = client.q7_wear_outliers("calculate_wear_and_tear", 0.5);
                    q2.fetch_add(7, Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_secs_f64(interval_secs));
                }
            })
            .expect("spawn monitor");
        Monitor { queries_run, stop, handle: Some(handle) }
    }

    /// Stop and join the monitor; returns how many queries it issued.
    pub fn stop(mut self) -> u64 {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.queries_run.load(Ordering::Relaxed)
    }
}

impl Drop for Monitor {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{DChironEngine, EngineConfig};
    use crate::coordinator::payload::{Payload, SyntheticKind};
    use crate::coordinator::workflow::{ActivitySpec, Operator, WorkflowSpec};
    use crate::workload;

    /// Build a finished risers-style database to steer against.
    fn run_risers() -> Arc<DbCluster> {
        let wf = workload::risers_workflow(12);
        let inputs = workload::risers_inputs(12, 99);
        let engine = DChironEngine::new(EngineConfig {
            workers: 2,
            threads_per_worker: 2,
            time_scale: 0.0,
            supervisor_poll_secs: 0.001,
            ..Default::default()
        });
        let running = engine.start(wf, inputs).unwrap();
        let db = running.db.clone();
        running.join().unwrap();
        db
    }

    #[test]
    fn q1_to_q6_shapes() {
        let db = run_risers();
        let c = SteeringClient::new(db);
        let q1 = c.q1_recent_status_by_node().unwrap();
        assert_eq!(q1.columns, vec!["hostname", "status", "tasks", "failure_tries"]);
        assert!(!q1.rows.is_empty());
        let q2 = c.q2_bytes_by_task("node000").unwrap();
        assert_eq!(q2.columns, vec!["taskid", "status", "bytes"]);
        assert!(!q2.rows.is_empty(), "preprocessing emitted files on node000");
        // bytes ordered descending
        let bytes: Vec<f64> =
            q2.rows.iter().map(|r| r.values[2].as_f64().unwrap()).collect();
        assert!(bytes.windows(2).all(|w| w[0] >= w[1]));
        let q3 = c.q3_worst_nodes().unwrap();
        assert!(q3.rows.is_empty(), "no failures expected");
        assert_eq!(c.q4_tasks_left(1).unwrap(), 0);
        // finished workflow -> q5/q6 empty but valid
        c.q5_busiest_activity().unwrap();
        c.q6_activity_times().unwrap();
    }

    #[test]
    fn steering_mix_takes_lock_free_paths() {
        // The Table-2 mix must run on the scatter-gather engine: join
        // queries via parallel snapshot scans, single-table aggregates via
        // partial-aggregate pushdown — never on the 2PL read path that
        // contends with scheduling.
        let db = run_risers();
        let before = db.route_counts();
        let (s0, j0) = (before.scatter, before.snapshot_join);
        let c = SteeringClient::new(db.clone());
        c.q1_recent_status_by_node().unwrap();
        c.q2_bytes_by_task("node000").unwrap();
        c.q3_worst_nodes().unwrap();
        c.q4_tasks_left(1).unwrap();
        c.q5_busiest_activity().unwrap();
        c.q6_activity_times().unwrap();
        c.q7_wear_outliers("calculate_wear_and_tear", 0.5).unwrap();
        let after = db.route_counts();
        let (s1, j1) = (after.scatter, after.snapshot_join);
        assert!(
            j1 - j0 >= 6,
            "Q1–Q3 and Q5–Q7 are joins and must snapshot-join (got {})",
            j1 - j0
        );
        assert!(
            s1 - s0 >= 1,
            "Q4 is a single-table aggregate and must scatter (got {})",
            s1 - s0
        );
    }

    #[test]
    fn quoted_user_input_is_data_not_sql() {
        let db = run_risers();
        let c = SteeringClient::new(db);
        // historical hazard: a single quote in an interpolated hostname or
        // activity name broke the lexer; bound parameters make it inert
        let rs = c.q2_bytes_by_task("o'brien-03").unwrap();
        assert!(rs.rows.is_empty());
        let q7 = c.q7_wear_outliers("it's-not-an-activity", 0.5).unwrap();
        assert!(q7.rows.is_empty());
        assert_eq!(c.q8_adapt_ready_inputs("o'hara", "x", 1.0, 4).unwrap(), 0);
    }

    #[test]
    fn q7_joins_domain_execution_and_files() {
        let db = run_risers();
        let c = SteeringClient::new(db);
        // threshold 0 + avg gate means "slower than average" only; shape check
        let q7 = c.q7_wear_outliers("calculate_wear_and_tear", 0.0).unwrap();
        assert_eq!(q7.columns, vec!["taskid", "cx", "cy", "cz", "f1", "path"]);
        for r in &q7.rows {
            let f1 = r.values[4].as_f64().unwrap();
            assert!(f1 > 0.0);
        }
    }

    #[test]
    fn q8_rewrites_ready_inputs_atomically() {
        // build a db with a workflow still waiting: run only bootstrap
        use crate::coordinator::schema;
        use crate::coordinator::supervisor::{IdGen, Supervisor};
        let db = DbCluster::start(crate::storage::cluster::ClusterConfig::default()).unwrap();
        schema::create_schema(&db, 2).unwrap();
        let wf = WorkflowSpec::new("adapt", 4).activity(
            ActivitySpec::new(
                "analyze_risers",
                Operator::Map,
                Payload::Synthetic { kind: SyntheticKind::Quadratic },
            ),
        );
        let ids = Arc::new(IdGen::default());
        ids.task.store(1, std::sync::atomic::Ordering::Relaxed);
        let mut sup = Supervisor::new(db.clone(), wf, 2, ids, 3);
        sup.bootstrap(&vec![vec![("a".into(), 1.0)]; 4]).unwrap();

        let c = SteeringClient::new(db.clone());
        let changed = c.q8_adapt_ready_inputs("analyze_risers", "a", 9.5, 2).unwrap();
        assert_eq!(changed, 2);
        let rs = db
            .query("SELECT COUNT(*) FROM taskfield WHERE field = 'a' AND value = 9.5")
            .unwrap();
        assert_eq!(rs.rows[0].values[0].as_i64().unwrap(), 2);
    }

    #[test]
    fn provenance_and_footprint() {
        let db = run_risers();
        let c = SteeringClient::new(db);
        // pick a preprocessing task (activity 2): it generated cx/cy/cz
        let rs = c
            .q("SELECT taskid FROM workqueue WHERE actid = 2 ORDER BY taskid LIMIT 1")
            .unwrap();
        let tid = rs.rows[0].values[0].as_i64().unwrap();
        let prov = c.provenance_of(tid).unwrap();
        assert!(prov.rows.iter().any(|r| r.values[0].as_str() == Some("wasGeneratedBy")));
        assert!(prov.rows.iter().any(|r| r.values[0].as_str() == Some("used")));
        let (total, per) = c.db_footprint();
        assert!(total > 0);
        assert!(per.iter().any(|(t, _)| t == "workqueue"));
    }
}
