//! Redo logging for data nodes: per-partition WAL segments with group
//! commit.
//!
//! The paper runs MySQL Cluster fully in-memory with "occasional on-disk
//! checkpoints"; NDB's durability unit is the *fragment* (our partition).
//! Earlier revisions kept one flat per-node log; that made checkpointing a
//! stop-the-world affair and gave a restarting node no way to reason about
//! how far each of its partitions had progressed. The log is now organized
//! as one [`Segment`] per hosted `(table, partition)`:
//!
//! - every committed mutation is a [`WalRecord`]: the redo op plus the
//!   partition's **log sequence number** (the partition version right after
//!   the op applied — dense, per partition) and the **cluster epoch** it
//!   committed under (bumped on every failover promotion; see
//!   `PartitionStore::apply_redo` for the fencing rule);
//! - a commit appends its records to the owning segments and counts one
//!   commit toward the **group commit** window: the buffered sink writers
//!   are flushed once every `group_commit` commits rather than per record,
//!   so the claim loop's point commits amortize the file write;
//! - a checkpoint cut truncates a segment up to the checkpointed LSN; the
//!   retained tail doubles as the **redo-ship stream** a rejoining node
//!   catches up from ([`Segment::tail_since`]).
//!
//! Recovery = load the partition checkpoint + replay the segment tail,
//! stopping cleanly at a torn final line (a crash mid-append must not turn
//! into a parse error).

use crate::storage::value::{Row, Value};
use crate::util::failpoint;
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::fmt::Write as _;
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// One redo record: a row-level mutation on a (table, partition).
///
/// Rows travel as `Arc<Row>` so one materialized row is shared by the
/// transaction's redo list, the WAL append, and (on the fast DML path) the
/// backup apply — committing a point update no longer re-clones the row per
/// consumer.
#[derive(Clone, Debug, PartialEq)]
pub enum LogOp {
    Insert { table: String, pidx: usize, slot: usize, row: Arc<Row> },
    Update { table: String, pidx: usize, slot: usize, row: Arc<Row> },
    Delete { table: String, pidx: usize, slot: usize },
}

impl LogOp {
    pub fn table(&self) -> &str {
        match self {
            LogOp::Insert { table, .. } | LogOp::Update { table, .. } | LogOp::Delete { table, .. } => {
                table
            }
        }
    }

    /// Partition index the op applies to.
    pub fn pidx(&self) -> usize {
        match self {
            LogOp::Insert { pidx, .. }
            | LogOp::Update { pidx, .. }
            | LogOp::Delete { pidx, .. } => *pidx,
        }
    }

    /// Serialize to one line: `kind\ttable\tpidx\tslot\tv1\tv2...`
    pub fn to_line(&self) -> String {
        let mut s = String::new();
        match self {
            LogOp::Insert { table, pidx, slot, row } => {
                let _ = write!(s, "I\t{table}\t{pidx}\t{slot}");
                for v in &row.values {
                    let _ = write!(s, "\t{}", encode_value(v));
                }
            }
            LogOp::Update { table, pidx, slot, row } => {
                let _ = write!(s, "U\t{table}\t{pidx}\t{slot}");
                for v in &row.values {
                    let _ = write!(s, "\t{}", encode_value(v));
                }
            }
            LogOp::Delete { table, pidx, slot } => {
                let _ = write!(s, "D\t{table}\t{pidx}\t{slot}");
            }
        }
        s
    }

    /// Parse one serialized line.
    pub fn from_line(line: &str) -> Result<LogOp> {
        let mut it = line.split('\t');
        let kind = it.next().ok_or_else(|| Error::Parse("empty WAL line".into()))?;
        let table = it
            .next()
            .ok_or_else(|| Error::Parse("WAL line missing table".into()))?
            .to_string();
        let pidx: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("WAL line missing pidx".into()))?;
        let slot: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("WAL line missing slot".into()))?;
        match kind {
            "D" => Ok(LogOp::Delete { table, pidx, slot }),
            "I" | "U" => {
                let values = it.map(decode_value).collect::<Result<Vec<_>>>()?;
                let row = Arc::new(Row::new(values));
                if kind == "I" {
                    Ok(LogOp::Insert { table, pidx, slot, row })
                } else {
                    Ok(LogOp::Update { table, pidx, slot, row })
                }
            }
            other => Err(Error::Parse(format!("bad WAL op '{other}'"))),
        }
    }
}

/// Encode a value for WAL/checkpoint lines. Floats round-trip via hex bits.
pub fn encode_value(v: &Value) -> String {
    match v {
        Value::Null => "N".into(),
        Value::Int(i) => format!("I{i}"),
        Value::Float(f) => format!("F{:016x}", f.to_bits()),
        Value::Bool(b) => format!("B{}", u8::from(*b)),
        Value::Str(s) => {
            let mut out = String::with_capacity(s.len() + 1);
            out.push('S');
            for c in s.chars() {
                match c {
                    '\t' => out.push_str("\\t"),
                    '\n' => out.push_str("\\n"),
                    '\\' => out.push_str("\\\\"),
                    c => out.push(c),
                }
            }
            out
        }
    }
}

/// Decode a WAL/checkpoint value token.
pub fn decode_value(tok: &str) -> Result<Value> {
    let mut chars = tok.chars();
    let tag = chars.next().ok_or_else(|| Error::Parse("empty value token".into()))?;
    let rest = chars.as_str();
    Ok(match tag {
        'N' => Value::Null,
        'I' => Value::Int(rest.parse().map_err(|e| Error::Parse(format!("bad int: {e}")))?),
        'F' => {
            let bits = u64::from_str_radix(rest, 16)
                .map_err(|e| Error::Parse(format!("bad float bits: {e}")))?;
            Value::Float(f64::from_bits(bits))
        }
        'B' => Value::Bool(rest == "1"),
        'S' => {
            let mut s = String::with_capacity(rest.len());
            let mut esc = false;
            for c in rest.chars() {
                if esc {
                    match c {
                        't' => s.push('\t'),
                        'n' => s.push('\n'),
                        '\\' => s.push('\\'),
                        c => s.push(c),
                    }
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else {
                    s.push(c);
                }
            }
            Value::str(s)
        }
        other => return Err(Error::Parse(format!("bad value tag '{other}'"))),
    })
}

/// One redo record as it travels through a segment: the op, the partition
/// LSN right after it applied, and the cluster epoch it committed under.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    pub lsn: u64,
    pub epoch: u64,
    pub op: LogOp,
}

impl WalRecord {
    /// One line: `lsn\tepoch\t<op line>\t#<fnv1a32>`. The trailing checksum
    /// exists for torn-tail detection: a crash can cut the final line at
    /// any byte, and without it a tear inside the last token could still
    /// parse as a valid, shorter record.
    pub fn to_line(&self) -> String {
        let payload = format!("{}\t{}\t{}", self.lsn, self.epoch, self.op.to_line());
        let sum = fnv1a32(payload.as_bytes());
        format!("{payload}\t#{sum:08x}")
    }

    /// Parse one serialized record line, verifying the checksum.
    pub fn from_line(line: &str) -> Result<WalRecord> {
        let (payload, tail) = line
            .rsplit_once('\t')
            .ok_or_else(|| Error::Parse("WAL record missing checksum".into()))?;
        let sum = tail
            .strip_prefix('#')
            .ok_or_else(|| Error::Parse("WAL record missing checksum tag".into()))?;
        let want = u32::from_str_radix(sum, 16)
            .map_err(|e| Error::Parse(format!("bad WAL checksum: {e}")))?;
        let got = fnv1a32(payload.as_bytes());
        if got != want {
            return Err(Error::Parse(format!(
                "WAL checksum mismatch ({got:08x} != {want:08x})"
            )));
        }
        let mut it = payload.splitn(3, '\t');
        let lsn: u64 = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("WAL record missing lsn".into()))?;
        let epoch: u64 = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("WAL record missing epoch".into()))?;
        let rest = it
            .next()
            .ok_or_else(|| Error::Parse("WAL record missing op".into()))?;
        Ok(WalRecord { lsn, epoch, op: LogOp::from_line(rest)? })
    }
}

/// FNV-1a over a record line's payload (fast, no tables, good enough to
/// catch arbitrary-byte tears). Shared with the checkpoint writer, whose
/// trailer checksums the whole file body with the same function.
pub fn fnv1a32(bytes: &[u8]) -> u32 {
    fnv1a32_fold(0x811c_9dc5, bytes)
}

/// Incremental FNV-1a step: fold `bytes` into a running hash `h` (seed it
/// with `fnv1a32(&[])`'s offset via [`fnv1a32`], or chain calls). Lets the
/// checkpoint writer checksum a streamed file without buffering it.
pub fn fnv1a32_fold(mut h: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        h ^= u32::from(b);
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Upper bound on records a segment retains **in memory**. The retained
/// tail is the rejoin redo-ship source; beyond the cap the oldest half is
/// dropped (advancing `base_lsn`), and a replica that fell further behind
/// re-seeds from a snapshot instead. This bounds memory on long-running
/// clusters that never cut checkpoints (the `durability: None` default);
/// the on-disk sink, where configured, keeps everything until a
/// checkpoint truncates it.
const SEGMENT_RETAIN_CAP: usize = 8192;

/// The redo log of one `(table, partition)` replica on one node.
///
/// In memory it retains the recent record tail since the last checkpoint
/// cut (the rejoin catch-up source, bounded by [`SEGMENT_RETAIN_CAP`]); on
/// disk — when the cluster runs with a durability dir — it appends records
/// to `<table>.p<idx>.wal` through a buffered writer that the owning
/// [`NodeWal`] flushes on group-commit boundaries.
pub struct Segment {
    records: Vec<WalRecord>,
    /// Every record with `lsn <= base_lsn` has been truncated by a
    /// checkpoint cut or evicted by the retention cap (or never existed on
    /// this node: a rejoined replica starts its segment at the LSN it
    /// rejoined at).
    base_lsn: u64,
    path: Option<PathBuf>,
    writer: Option<BufWriter<std::fs::File>>,
}

impl Segment {
    fn new(path: Option<PathBuf>) -> Segment {
        Segment { records: Vec::new(), base_lsn: 0, path, writer: None }
    }

    fn append(&mut self, rec: WalRecord) -> Result<()> {
        if let Some(p) = &self.path {
            if self.writer.is_none() {
                let f = std::fs::OpenOptions::new().create(true).append(true).open(p)?;
                self.writer = Some(BufWriter::new(f));
            }
            let w = self.writer.as_mut().expect("segment writer just opened");
            writeln!(w, "{}", rec.to_line())?;
        }
        self.records.push(rec);
        if self.records.len() > SEGMENT_RETAIN_CAP {
            // retention cap: drop the oldest half of the in-memory tail
            // (amortized O(1) per append), keeping base_lsn honest so
            // tail_since reports the gap instead of serving a hole
            self.records.sort_by_key(|r| r.lsn);
            let drop = self.records.len() - SEGMENT_RETAIN_CAP / 2;
            self.base_lsn = self.base_lsn.max(self.records[drop - 1].lsn);
            self.records.drain(..drop);
        }
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        if let Some(w) = self.writer.as_mut() {
            w.flush()?;
        }
        Ok(())
    }

    /// LSN below which nothing is retained.
    pub fn base_lsn(&self) -> u64 {
        self.base_lsn
    }

    /// Highest retained LSN (the base when the tail is empty).
    pub fn max_lsn(&self) -> u64 {
        self.records.iter().map(|r| r.lsn).max().unwrap_or(self.base_lsn)
    }

    /// Retained record count.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The redo-ship stream for a replica whose partition is at `lsn`:
    /// every retained record with a higher LSN, in LSN order. Returns
    /// `None` when the segment cannot cover the gap (the records below
    /// `base_lsn` were truncated by a checkpoint) — the caller must fall
    /// back to a full snapshot re-seed.
    pub fn tail_since(&self, lsn: u64) -> Option<Vec<WalRecord>> {
        if lsn < self.base_lsn {
            return None;
        }
        let mut out: Vec<WalRecord> =
            self.records.iter().filter(|r| r.lsn > lsn).cloned().collect();
        out.sort_by_key(|r| r.lsn);
        Some(out)
    }

    /// Checkpoint cut: drop records with `lsn <= cut`. The sink file is
    /// flushed and rewritten with the retained tail — via a temp file and
    /// an atomic rename, so a crash mid-rewrite leaves either the old or
    /// the new segment file, never a truncated one.
    fn truncate_upto(&mut self, cut: u64) -> Result<()> {
        self.flush()?;
        self.records.retain(|r| r.lsn > cut);
        self.base_lsn = self.base_lsn.max(cut);
        if let Some(p) = &self.path {
            let tmp = p.with_extension("wal.tmp");
            {
                let f = std::fs::File::create(&tmp)?;
                let mut w = BufWriter::new(f);
                for r in &self.records {
                    writeln!(w, "{}", r.to_line())?;
                }
                w.flush()?;
            }
            self.writer = None; // close the old handle before the swap
            std::fs::rename(&tmp, p)?;
            let f = std::fs::OpenOptions::new().create(true).append(true).open(p)?;
            self.writer = Some(BufWriter::new(f));
        }
        Ok(())
    }

    /// Drop the in-memory tail and rebase at `base` without touching the
    /// sink file (rejoin: the file's history was already replayed; the
    /// post-rejoin checkpoint cut rewrites it).
    fn reset(&mut self, base: u64) {
        self.records.clear();
        self.base_lsn = base;
    }

    /// Drop the sink writer **without flushing** its buffered bytes
    /// (`BufWriter`'s own drop would flush them). Crash simulation only —
    /// see [`NodeWal::discard`].
    fn discard_writer(&mut self) {
        if let Some(w) = self.writer.take() {
            let _ = w.into_parts(); // hands the File back unflushed
        }
    }
}

/// A node's write-ahead log: one [`Segment`] per hosted `(table, partition)`
/// plus the group-commit machinery.
///
/// Group commit rule: a commit's records are appended to the in-memory
/// segments immediately (they must be visible to the redo-ship stream), but
/// the buffered sink writers are only flushed once `group_commit` commits
/// have accumulated — batching many small commits into one file write. A
/// checkpoint cut always flushes first (it is the durability boundary).
pub struct NodeWal {
    /// Outer key: the **lowercased** table name (commit streams pass the
    /// lowercased catalog key already, so the hot path looks segments up
    /// by borrowed `&str` with no per-op key allocation).
    segments: FxHashMap<String, FxHashMap<usize, Segment>>,
    dir: Option<PathBuf>,
    group_commit: usize,
    pending: usize,
    /// Commits appended since start (monitoring).
    pub commits: u64,
    /// Sink flushes performed (monitoring; the group-commit ratio).
    pub flushes: u64,
}

impl NodeWal {
    /// Memory-only log (no durability dir configured).
    pub fn new() -> NodeWal {
        NodeWal {
            segments: FxHashMap::default(),
            dir: None,
            group_commit: 1,
            pending: 0,
            commits: 0,
            flushes: 0,
        }
    }

    /// Log with file sinks under `dir` (one file per segment), flushing
    /// every `group_commit` commits.
    pub fn with_dir(dir: PathBuf, group_commit: usize) -> NodeWal {
        NodeWal {
            segments: FxHashMap::default(),
            dir: Some(dir),
            group_commit: group_commit.max(1),
            pending: 0,
            commits: 0,
            flushes: 0,
        }
    }

    // contains_key+insert instead of the entry API on purpose: entry()
    // demands an owned String on every call, which is exactly the per-op
    // allocation this path exists to avoid.
    #[allow(clippy::map_entry)]
    fn segment_mut(&mut self, table: &str, pidx: usize) -> &mut Segment {
        // Commit streams pass the lowercased catalog key, so the common
        // path is borrowed lookups only — no per-op key allocation on the
        // claim loop (PR 3's constraint); mixed-case callers normalize.
        let lower;
        let key: &str = if table.chars().any(char::is_uppercase) {
            lower = table.to_lowercase();
            &lower
        } else {
            table
        };
        if !self.segments.contains_key(key) {
            self.segments.insert(key.to_string(), FxHashMap::default());
        }
        let dir = self.dir.as_deref();
        let per_table = self.segments.get_mut(key).expect("ensured above");
        per_table.entry(pidx).or_insert_with(|| {
            Segment::new(dir.map(|d| d.join(format!("{key}.p{pidx}.wal"))))
        })
    }

    /// Segment of one partition, if any commit or cut created it.
    pub fn segment(&self, table: &str, pidx: usize) -> Option<&Segment> {
        match self.segments.get(table) {
            Some(m) => m.get(&pidx),
            // keys are always lowercase; a miss may be a mixed-case alias
            None => self.segments.get(&table.to_lowercase())?.get(&pidx),
        }
    }

    /// Append one commit's records (`(lsn, op)` pairs, all partitions the
    /// commit touched on this node) under `epoch`, then apply the group
    /// commit rule.
    pub fn commit(&mut self, epoch: u64, ops: &[(u64, LogOp)]) -> Result<()> {
        failpoint::hit("wal-append-before-flush")?;
        for (lsn, op) in ops {
            let rec = WalRecord { lsn: *lsn, epoch, op: op.clone() };
            self.segment_mut(op.table(), op.pidx()).append(rec)?;
        }
        self.commits += 1;
        self.pending += 1;
        if self.pending >= self.group_commit {
            self.flush_all()?;
        }
        Ok(())
    }

    /// Commits appended since the last group-commit boundary (window
    /// occupancy; monitoring).
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// Flush every segment's sink writer (group-commit boundary, shutdown,
    /// checkpoint cut).
    pub fn flush_all(&mut self) -> Result<()> {
        failpoint::hit("wal-flush")?;
        for m in self.segments.values_mut() {
            for s in m.values_mut() {
                s.flush()?;
            }
        }
        if self.dir.is_some() && self.pending > 0 {
            self.flushes += 1;
        }
        self.pending = 0;
        Ok(())
    }

    /// Redo-ship stream for `(table, pidx)` from `lsn` (see
    /// [`Segment::tail_since`]); `None` when the segment does not exist or
    /// cannot cover the gap.
    pub fn tail_since(&self, table: &str, pidx: usize, lsn: u64) -> Option<Vec<WalRecord>> {
        self.segment(table, pidx)?.tail_since(lsn)
    }

    /// Checkpoint cut for one partition: flush, drop records with
    /// `lsn <= cut`, rewrite the sink with the retained tail.
    pub fn truncate_upto(&mut self, table: &str, pidx: usize, cut: u64) -> Result<()> {
        failpoint::hit("wal-truncate")?;
        self.flush_all()?;
        self.segment_mut(table, pidx).truncate_upto(cut)
    }

    /// Rebase one partition's segment at `base` with an empty tail
    /// (rejoin hand-off; the sink file is left for the next checkpoint cut
    /// to rewrite).
    pub fn reset_segment(&mut self, table: &str, pidx: usize, base: u64) {
        self.segment_mut(table, pidx).reset(base);
    }

    /// Retained records across all segments (tests/monitoring).
    pub fn total_records(&self) -> usize {
        self.segments.values().flat_map(|m| m.values()).map(|s| s.len()).sum()
    }

    /// Simulate a **process crash**: throw away every segment's buffered
    /// sink bytes and in-memory tail without flushing anything to disk.
    ///
    /// A real crash loses whatever the group-commit window had buffered
    /// (up to `group_commit - 1` commits); both this struct's `Drop` and
    /// `BufWriter`'s drop flush best-effort, which models a *clean
    /// shutdown*. `DbCluster::restart_node` calls this before replacing
    /// the log so the recovery it then exercises is the one a crash
    /// actually leaves behind, not a silently upgraded stronger one.
    pub fn discard(&mut self) {
        // `discard` is infallible (crash simulation); only Delay/Panic
        // actions are meaningful here.
        let _ = failpoint::hit("wal-discard");
        for m in self.segments.values_mut() {
            for s in m.values_mut() {
                s.discard_writer();
            }
        }
        self.segments.clear();
        self.pending = 0;
    }
}

impl Default for NodeWal {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for NodeWal {
    fn drop(&mut self) {
        // Best-effort: `BufWriter`'s own drop also flushes, but doing it
        // here surfaces the intent (flush on checkpoint *and* shutdown).
        let _ = self.flush_all();
    }
}

/// Read a segment file back, stopping **cleanly** at a torn tail: a crash
/// can truncate the final line mid-byte, and recovery must treat that as
/// "the log ends here", not as corruption. A parse failure that is *not*
/// on the final line is real corruption and errors out.
pub fn read_segment_file(path: &Path) -> Result<Vec<WalRecord>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e.into()),
    };
    let lines: Vec<&str> = text.split('\n').collect();
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.is_empty() {
            continue;
        }
        match WalRecord::from_line(line) {
            Ok(r) => out.push(r),
            Err(e) => {
                let rest_is_tail = lines[i + 1..].iter().all(|l| l.is_empty());
                if rest_is_tail {
                    break; // torn tail: replay stops here
                }
                return Err(e);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Arc<Row> {
        Arc::new(Row::new(vec![
            Value::Int(1),
            Value::Float(2.5),
            Value::str("a\tb\nc\\d"),
            Value::Null,
            Value::Bool(true),
        ]))
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("schaladb-wal-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn logop_line_roundtrip() {
        let ops = vec![
            LogOp::Insert { table: "wq".into(), pidx: 3, slot: 7, row: row() },
            LogOp::Update { table: "wq".into(), pidx: 0, slot: 2, row: row() },
            LogOp::Delete { table: "prov".into(), pidx: 1, slot: 9 },
        ];
        for op in ops {
            let line = op.to_line();
            let back = LogOp::from_line(&line).unwrap();
            assert_eq!(op, back);
        }
    }

    #[test]
    fn walrecord_line_roundtrip() {
        let rec = WalRecord {
            lsn: 42,
            epoch: 3,
            op: LogOp::Insert { table: "wq".into(), pidx: 1, slot: 0, row: row() },
        };
        let back = WalRecord::from_line(&rec.to_line()).unwrap();
        assert_eq!(rec, back);
        assert!(WalRecord::from_line("notanumber\t0\tD\tt\t0\t0").is_err());
        assert!(WalRecord::from_line("1\t0").is_err());
    }

    /// Property-style round-trip across every `Value` variant, including
    /// the quoting/escape edge cases the text format has to survive.
    #[test]
    fn value_roundtrip_all_variants() {
        let mut vals = vec![
            Value::Null,
            Value::Int(0),
            Value::Int(i64::MIN),
            Value::Int(i64::MAX),
            Value::Bool(true),
            Value::Bool(false),
            Value::Float(0.0),
            Value::Float(-0.0),
            Value::Float(f64::INFINITY),
            Value::Float(f64::NEG_INFINITY),
            Value::Float(f64::MAX),
            Value::Float(f64::MIN_POSITIVE),
            Value::Float(1e-300),
            Value::str(""),
            Value::str("plain"),
            Value::str("tab\tnewline\nback\\slash"),
            Value::str("\\t literal backslash-t"),
            Value::str("trailing backslash \\"),
            Value::str("\t\n\\"),
            Value::str("quote ' and double \" and unicode s\u{00e9}quen\u{00e7}e \u{2603}"),
            Value::str("it's; DROP TABLE x -- '"),
        ];
        // a deterministic pseudo-random sweep over escape-heavy strings
        let mut x: u64 = 0x9e3779b97f4a7c15;
        for _ in 0..200 {
            let mut s = String::new();
            for _ in 0..(x % 17) {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let c = match x % 7 {
                    0 => '\t',
                    1 => '\n',
                    2 => '\\',
                    3 => 't',
                    4 => 'n',
                    5 => '\u{00e9}',
                    _ => 'a',
                };
                s.push(c);
            }
            vals.push(Value::str(s));
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        }
        for v in vals {
            let tok = encode_value(&v);
            assert!(!tok.contains('\t') && !tok.contains('\n'), "token must stay one field");
            let back = decode_value(&tok).unwrap();
            assert_eq!(v, back, "round-trip failed for {v:?}");
        }
        // NaN round-trips by bits
        let v = decode_value(&encode_value(&Value::Float(f64::NAN))).unwrap();
        match v {
            Value::Float(f) => assert!(f.is_nan()),
            _ => panic!(),
        }
    }

    #[test]
    fn segment_tail_and_truncate() {
        let mut s = Segment::new(None);
        for lsn in 1..=5u64 {
            s.append(WalRecord {
                lsn,
                epoch: 0,
                op: LogOp::Delete { table: "t".into(), pidx: 0, slot: lsn as usize },
            })
            .unwrap();
        }
        assert_eq!(s.max_lsn(), 5);
        let tail = s.tail_since(2).unwrap();
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[0].lsn, 3);
        assert_eq!(s.tail_since(5).unwrap().len(), 0);
        s.truncate_upto(3).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.base_lsn(), 3);
        // a replica below the base cannot be served from this segment
        assert!(s.tail_since(2).is_none());
        assert_eq!(s.tail_since(3).unwrap().len(), 2);
    }

    #[test]
    fn group_commit_batches_flushes() {
        let dir = tmpdir("group");
        let mut w = NodeWal::with_dir(dir.clone(), 4);
        let op = |lsn: u64| {
            (lsn, LogOp::Delete { table: "t".into(), pidx: 0, slot: lsn as usize })
        };
        for lsn in 1..=3u64 {
            w.commit(0, &[op(lsn)]).unwrap();
        }
        assert_eq!(w.flushes, 0, "3 commits under a group of 4 must not flush");
        w.commit(0, &[op(4)]).unwrap();
        assert_eq!(w.flushes, 1, "4th commit closes the group");
        let text = std::fs::read_to_string(dir.join("t.p0.wal")).unwrap();
        assert_eq!(text.lines().count(), 4);
        // per-op mode flushes every commit
        let dir2 = tmpdir("group1");
        let mut w1 = NodeWal::with_dir(dir2.clone(), 1);
        w1.commit(0, &[op(1)]).unwrap();
        w1.commit(0, &[op(2)]).unwrap();
        assert_eq!(w1.flushes, 2);
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    /// `discard` models a crash: buffered-but-unflushed commits must be
    /// lost, while a plain drop (clean shutdown) flushes them. The two
    /// must differ, or restart simulations verify durability the code
    /// does not provide.
    #[test]
    fn discard_loses_the_buffered_tail_drop_keeps_it() {
        let op = |lsn: u64| (lsn, LogOp::Delete { table: "t".into(), pidx: 0, slot: 0 });
        // clean shutdown: Drop's best-effort flush lands all 3 pending
        let dir = tmpdir("drop-flush");
        {
            let mut w = NodeWal::with_dir(dir.clone(), 8);
            for lsn in 1..=3u64 {
                w.commit(0, &[op(lsn)]).unwrap();
            }
        }
        let text = std::fs::read_to_string(dir.join("t.p0.wal")).unwrap();
        assert_eq!(text.lines().count(), 3, "clean shutdown flushes the pending group");
        // crash: only the closed group-commit boundary (8 commits) is on
        // disk; the 2 buffered commits after it are gone
        let dir2 = tmpdir("discard");
        let mut w = NodeWal::with_dir(dir2.clone(), 8);
        for lsn in 1..=10u64 {
            w.commit(0, &[op(lsn)]).unwrap();
        }
        w.discard();
        drop(w);
        let text = std::fs::read_to_string(dir2.join("t.p0.wal")).unwrap();
        assert_eq!(text.lines().count(), 8, "a crash must lose the unflushed tail, not persist it");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn commits_split_per_partition_segment() {
        let mut w = NodeWal::new();
        w.commit(
            0,
            &[
                (1, LogOp::Delete { table: "t".into(), pidx: 0, slot: 1 }),
                (1, LogOp::Delete { table: "t".into(), pidx: 2, slot: 1 }),
                (1, LogOp::Delete { table: "u".into(), pidx: 0, slot: 1 }),
            ],
        )
        .unwrap();
        assert_eq!(w.segment("t", 0).unwrap().len(), 1);
        assert_eq!(w.segment("t", 2).unwrap().len(), 1);
        assert_eq!(w.segment("u", 0).unwrap().len(), 1);
        assert!(w.segment("t", 1).is_none());
        assert_eq!(w.total_records(), 3);
        // table keys are case-insensitive
        assert_eq!(w.segment("T", 0).unwrap().len(), 1);
    }

    #[test]
    fn truncate_rewrites_sink_with_retained_tail() {
        let dir = tmpdir("trunc");
        let mut w = NodeWal::with_dir(dir.clone(), 1);
        for lsn in 1..=4u64 {
            w.commit(0, &[(lsn, LogOp::Delete { table: "t".into(), pidx: 0, slot: 0 })])
                .unwrap();
        }
        w.truncate_upto("t", 0, 3).unwrap();
        let text = std::fs::read_to_string(dir.join("t.p0.wal")).unwrap();
        assert_eq!(text.lines().count(), 1, "only the post-cut tail survives on disk");
        assert!(text.starts_with("4\t"));
        // appends continue into the rewritten file
        w.commit(0, &[(5, LogOp::Delete { table: "t".into(), pidx: 0, slot: 0 })]).unwrap();
        w.flush_all().unwrap();
        let text = std::fs::read_to_string(dir.join("t.p0.wal")).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_stops_replay_cleanly() {
        let dir = tmpdir("torn");
        let path = dir.join("t.p0.wal");
        let good1 = WalRecord {
            lsn: 1,
            epoch: 0,
            op: LogOp::Insert { table: "t".into(), pidx: 0, slot: 0, row: row() },
        };
        let good2 = WalRecord {
            lsn: 2,
            epoch: 0,
            op: LogOp::Delete { table: "t".into(), pidx: 0, slot: 0 },
        };
        // a full line, then a line torn mid-record (no trailing newline)
        let torn = format!("{}\n{}\n3\t0\tI\tt\t0", good1.to_line(), good2.to_line());
        std::fs::write(&path, torn).unwrap();
        let recs = read_segment_file(&path).unwrap();
        assert_eq!(recs.len(), 2, "replay must stop at the torn tail, not error");
        assert_eq!(recs[0], good1);
        assert_eq!(recs[1], good2);
        // corruption *before* the tail is a real error
        let bad = format!("{}\nGARBAGE LINE\n{}\n", good1.to_line(), good2.to_line());
        std::fs::write(&path, bad).unwrap();
        assert!(read_segment_file(&path).is_err());
        // a missing file is an empty log
        assert!(read_segment_file(&dir.join("absent.wal")).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_catches_inner_tears() {
        let rec = WalRecord {
            lsn: 12,
            epoch: 0,
            op: LogOp::Insert { table: "wq".into(), pidx: 0, slot: 5, row: row() },
        };
        let line = rec.to_line();
        assert_eq!(WalRecord::from_line(&line).unwrap(), rec);
        // a tear that still looks like a structurally valid, shorter line
        // must fail the checksum, not parse as a different record
        let torn = &line[..line.len() - 12];
        assert!(WalRecord::from_line(torn).is_err());
        // flipping one payload byte is caught too
        let corrupt = line.replacen("wq", "wx", 1);
        assert!(WalRecord::from_line(&corrupt).is_err());
    }

    #[test]
    fn retention_cap_bounds_memory_and_reports_gap() {
        let mut s = Segment::new(None);
        for lsn in 1..=(SEGMENT_RETAIN_CAP as u64 + 1) {
            s.append(WalRecord {
                lsn,
                epoch: 0,
                op: LogOp::Delete { table: "t".into(), pidx: 0, slot: 0 },
            })
            .unwrap();
        }
        assert!(s.len() <= SEGMENT_RETAIN_CAP, "cap must bound the retained tail");
        assert!(s.base_lsn() > 0, "eviction must advance the base");
        assert!(s.tail_since(0).is_none(), "an evicted range must read as a gap");
        let tail = s.tail_since(s.base_lsn()).unwrap();
        assert_eq!(tail.len(), s.len());
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(LogOp::from_line("").is_err());
        assert!(LogOp::from_line("X\tt\t0\t0").is_err());
        assert!(LogOp::from_line("I\tt\tnope\t0").is_err());
        assert!(decode_value("Zfoo").is_err());
        assert!(decode_value("Iabc").is_err());
    }
}
