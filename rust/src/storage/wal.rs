//! Redo logging for data nodes.
//!
//! The paper runs MySQL Cluster fully in-memory with "occasional on-disk
//! checkpoints". We mirror that: every committed mutation appends a redo
//! record to the node's WAL buffer; the buffer is only flushed to disk when
//! a checkpoint is cut (or when the caller opts into eager flushing, used by
//! the durability tests). Recovery = load checkpoint + replay the WAL tail.

use crate::storage::value::{Row, Value};
use crate::{Error, Result};
use std::fmt::Write as _;
use std::io::{BufWriter, Write as _};
use std::path::PathBuf;
use std::sync::Arc;

/// One redo record: a row-level mutation on a (table, partition).
///
/// Rows travel as `Arc<Row>` so one materialized row is shared by the
/// transaction's redo list, the WAL append, and (on the fast DML path) the
/// backup apply — committing a point update no longer re-clones the row per
/// consumer.
#[derive(Clone, Debug, PartialEq)]
pub enum LogOp {
    Insert { table: String, pidx: usize, slot: usize, row: Arc<Row> },
    Update { table: String, pidx: usize, slot: usize, row: Arc<Row> },
    Delete { table: String, pidx: usize, slot: usize },
}

impl LogOp {
    pub fn table(&self) -> &str {
        match self {
            LogOp::Insert { table, .. } | LogOp::Update { table, .. } | LogOp::Delete { table, .. } => {
                table
            }
        }
    }

    /// Serialize to one line: `kind\ttable\tpidx\tslot\tv1\tv2...`
    pub fn to_line(&self) -> String {
        let mut s = String::new();
        match self {
            LogOp::Insert { table, pidx, slot, row } => {
                let _ = write!(s, "I\t{table}\t{pidx}\t{slot}");
                for v in &row.values {
                    let _ = write!(s, "\t{}", encode_value(v));
                }
            }
            LogOp::Update { table, pidx, slot, row } => {
                let _ = write!(s, "U\t{table}\t{pidx}\t{slot}");
                for v in &row.values {
                    let _ = write!(s, "\t{}", encode_value(v));
                }
            }
            LogOp::Delete { table, pidx, slot } => {
                let _ = write!(s, "D\t{table}\t{pidx}\t{slot}");
            }
        }
        s
    }

    /// Parse one serialized line.
    pub fn from_line(line: &str) -> Result<LogOp> {
        let mut it = line.split('\t');
        let kind = it.next().ok_or_else(|| Error::Parse("empty WAL line".into()))?;
        let table = it
            .next()
            .ok_or_else(|| Error::Parse("WAL line missing table".into()))?
            .to_string();
        let pidx: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("WAL line missing pidx".into()))?;
        let slot: usize = it
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Parse("WAL line missing slot".into()))?;
        match kind {
            "D" => Ok(LogOp::Delete { table, pidx, slot }),
            "I" | "U" => {
                let values = it.map(decode_value).collect::<Result<Vec<_>>>()?;
                let row = Arc::new(Row::new(values));
                if kind == "I" {
                    Ok(LogOp::Insert { table, pidx, slot, row })
                } else {
                    Ok(LogOp::Update { table, pidx, slot, row })
                }
            }
            other => Err(Error::Parse(format!("bad WAL op '{other}'"))),
        }
    }
}

/// Encode a value for WAL/checkpoint lines. Floats round-trip via hex bits.
pub fn encode_value(v: &Value) -> String {
    match v {
        Value::Null => "N".into(),
        Value::Int(i) => format!("I{i}"),
        Value::Float(f) => format!("F{:016x}", f.to_bits()),
        Value::Bool(b) => format!("B{}", u8::from(*b)),
        Value::Str(s) => {
            let mut out = String::with_capacity(s.len() + 1);
            out.push('S');
            for c in s.chars() {
                match c {
                    '\t' => out.push_str("\\t"),
                    '\n' => out.push_str("\\n"),
                    '\\' => out.push_str("\\\\"),
                    c => out.push(c),
                }
            }
            out
        }
    }
}

/// Decode a WAL/checkpoint value token.
pub fn decode_value(tok: &str) -> Result<Value> {
    let mut chars = tok.chars();
    let tag = chars.next().ok_or_else(|| Error::Parse("empty value token".into()))?;
    let rest = chars.as_str();
    Ok(match tag {
        'N' => Value::Null,
        'I' => Value::Int(rest.parse().map_err(|e| Error::Parse(format!("bad int: {e}")))?),
        'F' => {
            let bits = u64::from_str_radix(rest, 16)
                .map_err(|e| Error::Parse(format!("bad float bits: {e}")))?;
            Value::Float(f64::from_bits(bits))
        }
        'B' => Value::Bool(rest == "1"),
        'S' => {
            let mut s = String::with_capacity(rest.len());
            let mut esc = false;
            for c in rest.chars() {
                if esc {
                    match c {
                        't' => s.push('\t'),
                        'n' => s.push('\n'),
                        '\\' => s.push('\\'),
                        c => s.push(c),
                    }
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else {
                    s.push(c);
                }
            }
            Value::str(s)
        }
        other => return Err(Error::Parse(format!("bad value tag '{other}'"))),
    })
}

/// Per-node write-ahead log: an in-memory buffer with an optional file sink.
pub struct Wal {
    buffer: Vec<LogOp>,
    /// Sequence number of the first op in `buffer` (ops before it were
    /// truncated by a checkpoint).
    base_seq: u64,
    sink: Option<PathBuf>,
    /// Persistent handle to the sink file. The log used to reopen the file
    /// for every appended record — a syscall triplet (open/write/close) on
    /// each committed transaction. The handle is now opened once on first
    /// append and writes go through a `BufWriter` that is flushed at
    /// checkpoint cuts ([`Wal::truncate_before`] / [`Wal::flush_sink`]) and
    /// on drop, matching the paper's "in-memory with occasional on-disk
    /// checkpoints" durability model.
    writer: Option<BufWriter<std::fs::File>>,
}

impl Wal {
    pub fn new() -> Wal {
        Wal { buffer: Vec::new(), base_seq: 0, sink: None, writer: None }
    }

    /// Enable writing appended records to `path` (buffered; see `writer`).
    pub fn with_sink(path: PathBuf) -> Wal {
        Wal { buffer: Vec::new(), base_seq: 0, sink: Some(path), writer: None }
    }

    /// Append a committed op. Returns its sequence number.
    pub fn append(&mut self, op: LogOp) -> Result<u64> {
        if let Some(path) = &self.sink {
            if self.writer.is_none() {
                let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
                self.writer = Some(BufWriter::new(f));
            }
            let w = self.writer.as_mut().expect("sink writer just opened");
            writeln!(w, "{}", op.to_line())?;
        }
        self.buffer.push(op);
        Ok(self.base_seq + self.buffer.len() as u64 - 1)
    }

    /// Flush buffered sink writes to the file (no-op without a sink).
    pub fn flush_sink(&mut self) -> Result<()> {
        if let Some(w) = self.writer.as_mut() {
            w.flush()?;
        }
        Ok(())
    }

    /// Next sequence number to be assigned.
    pub fn next_seq(&self) -> u64 {
        self.base_seq + self.buffer.len() as u64
    }

    /// Ops with sequence numbers >= `from_seq` (the tail to replay on top of
    /// a checkpoint cut at `from_seq`).
    pub fn tail(&self, from_seq: u64) -> &[LogOp] {
        let skip = from_seq.saturating_sub(self.base_seq) as usize;
        &self.buffer[skip.min(self.buffer.len())..]
    }

    /// Drop ops covered by a checkpoint cut at `seq` (all ops < seq). A
    /// checkpoint cut is the durability boundary, so the sink is flushed
    /// first — and a flush failure aborts the cut *before* the in-memory
    /// buffer (the only other copy of those records) is drained.
    pub fn truncate_before(&mut self, seq: u64) -> Result<()> {
        self.flush_sink()?;
        let drop = seq.saturating_sub(self.base_seq) as usize;
        let drop = drop.min(self.buffer.len());
        self.buffer.drain(..drop);
        self.base_seq += drop as u64;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.buffer.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffer.is_empty()
    }
}

impl Default for Wal {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for Wal {
    fn drop(&mut self) {
        // Best-effort: `BufWriter`'s own drop also flushes, but doing it
        // here surfaces the intent (flush on checkpoint *and* shutdown).
        let _ = self.flush_sink();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> Arc<Row> {
        Arc::new(Row::new(vec![
            Value::Int(1),
            Value::Float(2.5),
            Value::str("a\tb\nc\\d"),
            Value::Null,
            Value::Bool(true),
        ]))
    }

    #[test]
    fn logop_line_roundtrip() {
        let ops = vec![
            LogOp::Insert { table: "wq".into(), pidx: 3, slot: 7, row: row() },
            LogOp::Update { table: "wq".into(), pidx: 0, slot: 2, row: row() },
            LogOp::Delete { table: "prov".into(), pidx: 1, slot: 9 },
        ];
        for op in ops {
            let line = op.to_line();
            let back = LogOp::from_line(&line).unwrap();
            assert_eq!(op, back);
        }
    }

    #[test]
    fn float_bits_roundtrip_exactly() {
        for f in [0.1, -0.0, f64::MAX, f64::MIN_POSITIVE, 1e-300] {
            let v = decode_value(&encode_value(&Value::Float(f))).unwrap();
            assert_eq!(v, Value::Float(f));
        }
        // NaN round-trips by bits
        let v = decode_value(&encode_value(&Value::Float(f64::NAN))).unwrap();
        match v {
            Value::Float(f) => assert!(f.is_nan()),
            _ => panic!(),
        }
    }

    #[test]
    fn wal_seq_tail_truncate() {
        let mut w = Wal::new();
        for i in 0..5 {
            let seq = w
                .append(LogOp::Delete { table: "t".into(), pidx: 0, slot: i })
                .unwrap();
            assert_eq!(seq, i as u64);
        }
        assert_eq!(w.next_seq(), 5);
        assert_eq!(w.tail(2).len(), 3);
        w.truncate_before(3).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.next_seq(), 5);
        assert_eq!(w.tail(3).len(), 2);
        assert_eq!(w.tail(0).len(), 2); // clamped
    }

    #[test]
    fn wal_file_sink_appends_lines() {
        let dir = std::env::temp_dir().join(format!("schaladb-wal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("node0.wal");
        let _ = std::fs::remove_file(&path);
        {
            let mut w = Wal::with_sink(path.clone());
            w.append(LogOp::Delete { table: "t".into(), pidx: 0, slot: 1 }).unwrap();
            w.append(LogOp::Insert { table: "t".into(), pidx: 0, slot: 1, row: row() })
                .unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("D\t"));
        assert!(lines[1].starts_with("I\t"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sink_flushes_on_checkpoint_cut_and_explicitly() {
        let dir = std::env::temp_dir().join(format!("schaladb-walbuf-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("buf.wal");
        let _ = std::fs::remove_file(&path);
        let mut w = Wal::with_sink(path.clone());
        w.append(LogOp::Delete { table: "t".into(), pidx: 0, slot: 1 }).unwrap();
        // a checkpoint cut is a durability boundary: the record must be on
        // disk afterwards even though the writer is buffered
        w.truncate_before(1).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 1);
        w.append(LogOp::Delete { table: "t".into(), pidx: 0, slot: 2 }).unwrap();
        w.flush_sink().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        drop(w);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(LogOp::from_line("").is_err());
        assert!(LogOp::from_line("X\tt\t0\t0").is_err());
        assert!(LogOp::from_line("I\tt\tnope\t0").is_err());
        assert!(decode_value("Zfoo").is_err());
        assert!(decode_value("Iabc").is_err());
    }
}
