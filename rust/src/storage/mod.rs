//! The distributed in-memory relational engine underpinning SchalaDB.
//!
//! This is our from-scratch substitute for MySQL Cluster (see DESIGN.md
//! §Substitutions): tables are hash-partitioned on a declared column, each
//! partition has one primary and one backup replica assigned to *data
//! nodes*, statements route through *connectors*, point transactions take
//! per-partition latches, multi-partition writes go through a two-phase
//! commit, and all of it sits behind a small SQL dialect so the workflow
//! engine and the steering layer share one query path — exactly the
//! integration the paper argues for.

pub mod cexpr;
pub mod checkpoint;
pub mod cluster;
pub mod connector;
pub mod datanode;
pub mod dml_plan;
pub mod partition;
pub mod prepared;
pub mod replication;
pub mod sql;
pub mod stats;

pub mod table_def;
pub mod txn;
pub mod value;
pub mod wal;

pub use cluster::{
    AdviceAction, ClusterConfig, ClusterConfigBuilder, ConcurrencyMode, DbCluster,
    DurabilityConfig, NodeInfo, PartitionInfo, RejoinStart, TableTopology, Topology,
    TopologyAdvice,
};
pub use connector::Connector;
pub use datanode::NodeState;
pub use prepared::Prepared;
pub use replication::{AvailabilityManager, SweepReport};
pub use stats::{AccessKind, StatsRegistry};
pub use table_def::TableDef;
pub use value::{ColumnType, Row, Schema, Value};

/// Result set returned by `SELECT`; column names plus materialized rows.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ResultSet {
    pub columns: Vec<String>,
    pub rows: Vec<Row>,
}

impl ResultSet {
    /// Index of a named output column.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    /// Value at (row, named column); `None` when either is missing.
    pub fn get(&self, row: usize, name: &str) -> Option<&Value> {
        let c = self.col(name)?;
        self.rows.get(row)?.values.get(c)
    }

    /// Render as an aligned text table (steering CLI output).
    pub fn render(&self) -> String {
        let header: Vec<&str> = self.columns.iter().map(|s| s.as_str()).collect();
        let rows: Vec<Vec<String>> = self
            .rows
            .iter()
            .map(|r| r.values.iter().map(|v| v.to_string()).collect())
            .collect();
        crate::util::render_table(&header, &rows)
    }
}

/// Outcome of any SQL statement.
#[derive(Clone, Debug, PartialEq)]
pub enum StatementResult {
    /// Rows from a SELECT.
    Rows(ResultSet),
    /// Row count affected by INSERT/UPDATE/DELETE.
    Affected(usize),
    /// DDL acknowledgement.
    Ok,
}

impl StatementResult {
    /// Unwrap rows, panicking with context otherwise (test/driver helper).
    pub fn rows(self) -> ResultSet {
        match self {
            StatementResult::Rows(r) => r,
            other => panic!("expected rows, got {other:?}"),
        }
    }

    /// Unwrap affected-row count.
    pub fn affected(self) -> usize {
        match self {
            StatementResult::Affected(n) => n,
            other => panic!("expected affected count, got {other:?}"),
        }
    }
}
