//! Compiled DML physical plans: bind-to-plan execution for point operations.
//!
//! The paper's scheduling transitions (`updateToRUNNING`, `updateToFINISHED`,
//! the provenance inserts, `getREADYtasks`) are *transaction-oriented point
//! operations*: a predictable statement shape executed millions of times with
//! only the bound values changing. Re-walking the AST for every call — clone
//! + parameter substitution, per-call lock-set hashmaps, per-call expression
//! binding — is pure interpretive overhead on exactly the path the paper says
//! must stay negligible (§3.2, up to 960 concurrent cores). MySQL Cluster
//! sidesteps it with NDB's precompiled key-operation API; this module is our
//! equivalent.
//!
//! At [`DbCluster::prepare`](crate::storage::cluster::DbCluster::prepare)
//! time, [`compile`] classifies the statement shape:
//!
//! | shape                                                | plan             |
//! |------------------------------------------------------|------------------|
//! | `UPDATE t SET c = e, ... WHERE conj [ORDER BY cols] [LIMIT n] [RETURNING cols]` | [`UpdatePlan`] |
//! | `DELETE FROM t WHERE conj`                           | [`DeletePlan`]   |
//! | `INSERT INTO t (...) VALUES (tuple)` (single row)    | [`InsertPlan`]   |
//! | `SELECT cols FROM t WHERE conj [ORDER BY cols] [LIMIT n]` (single-partition routable) | [`SelectPlan`] |
//!
//! where `conj` is a conjunction of `col <cmp> literal-or-param` predicates.
//! The compiled plan holds resolved column indices, a [`Conjunct`] predicate
//! evaluator, compiled [`CExpr`] assignment expressions, and a partition
//! [`Route`] over parameter positions — everything the executor needs to go
//! from bound values straight to the pruned partition with no AST in sight.
//!
//! A compiled plan feeds two executors: the 2PL fast path (write latches
//! held for the whole statement) and, when the cluster runs with
//! [`ConcurrencyMode::Occ`](crate::storage::cluster::ConcurrencyMode) and
//! the plan is a PK-probe point `UPDATE`/`DELETE` on a single partition,
//! the optimistic path (read + compute off-lock, per-row versioned
//! validation in a short commit section, 2PL fallback on repeated
//! conflict). Statements that do not fit a fast shape compile to `None`
//! and keep executing through the interpreted `exec_txn` path, which
//! remains the semantic reference for both (see `tests/dml_fastpath.rs`
//! and `tests/occ_equivalence.rs` for the differential property tests,
//! and DESIGN.md §"Concurrency control" for tier dispatch and fallback
//! rules).

use crate::storage::cexpr::{compile_where, resolve_col};
use crate::storage::sql::ast::{Expr, Op, SelectItem, SelectStmt, Statement, TableRef};
use crate::storage::table_def::TableDef;
use crate::storage::value::Value;

// The compiled evaluators were extracted to `storage::cexpr` when the
// scatter-gather scan engine became their second consumer (zone-map chunk
// pruning + compiled row filters); re-exported here so the fast-path plan
// types keep reading naturally.
pub use crate::storage::cexpr::{CExpr, CVal, Conjunct};

/// The partition-routing recipe: how bound values select the partitions a
/// plan touches. Mirrors the interpreter's `prune_partitions` (which only
/// prunes on an integer pin of the partition column).
#[derive(Clone, Debug)]
pub enum Route {
    /// Single-partition table: always partition 0.
    Single,
    /// `partition_col = <int literal>` — the literal key is stored and the
    /// partition computed against the **live** def at execution time, so a
    /// cached plan keeps routing correctly after an online partition split
    /// changes the key→partition map.
    Pinned(i64),
    /// `partition_col = ?i` — partition computed from the bound value.
    ByParam(usize),
    /// No pinning conjunct: every partition (writes lock all of them, like
    /// the interpreter; SELECT plans never compile to this on
    /// multi-partition tables — those route to the scatter engine instead).
    All,
}

impl Route {
    /// Resolve to a sorted partition list, or `None` when a `ByParam` bind
    /// is not an integer (the caller falls back to the interpreted path,
    /// which handles the degenerate cases).
    pub fn resolve(&self, def: &TableDef, params: &[Value]) -> Option<Vec<usize>> {
        Some(match self {
            Route::Single => vec![0],
            Route::Pinned(k) => vec![def.partition_of_key(*k)],
            Route::ByParam(i) => match params.get(*i) {
                Some(Value::Int(k)) => vec![def.partition_of_key(*k)],
                _ => return None,
            },
            Route::All => (0..def.num_partitions()).collect(),
        })
    }
}

/// The index access path used to find candidate rows within a partition.
#[derive(Clone, Debug)]
pub enum Probe {
    /// Primary-key point lookup.
    Pk(CVal),
    /// Secondary-index equality on schema column `col`.
    Index { col: usize, val: CVal },
    /// No usable equality conjunct: scan the routed partitions.
    Scan,
}

/// Compiled point/batch UPDATE.
#[derive(Clone, Debug)]
pub struct UpdatePlan {
    /// Catalog key (lowercased table name).
    pub table: String,
    pub route: Route,
    pub probe: Probe,
    /// Full WHERE re-check (probe candidates may be hash-collision
    /// superset).
    pub preds: Vec<Conjunct>,
    /// `(schema column, value expression)` per SET clause; never touches
    /// the partition column (those statements stay interpreted).
    pub sets: Vec<(usize, CExpr)>,
    /// ORDER BY over plain columns (schema index, ascending).
    pub order: Vec<(usize, bool)>,
    pub limit: Option<u64>,
    /// RETURNING projection: `(schema column, output name)`.
    pub returning: Option<Vec<(usize, String)>>,
}

/// Compiled point DELETE.
#[derive(Clone, Debug)]
pub struct DeletePlan {
    pub table: String,
    pub route: Route,
    pub probe: Probe,
    pub preds: Vec<Conjunct>,
}

/// Compiled single-row INSERT template (also executed per row for prepared
/// batches).
#[derive(Clone, Debug)]
pub struct InsertPlan {
    pub table: String,
    /// One expression per schema column (unlisted columns insert NULL).
    pub row: Vec<CExpr>,
    /// PK uniqueness must be checked in sibling partitions (PK is not the
    /// partition key on a multi-partition table). The fast path takes
    /// *read* latches on the sibling partitions for the check, where the
    /// interpreter write-locks the whole table.
    pub cross_partition_pk: bool,
}

/// Compiled indexed-equality SELECT (the `getREADYtasks` shape). Only
/// single-partition-routable statements compile — multi-partition reads
/// belong to the scatter-gather engine.
#[derive(Clone, Debug)]
pub struct SelectPlan {
    pub table: String,
    pub route: Route,
    pub probe: Probe,
    pub preds: Vec<Conjunct>,
    /// Projection: `(schema column, output name)`.
    pub cols: Vec<(usize, String)>,
    pub order: Vec<(usize, bool)>,
    pub limit: Option<u64>,
}

/// A compiled physical plan for one fast statement shape.
#[derive(Clone, Debug)]
pub enum DmlPlan {
    Update(UpdatePlan),
    Delete(DeletePlan),
    Insert(InsertPlan),
    Select(SelectPlan),
}

impl DmlPlan {
    /// Short tag for diagnostics and `Prepared::describe`.
    pub fn kind(&self) -> &'static str {
        match self {
            DmlPlan::Update(_) => "fast point update",
            DmlPlan::Delete(_) => "fast point delete",
            DmlPlan::Insert(_) => "fast insert",
            DmlPlan::Select(_) => "fast indexed select",
        }
    }
}

/// Classify `stmt` into a fast physical plan, or `None` when it must run
/// interpreted. `lookup` resolves a table name against the live catalog.
pub fn compile(
    stmt: &Statement,
    lookup: impl Fn(&str) -> Option<std::sync::Arc<TableDef>>,
) -> Option<DmlPlan> {
    match stmt {
        Statement::Update { table, sets, where_, order_by, limit, returning } => {
            let def = lookup(&table.table)?;
            compile_update(&def, table, sets, where_, order_by, *limit, returning)
        }
        Statement::Delete { table, where_ } => {
            let def = lookup(&table.table)?;
            compile_delete(&def, table, where_)
        }
        Statement::Insert { table, columns, values } => {
            let def = lookup(table)?;
            compile_insert(&def, columns, values)
        }
        Statement::Select(s) => {
            let def = lookup(&s.from.table)?;
            compile_select(&def, s)
        }
        Statement::CreateTable { .. } => None,
    }
}

/// Routing recipe from the compiled conjuncts (mirrors `prune_partitions`:
/// only an integer pin of the partition column prunes).
fn route_of(def: &TableDef, preds: &[Conjunct]) -> Route {
    if def.num_partitions() <= 1 {
        return Route::Single;
    }
    if let Some(ci) = def.partition_col_idx() {
        for c in preds {
            if c.col == ci && c.op == Op::Eq {
                match &c.rhs {
                    CVal::Lit(Value::Int(k)) => return Route::Pinned(*k),
                    CVal::Param(i) => return Route::ByParam(*i),
                    CVal::Lit(_) => {}
                }
            }
        }
    }
    Route::All
}

/// Access-path choice from the compiled conjuncts (mirrors
/// `index_probe_for`: the first equality pin of an indexed-or-PK column).
fn probe_of(def: &TableDef, preds: &[Conjunct]) -> Probe {
    for c in preds {
        if c.op != Op::Eq {
            continue;
        }
        let name = &def.schema.columns[c.col].name;
        if def.indexes.iter().any(|x| x.eq_ignore_ascii_case(name)) {
            return Probe::Index { col: c.col, val: c.rhs.clone() };
        }
        if def.pk_idx() == Some(c.col) {
            return Probe::Pk(c.rhs.clone());
        }
    }
    Probe::Scan
}

/// Compile a scalar expression. `cols` enables column references (UPDATE
/// SET reads the old row); INSERT templates pass `None`, since the
/// interpreter evaluates them against an empty layout.
fn compile_expr(e: &Expr, cols: Option<(&TableDef, &str)>) -> Option<CExpr> {
    Some(match e {
        Expr::Lit(v) => CExpr::Lit(v.clone()),
        Expr::Param(i) => CExpr::Param(*i),
        Expr::Col { table, name } => {
            let (def, binding) = cols?;
            CExpr::Col(resolve_col(def, binding, table, name)?)
        }
        Expr::Func { name, args } if name == "NOW" && args.is_empty() => CExpr::Now,
        Expr::Unary(op, x) => match op {
            Op::Not | Op::Neg => CExpr::Unary(*op, Box::new(compile_expr(x, cols)?)),
            _ => return None,
        },
        Expr::Binary(op, a, b) => match op {
            Op::Add
            | Op::Sub
            | Op::Mul
            | Op::Div
            | Op::Mod
            | Op::Eq
            | Op::Ne
            | Op::Lt
            | Op::Le
            | Op::Gt
            | Op::Ge
            | Op::And
            | Op::Or => CExpr::Binary(
                *op,
                Box::new(compile_expr(a, cols)?),
                Box::new(compile_expr(b, cols)?),
            ),
            _ => return None,
        },
        Expr::Case { arms, else_ } => CExpr::Case {
            arms: arms
                .iter()
                .map(|(c, v)| Some((compile_expr(c, cols)?, compile_expr(v, cols)?)))
                .collect::<Option<Vec<_>>>()?,
            else_: match else_ {
                Some(x) => Some(Box::new(compile_expr(x, cols)?)),
                None => None,
            },
        },
        _ => return None,
    })
}

#[allow(clippy::too_many_arguments)]
fn compile_update(
    def: &TableDef,
    table: &TableRef,
    sets: &[(String, Expr)],
    where_: &Option<Expr>,
    order_by: &[(Expr, bool)],
    limit: Option<u64>,
    returning: &Option<Vec<SelectItem>>,
) -> Option<DmlPlan> {
    let binding = table.binding();
    let preds = compile_where(where_.as_ref(), def, binding)?;
    let mut csets = Vec::with_capacity(sets.len());
    for (name, e) in sets {
        // exact-name resolution like the interpreter's executor: a miss
        // there is a catalog error, so a miss here must fall back.
        let ci = def.schema.index_of(name)?;
        if def.partition_col_idx() == Some(ci) {
            // rewriting the partition key can move rows across partitions;
            // that machinery stays on the interpreted path
            return None;
        }
        csets.push((ci, compile_expr(e, Some((def, binding)))?));
    }
    let mut order = Vec::with_capacity(order_by.len());
    for (e, asc) in order_by {
        let Expr::Col { table: q, name } = e else { return None };
        order.push((resolve_col(def, binding, q, name)?, *asc));
    }
    let ret = match returning {
        None => None,
        Some(items) => Some(compile_projection(def, binding, items, None)?),
    };
    Some(DmlPlan::Update(UpdatePlan {
        table: def.name.to_lowercase(),
        route: route_of(def, &preds),
        probe: probe_of(def, &preds),
        preds,
        sets: csets,
        order,
        limit,
        returning: ret,
    }))
}

fn compile_delete(def: &TableDef, table: &TableRef, where_: &Option<Expr>) -> Option<DmlPlan> {
    let binding = table.binding();
    let preds = compile_where(where_.as_ref(), def, binding)?;
    Some(DmlPlan::Delete(DeletePlan {
        table: def.name.to_lowercase(),
        route: route_of(def, &preds),
        probe: probe_of(def, &preds),
        preds,
    }))
}

fn compile_insert(def: &TableDef, columns: &[String], values: &[Vec<Expr>]) -> Option<DmlPlan> {
    if values.len() != 1 {
        return None;
    }
    let schema = &def.schema;
    let col_indices: Vec<usize> = if columns.is_empty() {
        (0..schema.len()).collect()
    } else {
        columns
            .iter()
            .map(|c| schema.index_of(c))
            .collect::<Option<Vec<_>>>()?
    };
    let tuple = &values[0];
    if tuple.len() != col_indices.len() {
        return None; // arity error: let the interpreter raise it
    }
    let mut row: Vec<CExpr> = (0..schema.len()).map(|_| CExpr::Lit(Value::Null)).collect();
    for (e, ci) in tuple.iter().zip(&col_indices) {
        row[*ci] = compile_expr(e, None)?;
    }
    let cross_partition_pk = match def.pk_idx() {
        Some(pk) => def.num_partitions() > 1 && def.partition_col_idx() != Some(pk),
        None => false,
    };
    Some(DmlPlan::Insert(InsertPlan {
        table: def.name.to_lowercase(),
        row,
        cross_partition_pk,
    }))
}

fn compile_select(def: &TableDef, s: &SelectStmt) -> Option<DmlPlan> {
    if !s.joins.is_empty() || !s.group_by.is_empty() || s.having.is_some() {
        return None;
    }
    let binding = s.from.binding();
    let preds = compile_where(s.where_.as_ref(), def, binding)?;
    let route = route_of(def, &preds);
    if matches!(route, Route::All) && def.num_partitions() > 1 {
        // multi-partition reads belong to the scatter-gather engine
        return None;
    }
    // select aliases are visible to ORDER BY in the interpreter; collect
    // them so alias-shadowed order keys fall back rather than mis-sort
    let mut aliases: Vec<&str> = Vec::new();
    let cols = compile_projection(def, binding, &s.items, Some(&mut aliases))?;
    let mut order = Vec::with_capacity(s.order_by.len());
    for (e, asc) in &s.order_by {
        let Expr::Col { table: q, name } = e else { return None };
        if q.is_none() && aliases.iter().any(|a| a.eq_ignore_ascii_case(name)) {
            return None;
        }
        order.push((resolve_col(def, binding, q, name)?, *asc));
    }
    Some(DmlPlan::Select(SelectPlan {
        table: def.name.to_lowercase(),
        route,
        probe: probe_of(def, &preds),
        preds,
        cols,
        order,
        limit: s.limit,
    }))
}

/// Compile a projection of plain columns / wildcards, mirroring the
/// interpreter's output naming (alias wins, else the name as written;
/// wildcards expand to schema order). Aliases are collected into the
/// caller's sink when one is provided (SELECT needs them for the ORDER BY
/// alias-shadowing check; UPDATE RETURNING does not).
fn compile_projection<'a>(
    def: &TableDef,
    binding: &str,
    items: &'a [SelectItem],
    mut aliases: Option<&mut Vec<&'a str>>,
) -> Option<Vec<(usize, String)>> {
    let mut cols = Vec::new();
    for it in items {
        match it {
            SelectItem::Wildcard(q) => {
                if let Some(q) = q {
                    if !q.eq_ignore_ascii_case(binding) {
                        return None;
                    }
                }
                for (ci, c) in def.schema.columns.iter().enumerate() {
                    cols.push((ci, c.name.clone()));
                }
            }
            SelectItem::Expr { expr: Expr::Col { table: q, name }, alias } => {
                let ci = resolve_col(def, binding, q, name)?;
                if let Some(sink) = aliases.as_mut() {
                    if let Some(a) = alias.as_deref() {
                        sink.push(a);
                    }
                }
                cols.push((ci, alias.clone().unwrap_or_else(|| name.clone())));
            }
            _ => return None,
        }
    }
    Some(cols)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::sql::parse_prepared;
    use crate::storage::value::{ColumnType, Schema};
    use std::sync::Arc;

    fn wq_def() -> Arc<TableDef> {
        let schema = Schema::of(&[
            ("taskid", ColumnType::Int),
            ("workerid", ColumnType::Int),
            ("status", ColumnType::Str),
            ("failtries", ColumnType::Int),
            ("starttime", ColumnType::Float),
        ]);
        Arc::new(
            TableDef::new("workqueue", schema)
                .partition_by_hash("workerid", 4)
                .unwrap()
                .with_primary_key("taskid")
                .unwrap()
                .with_index("status")
                .unwrap(),
        )
    }

    fn compile_sql(sql: &str) -> Option<DmlPlan> {
        let (stmt, _) = parse_prepared(sql).unwrap();
        compile(&stmt, |_| Some(wq_def()))
    }

    #[test]
    fn claim_shape_compiles_to_point_update() {
        let plan = compile_sql(
            "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
             WHERE taskid = ? AND status = 'READY' AND workerid = ?",
        )
        .expect("claim must classify");
        let DmlPlan::Update(u) = plan else { panic!("expected update plan") };
        assert!(matches!(u.probe, Probe::Pk(CVal::Param(0))), "{:?}", u.probe);
        assert!(matches!(u.route, Route::ByParam(1)), "{:?}", u.route);
        assert_eq!(u.preds.len(), 3);
        assert_eq!(u.sets.len(), 2);
        assert!(u.returning.is_none());
    }

    #[test]
    fn get_ready_shape_compiles_to_indexed_select() {
        let plan = compile_sql(
            "SELECT taskid, status FROM workqueue \
             WHERE workerid = ? AND status = 'READY' ORDER BY taskid LIMIT 4",
        )
        .expect("getREADYtasks must classify");
        let DmlPlan::Select(s) = plan else { panic!("expected select plan") };
        assert!(matches!(s.route, Route::ByParam(0)), "{:?}", s.route);
        assert!(matches!(s.probe, Probe::Index { col: 2, .. }), "{:?}", s.probe);
        assert_eq!(s.order, vec![(0, true)]);
        assert_eq!(s.limit, Some(4));
        assert_eq!(s.cols.len(), 2);
        assert_eq!(s.cols[0].1, "taskid");
    }

    #[test]
    fn insert_template_compiles_with_cross_partition_pk() {
        let plan = compile_sql(
            "INSERT INTO workqueue (taskid, workerid, status) VALUES (?, ?, 'READY')",
        )
        .expect("single-row insert must classify");
        let DmlPlan::Insert(i) = plan else { panic!("expected insert plan") };
        assert!(i.cross_partition_pk, "pk != partition key on 4 partitions");
        assert_eq!(i.row.len(), 5, "template covers the whole schema");
    }

    #[test]
    fn unsupported_shapes_fall_back() {
        // OR is not a conjunction of simple predicates
        assert!(compile_sql(
            "UPDATE workqueue SET status = 'X' WHERE taskid = ? OR workerid = ?"
        )
        .is_none());
        // IN lists stay interpreted
        assert!(
            compile_sql("UPDATE workqueue SET status = 'X' WHERE taskid IN (?, ?)").is_none()
        );
        // rewriting the partition column can move rows across partitions
        assert!(compile_sql("UPDATE workqueue SET workerid = ? WHERE taskid = ?").is_none());
        // aggregates belong to the scatter engine
        assert!(compile_sql("SELECT COUNT(*) FROM workqueue WHERE workerid = ?").is_none());
        // multi-partition scans are the scatter engine's job too
        assert!(compile_sql("SELECT taskid FROM workqueue WHERE status = ?").is_none());
        // scalar functions other than NOW() stay interpreted
        assert!(
            compile_sql("UPDATE workqueue SET status = UPPER(status) WHERE taskid = ?").is_none()
        );
        // multi-row VALUES lists stay interpreted
        assert!(compile_sql(
            "INSERT INTO workqueue (taskid, workerid, status) VALUES (1, 1, 'R'), (2, 2, 'R')"
        )
        .is_none());
    }

    #[test]
    fn order_by_alias_shadowing_falls_back() {
        // `ORDER BY status` names the alias, which the interpreter
        // substitutes with `taskid`; the fast path must refuse the shape
        // rather than sort by the real `status` column.
        assert!(compile_sql(
            "SELECT taskid AS status FROM workqueue WHERE workerid = ? ORDER BY status"
        )
        .is_none());
    }

    #[test]
    fn compiled_case_and_arith_match_interpreter_semantics() {
        let plan = compile_sql(
            "UPDATE workqueue SET failtries = failtries + 1, \
             status = CASE WHEN failtries + 1 >= ? THEN 'FAILED' ELSE 'READY' END \
             WHERE taskid = ? AND workerid = ?",
        )
        .expect("retry bookkeeping must classify");
        let DmlPlan::Update(u) = plan else { panic!("expected update plan") };
        let row = vec![
            Value::Int(7),
            Value::Int(1),
            Value::str("RUNNING"),
            Value::Int(2),
            Value::Null,
        ];
        let params = vec![Value::Int(3), Value::Int(7), Value::Int(1)];
        // failtries 2 -> 3; 3 >= 3 -> FAILED
        let (ci0, e0) = &u.sets[0];
        assert_eq!(*ci0, 3);
        assert_eq!(e0.eval(&row, &params, 0.0).unwrap(), Value::Int(3));
        let (ci1, e1) = &u.sets[1];
        assert_eq!(*ci1, 2);
        assert_eq!(e1.eval(&row, &params, 0.0).unwrap(), Value::str("FAILED"));
        // one retry earlier: 1 + 1 < 3 -> READY
        let row2 = vec![
            Value::Int(7),
            Value::Int(1),
            Value::str("RUNNING"),
            Value::Int(1),
            Value::Null,
        ];
        assert_eq!(e1.eval(&row2, &params, 0.0).unwrap(), Value::str("READY"));
    }

    #[test]
    fn conjuncts_use_sql_3vl() {
        let plan =
            compile_sql("UPDATE workqueue SET status = 'X' WHERE taskid = ? AND workerid = ?")
                .unwrap();
        let DmlPlan::Update(u) = plan else { panic!() };
        let row = vec![Value::Int(1), Value::Null, Value::str("R"), Value::Int(0), Value::Null];
        let params = vec![Value::Int(1), Value::Int(0)];
        assert!(u.preds[0].matches(&row, &params), "taskid pins");
        assert!(!u.preds[1].matches(&row, &params), "NULL never matches");
    }
}
