//! Compiled predicate/expression evaluators, shared by the DML fast path
//! and the scatter-gather scan engine.
//!
//! [`Conjunct`] and [`CExpr`] started life inside `storage::dml_plan` as
//! the claim loop's bind-to-physical-plan evaluators. The chunked-snapshot
//! work gave the analytical scan path a second consumer: steering scans
//! compile their WHERE conjuncts into the same `Conjunct` form so that
//! (a) row evaluation skips the interpreter on the hot filter shapes and
//! (b) per-chunk **zone maps** can exclude whole chunks before any row is
//! touched (see `PartitionStore` / `Chunk::may_match`). Extracting the
//! evaluators here keeps one implementation of the comparison semantics —
//! `sql_cmp` three-valued logic, byte-for-byte the interpreter's
//! `Bound::ColCmp` fast form — under both executors.

use crate::storage::sql::ast::{Expr, Op};
use crate::storage::sql::expr::{arith, truthy};
use crate::storage::table_def::TableDef;
use crate::storage::value::Value;
use crate::{Error, Result};
use std::cmp::Ordering;

/// A compiled operand: a literal frozen at prepare time, or a parameter
/// position resolved against the bound values at execution.
#[derive(Clone, Debug)]
pub enum CVal {
    Lit(Value),
    Param(usize),
}

impl CVal {
    /// The concrete value for this execution. Out-of-range parameters
    /// resolve to NULL (the dispatcher checks arity before running a plan,
    /// so this is purely defensive — NULL makes every comparison miss).
    pub fn get<'a>(&'a self, params: &'a [Value]) -> &'a Value {
        match self {
            CVal::Lit(v) => v,
            CVal::Param(i) => params.get(*i).unwrap_or(&Value::Null),
        }
    }
}

/// One compiled WHERE conjunct: `row[col] <op> rhs` with SQL 3VL semantics
/// (a NULL comparison does not match), byte-for-byte the behavior of the
/// interpreter's `Bound::ColCmp` fast form.
#[derive(Clone, Debug)]
pub struct Conjunct {
    pub col: usize,
    pub op: Op,
    pub rhs: CVal,
}

impl Conjunct {
    pub fn matches(&self, row: &[Value], params: &[Value]) -> bool {
        match row[self.col].sql_cmp(self.rhs.get(params)) {
            None => false,
            Some(o) => match self.op {
                Op::Eq => o == Ordering::Equal,
                Op::Ne => o != Ordering::Equal,
                Op::Lt => o == Ordering::Less,
                Op::Le => o != Ordering::Greater,
                Op::Gt => o == Ordering::Greater,
                Op::Ge => o != Ordering::Less,
                _ => false,
            },
        }
    }
}

/// A compiled scalar expression for SET clauses and INSERT templates.
/// Column references are pre-resolved schema indices; parameters read
/// straight from the bound slice. Semantics delegate to the interpreter's
/// `arith`/`truthy`/`sql_cmp` so both paths compute identical values.
#[derive(Clone, Debug)]
pub enum CExpr {
    Lit(Value),
    Param(usize),
    Col(usize),
    /// `NOW()` — evaluates to the statement's start time.
    Now,
    Unary(Op, Box<CExpr>),
    Binary(Op, Box<CExpr>, Box<CExpr>),
    Case { arms: Vec<(CExpr, CExpr)>, else_: Option<Box<CExpr>> },
}

impl CExpr {
    pub fn eval(&self, row: &[Value], params: &[Value], now: f64) -> Result<Value> {
        Ok(match self {
            CExpr::Lit(v) => v.clone(),
            CExpr::Param(i) => params.get(*i).cloned().ok_or_else(|| {
                Error::Type(format!("parameter ?{i} out of range ({} bound)", params.len()))
            })?,
            CExpr::Col(i) => row[*i].clone(),
            CExpr::Now => Value::Float(now),
            CExpr::Unary(op, e) => {
                let v = e.eval(row, params, now)?;
                match op {
                    Op::Not => match truthy(&v)? {
                        None => Value::Null,
                        Some(b) => Value::Bool(!b),
                    },
                    Op::Neg => match v {
                        Value::Null => Value::Null,
                        Value::Int(i) => Value::Int(-i),
                        Value::Float(f) => Value::Float(-f),
                        other => return Err(Error::Type(format!("cannot negate {other}"))),
                    },
                    other => return Err(Error::Type(format!("bad unary op {other:?}"))),
                }
            }
            CExpr::Binary(op, a, b) => {
                match op {
                    Op::And => {
                        let l = truthy(&a.eval(row, params, now)?)?;
                        if l == Some(false) {
                            return Ok(Value::Bool(false));
                        }
                        let r = truthy(&b.eval(row, params, now)?)?;
                        return Ok(match (l, r) {
                            (_, Some(false)) => Value::Bool(false),
                            (Some(true), Some(true)) => Value::Bool(true),
                            _ => Value::Null,
                        });
                    }
                    Op::Or => {
                        let l = truthy(&a.eval(row, params, now)?)?;
                        if l == Some(true) {
                            return Ok(Value::Bool(true));
                        }
                        let r = truthy(&b.eval(row, params, now)?)?;
                        return Ok(match (l, r) {
                            (_, Some(true)) => Value::Bool(true),
                            (Some(false), Some(false)) => Value::Bool(false),
                            _ => Value::Null,
                        });
                    }
                    _ => {}
                }
                let l = a.eval(row, params, now)?;
                let r = b.eval(row, params, now)?;
                match op {
                    Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Mod => arith(*op, &l, &r)?,
                    Op::Eq | Op::Ne | Op::Lt | Op::Le | Op::Gt | Op::Ge => match l.sql_cmp(&r) {
                        None => Value::Null,
                        Some(o) => Value::Bool(match op {
                            Op::Eq => o == Ordering::Equal,
                            Op::Ne => o != Ordering::Equal,
                            Op::Lt => o == Ordering::Less,
                            Op::Le => o != Ordering::Greater,
                            Op::Gt => o == Ordering::Greater,
                            Op::Ge => o != Ordering::Less,
                            _ => unreachable!(),
                        }),
                    },
                    other => return Err(Error::Type(format!("bad binary op {other:?}"))),
                }
            }
            CExpr::Case { arms, else_ } => {
                for (c, v) in arms {
                    if truthy(&c.eval(row, params, now)?)? == Some(true) {
                        return v.eval(row, params, now);
                    }
                }
                match else_ {
                    Some(e) => e.eval(row, params, now)?,
                    None => Value::Null,
                }
            }
        })
    }
}

/// Is `op` a row comparison usable in a [`Conjunct`]?
pub fn is_cmp(op: Op) -> bool {
    matches!(op, Op::Eq | Op::Ne | Op::Lt | Op::Le | Op::Gt | Op::Ge)
}

/// Mirror a comparison operator (for `lit op col` → `col op' lit`).
pub fn flip_cmp(op: Op) -> Op {
    match op {
        Op::Lt => Op::Gt,
        Op::Le => Op::Ge,
        Op::Gt => Op::Lt,
        Op::Ge => Op::Le,
        other => other,
    }
}

/// Compile a comparison operand: literal or parameter, nothing else.
pub fn compile_rhs(e: &Expr) -> Option<CVal> {
    match e {
        Expr::Lit(v) => Some(CVal::Lit(v.clone())),
        Expr::Param(i) => Some(CVal::Param(*i)),
        _ => None,
    }
}

/// Resolve a possibly-qualified column reference against a table schema,
/// mirroring `Layout::resolve` (case-insensitive, ambiguity → give up).
pub fn resolve_col(
    def: &TableDef,
    binding: &str,
    qual: &Option<String>,
    name: &str,
) -> Option<usize> {
    if let Some(q) = qual {
        if !q.eq_ignore_ascii_case(binding) {
            return None;
        }
    }
    let mut hit = None;
    for (i, c) in def.schema.columns.iter().enumerate() {
        if c.name.eq_ignore_ascii_case(name) {
            if hit.is_some() {
                return None; // ambiguous: let the interpreter raise its error
            }
            hit = Some(i);
        }
    }
    hit
}

/// Compile one expression into a [`Conjunct`] if it has the
/// `col <cmp> literal-or-param` shape against `def` (bound as `binding`).
pub fn compile_conjunct(e: &Expr, def: &TableDef, binding: &str) -> Option<Conjunct> {
    let Expr::Binary(op, a, b) = e else { return None };
    if !is_cmp(*op) {
        return None;
    }
    match (a.as_ref(), b.as_ref()) {
        (Expr::Col { table, name }, rhs) => Some(Conjunct {
            col: resolve_col(def, binding, table, name)?,
            op: *op,
            rhs: compile_rhs(rhs)?,
        }),
        (lhs, Expr::Col { table, name }) => Some(Conjunct {
            col: resolve_col(def, binding, table, name)?,
            op: flip_cmp(*op),
            rhs: compile_rhs(lhs)?,
        }),
        _ => None,
    }
}

/// Compile a WHERE clause into simple conjuncts; `None` when any conjunct
/// is not of the `col <cmp> literal-or-param` form. (The fast DML path
/// needs all-or-nothing: a partially compiled predicate cannot replace the
/// full statement. The scan engine instead collects the compilable subset
/// for zone pruning — see `query::engine`.)
pub fn compile_where(w: Option<&Expr>, def: &TableDef, binding: &str) -> Option<Vec<Conjunct>> {
    let Some(w) = w else { return Some(Vec::new()) };
    let mut out = Vec::new();
    for c in w.conjuncts() {
        out.push(compile_conjunct(c, def, binding)?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::value::{ColumnType, Schema};

    fn def() -> TableDef {
        TableDef::new(
            "t",
            Schema::of(&[("a", ColumnType::Int), ("b", ColumnType::Float), ("s", ColumnType::Str)]),
        )
    }

    #[test]
    fn conjuncts_match_with_3vl() {
        let c = Conjunct { col: 0, op: Op::Ge, rhs: CVal::Lit(Value::Int(3)) };
        assert!(c.matches(&[Value::Int(3)], &[]));
        assert!(!c.matches(&[Value::Int(2)], &[]));
        assert!(!c.matches(&[Value::Null], &[]), "NULL never matches");
        // cross-type comparison yields None, i.e. no match
        assert!(!c.matches(&[Value::str("x")], &[]));
    }

    #[test]
    fn compile_conjunct_handles_both_operand_orders() {
        use crate::storage::sql::parse;
        use crate::storage::sql::Statement;
        let d = def();
        let stmt = parse("SELECT a FROM t WHERE 5 > a AND b <= 2.5 AND s = 'x'").unwrap();
        let Statement::Select(s) = stmt else { panic!() };
        let w = s.where_.unwrap();
        let cs: Vec<Conjunct> =
            w.conjuncts().into_iter().map(|c| compile_conjunct(c, &d, "t").unwrap()).collect();
        assert_eq!(cs.len(), 3);
        // `5 > a` flips into `a < 5`
        assert_eq!(cs[0].col, 0);
        assert!(matches!(cs[0].op, Op::Lt));
        assert!(cs[0].matches(&[Value::Int(4), Value::Null, Value::Null], &[]));
        assert!(!cs[0].matches(&[Value::Int(5), Value::Null, Value::Null], &[]));
    }

    #[test]
    fn compile_where_is_all_or_nothing() {
        use crate::storage::sql::parse;
        use crate::storage::sql::Statement;
        let d = def();
        let shapes = [
            ("SELECT a FROM t WHERE a = 1 AND s = 'x'", true),
            ("SELECT a FROM t WHERE a = 1 OR s = 'x'", false),
            ("SELECT a FROM t WHERE a IN (1, 2)", false),
            ("SELECT a FROM t WHERE nope = 1", false),
        ];
        for (sql, ok) in shapes {
            let Statement::Select(s) = parse(sql).unwrap() else { panic!() };
            assert_eq!(
                compile_where(s.where_.as_ref(), &d, "t").is_some(),
                ok,
                "{sql}"
            );
        }
    }
}
