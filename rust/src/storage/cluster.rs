//! The database cluster: catalog, partition placement, replication, and the
//! statement/transaction executor.
//!
//! This is the component the paper calls "the distributed in-memory DBMS"
//! plus its *DBManager*. Everything the WMS and the steering layer do goes
//! through [`DbCluster::exec_tagged`] (single statements, auto-commit) or
//! [`DbCluster::exec_txn`] (atomic multi-statement transactions with
//! two-phase locking across partitions and synchronous replica apply —
//! the in-process analogue of NDB's 2PC).

use crate::query::engine::{self as query_engine, TableSnapshots};
use crate::query::plan::{self as query_plan, ScatterPlan, TableInfo};
use crate::query::pool::ScanPool;
use crate::query::ScanMetrics;
use crate::storage::checkpoint;
use crate::storage::datanode::{DataNode, NodeState};
use crate::storage::dml_plan::{
    self, DeletePlan, DmlPlan, InsertPlan, Probe, SelectPlan, UpdatePlan,
};
use crate::storage::partition::{ChunkSnapshot, PartitionStore, Slot};
use crate::storage::prepared::{Prepared, PreparedPlan};
use crate::storage::sql::exec::{run_select, TableInput};
use crate::storage::sql::expr::{bind, EvalCtx, Layout};
use crate::storage::sql::{self, Expr, SelectItem, SelectStmt, Statement, TableRef};
use crate::storage::stats::{AccessKind, StatsRegistry};
use crate::storage::table_def::TableDef;
use crate::storage::value::{Column, Row, Schema, Value};
use crate::storage::wal::{encode_value, read_segment_file, LogOp, NodeWal};
use crate::storage::{ResultSet, StatementResult};
use crate::obs::{span, Counter, Hist, ObsRegistry, PartMetric, Stage};
use crate::util::clock::{self, SharedClock};
use crate::util::failpoint;
use crate::util::rng::Rng;
use crate::{Error, Result};
use rustc_hash::FxHashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Instant;

/// Durable-logging parameters: where WAL segments and partition
/// checkpoints live, and how commits batch their sink flushes.
#[derive(Clone)]
pub struct DurabilityConfig {
    /// Data directory; each node logs under `<dir>/node<id>/`.
    pub dir: PathBuf,
    /// Group-commit window: flush the buffered WAL sinks once every this
    /// many commits (1 = flush per commit).
    pub group_commit: usize,
    /// Automatic checkpoint cadence: every this many availability sweeps,
    /// `AvailabilityManager::sweep` cuts incremental per-partition
    /// checkpoints on every serving node (truncating the WAL segments at
    /// the cut). 0 disables the cadence — cuts then happen only when
    /// requested explicitly or after a rejoin hand-off.
    pub checkpoint_every_sweeps: usize,
}

impl DurabilityConfig {
    /// Durability under `dir` with the given group-commit window and no
    /// automatic checkpoint cadence.
    pub fn new(dir: PathBuf, group_commit: usize) -> DurabilityConfig {
        DurabilityConfig { dir, group_commit, checkpoint_every_sweeps: 0 }
    }

    /// Builder: cut per-partition checkpoints every `n` availability
    /// sweeps (0 disables).
    pub fn with_checkpoint_cadence(mut self, n: usize) -> DurabilityConfig {
        self.checkpoint_every_sweeps = n;
        self
    }
}

/// Concurrency-control discipline for compiled point DML (the claim loop).
///
/// Selects how `exec_prepared` executes fast-classified single-partition
/// point UPDATE/DELETE statements; everything else (interpreted
/// transactions, scatter reads, inserts) is unaffected. The two modes are
/// byte-equivalent by construction — `tests/occ_equivalence.rs` and the
/// chaos/scatter suites drive both against the same workload and require
/// identical `fingerprint()`s.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ConcurrencyMode {
    /// Pessimistic (the PR 3 fast path): probe, compute, and apply all
    /// happen under the target partition's primary+backup write latches.
    #[default]
    TwoPL,
    /// Optimistic: read the target row and its slot stamp without write
    /// latches, compute the new row off-lock, then revalidate-and-install
    /// under a short commit critical section, retrying with jittered
    /// backoff on conflict and falling back to [`ConcurrencyMode::TwoPL`]
    /// when the retry budget is exhausted (see `DbCluster::occ_update`).
    Occ,
}

impl ConcurrencyMode {
    /// Parse a mode name (env-var plumbing for benches/tests/CI matrices).
    pub fn from_name(s: &str) -> Option<ConcurrencyMode> {
        match s.to_ascii_lowercase().as_str() {
            "2pl" | "twopl" | "two_pl" => Some(ConcurrencyMode::TwoPL),
            "occ" => Some(ConcurrencyMode::Occ),
            _ => None,
        }
    }
}

/// Cluster construction parameters.
#[derive(Clone)]
pub struct ClusterConfig {
    /// Number of data nodes (the paper uses 2 in all experiments).
    pub data_nodes: usize,
    /// Keep one backup replica per partition (paper: replication factor 1,
    /// "each relation has one replica"). Requires `data_nodes >= 2`.
    pub replication: bool,
    /// Time source for `NOW()` and timestamps.
    pub clock: SharedClock,
    /// When set, committed redo is logged to per-partition WAL segment
    /// files (group-committed) and per-partition checkpoints become
    /// available — the substrate of `DbCluster::restart_node`. `None`
    /// keeps the WAL in memory only (tests, benchmarks).
    pub durability: Option<DurabilityConfig>,
    /// Concurrency control for compiled point DML (default: 2PL latches).
    pub concurrency: ConcurrencyMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            data_nodes: 2,
            replication: true,
            clock: clock::wall(),
            durability: None,
            concurrency: ConcurrencyMode::default(),
        }
    }
}

impl ClusterConfig {
    /// Fluent construction over the defaults (2 nodes, replication on,
    /// wall clock, no durability, 2PL). The builder's `build()` validates
    /// the knob combination up front, where the positional field-stuffing
    /// pattern deferred every mistake to `DbCluster::start`.
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder { cfg: ClusterConfig::default() }
    }

    /// Positional shim for the pre-builder construction pattern.
    #[deprecated(note = "use ClusterConfig::builder()")]
    pub fn positional(
        data_nodes: usize,
        replication: bool,
        durability: Option<DurabilityConfig>,
        concurrency: ConcurrencyMode,
    ) -> ClusterConfig {
        ClusterConfig { data_nodes, replication, clock: clock::wall(), durability, concurrency }
    }
}

/// Builder for [`ClusterConfig`] (see [`ClusterConfig::builder`]).
#[derive(Clone)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    /// Number of data nodes (more can be added online, `DbCluster::add_node`).
    pub fn data_nodes(mut self, n: usize) -> Self {
        self.cfg.data_nodes = n;
        self
    }

    /// Keep one backup replica per partition (needs ≥ 2 nodes).
    pub fn replication(mut self, on: bool) -> Self {
        self.cfg.replication = on;
        self
    }

    /// Time source for `NOW()` and timestamps.
    pub fn clock(mut self, clock: SharedClock) -> Self {
        self.cfg.clock = clock;
        self
    }

    /// Enable durable logging (per-partition WAL segments + checkpoints).
    pub fn durability(mut self, d: DurabilityConfig) -> Self {
        self.cfg.durability = Some(d);
        self
    }

    /// Concurrency control for compiled point DML.
    pub fn concurrency(mut self, mode: ConcurrencyMode) -> Self {
        self.cfg.concurrency = mode;
        self
    }

    /// Validate and produce the config. The same invariants
    /// `DbCluster::start` enforces, surfaced at construction time.
    pub fn build(self) -> Result<ClusterConfig> {
        if self.cfg.data_nodes == 0 {
            return Err(Error::Catalog("need at least one data node".into()));
        }
        if self.cfg.replication && self.cfg.data_nodes < 2 {
            return Err(Error::Catalog("replication needs >= 2 data nodes".into()));
        }
        Ok(self.cfg)
    }
}

/// Placement of one partition: which nodes host its primary and backup.
#[derive(Clone, Copy, Debug)]
pub struct Placement {
    pub primary: u32,
    pub backup: Option<u32>,
}

struct TableMeta {
    def: Arc<TableDef>,
    placements: Vec<Placement>,
}

/// Upper bound on cached plans; at the bound, each new statement evicts one
/// arbitrary cached entry (the working set of a workflow run is a few dozen
/// statements, so eviction never triggers outside adversarial use).
const PLAN_CACHE_MAX: usize = 1024;

/// Which execution path served each statement (adoption telemetry; tests
/// assert the steering mix runs lock-free and that the claim loop takes the
/// compiled fast path).
#[derive(Default)]
pub struct RouteCounters {
    /// Join-free SELECTs served by partial-aggregate / top-k pushdown.
    pub scatter: AtomicU64,
    /// Join SELECTs served by parallel snapshot scans + coordinator join.
    pub snapshot_join: AtomicU64,
    /// SELECTs that fell back to the centralized 2PL path (point reads).
    pub centralized: AtomicU64,
    /// Prepared statements served by the compiled DML fast path (no AST,
    /// no interpreter — see `storage::dml_plan`).
    pub fast_dml: AtomicU64,
    /// Point-DML commits installed by OCC validation (subset of
    /// `fast_dml`; only meaningful under [`ConcurrencyMode::Occ`]).
    pub occ_dml: AtomicU64,
    /// OCC validation conflicts (each one re-ran the read phase).
    pub occ_retries: AtomicU64,
    /// OCC statements that exhausted the retry budget and completed on
    /// the 2PL fast path instead.
    pub occ_fallbacks: AtomicU64,
}

/// Snapshot of [`RouteCounters`] (see [`DbCluster::route_counts`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouteCounts {
    pub scatter: u64,
    pub snapshot_join: u64,
    pub centralized: u64,
    pub fast_dml: u64,
    /// Chunks whose rows actually ran through a scatter/snapshot-join
    /// partial filter.
    pub chunks_scanned: u64,
    /// Chunks a zone map excluded before any row was touched.
    pub chunks_pruned: u64,
    /// OCC-installed point-DML commits (see [`RouteCounters::occ_dml`]).
    pub occ_dml: u64,
    /// OCC validation conflicts (see [`RouteCounters::occ_retries`]).
    pub occ_retries: u64,
    /// OCC retry-budget exhaustions that completed via 2PL (see
    /// [`RouteCounters::occ_fallbacks`]).
    pub occ_fallbacks: u64,
}

/// What [`DbCluster::restart_node`] reconstructed locally before the
/// catch-up phase.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RejoinStart {
    /// Hosted partition replicas that restarted (all of them, empty).
    pub partitions: usize,
    /// Replicas restored from a per-partition checkpoint.
    pub from_checkpoint: usize,
    /// WAL records replayed on top of the checkpoints.
    pub replayed: u64,
    /// Local checkpoints rejected (checksum mismatch / torn body) and
    /// discarded before falling back to WAL replay or peer shipping.
    pub ckpt_rejected: usize,
    /// Partitions whose checkpoint + WAL tail were shipped cross-node from
    /// a live peer replica because nothing usable survived locally.
    pub shipped: usize,
    /// The node's durability directory was missing entirely (disk loss)
    /// and had to be recreated.
    pub disk_lost: bool,
}

/// Point-in-time snapshot of the cluster topology (see
/// [`DbCluster::topology`]): every node with its lifecycle state, and every
/// `(table, partition)` with its placement, congruence class, and size.
/// This is the introspection surface the admin CLI and the wire protocol's
/// `Request::Topology` serve; it replaces ad-hoc stats spelunking.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Cluster epoch at the time of the snapshot.
    pub epoch: u64,
    pub nodes: Vec<NodeInfo>,
    /// Per-table placement maps, sorted by table name.
    pub tables: Vec<TableTopology>,
}

/// One data node in a [`Topology`] snapshot.
#[derive(Clone, Debug)]
pub struct NodeInfo {
    pub id: u32,
    pub state: NodeState,
    /// Partition replicas hosted (primary and backup roles both count).
    pub partitions: usize,
}

/// One table's placement map in a [`Topology`] snapshot.
#[derive(Clone, Debug)]
pub struct TableTopology {
    /// Catalog key (lowercased table name).
    pub table: String,
    pub partitions: Vec<PartitionInfo>,
}

/// One partition's placement and size in a [`Topology`] snapshot.
#[derive(Clone, Debug)]
pub struct PartitionInfo {
    pub pidx: usize,
    pub primary: u32,
    pub backup: Option<u32>,
    /// Row count / approximate bytes of the serving replica (0 when no
    /// replica is reachable — the snapshot degrades, never errors).
    pub rows: usize,
    pub bytes: usize,
    /// Partition LSN and epoch fence of the serving replica.
    pub version: u64,
    pub store_epoch: u64,
    /// Congruence class `(modulus, residue)` owning this partition's keys
    /// (`None` for single-partition tables).
    pub class: Option<(i64, i64)>,
}

/// One recommendation from the hot-partition advisor
/// (see [`DbCluster::advise_topology`]).
#[derive(Clone, Debug)]
pub struct TopologyAdvice {
    pub table: String,
    pub pidx: usize,
    /// Claims + WAL records observed on the partition's obs shard cell.
    /// Shards alias `pidx % 64` **across tables**, so heat is an upper
    /// bound attributed to every partition sharing the cell.
    pub heat: u64,
    pub action: AdviceAction,
}

/// What the advisor suggests doing with a hot partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdviceAction {
    /// Hot and larger than its table's average partition: halve it in
    /// place (`DbCluster::split_partition`).
    Split,
    /// Hot but small: move its primary to the least-loaded eligible node
    /// (`DbCluster::rebalance_partition`).
    Move { to_node: u32 },
}

/// The cluster facade.
pub struct DbCluster {
    /// Data nodes, growable online (`add_node`). Lock order: a thread
    /// holding `catalog` may take `nodes`, never the reverse.
    nodes: RwLock<Vec<Arc<DataNode>>>,
    catalog: RwLock<FxHashMap<String, Arc<TableMeta>>>,
    pub clock: SharedClock,
    pub stats: Arc<StatsRegistry>,
    replication: bool,
    durability: Option<DurabilityConfig>,
    /// Concurrency control for compiled point DML (see [`ConcurrencyMode`]).
    concurrency: ConcurrencyMode,
    /// Cluster epoch: bumped on every failover promotion. Committed redo
    /// records carry the epoch they committed under; replicas fence
    /// applies from older epochs (see `PartitionStore::apply_redo`).
    epoch: AtomicU64,
    place_cursor: AtomicUsize,
    /// Shared plan cache: statement text → prepared plan. Every client of
    /// the cluster (supervisors, workers via connectors, steering) shares
    /// it, so each distinct statement is parsed once per cluster lifetime.
    plans: RwLock<FxHashMap<String, Arc<PreparedPlan>>>,
    /// Scan pool for the scatter-gather engine, created on first use.
    pool: OnceLock<ScanPool>,
    routes: RouteCounters,
    /// Chunk scan/prune telemetry, shared with every partial task the
    /// scatter engine spawns (see `query::ScanMetrics`).
    scan_metrics: Arc<ScanMetrics>,
    /// Always-on observability registry, shared with every data node and
    /// the wire server (see `crate::obs`).
    obs: Arc<ObsRegistry>,
    /// Serializes `refresh_monitoring`: the delete+reinsert of the system
    /// `monitoring` table must not interleave between concurrent readers.
    monitoring_refresh: Mutex<()>,
    /// Serializes topology-change operations (`add_node`,
    /// `rebalance_partition`, `split_partition`) against each other; the
    /// data path never takes it.
    admin: Mutex<()>,
}

/// Name of the system telemetry table (see
/// [`DbCluster::refresh_monitoring`]). Created lazily on first reference;
/// excluded from [`DbCluster::fingerprint`] so twin-cluster equivalence
/// tests compare workflow state, not telemetry.
pub const MONITORING_TABLE: &str = "monitoring";

/// Does this SELECT read `table` (as base table or join side)?
fn select_references(s: &SelectStmt, table: &str) -> bool {
    s.from.table.eq_ignore_ascii_case(table)
        || s.joins.iter().any(|j| j.table.table.eq_ignore_ascii_case(table))
}

/// Can this on-disk WAL segment alone reconstruct its partition from the
/// origin? True when the earliest surviving record is the partition's first
/// LSN — replay then needs no checkpoint underneath it. A missing, empty,
/// or unreadable segment cannot.
fn wal_covers_origin(path: &std::path::Path) -> bool {
    match read_segment_file(path) {
        Ok(recs) => recs.iter().map(|r| r.lsn).min() == Some(1),
        Err(_) => false,
    }
}

/// Split a per-partition durability file stem `{table}.p{pidx}` into its
/// parts (see `checkpoint::partition_ckpt_name`). `None` for foreign files
/// (tmp debris, unrelated names) — cold start ignores those.
fn split_part_stem(stem: &str) -> Option<(String, usize)> {
    let (table, p) = stem.rsplit_once(".p")?;
    if table.is_empty() {
        return None;
    }
    Some((table.to_string(), p.parse().ok()?))
}

// ---------- lock plumbing ----------

/// Which replica a lock request targets.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
enum Role {
    Primary,
    Backup,
}

/// One entry of a statement's lock set.
struct LockReq {
    table: String,
    pidx: usize,
    node: u32,
    role: Role,
    write: bool,
    store: Arc<RwLock<PartitionStore>>,
}

enum Guard<'a> {
    R(RwLockReadGuard<'a, PartitionStore>),
    W(RwLockWriteGuard<'a, PartitionStore>),
}

/// Executor context: held guards indexed by (table, pidx, role).
struct ExecCtx<'a> {
    guards: Vec<Guard<'a>>,
    index: FxHashMap<(String, usize, Role), usize>,
    placements: FxHashMap<String, Arc<TableMeta>>,
    now: f64,
    /// Redo ops of this transaction — `(partition LSN after apply, op,
    /// undo)`.
    applied: Vec<(u64, LogOp, Undo)>,
    /// Version of each touched primary partition before the transaction
    /// first mutated it. A rollback restores these, keeping the partition
    /// LSN sequence dense (aborted work never consumes LSNs) and the
    /// primary/backup versions in lockstep.
    pre_versions: FxHashMap<(String, usize), u64>,
}

/// Inverse of an applied primary mutation. Rows are shared handles: undo
/// state aliases the displaced row instead of cloning it (the chunked
/// slab hands the old `Arc<Row>` back on update/delete).
enum Undo {
    Remove { table: String, pidx: usize, slot: usize },
    Restore { table: String, pidx: usize, slot: usize, row: Arc<Row> },
    Reinsert { table: String, pidx: usize, slot: usize, row: Arc<Row> },
}

impl<'a> ExecCtx<'a> {
    fn store(&self, table: &str, pidx: usize, role: Role) -> Result<&PartitionStore> {
        let i = self
            .index
            .get(&(table.to_string(), pidx, role))
            .copied()
            .ok_or_else(|| Error::Engine(format!("partition {table}[{pidx}] not locked")))?;
        Ok(match &self.guards[i] {
            Guard::R(g) => g,
            Guard::W(g) => g,
        })
    }

    fn store_mut(&mut self, table: &str, pidx: usize, role: Role) -> Result<&mut PartitionStore> {
        let i = self
            .index
            .get(&(table.to_string(), pidx, role))
            .copied()
            .ok_or_else(|| Error::Engine(format!("partition {table}[{pidx}] not locked")))?;
        match &mut self.guards[i] {
            Guard::R(_) => Err(Error::Engine(format!(
                "partition {table}[{pidx}] locked for read, write needed"
            ))),
            Guard::W(g) => Ok(g),
        }
    }

    fn has(&self, table: &str, pidx: usize, role: Role) -> bool {
        self.index.contains_key(&(table.to_string(), pidx, role))
    }

    /// Remember the primary partition's version before its first mutation
    /// in this transaction (rollback restores it — see `pre_versions`).
    fn note_pre_version(&mut self, table: &str, pidx: usize) -> Result<()> {
        let key = (table.to_string(), pidx);
        if !self.pre_versions.contains_key(&key) {
            let v = self.store(table, pidx, Role::Primary)?.version;
            self.pre_versions.insert(key, v);
        }
        Ok(())
    }

    fn ectx(&self) -> EvalCtx {
        EvalCtx { now: self.now }
    }
}

impl DbCluster {
    /// Start a cluster (`DBManager --start`).
    pub fn start(config: ClusterConfig) -> Result<Arc<DbCluster>> {
        if config.data_nodes == 0 {
            return Err(Error::Catalog("need at least one data node".into()));
        }
        if config.replication && config.data_nodes < 2 {
            return Err(Error::Catalog("replication needs >= 2 data nodes".into()));
        }
        let nodes: Vec<Arc<DataNode>> =
            (0..config.data_nodes as u32).map(|i| Arc::new(DataNode::new(i))).collect();
        let obs = Arc::new(ObsRegistry::new(config.data_nodes));
        for n in &nodes {
            n.attach_obs(obs.clone());
        }
        if let Some(d) = &config.durability {
            for n in &nodes {
                let ndir = d.dir.join(format!("node{}", n.id));
                // A *fresh* cluster is authoritative: stale segments and
                // checkpoints from a previous process under the same dir
                // would interleave two unrelated LSN histories. (Whole-
                // cluster recovery from an existing dir goes through
                // `DbCluster::open`, per-node recovery through
                // `restart_node`; neither reaches here.)
                let _ = std::fs::remove_dir_all(&ndir);
                std::fs::create_dir_all(&ndir)?;
                n.attach_durability(ndir, d.group_commit);
            }
        }
        Ok(Arc::new(DbCluster {
            nodes: RwLock::new(nodes),
            catalog: RwLock::new(FxHashMap::default()),
            clock: config.clock,
            stats: Arc::new(StatsRegistry::new()),
            replication: config.replication,
            durability: config.durability,
            concurrency: config.concurrency,
            epoch: AtomicU64::new(0),
            place_cursor: AtomicUsize::new(0),
            plans: RwLock::new(FxHashMap::default()),
            pool: OnceLock::new(),
            routes: RouteCounters::default(),
            scan_metrics: Arc::new(ScanMetrics::default()),
            obs,
            monitoring_refresh: Mutex::new(()),
            admin: Mutex::new(()),
        }))
    }

    /// Cold-start a cluster **from** an existing durability directory —
    /// the non-wiping sibling of [`DbCluster::start`], closing the
    /// full-cluster-stop recovery gap: `start` treats the directory as
    /// scratch space and wipes it, so until now only single-node restarts
    /// (`restart_node`) could recover from disk.
    ///
    /// Per node directory, every partition replica is rebuilt from its
    /// newest **valid** checkpoint (checksum-verified; corrupt files are
    /// detected and skipped, not loaded) plus a torn-tail-tolerant replay
    /// of its WAL segment. Replica pairs are then reconciled by
    /// `(epoch, LSN)` — the longer prefix under the highest epoch wins,
    /// the other replica is re-seeded from it — and every store is
    /// re-stamped with a fresh cluster epoch strictly above anything on
    /// disk, fencing stale redo from the previous incarnation.
    ///
    /// Refuses with [`Error::Recovery`] instead of guessing when:
    /// - no durability config is given (there is nothing to open);
    /// - a table left WAL segments but no readable checkpoint (rows exist
    ///   but their schema is unknowable);
    /// - two replicas of a partition are irreconcilable — a replica on a
    ///   stale epoch holds **more** committed records than the winner
    ///   (acked writes would be silently dropped), or the pair matches on
    ///   `(epoch, LSN)` but differs in content.
    ///
    /// Nothing on disk is modified until all validation has passed; the
    /// first write is the fresh post-open checkpoint baseline.
    pub fn open(config: ClusterConfig) -> Result<Arc<DbCluster>> {
        let d = config.durability.clone().ok_or_else(|| {
            Error::Recovery("DbCluster::open requires a durability configuration".into())
        })?;
        if config.data_nodes == 0 {
            return Err(Error::Catalog("need at least one data node".into()));
        }
        failpoint::hit("cold-start-open")?;
        // Node-dir discovery: a cluster that grew online (`add_node`) has
        // more directories than the configured baseline; cover them all.
        let mut n_nodes = config.data_nodes;
        if let Ok(rd) = std::fs::read_dir(&d.dir) {
            for e in rd.flatten() {
                let idx = e
                    .file_name()
                    .to_str()
                    .and_then(|s| s.strip_prefix("node"))
                    .and_then(|s| s.parse::<usize>().ok());
                if let Some(i) = idx {
                    if e.path().is_dir() {
                        n_nodes = n_nodes.max(i + 1);
                    }
                }
            }
        }
        if config.replication && n_nodes < 2 {
            return Err(Error::Catalog("replication needs >= 2 data nodes".into()));
        }

        // Phase 1 (read-only): load every valid checkpoint, note every WAL
        // segment, and pick each table's definition — the one from the
        // highest-epoch checkpoint, widest partitioning on a tie (splits
        // only ever add partitions).
        struct FoundCkpt {
            node: u32,
            ck: checkpoint::PartitionCheckpoint,
        }
        let mut ckpts: FxHashMap<(String, usize), Vec<FoundCkpt>> = FxHashMap::default();
        let mut wal_files: Vec<(String, usize, u32)> = Vec::new();
        let mut defs: FxHashMap<String, (u64, TableDef)> = FxHashMap::default();
        for node in 0..n_nodes as u32 {
            let ndir = d.dir.join(format!("node{node}"));
            let Ok(rd) = std::fs::read_dir(&ndir) else { continue };
            for e in rd.flatten() {
                let path = e.path();
                let Some(fname) = path.file_name().and_then(|s| s.to_str()) else {
                    continue;
                };
                if let Some(stem) = fname.strip_suffix(".ckpt") {
                    let Some((table, pidx)) = split_part_stem(stem) else { continue };
                    match checkpoint::load_partition_checkpoint(&path) {
                        Ok(ck) => {
                            let slot = defs.entry(table.clone()).or_insert_with(|| {
                                (ck.epoch, ck.def.clone())
                            });
                            let wider = ck.def.num_partitions() > slot.1.num_partitions();
                            let newer = ck.def.num_partitions() == slot.1.num_partitions()
                                && ck.epoch > slot.0;
                            if wider || newer {
                                *slot = (ck.epoch, ck.def.clone());
                            }
                            ckpts
                                .entry((table, pidx))
                                .or_default()
                                .push(FoundCkpt { node, ck });
                        }
                        Err(e) => {
                            log::warn!(
                                "cold start: skipping unusable checkpoint {path:?}: {e}"
                            );
                        }
                    }
                } else if let Some(stem) = fname.strip_suffix(".wal") {
                    if let Some((table, pidx)) = split_part_stem(stem) {
                        wal_files.push((table, pidx, node));
                    }
                }
            }
        }
        for (table, _, _) in &wal_files {
            if !defs.contains_key(table) {
                return Err(Error::Recovery(format!(
                    "table '{table}' left WAL segments but no readable checkpoint \
                     defines its schema; cannot cold-start"
                )));
            }
        }

        // Phase 2 (read-only): reconstruct each surviving replica —
        // checkpoint base + WAL replay — into standalone stores.
        struct Replica {
            node: u32,
            store: PartitionStore,
        }
        let def_arcs: FxHashMap<String, Arc<TableDef>> = defs
            .into_iter()
            .map(|(k, (_, def))| (k, Arc::new(def)))
            .collect();
        let mut candidates: FxHashMap<(String, usize), Vec<Replica>> = FxHashMap::default();
        let mut seen: std::collections::HashSet<(String, usize, u32)> =
            std::collections::HashSet::new();
        let mut recover_one = |table: &str,
                               pidx: usize,
                               node: u32,
                               ck: Option<checkpoint::PartitionCheckpoint>|
         -> Result<()> {
            if !seen.insert((table.to_string(), pidx, node)) {
                return Ok(());
            }
            let def = def_arcs
                .get(table)
                .ok_or_else(|| Error::Recovery(format!("no definition for '{table}'")))?;
            let mut store = PartitionStore::new(def.clone());
            if let Some(ck) = ck {
                let rows = ck.rows.into_iter().map(|(s, r)| (s, Arc::new(r))).collect();
                store.load_slotted(ck.cap, rows)?;
                store.version = ck.version;
                store.epoch = ck.epoch;
            }
            let walp = d
                .dir
                .join(format!("node{node}"))
                .join(checkpoint::partition_wal_name(table, pidx));
            match read_segment_file(&walp) {
                Ok(mut recs) => {
                    recs.sort_by_key(|r| r.lsn);
                    for rec in recs {
                        if !matches!(store.apply_redo(&rec), Ok(_)) {
                            break; // gap or fence: this replica's history ends here
                        }
                    }
                }
                Err(e) => log::warn!("cold start: unreadable WAL {walp:?}: {e}"),
            }
            if store.version > 0 || store.len() > 0 {
                candidates
                    .entry((table.to_string(), pidx))
                    .or_default()
                    .push(Replica { node, store });
            }
            Ok(())
        };
        for ((table, pidx), found) in std::mem::take(&mut ckpts) {
            for f in found {
                recover_one(&table, pidx, f.node, Some(f.ck))?;
            }
        }
        for (table, pidx, node) in &wal_files {
            recover_one(table, *pidx, *node, None)?;
        }

        // Phase 3 (read-only): reconcile replica sets. Winner = highest
        // (epoch, LSN); refuse on irreconcilable divergence.
        let mut max_epoch = 0u64;
        for (key, reps) in candidates.iter_mut() {
            reps.sort_by(|a, b| {
                (b.store.epoch, b.store.version, a.node).cmp(&(
                    a.store.epoch,
                    a.store.version,
                    b.node,
                ))
            });
            let (w_epoch, w_version, w_len) = {
                let w = &reps[0].store;
                (w.epoch, w.version, w.len())
            };
            max_epoch = max_epoch.max(w_epoch);
            for c in &reps[1..] {
                if c.store.version > w_version {
                    return Err(Error::Recovery(format!(
                        "irreconcilable replicas of {}[{}]: node {} holds LSN {} under \
                         epoch {}, past the winner's LSN {} (epoch {}); acked writes \
                         would be lost",
                        key.0, key.1, c.node, c.store.version, c.store.epoch, w_version,
                        w_epoch
                    )));
                }
                if c.store.epoch == w_epoch
                    && c.store.version == w_version
                    && c.store.len() != w_len
                {
                    return Err(Error::Recovery(format!(
                        "irreconcilable replicas of {}[{}]: equal (epoch {}, LSN {}) \
                         but {} vs {} rows",
                        key.0, key.1, w_epoch, w_version, c.store.len(), w_len
                    )));
                }
            }
        }
        let fresh_epoch = max_epoch + 1;

        // Phase 4: assemble the cluster. First write to disk happens only
        // after this point (the post-open checkpoint baseline).
        let nodes: Vec<Arc<DataNode>> =
            (0..n_nodes as u32).map(|i| Arc::new(DataNode::new(i))).collect();
        let obs = Arc::new(ObsRegistry::new(n_nodes));
        for n in &nodes {
            n.attach_obs(obs.clone());
            let ndir = d.dir.join(format!("node{}", n.id));
            std::fs::create_dir_all(&ndir)?;
            n.attach_durability(ndir, d.group_commit);
        }
        let mut catalog: FxHashMap<String, Arc<TableMeta>> = FxHashMap::default();
        let mut tables: Vec<&String> = def_arcs.keys().collect();
        tables.sort();
        for key in tables {
            let def = def_arcs[key].clone();
            let name = def.name.clone();
            let mut placements = Vec::with_capacity(def.num_partitions());
            for pidx in 0..def.num_partitions() {
                let mut reps = candidates.remove(&(key.clone(), pidx)).unwrap_or_default();
                let primary_id = reps
                    .first()
                    .map(|r| r.node)
                    .unwrap_or((pidx % n_nodes) as u32);
                let backup_id = if config.replication {
                    reps.get(1).map(|r| r.node).or_else(|| {
                        (0..n_nodes as u32).find(|i| *i != primary_id)
                    })
                } else {
                    None
                };
                let pn = &nodes[primary_id as usize];
                pn.host_partition(def.clone(), pidx)?;
                let pstore = pn.partition_even_if_dead(&name, pidx)?;
                let version = if let Some(winner) = reps.first_mut() {
                    let mut g = pstore.write().unwrap();
                    winner.store.epoch = fresh_epoch;
                    *g = std::mem::replace(
                        &mut winner.store,
                        PartitionStore::new(def.clone()),
                    );
                    g.version
                } else {
                    0
                };
                pn.wal.lock().unwrap().reset_segment(&name, pidx, version);
                if let Some(bid) = backup_id {
                    let bn = &nodes[bid as usize];
                    bn.host_partition(def.clone(), pidx)?;
                    let bstore = bn.partition_even_if_dead(&name, pidx)?;
                    let g = pstore.read().unwrap();
                    let mut bg = bstore.write().unwrap();
                    let (cap, rows) = g.snapshot_slotted();
                    bg.load_slotted(cap, rows)?;
                    bg.version = g.version;
                    bg.epoch = fresh_epoch;
                    bn.wal.lock().unwrap().reset_segment(&name, pidx, version);
                }
                placements.push(Placement { primary: primary_id, backup: backup_id });
            }
            catalog.insert(key.clone(), Arc::new(TableMeta { def, placements }));
        }
        let cluster = Arc::new(DbCluster {
            nodes: RwLock::new(nodes),
            catalog: RwLock::new(catalog),
            clock: config.clock,
            stats: Arc::new(StatsRegistry::new()),
            replication: config.replication,
            durability: Some(d),
            concurrency: config.concurrency,
            epoch: AtomicU64::new(fresh_epoch),
            place_cursor: AtomicUsize::new(0),
            plans: RwLock::new(FxHashMap::default()),
            pool: OnceLock::new(),
            routes: RouteCounters::default(),
            scan_metrics: Arc::new(ScanMetrics::default()),
            obs,
            monitoring_refresh: Mutex::new(()),
            admin: Mutex::new(()),
        });
        // Fresh durable baseline under the new epoch: re-cut every node's
        // checkpoints (this also truncates the replayed WAL segments, so
        // the previous incarnation's records cannot be replayed twice).
        for id in 0..cluster.num_nodes() as u32 {
            if let Err(e) = checkpoint::checkpoint_node(&cluster, id) {
                log::warn!("cold start: baseline checkpoint of node {id} failed: {e}");
            }
        }
        Ok(cluster)
    }

    /// The cluster's observability registry (see `crate::obs`).
    pub fn obs(&self) -> &Arc<ObsRegistry> {
        &self.obs
    }

    /// The durability configuration this cluster runs with, if any.
    pub fn durability(&self) -> Option<&DurabilityConfig> {
        self.durability.as_ref()
    }

    /// The concurrency-control mode compiled point DML runs under.
    pub fn concurrency(&self) -> ConcurrencyMode {
        self.concurrency
    }

    /// Current cluster epoch (bumped on every failover promotion).
    pub fn cluster_epoch(&self) -> u64 {
        self.epoch.load(AtomicOrdering::SeqCst)
    }

    /// The scan pool backing scatter-gather execution (lazily created).
    pub(crate) fn scan_pool(&self) -> &ScanPool {
        self.pool.get_or_init(ScanPool::with_default_size)
    }

    /// Routing counters since start: scatter / snapshot-join / centralized
    /// SELECT service, compiled-fast-path DML executions, and the scan
    /// engine's chunk-granularity telemetry (zone-map pruning adoption).
    pub fn route_counts(&self) -> RouteCounts {
        RouteCounts {
            scatter: self.routes.scatter.load(AtomicOrdering::Relaxed),
            snapshot_join: self.routes.snapshot_join.load(AtomicOrdering::Relaxed),
            centralized: self.routes.centralized.load(AtomicOrdering::Relaxed),
            fast_dml: self.routes.fast_dml.load(AtomicOrdering::Relaxed),
            chunks_scanned: self.scan_metrics.chunks_scanned.load(AtomicOrdering::Relaxed),
            chunks_pruned: self.scan_metrics.chunks_pruned.load(AtomicOrdering::Relaxed),
            occ_dml: self.routes.occ_dml.load(AtomicOrdering::Relaxed),
            occ_retries: self.routes.occ_retries.load(AtomicOrdering::Relaxed),
            occ_fallbacks: self.routes.occ_fallbacks.load(AtomicOrdering::Relaxed),
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.read().unwrap().len()
    }

    pub fn node(&self, id: u32) -> Option<Arc<DataNode>> {
        self.nodes.read().unwrap().get(id as usize).cloned()
    }

    /// Kill a data node (failure injection).
    pub fn kill_node(&self, id: u32) -> Result<()> {
        let n = self.node(id).ok_or_else(|| Error::Unavailable(format!("no node {id}")))?;
        n.kill();
        Ok(())
    }

    /// Revive a node. Its replicas are stale; callers should re-seed via
    /// [`DbCluster::heal`].
    pub fn revive_node(&self, id: u32) -> Result<()> {
        let n = self.node(id).ok_or_else(|| Error::Unavailable(format!("no node {id}")))?;
        n.revive();
        Ok(())
    }

    // ---------- DDL ----------

    /// Create a table from a definition, assigning partition placements
    /// round-robin over alive nodes (backup on a different node).
    pub fn create_table(&self, def: TableDef) -> Result<()> {
        let name = def.name.to_lowercase();
        let mut cat = self.catalog.write().unwrap();
        if cat.contains_key(&name) {
            return Err(Error::Catalog(format!("table '{}' already exists", def.name)));
        }
        let def = Arc::new(def);
        let nodes = self.nodes.read().unwrap();
        let alive: Vec<&Arc<DataNode>> = nodes.iter().filter(|n| n.is_alive()).collect();
        if alive.is_empty() {
            return Err(Error::Unavailable("no alive data nodes".into()));
        }
        let mut placements = Vec::with_capacity(def.num_partitions());
        for pidx in 0..def.num_partitions() {
            let c = self.place_cursor.fetch_add(1, AtomicOrdering::SeqCst);
            let p = alive[c % alive.len()];
            p.host_partition(def.clone(), pidx)?;
            let backup = if self.replication && alive.len() > 1 {
                let b = alive[(c + 1) % alive.len()];
                b.host_partition(def.clone(), pidx)?;
                Some(b.id)
            } else {
                None
            };
            placements.push(Placement { primary: p.id, backup });
        }
        cat.insert(name, Arc::new(TableMeta { def, placements }));
        Ok(())
    }

    fn meta(&self, table: &str) -> Result<Arc<TableMeta>> {
        let lookup = |name: &str| self.catalog.read().unwrap().get(name).cloned();
        let name = table.to_lowercase();
        if let Some(m) = lookup(&name) {
            return Ok(m);
        }
        // The system `monitoring` table materializes lazily on first
        // reference so fresh clusters pay nothing for it.
        if name == MONITORING_TABLE {
            self.ensure_monitoring()?;
            if let Some(m) = lookup(&name) {
                return Ok(m);
            }
        }
        Err(Error::Catalog(format!("unknown table '{table}'")))
    }

    /// Definition of a table (checkpointing, schema introspection).
    pub fn table_def(&self, table: &str) -> Result<Arc<TableDef>> {
        Ok(self.meta(table)?.def.clone())
    }

    /// Table names in the catalog (sorted).
    pub fn tables(&self) -> Vec<String> {
        let mut v: Vec<String> = self.catalog.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Approximate resident bytes of one table across its reachable
    /// replicas. Partitions whose every replica is down are skipped (they
    /// contribute 0) rather than aborting the walk: footprint reporting
    /// must degrade under failure, not erase whole tables. Only an unknown
    /// table name errors.
    pub fn table_bytes(&self, table: &str) -> Result<usize> {
        let meta = self.meta(table)?;
        let mut total = 0;
        for (pidx, pl) in meta.placements.iter().enumerate() {
            let Ok((store, _, _)) = self.replica_store(&meta, pidx, pl, false) else {
                continue; // all replicas down: skip, keep counting the rest
            };
            total += store.read().unwrap().approx_bytes();
        }
        Ok(total)
    }

    /// Approximate resident bytes of the whole database across reachable
    /// replicas (dead partitions degrade the number, never drop a table).
    pub fn total_bytes(&self) -> usize {
        self.tables().iter().map(|t| self.table_bytes(t).unwrap_or(0)).sum()
    }

    /// Row count of a table (test/monitoring helper); like
    /// [`DbCluster::table_bytes`], unreachable partitions are skipped.
    pub fn table_rows(&self, table: &str) -> Result<usize> {
        let meta = self.meta(table)?;
        let mut total = 0;
        for (pidx, pl) in meta.placements.iter().enumerate() {
            let Ok((store, _, _)) = self.replica_store(&meta, pidx, pl, false) else {
                continue;
            };
            total += store.read().unwrap().len();
        }
        Ok(total)
    }

    // ---------- replica selection ----------

    /// Store for reading or writing partition `pidx`, honoring failover:
    /// if the primary's node is dead, fall back to the backup (Role is
    /// reported so the caller locks the right entry).
    fn replica_store(
        &self,
        meta: &TableMeta,
        pidx: usize,
        pl: &Placement,
        _write: bool,
    ) -> Result<(Arc<RwLock<PartitionStore>>, u32, Role)> {
        let primary = self
            .node(pl.primary)
            .ok_or_else(|| Error::Unavailable(format!("no node {}", pl.primary)))?;
        if primary.is_alive() {
            let s = primary.partition(&meta.def.name, pidx)?;
            return Ok((s, pl.primary, Role::Primary));
        }
        if let Some(b) = pl.backup {
            let backup = self
                .node(b)
                .ok_or_else(|| Error::Unavailable(format!("no node {b}")))?;
            if backup.is_alive() {
                let s = backup.partition(&meta.def.name, pidx)?;
                return Ok((s, b, Role::Backup));
            }
        }
        Err(Error::Unavailable(format!(
            "all replicas of {}[{pidx}] are down",
            meta.def.name
        )))
    }

    /// Promote backups of every partition whose primary is dead. Returns
    /// the number of promotions. (NDB does this automatically on heartbeat
    /// loss; our tests call it explicitly after `kill_node`.)
    pub fn promote_dead_primaries(&self) -> usize {
        let mut promoted = 0;
        let mut cat = self.catalog.write().unwrap();
        let metas: Vec<(String, Arc<TableMeta>)> =
            cat.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        for (name, meta) in metas {
            let mut placements = meta.placements.clone();
            let mut changed = false;
            for pl in placements.iter_mut() {
                let primary_dead = self.node(pl.primary).map_or(true, |n| !n.is_alive());
                if primary_dead {
                    if let Some(b) = pl.backup {
                        if self.node(b).map_or(false, |n| n.is_alive()) {
                            // swap roles; old primary becomes (stale) backup
                            let old = pl.primary;
                            pl.primary = b;
                            pl.backup = Some(old);
                            changed = true;
                            promoted += 1;
                        }
                    }
                }
            }
            if changed {
                cat.insert(name, Arc::new(TableMeta { def: meta.def.clone(), placements }));
            }
        }
        if promoted > 0 {
            // A promotion opens a new epoch: anything a stale replica logged
            // before the failover must not clobber post-promotion writes.
            self.epoch.fetch_add(1, AtomicOrdering::SeqCst);
        }
        promoted
    }

    /// Re-seed stale replicas on revived nodes from the current primaries,
    /// restoring full redundancy after a failure. Returns partitions healed.
    ///
    /// The re-seed is **slot-preserving** (`snapshot_slotted`): the backup
    /// reproduces the primary's slab layout, holes included, so the two
    /// replicas keep making identical canonical slot choices and
    /// slot-addressed redo stays applicable on both sides. Rows ship as
    /// shared `Arc<Row>` handles — a heal aliases the primary's
    /// materializations rather than deep-copying every live row.
    pub fn heal(&self) -> Result<usize> {
        let mut healed = 0;
        // Clone the metas and release the catalog lock before latching any
        // partition: a topology cut takes partition latches first and the
        // catalog lock second, so holding the catalog across a latch wait
        // here would deadlock against a concurrent move/split.
        let metas: Vec<Arc<TableMeta>> =
            self.catalog.read().unwrap().values().cloned().collect();
        for meta in metas {
            let key = meta.def.name.to_lowercase();
            for (pidx, pl) in meta.placements.iter().enumerate() {
                let Some(bid) = pl.backup else { continue };
                let (Some(pn), Some(bn)) = (self.node(pl.primary), self.node(bid)) else {
                    continue;
                };
                if !pn.is_alive() || !bn.is_alive() {
                    continue;
                }
                // A concurrent move may have dropped these replicas from
                // their nodes; skip rather than abort the whole sweep.
                let Ok(ps) = pn.partition(&meta.def.name, pidx) else { continue };
                let Ok(bs) = bn.partition(&meta.def.name, pidx) else { continue };
                // Primary read latch and backup write latch held *together*
                // (primary before backup — the executor's canonical order,
                // so no deadlock). Snapshotting the primary under a latch
                // released before the backup latch let a commit land on
                // both replicas in the gap; the version mismatch would then
                // "heal" the backup back to the stale snapshot, erasing an
                // acked mirrored write from its store and WAL segment.
                // Comparing under the pair also means a healthy partition
                // costs two version reads per sweep, not a full row clone.
                let g = ps.read().unwrap();
                let mut bg = bs.write().unwrap();
                // Under the held latch pair, verify this meta is still the
                // installed catalog entry. A topology cut that retired
                // these placements ran while we waited for the latches;
                // re-seeding from the orphaned pre-cut store would
                // resurrect state the cut already moved. Skip — the next
                // sweep re-reads the catalog.
                {
                    let cat = self.catalog.read().unwrap();
                    match cat.get(&key) {
                        Some(cur) if Arc::ptr_eq(cur, &meta) => {}
                        _ => continue,
                    }
                }
                if bg.version != g.version || bg.len() != g.len() {
                    let (cap, rows) = g.snapshot_slotted();
                    bg.load_slotted(cap, rows)?;
                    bg.version = g.version;
                    // fence stamped under the write latch (like the rejoin
                    // cut), not from a pre-walk epoch sample
                    bg.epoch = self.cluster_epoch();
                    // the backup's redo tail restarts at the seeded LSN
                    bn.wal.lock().unwrap().reset_segment(&meta.def.name, pidx, g.version);
                    healed += 1;
                }
            }
        }
        Ok(healed)
    }

    // ---------- online recovery: restart + rejoin ----------

    /// Simulate a **process restart** of a dead node and enter the rejoin
    /// state machine. Unlike [`DbCluster::revive_node`] (a transient outage
    /// with memory intact), this wipes the node's in-memory partitions and
    /// rebuilds what it can locally:
    ///
    /// 1. every hosted replica restarts empty;
    /// 2. with a durability dir, its latest per-partition checkpoint is
    ///    loaded (slot-preserving, with the LSN/epoch of the cut);
    /// 3. its WAL segment file is replayed on top, in LSN order, stopping
    ///    cleanly at a torn tail.
    ///
    /// The node is then `Rejoining`: it serves nothing until an
    /// availability sweep drives the bounded redo-ship catch-up from the
    /// current primaries and flips it back to `Alive`
    /// (`AvailabilityManager::sweep` → `DbCluster::rejoin_final_cut`).
    /// Workers keep claiming tasks throughout — reads and writes stay on
    /// the promoted replicas until the hand-off.
    pub fn restart_node(&self, id: u32) -> Result<RejoinStart> {
        let node = self
            .node(id)
            .ok_or_else(|| Error::Unavailable(format!("no node {id}")))?
            .clone();
        if node.state() != NodeState::Dead {
            return Err(Error::Engine(format!(
                "restart_node({id}): node must be dead, is {:?}",
                node.state()
            )));
        }
        failpoint::hit("rejoin-seed")?;
        let ndir = self.durability.as_ref().map(|d| d.dir.join(format!("node{id}")));
        let mut report = RejoinStart::default();
        // Disk loss: the node's durability directory vanished (operator
        // wiped the volume, disk replaced). Recreate it — without this,
        // every later WAL append on the node would fail (the open of a
        // sink file in a missing directory errors), wedging commits that
        // mirror to this replica after rejoin.
        if let Some(dir) = &ndir {
            if !dir.is_dir() {
                report.disk_lost = true;
                std::fs::create_dir_all(dir)?;
            }
        }
        node.begin_rejoin();
        // A restart loses the in-memory WAL buffers *and* whatever the
        // group-commit window had buffered but not yet flushed: discard
        // the old log (replacing it without `discard` would run NodeWal's
        // drop-flush and silently upgrade the crash to a clean shutdown —
        // recovery would then verify durability the code doesn't provide),
        // then start from a fresh NodeWal over the same directory.
        {
            let mut w = node.wal.lock().unwrap();
            w.discard();
            *w = match (&ndir, &self.durability) {
                (Some(dir), Some(d)) => NodeWal::with_dir(dir.clone(), d.group_commit),
                _ => NodeWal::new(),
            };
        }
        let mut keys = node.hosted_keys();
        keys.sort();
        for (table, pidx) in keys {
            let store = node.partition_even_if_dead(&table, pidx)?;
            let def = store.read().unwrap().def().clone();
            let mut g = store.write().unwrap();
            *g = PartitionStore::new(def);
            report.partitions += 1;
            if let Some(dir) = &ndir {
                let ckpt = dir.join(checkpoint::partition_ckpt_name(&table, pidx));
                let walp = dir.join(checkpoint::partition_wal_name(&table, pidx));
                // Validate the local checkpoint. A checksum mismatch or
                // torn body is *detected*, never loaded: discard the file
                // and fall back to WAL replay or peer shipping.
                let mut ck = match checkpoint::load_partition_checkpoint(&ckpt) {
                    Ok(ck) => Some(ck),
                    Err(Error::Io(e)) if e.kind() == std::io::ErrorKind::NotFound => None,
                    Err(e) => {
                        log::warn!(
                            "restart_node({id}): rejecting checkpoint {ckpt:?}: {e}"
                        );
                        let _ = std::fs::remove_file(&ckpt);
                        report.ckpt_rejected += 1;
                        None
                    }
                };
                // Nothing local can reconstruct this replica's prefix —
                // no valid checkpoint, and the surviving WAL (if any) does
                // not start at the partition's origin. Ship the peer
                // replica's checkpoint + WAL tail into our directory and
                // recover from the copies, instead of restarting empty
                // with no durable baseline.
                if ck.is_none() && !wal_covers_origin(&walp) {
                    match self.ship_partition_from_peer(id, &table, pidx, dir) {
                        Ok(true) => {
                            report.shipped += 1;
                            ck = match checkpoint::load_partition_checkpoint(&ckpt) {
                                Ok(ck) => Some(ck),
                                Err(e) => {
                                    log::warn!(
                                        "restart_node({id}): shipped checkpoint for \
                                         {table}[{pidx}] unusable: {e}"
                                    );
                                    None
                                }
                            };
                        }
                        Ok(false) => {}
                        Err(e) => log::warn!(
                            "restart_node({id}): peer ship of {table}[{pidx}] failed: {e}"
                        ),
                    }
                }
                if let Some(ck) = ck {
                    let rows = ck.rows.into_iter().map(|(s, r)| (s, Arc::new(r))).collect();
                    g.load_slotted(ck.cap, rows)?;
                    g.version = ck.version;
                    g.epoch = ck.epoch;
                    report.from_checkpoint += 1;
                }
                let mut recs = read_segment_file(&walp)?;
                recs.sort_by_key(|r| r.lsn);
                for rec in recs {
                    match g.apply_redo(&rec) {
                        Ok(true) => report.replayed += 1,
                        Ok(false) => {}
                        // gap or fence: local history ends here, the rest
                        // arrives via the redo-ship catch-up
                        Err(_) => break,
                    }
                }
                node.wal.lock().unwrap().reset_segment(&table, pidx, g.version);
            }
        }
        Ok(report)
    }

    /// Copy a live peer replica's on-disk checkpoint + WAL segment for
    /// `(table, pidx)` into `dst_dir` (cross-node checkpoint shipping —
    /// the disk-loss recovery path). The peer's buffered WAL tail is
    /// flushed first so the copied segment is current; a concurrent peer
    /// append at most tears the copy's final line, which replay tolerates.
    /// Returns whether any file was shipped.
    fn ship_partition_from_peer(
        &self,
        id: u32,
        table: &str,
        pidx: usize,
        dst_dir: &std::path::Path,
    ) -> Result<bool> {
        failpoint::hit("rejoin-ship-checkpoint")?;
        let Some(d) = &self.durability else { return Ok(false) };
        let Ok(meta) = self.meta(table) else { return Ok(false) };
        let Some(pl) = meta.placements.get(pidx) else { return Ok(false) };
        for peer in std::iter::once(pl.primary).chain(pl.backup) {
            if peer == id {
                continue;
            }
            let Some(pn) = self.node(peer) else { continue };
            if !pn.is_alive() {
                continue;
            }
            let _ = pn.wal.lock().unwrap().flush_all();
            let src_dir = d.dir.join(format!("node{peer}"));
            let ck_name = checkpoint::partition_ckpt_name(table, pidx);
            let wal_name = checkpoint::partition_wal_name(table, pidx);
            let mut copied = false;
            for name in [&ck_name, &wal_name] {
                let src = src_dir.join(name);
                if src.is_file() {
                    std::fs::copy(&src, dst_dir.join(name))?;
                    copied = true;
                }
            }
            if copied {
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// One opportunistic catch-up round for a rejoining node: for every
    /// hosted partition, copy the serving replica's retained redo tail
    /// (brief wal lock, no partition latch held during the apply) and
    /// replay it locally. Returns the number of records shipped. The last
    /// stretch — and anything the tail cannot cover — is handled by
    /// [`DbCluster::rejoin_final_cut`].
    pub(crate) fn rejoin_catchup_round(&self, id: u32) -> Result<u64> {
        let node = self
            .node(id)
            .ok_or_else(|| Error::Unavailable(format!("no node {id}")))?
            .clone();
        if node.state() != NodeState::Rejoining {
            return Ok(0);
        }
        failpoint::hit("rejoin-catchup")?;
        let mut shipped = 0u64;
        for (table, pidx) in node.hosted_keys() {
            let Ok(meta) = self.meta(&table) else { continue };
            let pl = &meta.placements[pidx];
            let Ok((_, src_node, _)) = self.replica_store(&meta, pidx, pl, false) else {
                continue; // no serving replica right now; the sweep retries
            };
            if src_node == id {
                continue;
            }
            let store = node.partition_even_if_dead(&table, pidx)?;
            let myv = store.read().unwrap().version;
            let tail = self
                .node(src_node)
                .and_then(|n| n.wal.lock().unwrap().tail_since(&table, pidx, myv));
            let Some(recs) = tail else { continue };
            if recs.is_empty() {
                continue;
            }
            let mut g = store.write().unwrap();
            for rec in recs {
                match g.apply_redo(&rec) {
                    Ok(true) => shipped += 1,
                    Ok(false) => {}
                    Err(_) => break,
                }
            }
        }
        Ok(shipped)
    }

    /// The rejoin hand-off. Takes a read latch on the serving replica of
    /// **every** partition the rejoining node hosts (canonical order, so
    /// this cannot deadlock against the 2PL executor), finishes each
    /// partition — remaining redo tail when the segment covers it, full
    /// slot-preserving re-seed otherwise — stamps the current epoch, and
    /// flips the node to `Alive` before releasing the latches. Commits
    /// blocked on those latches resume with the node serving and in sync.
    ///
    /// Returns `(records shipped, partitions re-seeded)`.
    pub(crate) fn rejoin_final_cut(&self, id: u32) -> Result<(u64, usize)> {
        let node = self
            .node(id)
            .ok_or_else(|| Error::Unavailable(format!("no node {id}")))?
            .clone();
        if node.state() != NodeState::Rejoining {
            return Err(Error::Engine(format!("node {id} is not rejoining")));
        }
        // Before any latch is taken: an injected fault aborts the cut with
        // the node still Rejoining, and the next sweep retries it.
        failpoint::hit("rejoin-final-cut")?;
        // (table, pidx, serving replica) — `None` for a sole-replica
        // partition (no backup, primary is the rejoiner): there is no peer
        // to catch up from, and the local recovery *is* the authoritative
        // copy, so the hand-off must not wedge on it.
        type SrcItem = (String, usize, Option<(Arc<RwLock<PartitionStore>>, u32)>);
        let mut items: Vec<SrcItem> = Vec::new();
        for (table, pidx) in node.hosted_keys() {
            let meta = self.meta(&table)?;
            let pl = &meta.placements[pidx];
            if pl.primary == id && pl.backup.is_none() {
                items.push((table, pidx, None));
                continue;
            }
            let (src, src_node, _) = self.replica_store(&meta, pidx, pl, false)?;
            if src_node == id {
                return Err(Error::Engine(format!(
                    "rejoining node {id} is still listed as serving {table}[{pidx}]"
                )));
            }
            items.push((table, pidx, Some((src, src_node))));
        }
        items.sort_by(|a, b| (a.0.to_lowercase(), a.1).cmp(&(b.0.to_lowercase(), b.1)));
        let src_guards: Vec<Option<RwLockReadGuard<'_, PartitionStore>>> = items
            .iter()
            .map(|e| e.2.as_ref().map(|(s, _)| s.read().unwrap()))
            .collect();
        // Epoch stamped under the held latches, so commits serialized
        // before this cut were stamped at or below it.
        let epoch = self.cluster_epoch();
        let mut shipped = 0u64;
        let mut reseeded = 0usize;
        for (i, (table, pidx, src)) in items.iter().enumerate() {
            let mystore = node.partition_even_if_dead(table, *pidx)?;
            let mut mine = mystore.write().unwrap();
            if let (Some(srcg), Some((_, src_node))) = (&src_guards[i], src) {
                if mine.version != srcg.version {
                    let tail = self
                        .node(*src_node)
                        .and_then(|n| n.wal.lock().unwrap().tail_since(table, *pidx, mine.version));
                    if let Some(recs) = tail {
                        for rec in recs {
                            match mine.apply_redo(&rec) {
                                Ok(true) => shipped += 1,
                                Ok(false) => {}
                                Err(_) => break,
                            }
                        }
                    }
                }
                if mine.version != srcg.version || mine.len() != srcg.len() {
                    // the tail could not close the gap: full re-seed
                    let (cap, rows) = srcg.snapshot_slotted();
                    mine.load_slotted(cap, rows)?;
                    mine.version = srcg.version;
                    reseeded += 1;
                }
            }
            mine.epoch = epoch;
            node.wal.lock().unwrap().reset_segment(table, *pidx, mine.version);
        }
        node.finish_rejoin(epoch);
        drop(src_guards);
        // Fresh durable baseline: the in-memory segments were rebased, so
        // cut checkpoints now and let them truncate the on-disk tails.
        if self.durability.is_some() {
            if let Err(e) = checkpoint::checkpoint_node(self, id) {
                log::warn!("post-rejoin checkpoint of node {id} failed: {e}");
            }
        }
        Ok((shipped, reseeded))
    }

    // ---------- elastic topology: add_node / rebalance / split ----------
    //
    // All three operations are serialized by `self.admin` and share the
    // cut discipline the rejoin machinery established: latch the affected
    // partition replicas first, then (still holding the latches) take the
    // catalog write lock, verify the captured `TableMeta` is still the
    // installed entry, re-stamp the epoch, and swap the catalog entry in.
    // Writers that were queued on those latches revalidate by `Arc`
    // identity (`fast_mirror_valid` / `mirror_set_valid`) and re-route.
    // The inverse order — holding the catalog lock while *waiting* on a
    // partition latch — exists nowhere in the executor, so this cannot
    // deadlock.

    /// Register a fresh data node with the running cluster and return its
    /// id. The node starts [`NodeState::Joining`]: it hosts nothing and
    /// serves nothing, but it is an eligible **rebalance target** — the
    /// first completed [`DbCluster::rebalance_partition`] onto it flips it
    /// to `Alive`. With durability configured the node gets its own
    /// `node<id>/` directory and WAL segments, exactly like a start-time
    /// node.
    pub fn add_node(&self) -> Result<u32> {
        let _admin = self.admin.lock().unwrap();
        let mut nodes = self.nodes.write().unwrap();
        let id = nodes.len() as u32;
        let n = Arc::new(DataNode::new_joining(id));
        n.attach_obs(self.obs.clone());
        // Grow the obs registry's per-node WAL ledgers so this node gets
        // its own `node_wal_*` breakouts, like a start-time node.
        self.obs.ensure_node(id as usize);
        if let Some(d) = &self.durability {
            let ndir = d.dir.join(format!("node{id}"));
            let _ = std::fs::remove_dir_all(&ndir);
            std::fs::create_dir_all(&ndir)?;
            n.attach_durability(ndir, d.group_commit);
        }
        nodes.push(n);
        Ok(id)
    }

    /// Snapshot the cluster topology: nodes with lifecycle states, and
    /// per-(table, partition) placement, congruence class, LSN/epoch, and
    /// size. Purely observational — unreachable partitions report zero
    /// sizes rather than erroring.
    pub fn topology(&self) -> Topology {
        let metas: Vec<(String, Arc<TableMeta>)> = {
            let cat = self.catalog.read().unwrap();
            let mut v: Vec<_> = cat.iter().map(|(k, m)| (k.clone(), m.clone())).collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        let mut tables = Vec::with_capacity(metas.len());
        for (name, meta) in &metas {
            let mut partitions = Vec::with_capacity(meta.placements.len());
            for (pidx, pl) in meta.placements.iter().enumerate() {
                let (rows, bytes, version, store_epoch) =
                    match self.replica_store(meta, pidx, pl, false) {
                        Ok((store, _, _)) => {
                            let g = store.read().unwrap();
                            (g.len(), g.approx_bytes(), g.version, g.epoch)
                        }
                        Err(_) => (0, 0, 0, 0),
                    };
                partitions.push(PartitionInfo {
                    pidx,
                    primary: pl.primary,
                    backup: pl.backup,
                    rows,
                    bytes,
                    version,
                    store_epoch,
                    class: meta.def.partition_class(pidx),
                });
            }
            tables.push(TableTopology { table: name.clone(), partitions });
        }
        let nodes = self
            .nodes
            .read()
            .unwrap()
            .iter()
            .map(|n| NodeInfo { id: n.id, state: n.state(), partitions: n.hosted_keys().len() })
            .collect();
        Topology { epoch: self.cluster_epoch(), nodes, tables }
    }

    /// Move the **primary replica** of `table[pidx]` onto `to_node`,
    /// online, while claims keep committing. Three cases:
    ///
    /// - target already primary: no-op;
    /// - target hosts the in-lockstep backup: a latched **role flip** —
    ///   placement metadata only, no data movement;
    /// - otherwise the rejoin pipeline, generalized: slot-preserving seed
    ///   under a brief source read latch, two off-latch redo-ship
    ///   catch-up rounds, then a final cut that read-latches *every* old
    ///   replica (freezing writers wherever they are routed), ships the
    ///   remaining tail, re-stamps the epoch, and swaps the placement.
    ///   The donor's orphaned replica is dropped after the cut; the old
    ///   backup (when present) stays the backup, so redundancy never dips.
    ///
    /// A [`NodeState::Joining`] target is flipped to `Alive` inside the
    /// cut (before the new placement is published, so there is no window
    /// where the new primary is unreachable).
    pub fn rebalance_partition(&self, table: &str, pidx: usize, to_node: u32) -> Result<()> {
        let _admin = self.admin.lock().unwrap();
        let meta = self.meta(table)?;
        let name = meta.def.name.clone();
        let key = name.to_lowercase();
        if pidx >= meta.placements.len() {
            return Err(Error::Catalog(format!(
                "partition {pidx} out of range for '{name}' ({} partitions)",
                meta.placements.len()
            )));
        }
        let pl = meta.placements[pidx];
        if pl.primary == to_node {
            return Ok(());
        }
        let target = self
            .node(to_node)
            .ok_or_else(|| Error::Unavailable(format!("no node {to_node}")))?;
        if !matches!(target.state(), NodeState::Alive | NodeState::Joining) {
            return Err(Error::Unavailable(format!(
                "rebalance target node {to_node} is {:?}",
                target.state()
            )));
        }
        if pl.backup == Some(to_node) {
            return self.flip_primary(&meta, &key, pidx, &target);
        }
        if target.hosts(&name, pidx) {
            // debris from an earlier aborted attempt: restart from scratch
            target.drop_partition(&name, pidx);
        }
        target.host_partition(meta.def.clone(), pidx)?;
        let res = self.move_into(&meta, &key, pidx, &target);
        if res.is_err() {
            target.drop_partition(&name, pidx);
        }
        res
    }

    /// Latched role flip (rebalance onto the current backup): both
    /// replicas already hold the rows in lockstep, so the cut is placement
    /// metadata only. Write latches on both stores exclude every writer;
    /// the epoch is bumped and stamped, and the catalog entry swapped,
    /// under those latches.
    fn flip_primary(
        &self,
        meta: &Arc<TableMeta>,
        key: &str,
        pidx: usize,
        target: &Arc<DataNode>,
    ) -> Result<()> {
        let name = &meta.def.name;
        let pl = meta.placements[pidx];
        if !target.is_alive() {
            return Err(Error::Unavailable(format!(
                "backup node {} of {name}[{pidx}] is not serving",
                target.id
            )));
        }
        let pn = self
            .node(pl.primary)
            .ok_or_else(|| Error::Unavailable(format!("no node {}", pl.primary)))?;
        let ps = pn.partition_even_if_dead(name, pidx)?;
        let bs = target.partition_even_if_dead(name, pidx)?;
        let mut g = ps.write().unwrap();
        let mut bg = bs.write().unwrap();
        let mut cat = self.catalog.write().unwrap();
        match cat.get(key) {
            Some(cur) if Arc::ptr_eq(cur, meta) => {}
            _ => {
                return Err(Error::Unavailable(
                    "topology changed during rebalance; retry".into(),
                ))
            }
        }
        if pn.is_alive() && (bg.version != g.version || bg.len() != g.len()) {
            return Err(Error::Unavailable(format!(
                "backup of {name}[{pidx}] is not in lockstep; heal first"
            )));
        }
        let epoch = self.epoch.fetch_add(1, AtomicOrdering::SeqCst) + 1;
        g.epoch = epoch;
        bg.epoch = epoch;
        let mut placements = meta.placements.clone();
        placements[pidx] = Placement { primary: target.id, backup: Some(pl.primary) };
        cat.insert(key.to_string(), Arc::new(TableMeta { def: meta.def.clone(), placements }));
        Ok(())
    }

    /// The full-move pipeline behind [`DbCluster::rebalance_partition`]:
    /// the target already hosts a fresh (empty) replica; seed it, catch it
    /// up off-latch, and cut.
    fn move_into(
        &self,
        meta: &Arc<TableMeta>,
        key: &str,
        pidx: usize,
        target: &Arc<DataNode>,
    ) -> Result<()> {
        let name = &meta.def.name;
        let pl = meta.placements[pidx];
        let dst = target.partition_even_if_dead(name, pidx)?;
        // Phase 1: slot-preserving seed under a brief source read latch.
        // Writers resume the moment it drops; the target reproduces the
        // source's slab layout (holes included) so slot-addressed redo
        // stays applicable.
        {
            let (src, _, _) = self.replica_store(meta, pidx, &pl, false)?;
            let g = src.read().unwrap();
            let (cap, rows) = g.snapshot_slotted();
            let mut d = dst.write().unwrap();
            d.load_slotted(cap, rows)?;
            d.version = g.version;
            d.epoch = g.epoch;
        }
        // Phase 2: bounded off-latch catch-up from the serving replica's
        // retained redo tail (the rejoin loop, re-aimed). The serving
        // replica is re-resolved each round so a donor death mid-move
        // degrades to catch-up from the surviving backup.
        for _ in 0..2 {
            let Ok((_, src_node, _)) = self.replica_store(meta, pidx, &pl, false) else {
                break;
            };
            let myv = dst.read().unwrap().version;
            let tail = self
                .node(src_node)
                .and_then(|n| n.wal.lock().unwrap().tail_since(name, pidx, myv));
            let Some(recs) = tail else { continue };
            if recs.is_empty() {
                continue;
            }
            let mut d = dst.write().unwrap();
            for rec in recs {
                if d.apply_redo(&rec).is_err() {
                    break;
                }
            }
        }
        // Phase 3: final cut. Read latches on *every* old replica — not
        // just the serving one — freeze writers wherever failover may have
        // routed them; the serving replica is then chosen from liveness
        // observed under those latches (the mirror-set rule, reused).
        // An injected fault here aborts the move before any latch or
        // catalog mutation; the caller drops the seeded target replica.
        failpoint::hit("rebalance-cut")?;
        let pn = self
            .node(pl.primary)
            .ok_or_else(|| Error::Unavailable(format!("no node {}", pl.primary)))?;
        let p_store = pn.partition_even_if_dead(name, pidx)?;
        let b_node = pl.backup.and_then(|b| self.node(b));
        let b_store = match &b_node {
            Some(bn) => Some(bn.partition_even_if_dead(name, pidx)?),
            None => None,
        };
        let pg = p_store.read().unwrap();
        let bg = b_store.as_ref().map(|s| s.read().unwrap());
        let (srcg, src_node): (&PartitionStore, u32) = if pn.is_alive() {
            (&pg, pl.primary)
        } else if let (Some(g), Some(bn)) = (bg.as_ref(), &b_node) {
            if bn.is_alive() {
                (g, bn.id)
            } else {
                return Err(Error::Unavailable(format!(
                    "all replicas of {name}[{pidx}] are down"
                )));
            }
        } else {
            return Err(Error::Unavailable(format!(
                "all replicas of {name}[{pidx}] are down"
            )));
        };
        let mut d = dst.write().unwrap();
        let mut cat = self.catalog.write().unwrap();
        match cat.get(key) {
            Some(cur) if Arc::ptr_eq(cur, meta) => {}
            _ => {
                return Err(Error::Unavailable(
                    "topology changed during rebalance; retry".into(),
                ))
            }
        }
        if d.version != srcg.version {
            let tail = self
                .node(src_node)
                .and_then(|n| n.wal.lock().unwrap().tail_since(name, pidx, d.version));
            if let Some(recs) = tail {
                for rec in recs {
                    if d.apply_redo(&rec).is_err() {
                        break;
                    }
                }
            }
        }
        if d.version != srcg.version || d.len() != srcg.len() {
            // the tail could not close the gap: full re-seed under the cut
            let (cap, rows) = srcg.snapshot_slotted();
            d.load_slotted(cap, rows)?;
            d.version = srcg.version;
        }
        let epoch = self.epoch.fetch_add(1, AtomicOrdering::SeqCst) + 1;
        d.epoch = epoch;
        target.wal.lock().unwrap().reset_segment(name, pidx, d.version);
        // Old backup stays the backup (no redundancy dip, no extra data
        // movement); without one, the donor itself becomes the backup —
        // its store is in lockstep at the cut by construction.
        let backup = if self.replication {
            match pl.backup {
                Some(b) => Some(b),
                None => Some(src_node),
            }
        } else {
            None
        };
        // Flip a Joining target to Alive *before* publishing the
        // placement, so the new primary is never published-but-unservable.
        if target.state() == NodeState::Joining {
            target.finish_join(epoch);
        }
        let mut placements = meta.placements.clone();
        placements[pidx] = Placement { primary: target.id, backup };
        cat.insert(key.to_string(), Arc::new(TableMeta { def: meta.def.clone(), placements }));
        drop(cat);
        drop(d);
        drop(bg);
        drop(pg);
        // Drop replicas orphaned by the new placement (the donor, unless
        // it became the backup).
        let kept: Vec<u32> = std::iter::once(target.id).chain(backup).collect();
        for nid in [Some(pl.primary), pl.backup].into_iter().flatten() {
            if !kept.contains(&nid) {
                if let Some(n) = self.node(nid) {
                    n.drop_partition(name, pidx);
                }
            }
        }
        // Fresh durable baseline for the target's rebased segment.
        if self.durability.is_some() {
            if let Err(e) = checkpoint::checkpoint_node(self, target.id) {
                log::warn!("post-rebalance checkpoint of node {} failed: {e}", target.id);
            }
        }
        Ok(())
    }

    /// Split a hot partition of `table` in two, online. The partition's
    /// congruence class `(m, r)` halves: the old index keeps
    /// `key mod 2m == r`, and a **new partition index** (appended) takes
    /// `key mod 2m == r + m` — see [`TableDef::split_partition`]. The new
    /// partition is placed on the same nodes as the source, so the split
    /// itself moves no data between nodes (chain a
    /// [`DbCluster::rebalance_partition`] to relocate it).
    ///
    /// The cut runs entirely under **write latches** on both source
    /// replicas: residue rows are re-dealt slot-preservingly into the four
    /// stores (source keeps its slots and holes; the new partition
    /// inherits the moved rows' slots, so primary and backup stay
    /// identical), the epoch is bumped and stamped, the WAL segments of
    /// all involved stores are rebased at the cut (the re-deal is a
    /// structural rewrite, not logged redo), and the catalog entry —
    /// including the new routing — is swapped before the latches drop.
    /// In-flight claims that latched behind the cut revalidate by `Arc`
    /// identity and re-route; analytics snapshots do the same.
    ///
    /// Returns the new partition's index.
    pub fn split_partition(&self, table: &str, pidx: usize) -> Result<usize> {
        let _admin = self.admin.lock().unwrap();
        let meta = self.meta(table)?;
        let name = meta.def.name.clone();
        let key = name.to_lowercase();
        let def2 = Arc::new(meta.def.split_partition(pidx)?);
        let new_pidx = meta.def.num_partitions();
        let pl = meta.placements[pidx];
        let pn = self
            .node(pl.primary)
            .ok_or_else(|| Error::Unavailable(format!("no node {}", pl.primary)))?;
        if !pn.is_alive() {
            return Err(Error::Unavailable(format!(
                "primary of {name}[{pidx}] is down; promote before splitting"
            )));
        }
        let b_node = pl.backup.and_then(|b| self.node(b));
        // Host the new partition's stores (invisible until the catalog
        // swap). A dead backup gets one too — stale until `heal` re-seeds
        // it, exactly like its stale source replica.
        for n in std::iter::once(&pn).chain(b_node.iter()) {
            if n.hosts(&name, new_pidx) {
                // debris from an earlier aborted attempt
                n.drop_partition(&name, new_pidx);
            }
            n.host_partition(def2.clone(), new_pidx)?;
        }
        let res = self.split_cut(&meta, &key, pidx, new_pidx, &def2, &pn, b_node.as_ref());
        if res.is_err() {
            pn.drop_partition(&name, new_pidx);
            if let Some(bn) = &b_node {
                bn.drop_partition(&name, new_pidx);
            }
        }
        res.map(|_| new_pidx)
    }

    /// The latched re-deal behind [`DbCluster::split_partition`].
    #[allow(clippy::too_many_arguments)]
    fn split_cut(
        &self,
        meta: &Arc<TableMeta>,
        key: &str,
        pidx: usize,
        new_pidx: usize,
        def2: &Arc<TableDef>,
        pn: &Arc<DataNode>,
        b_node: Option<&Arc<DataNode>>,
    ) -> Result<()> {
        let name = &meta.def.name;
        // Before any latch: an injected fault aborts the split cleanly
        // (the caller drops the freshly hosted, still-invisible stores).
        failpoint::hit("split-cut")?;
        let src = pn.partition(name, pidx)?;
        let ndst = pn.partition_even_if_dead(name, new_pidx)?;
        let b_src = match b_node {
            Some(bn) => Some(bn.partition_even_if_dead(name, pidx)?),
            None => None,
        };
        let b_ndst = match b_node {
            Some(bn) => Some(bn.partition_even_if_dead(name, new_pidx)?),
            None => None,
        };
        // Write latches: source primary, source backup (canonical role
        // order), then the still-invisible new stores (uncontended).
        let mut g = src.write().unwrap();
        let mut bg = b_src.as_ref().map(|s| s.write().unwrap());
        let mut nd = ndst.write().unwrap();
        let mut bnd = b_ndst.as_ref().map(|s| s.write().unwrap());
        let mut cat = self.catalog.write().unwrap();
        match cat.get(key) {
            Some(cur) if Arc::ptr_eq(cur, meta) => {}
            _ => {
                return Err(Error::Unavailable("topology changed during split; retry".into()))
            }
        }
        let v = g.version;
        let pre_len = g.len();
        // Re-deal the source rows by the post-split routing. Kept rows
        // keep their slots (and the slab keeps its holes); moved rows keep
        // their slots in the new partition's slab — both replicas of both
        // partitions therefore reproduce identical layouts, and future
        // canonical slot choices stay in lockstep.
        let (cap, rows) = g.snapshot_slotted();
        let mut kept: Vec<(Slot, Arc<Row>)> = Vec::with_capacity(rows.len());
        let mut moved: Vec<(Slot, Arc<Row>)> = Vec::new();
        for (slot, row) in rows {
            match def2.partition_of_row(&row.values)? {
                p if p == pidx => kept.push((slot, row)),
                p if p == new_pidx => moved.push((slot, row)),
                p => {
                    return Err(Error::Engine(format!(
                        "split of {name}[{pidx}] routed a row to foreign partition {p}"
                    )))
                }
            }
        }
        let epoch = self.epoch.fetch_add(1, AtomicOrdering::SeqCst) + 1;
        g.load_slotted(cap, kept.clone())?;
        g.version = v;
        g.epoch = epoch;
        nd.load_slotted(cap, moved.clone())?;
        nd.version = v;
        nd.epoch = epoch;
        // The backup mirrors the re-deal only when it is serving and in
        // lockstep; a dead or stale backup keeps its stale stores and is
        // re-seeded wholesale by the next heal sweep.
        let backup_live = b_node.map_or(false, |bn| bn.is_alive())
            && bg.as_ref().map_or(false, |b| b.version == v && b.len() == pre_len);
        if backup_live {
            if let (Some(b), Some(bn_store)) = (bg.as_mut(), bnd.as_mut()) {
                b.load_slotted(cap, kept)?;
                b.version = v;
                b.epoch = epoch;
                bn_store.load_slotted(cap, moved)?;
                bn_store.version = v;
                bn_store.epoch = epoch;
            }
        }
        // Rebase the WAL segments of every store the cut touched: the
        // re-deal is a structural rewrite outside the redo stream, so the
        // segments restart at the cut version (dense from here on).
        {
            let mut w = pn.wal.lock().unwrap();
            w.reset_segment(name, pidx, v);
            w.reset_segment(name, new_pidx, v);
        }
        if backup_live {
            if let Some(bn) = b_node {
                let mut w = bn.wal.lock().unwrap();
                w.reset_segment(name, pidx, v);
                w.reset_segment(name, new_pidx, v);
            }
        }
        let mut placements = meta.placements.clone();
        let src_pl = meta.placements[pidx];
        placements.push(Placement { primary: src_pl.primary, backup: src_pl.backup });
        cat.insert(
            key.to_string(),
            Arc::new(TableMeta { def: def2.clone(), placements }),
        );
        drop(cat);
        drop(bnd);
        drop(nd);
        drop(bg);
        drop(g);
        // Fresh durable baseline: the on-disk checkpoints predate the
        // re-deal, and a crash before the next cut would otherwise replay
        // pre-split history into post-split stores (the rejoin length
        // check catches it, but a current checkpoint avoids the re-seed).
        if self.durability.is_some() {
            if let Err(e) = checkpoint::checkpoint_node(self, pn.id) {
                log::warn!("post-split checkpoint of node {} failed: {e}", pn.id);
            }
            if let Some(bn) = b_node {
                if bn.is_alive() {
                    if let Err(e) = checkpoint::checkpoint_node(self, bn.id) {
                        log::warn!("post-split checkpoint of node {} failed: {e}", bn.id);
                    }
                }
            }
        }
        Ok(())
    }

    /// Rank split/move candidates from the obs registry's 64-way sharded
    /// per-partition cells (claims + WAL records — the write-side heat the
    /// paper's skewed-workload concern is about). A partition is flagged
    /// when its shard cell carries more than twice the median heat; large
    /// ones (above their table's average rows) get [`AdviceAction::Split`],
    /// small ones a [`AdviceAction::Move`] to the least-loaded eligible
    /// node. Shard cells alias `pidx % 64` across tables, so treat heat as
    /// an attribution upper bound, not an exact count.
    pub fn advise_topology(&self) -> Vec<TopologyAdvice> {
        let topo = self.topology();
        // Least-loaded eligible target: Alive or Joining, fewest replicas.
        let target = topo
            .nodes
            .iter()
            .filter(|n| matches!(n.state, NodeState::Alive | NodeState::Joining))
            .min_by_key(|n| n.partitions)
            .map(|n| n.id);
        let mut heats: Vec<u64> = Vec::new();
        let mut cand: Vec<(u64, &TableTopology, &PartitionInfo)> = Vec::new();
        for t in &topo.tables {
            if t.table == MONITORING_TABLE {
                continue;
            }
            for p in &t.partitions {
                let heat = self.obs.part_shard(PartMetric::Claims, p.pidx)
                    + self.obs.part_shard(PartMetric::WalRecords, p.pidx);
                heats.push(heat);
                cand.push((heat, t, p));
            }
        }
        if heats.len() < 2 {
            return vec![];
        }
        heats.sort_unstable();
        let median = heats[heats.len() / 2].max(1);
        let mut out: Vec<TopologyAdvice> = Vec::new();
        for (heat, t, p) in cand {
            if heat <= median.saturating_mul(2) {
                continue;
            }
            let avg_rows =
                t.partitions.iter().map(|q| q.rows).sum::<usize>() / t.partitions.len().max(1);
            let action = if p.rows > avg_rows && t.partitions.len() > 1 {
                AdviceAction::Split
            } else {
                match target {
                    Some(n) if n != p.primary => AdviceAction::Move { to_node: n },
                    _ => continue,
                }
            };
            out.push(TopologyAdvice { table: t.table.clone(), pidx: p.pidx, heat, action });
        }
        out.sort_by(|a, b| b.heat.cmp(&a.heat));
        out.truncate(8);
        out
    }

    /// Canonical, order-independent serialization of every table's
    /// committed rows (read from the serving replicas). Two clusters fed
    /// the identical committed stream — e.g. a kill/rejoin survivor and a
    /// never-killed twin — must produce byte-equal fingerprints; the chaos
    /// tests enforce exactly that.
    pub fn fingerprint(&self) -> Result<String> {
        let mut out = String::new();
        for table in self.tables() {
            if table == MONITORING_TABLE {
                // telemetry is per-cluster by construction; twins diverge
                continue;
            }
            let meta = self.meta(&table)?;
            let mut lines: Vec<String> = Vec::new();
            for (pidx, pl) in meta.placements.iter().enumerate() {
                let (store, _, _) = self.replica_store(&meta, pidx, pl, false)?;
                let g = store.read().unwrap();
                for (_, row) in g.iter() {
                    let vals: Vec<String> = row.values.iter().map(encode_value).collect();
                    lines.push(vals.join("\t"));
                }
            }
            lines.sort();
            out.push_str(&table);
            out.push('\n');
            for l in lines {
                out.push_str(&l);
                out.push('\n');
            }
        }
        Ok(out)
    }

    // ---------- the system `monitoring` table ----------

    /// Create the system `monitoring` table if it does not exist yet. Its
    /// rows are keyed and hash-partitioned on a sequential row id (`mid`) —
    /// *not* on the `part`/`node` data columns, which carry `-1` sentinels
    /// for cluster-global metrics — so telemetry itself spreads over the
    /// partitions and is served by the normal scatter-gather path.
    fn ensure_monitoring(&self) -> Result<()> {
        if self.catalog.read().unwrap().contains_key(MONITORING_TABLE) {
            return Ok(());
        }
        let r = self.exec(&format!(
            "CREATE TABLE {MONITORING_TABLE} (mid INT NOT NULL, metric TEXT NOT NULL, \
             part INT NOT NULL, node INT NOT NULL, epoch INT NOT NULL, value FLOAT, \
             cnt INT NOT NULL) \
             PARTITION BY HASH(mid) PARTITIONS 4 PRIMARY KEY (mid) INDEX (metric)"
        ));
        match r {
            Ok(_) => Ok(()),
            // lost a create race: another reader materialized it first
            Err(Error::Catalog(msg)) if msg.contains("already exists") => Ok(()),
            Err(e) => Err(e),
        }
    }

    /// (Re)materialize the system `monitoring` table from the obs registry:
    /// one row per metric (× partition shard / × node), epoch-stamped.
    /// Serialized by an internal mutex; runs automatically before any
    /// SELECT that references the table, so steering clients always read a
    /// current snapshot through the ordinary SQL path. The row set is built
    /// *before* the delete+reinsert, so with writers quiesced the table is
    /// an exact, internally consistent image of the registry.
    pub fn refresh_monitoring(&self) -> Result<()> {
        let _g = self.monitoring_refresh.lock().unwrap();
        self.ensure_monitoring()?;
        let rows = self.obs.monitoring_rows(self.cluster_epoch());
        self.exec_tagged(0, AccessKind::Other, &format!("DELETE FROM {MONITORING_TABLE}"))?;
        let ins = self.prepare(&format!(
            "INSERT INTO {MONITORING_TABLE} (mid, metric, part, node, epoch, value, cnt) \
             VALUES (?, ?, ?, ?, ?, ?, ?)"
        ))?;
        self.exec_prepared_batch(0, AccessKind::Other, &ins, &rows)?;
        self.obs.inc(Counter::MonitoringRefreshes);
        Ok(())
    }

    // ---------- prepared statements ----------

    /// Prepare a statement: lex + parse once, resolve the referenced
    /// tables/columns against the catalog, and cache the plan so every
    /// later `prepare` of the same text is a map lookup. The returned
    /// handle is executor-independent — bind and run it through this
    /// cluster, any [`Connector`](crate::storage::connector::Connector),
    /// or a `WorkerLink`, before and after failover.
    pub fn prepare(&self, sql_text: &str) -> Result<Prepared> {
        if let Some(plan) = self.plans.read().unwrap().get(sql_text) {
            return Ok(Prepared::from_plan(plan.clone()));
        }
        let (stmt, params) = sql::parse_prepared(sql_text)?;
        self.validate_against_catalog(&stmt)?;
        // EXPLAIN-style plan summary, rendered once against the live
        // catalog (partition counts, partition columns) — what
        // `Prepared::describe()` returns.
        let describe = query_plan::explain(&stmt, |t: &str| {
            self.meta(t).ok().map(|m| TableInfo {
                partitions: m.def.num_partitions(),
                partition_col: m
                    .def
                    .partition_col_idx()
                    .map(|ci| m.def.schema.columns[ci].name.clone()),
            })
        });
        // Classify into a compiled physical plan when the statement fits a
        // fast point-DML shape; `None` keeps every execution interpreted.
        let dml = dml_plan::compile(&stmt, |t: &str| self.meta(t).ok().map(|m| m.def.clone()));
        let describe = match &dml {
            Some(d) => format!("{describe}\ncompiled: {}", d.kind()),
            None => describe,
        };
        let plan =
            Arc::new(PreparedPlan { sql: sql_text.to_string(), stmt, params, describe, dml });
        let mut cache = self.plans.write().unwrap();
        if cache.len() >= PLAN_CACHE_MAX {
            // evict one arbitrary entry; clearing everything would force a
            // cluster-wide re-parse of the hot statements mid-run
            if let Some(k) = cache.keys().next().cloned() {
                cache.remove(&k);
            }
        }
        let entry = cache
            .entry(sql_text.to_string())
            .or_insert_with(|| plan.clone())
            .clone();
        Ok(Prepared::from_plan(entry))
    }

    /// Number of plans currently cached (monitoring/tests).
    pub fn cached_plans(&self) -> usize {
        self.plans.read().unwrap().len()
    }

    /// Prepare-time catalog resolution: every referenced table must exist,
    /// and INSERT/UPDATE column lists must resolve against its schema, so
    /// typos surface at prepare time rather than on the Nth execution.
    /// (SELECT output columns resolve at execution against join layouts —
    /// alias scoping makes them a runtime concern.)
    fn validate_against_catalog(&self, stmt: &Statement) -> Result<()> {
        match stmt {
            Statement::Select(s) => {
                self.meta(&s.from.table)?;
                for j in &s.joins {
                    self.meta(&j.table.table)?;
                }
            }
            Statement::Insert { table, columns, values } => {
                let meta = self.meta(table)?;
                for c in columns {
                    if meta.def.schema.index_of(c).is_none() {
                        return Err(Error::Catalog(format!(
                            "unknown column '{c}' in INSERT INTO {table}"
                        )));
                    }
                }
                let arity = if columns.is_empty() { meta.def.schema.len() } else { columns.len() };
                for row in values {
                    if row.len() != arity {
                        return Err(Error::Type(format!(
                            "INSERT arity mismatch: {} values for {arity} columns",
                            row.len()
                        )));
                    }
                }
            }
            Statement::Update { table, sets, .. } => {
                let meta = self.meta(&table.table)?;
                for (c, _) in sets {
                    if meta.def.schema.index_of(c).is_none() {
                        return Err(Error::Catalog(format!(
                            "unknown column '{c}' in UPDATE {}",
                            table.table
                        )));
                    }
                }
            }
            Statement::Delete { table, .. } => {
                self.meta(&table.table)?;
            }
            Statement::CreateTable { .. } => {}
        }
        Ok(())
    }

    /// Execute a prepared statement with one value bound per placeholder.
    ///
    /// Statements whose prepare-time classification produced a compiled
    /// physical plan (see [`crate::storage::dml_plan`]) run through the
    /// fast path: bound values route straight to the pruned partition, no
    /// AST clone, no per-call lock-set map. Everything else — and any
    /// binding the fast path cannot route (e.g. a non-integer partition
    /// key) — binds and executes through the interpreted reference path.
    pub fn exec_prepared(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        let _span = span::begin(&self.obs, "exec_prepared");
        if let Some(plan) = prepared.fast_plan() {
            if params.len() == prepared.param_count() {
                let t0 = Instant::now();
                match self.exec_fast(plan, params) {
                    Ok(Some(r)) => {
                        self.routes.fast_dml.fetch_add(1, AtomicOrdering::Relaxed);
                        let el = t0.elapsed();
                        self.obs.inc(Counter::DmlFast);
                        self.obs.rec_nanos(Hist::ClaimFast, el.as_nanos() as u64);
                        self.stats.record(node, kind, el.as_secs_f64());
                        return Ok(r);
                    }
                    Ok(None) => {} // runtime shape mismatch: interpret
                    Err(e) => {
                        self.stats.record(node, kind, t0.elapsed().as_secs_f64());
                        return Err(e);
                    }
                }
            }
        }
        let is_dml = !matches!(prepared.statement(), Statement::Select(_));
        let t1 = self.obs.start();
        let r = self.exec_prepared_interpreted(node, kind, prepared, params);
        if is_dml && r.is_ok() {
            self.obs.rec_since(Hist::ClaimInterp, t1);
            self.obs.inc(Counter::DmlInterp);
        }
        r
    }

    /// Execute a prepared statement through the interpreted reference path,
    /// bypassing the compiled fast path. This is the semantic baseline the
    /// differential tests (`tests/dml_fastpath.rs`) and the claim-loop
    /// microbenchmark compare against; it is also the fallback `exec_prepared`
    /// takes for unsupported shapes.
    pub fn exec_prepared_interpreted(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        params: &[Value],
    ) -> Result<StatementResult> {
        let stmt = prepared.bind(params)?;
        self.exec_stmt(node, kind, &stmt)
    }

    /// Execute a prepared single-row INSERT template once per entry of
    /// `rows`, as one atomic multi-row insert. Fast-classified inserts
    /// apply each row directly (write-locking only the partitions the
    /// batch actually lands in); other shapes expand the template and run
    /// interpreted.
    pub fn exec_prepared_batch(
        &self,
        node: u32,
        kind: AccessKind,
        prepared: &Prepared,
        rows: &[Vec<Value>],
    ) -> Result<StatementResult> {
        let _span = span::begin(&self.obs, "exec_prepared_batch");
        if let Some(DmlPlan::Insert(p)) = prepared.fast_plan() {
            if !rows.is_empty() && rows.iter().all(|r| r.len() == prepared.param_count()) {
                let refs: Vec<&[Value]> = rows.iter().map(|r| r.as_slice()).collect();
                let t0 = Instant::now();
                match self.fast_insert(p, &refs) {
                    Ok(Some(r)) => {
                        self.routes.fast_dml.fetch_add(1, AtomicOrdering::Relaxed);
                        let el = t0.elapsed();
                        self.obs.inc(Counter::DmlFast);
                        self.obs.rec_nanos(Hist::ClaimFast, el.as_nanos() as u64);
                        self.stats.record(node, kind, el.as_secs_f64());
                        return Ok(r);
                    }
                    Ok(None) => {}
                    Err(e) => {
                        self.stats.record(node, kind, t0.elapsed().as_secs_f64());
                        return Err(e);
                    }
                }
            }
        }
        let t1 = self.obs.start();
        let stmt = prepared.bind_batch(rows)?;
        let r = self.exec_stmt(node, kind, &stmt);
        if r.is_ok() {
            // bind_batch only accepts INSERT templates, so this is DML
            self.obs.rec_since(Hist::ClaimInterp, t1);
            self.obs.inc(Counter::DmlInterp);
        }
        r
    }

    /// Convenience: prepared SELECT returning rows.
    pub fn query_prepared(&self, prepared: &Prepared, params: &[Value]) -> Result<ResultSet> {
        match self.exec_prepared(0, AccessKind::Other, prepared, params)? {
            StatementResult::Rows(r) => Ok(r),
            other => Err(Error::Engine(format!("expected rows, got {other:?}"))),
        }
    }

    // ---------- the compiled DML fast path ----------

    /// Execute a compiled plan. `Ok(None)` means this particular binding
    /// cannot be fast-routed (non-integer partition key, unpromoted dead
    /// primary); the caller falls back to the interpreted path, which
    /// remains the semantic reference.
    ///
    /// Under [`ConcurrencyMode::Occ`], eligible point writes try the
    /// optimistic path first; its fallback chain lands back here on the
    /// 2PL fast path (contention) or on `Ok(None)` (routing/mirror state
    /// the optimistic path does not handle), so the three-tier structure
    /// is OCC → 2PL fast → interpreted.
    fn exec_fast(&self, plan: &DmlPlan, params: &[Value]) -> Result<Option<StatementResult>> {
        match plan {
            DmlPlan::Update(p) => {
                if self.concurrency == ConcurrencyMode::Occ {
                    match self.occ_update(p, params)? {
                        OccOutcome::Done(r) => return Ok(Some(r)),
                        OccOutcome::Interpret => return Ok(None),
                        OccOutcome::TwoPL => {}
                    }
                }
                self.fast_update(p, params)
            }
            DmlPlan::Delete(p) => {
                if self.concurrency == ConcurrencyMode::Occ {
                    match self.occ_delete(p, params)? {
                        OccOutcome::Done(r) => return Ok(Some(r)),
                        OccOutcome::Interpret => return Ok(None),
                        OccOutcome::TwoPL => {}
                    }
                }
                self.fast_delete(p, params)
            }
            DmlPlan::Insert(p) => self.fast_insert(p, &[params]),
            DmlPlan::Select(p) => self.fast_select(p, params),
        }
    }

    // ---------- the optimistic (OCC) point-DML path ----------

    /// Optimistic point UPDATE (the claim-loop shape): read the target
    /// row's handle and slot stamp under the partition **read** latch,
    /// compute the new row entirely off-lock, then revalidate-and-install
    /// under a short commit critical section. Only the install — not the
    /// probe, predicate evaluation, expression evaluation, coercion, or
    /// row allocation — serializes on the write latches, which is what
    /// lets concurrent claimers of *different* rows in one partition
    /// scale past the 2PL fast path.
    ///
    /// Validation rule: the slot's stamp must equal the stamp observed at
    /// read time **and** the slot must still hold the very `Arc<Row>` we
    /// read. The stamp catches every in-store rewrite (stamps are
    /// monotone per store and never rewind, even on abort); the handle
    /// identity closes the cross-store hole where a failover between read
    /// and commit retargets validation at a re-seeded replica whose
    /// independent stamp clock could coincide — we hold the observed
    /// `Arc`, so its allocation cannot be reused while we compare.
    ///
    /// The commit section preserves every 2PL fast-path invariant: latch
    /// order via `fast_lock`, `fast_mirror_valid` under the held latches,
    /// dense LSNs (validation failure consumes none; aborts restore
    /// pre-versions), epoch captured under the latches, and WAL append to
    /// exactly the applied nodes.
    fn occ_update(&self, p: &UpdatePlan, params: &[Value]) -> Result<OccOutcome> {
        // Shape gate: single-row PK point updates. ORDER BY / LIMIT are
        // meaningless on a one-row match but imply a scan-shaped plan;
        // those and non-PK probes keep the 2PL fast path.
        if !p.order.is_empty() || p.limit.is_some() {
            return Ok(OccOutcome::TwoPL);
        }
        let Probe::Pk(pkv) = &p.probe else {
            return Ok(OccOutcome::TwoPL);
        };
        let meta = self.meta(&p.table)?;
        let def = meta.def.clone();
        let Some(parts) = p.route.resolve(&def, params) else {
            return Ok(OccOutcome::Interpret); // non-integer partition key
        };
        if parts.len() != 1 {
            return Ok(OccOutcome::TwoPL);
        }
        let pidx = parts[0];
        let mut retries: u64 = 0;
        loop {
            // ---- read phase: no write latches ----
            let pl = &meta.placements[pidx];
            let (store, _, role) = self.replica_store(&meta, pidx, pl, true)?;
            if role != Role::Primary {
                return Ok(OccOutcome::Interpret); // dead primary, unpromoted
            }
            let now = self.clock.now();
            let observed = {
                let g = store.read().unwrap();
                match pkv.get(params).as_i64().and_then(|k| g.slot_by_pk(k)) {
                    None => None,
                    Some(slot) => g.get_arc(slot).and_then(|row| {
                        p.preds
                            .iter()
                            .all(|c| c.matches(&row.values, params))
                            .then(|| (slot, g.slot_stamp(slot), row))
                    }),
                }
            };
            let Some((slot, stamp, old)) = observed else {
                // No match at the read latch — that latch hold is the
                // linearization point, exactly as if the 2PL fast path had
                // run then and found nothing. (Not an OCC commit: neither
                // occ_dml nor the retry distribution records it, keeping
                // the histogram-count invariants exact.)
                self.obs.part_add_list(PartMetric::Claims, &parts);
                return Ok(OccOutcome::Done(match &p.returning {
                    Some(cols) => StatementResult::Rows(ResultSet {
                        columns: cols.iter().map(|(_, n)| n.clone()).collect(),
                        rows: Vec::new(),
                    }),
                    None => StatementResult::Affected(0),
                }));
            };

            // ---- compute phase: off-lock ----
            let built: Result<Row> = (|| {
                let mut vals = old.values.clone();
                for (ci, e) in &p.sets {
                    vals[*ci] = e.eval(&old.values, params, now)?;
                }
                def.schema.coerce_row(Row::new(vals))
            })();
            let new_arc = match built {
                Ok(r) => Arc::new(r),
                // nothing applied: same no-trace abort as the 2PL path
                Err(e) => return Err(Error::TxnAborted(e.to_string())),
            };

            // ---- commit critical section ----
            let Some(set) = self.fast_lock(&meta, &parts, false)? else {
                return Ok(OccOutcome::Interpret);
            };
            let (locks, targets) = (set.locks, set.targets);
            let t_latch = self.obs.start();
            let mut guards: Vec<Guard<'_>> = locks
                .iter()
                .map(|(w, s)| {
                    if *w {
                        Guard::W(s.write().unwrap())
                    } else {
                        Guard::R(s.read().unwrap())
                    }
                })
                .collect();
            if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
                span::stage_add(Stage::Latch, n);
            }
            if !self.fast_mirror_valid(&meta, &targets) {
                return Ok(OccOutcome::Interpret);
            }
            let t_validate = self.obs.start();
            let t = &targets[0];
            let valid = {
                let prim = store_of(&guards, t.prim);
                prim.slot_stamp(slot) == stamp
                    && prim.get_arc(slot).map_or(false, |cur| Arc::ptr_eq(&cur, &old))
            };
            if !valid {
                drop(guards);
                self.routes.occ_retries.fetch_add(1, AtomicOrdering::Relaxed);
                self.obs.inc(Counter::OccRetries);
                self.obs.rec_since(Hist::OccValidate, t_validate);
                retries += 1;
                if retries >= OCC_MAX_RETRIES {
                    self.routes.occ_fallbacks.fetch_add(1, AtomicOrdering::Relaxed);
                    self.obs.inc(Counter::OccFallbacks);
                    self.obs.rec_count(Hist::OccRetryDist, retries);
                    return Ok(OccOutcome::TwoPL);
                }
                occ_backoff(retries);
                continue;
            }
            self.obs.part_add_list(PartMetric::Claims, &parts);
            let pre_versions = fast_pre_versions(&guards, &targets);
            let lsn = match store_of_mut(&mut guards, t.prim)
                .and_then(|s| s.update_arc(slot, new_arc.clone()))
            {
                Ok(displaced) => {
                    let lsn = store_of(&guards, t.prim).version;
                    let mut backup_err = None;
                    if let Some(bi) = t.backup {
                        if let Err(e) = store_of_mut(&mut guards, bi)
                            .and_then(|s| s.update_arc(slot, new_arc.clone()))
                        {
                            backup_err = Some(e);
                        }
                    }
                    if let Some(e) = backup_err {
                        store_of_mut(&mut guards, t.prim)
                            .and_then(|s| s.update_arc(slot, displaced.clone()).map(|_| ()))
                            .unwrap_or_else(|e2| {
                                panic!("occ rollback failed: {e2} (original error: {e})")
                            });
                        fast_restore_versions(&mut guards, &pre_versions);
                        return Err(Error::TxnAborted(e.to_string()));
                    }
                    lsn
                }
                Err(e) => {
                    fast_restore_versions(&mut guards, &pre_versions);
                    return Err(Error::TxnAborted(e.to_string()));
                }
            };
            let result = match &p.returning {
                Some(cols) => StatementResult::Rows(ResultSet {
                    columns: cols.iter().map(|(_, n)| n.clone()).collect(),
                    rows: vec![Row::new(
                        cols.iter().map(|(ci, _)| new_arc.values[*ci].clone()).collect(),
                    )],
                }),
                None => StatementResult::Affected(1),
            };
            let ops = vec![(
                lsn,
                LogOp::Update { table: p.table.clone(), pidx, slot, row: new_arc.clone() },
            )];
            let epoch = self.cluster_epoch();
            self.obs.rec_since(Hist::OccValidate, t_validate);
            drop(guards);
            self.append_committed_fast(epoch, &ops, &targets)?;
            self.routes.occ_dml.fetch_add(1, AtomicOrdering::Relaxed);
            self.obs.inc(Counter::OccDml);
            self.obs.rec_count(Hist::OccRetryDist, retries);
            return Ok(OccOutcome::Done(result));
        }
    }

    /// Optimistic point DELETE: same protocol as [`DbCluster::occ_update`]
    /// (read + stamp off-latch, revalidate-and-remove in the commit
    /// section, slot-addressed reinsert on backup failure).
    fn occ_delete(&self, p: &DeletePlan, params: &[Value]) -> Result<OccOutcome> {
        let Probe::Pk(pkv) = &p.probe else {
            return Ok(OccOutcome::TwoPL);
        };
        let meta = self.meta(&p.table)?;
        let def = meta.def.clone();
        let Some(parts) = p.route.resolve(&def, params) else {
            return Ok(OccOutcome::Interpret);
        };
        if parts.len() != 1 {
            return Ok(OccOutcome::TwoPL);
        }
        let pidx = parts[0];
        let mut retries: u64 = 0;
        loop {
            let pl = &meta.placements[pidx];
            let (store, _, role) = self.replica_store(&meta, pidx, pl, true)?;
            if role != Role::Primary {
                return Ok(OccOutcome::Interpret);
            }
            let observed = {
                let g = store.read().unwrap();
                match pkv.get(params).as_i64().and_then(|k| g.slot_by_pk(k)) {
                    None => None,
                    Some(slot) => g.get_arc(slot).and_then(|row| {
                        p.preds
                            .iter()
                            .all(|c| c.matches(&row.values, params))
                            .then(|| (slot, g.slot_stamp(slot), row))
                    }),
                }
            };
            let Some((slot, stamp, old)) = observed else {
                self.obs.part_add_list(PartMetric::Claims, &parts);
                return Ok(OccOutcome::Done(StatementResult::Affected(0)));
            };

            let Some(set) = self.fast_lock(&meta, &parts, false)? else {
                return Ok(OccOutcome::Interpret);
            };
            let (locks, targets) = (set.locks, set.targets);
            let t_latch = self.obs.start();
            let mut guards: Vec<Guard<'_>> = locks
                .iter()
                .map(|(w, s)| {
                    if *w {
                        Guard::W(s.write().unwrap())
                    } else {
                        Guard::R(s.read().unwrap())
                    }
                })
                .collect();
            if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
                span::stage_add(Stage::Latch, n);
            }
            if !self.fast_mirror_valid(&meta, &targets) {
                return Ok(OccOutcome::Interpret);
            }
            let t_validate = self.obs.start();
            let t = &targets[0];
            let valid = {
                let prim = store_of(&guards, t.prim);
                prim.slot_stamp(slot) == stamp
                    && prim.get_arc(slot).map_or(false, |cur| Arc::ptr_eq(&cur, &old))
            };
            if !valid {
                drop(guards);
                self.routes.occ_retries.fetch_add(1, AtomicOrdering::Relaxed);
                self.obs.inc(Counter::OccRetries);
                self.obs.rec_since(Hist::OccValidate, t_validate);
                retries += 1;
                if retries >= OCC_MAX_RETRIES {
                    self.routes.occ_fallbacks.fetch_add(1, AtomicOrdering::Relaxed);
                    self.obs.inc(Counter::OccFallbacks);
                    self.obs.rec_count(Hist::OccRetryDist, retries);
                    return Ok(OccOutcome::TwoPL);
                }
                occ_backoff(retries);
                continue;
            }
            self.obs.part_add_list(PartMetric::Claims, &parts);
            let pre_versions = fast_pre_versions(&guards, &targets);
            let lsn = match store_of_mut(&mut guards, t.prim).and_then(|s| s.delete(slot)) {
                Ok(removed) => {
                    let lsn = store_of(&guards, t.prim).version;
                    let mut backup_err = None;
                    if let Some(bi) = t.backup {
                        if let Err(e) =
                            store_of_mut(&mut guards, bi).and_then(|s| s.delete(slot).map(|_| ()))
                        {
                            backup_err = Some(e);
                        }
                    }
                    if let Some(e) = backup_err {
                        store_of_mut(&mut guards, t.prim)
                            .and_then(|s| s.insert_at_arc(slot, removed.clone()))
                            .unwrap_or_else(|e2| {
                                panic!("occ rollback failed: {e2} (original error: {e})")
                            });
                        fast_restore_versions(&mut guards, &pre_versions);
                        return Err(Error::TxnAborted(e.to_string()));
                    }
                    lsn
                }
                Err(e) => {
                    fast_restore_versions(&mut guards, &pre_versions);
                    return Err(Error::TxnAborted(e.to_string()));
                }
            };
            let ops =
                vec![(lsn, LogOp::Delete { table: p.table.clone(), pidx, slot })];
            let epoch = self.cluster_epoch();
            self.obs.rec_since(Hist::OccValidate, t_validate);
            drop(guards);
            self.append_committed_fast(epoch, &ops, &targets)?;
            self.routes.occ_dml.fetch_add(1, AtomicOrdering::Relaxed);
            self.obs.inc(Counter::OccDml);
            self.obs.rec_count(Hist::OccRetryDist, retries);
            return Ok(OccOutcome::Done(StatementResult::Affected(1)));
        }
    }

    /// Acquire the fast path's latch set for a write statement: for every
    /// target partition (ascending — the same canonical order the 2PL
    /// executor sorts into, so the two paths can never deadlock against
    /// each other) the live primary plus, when alive, its backup, both
    /// write-locked. With `read_rest`, every non-target partition is
    /// read-locked too (the cross-partition PK probe of fast inserts — the
    /// interpreter write-locks the whole table for this). Returns `None`
    /// when a target's live replica is serving in the backup role (dead
    /// primary not yet promoted): that corner stays interpreted.
    fn fast_lock(
        &self,
        meta: &TableMeta,
        parts: &[usize],
        read_rest: bool,
    ) -> Result<Option<FastLockSet>> {
        let n = meta.def.num_partitions();
        let mut locks: Vec<(bool, Arc<RwLock<PartitionStore>>)> = Vec::new();
        let mut targets: Vec<FastTarget> = Vec::new();
        let mut live_of: Vec<Option<usize>> = vec![None; n];
        for pidx in 0..n {
            let is_target = parts.binary_search(&pidx).is_ok();
            if !is_target && !read_rest {
                continue;
            }
            let pl = &meta.placements[pidx];
            if is_target {
                let (store, prim_node, role) = self.replica_store(meta, pidx, pl, true)?;
                if role != Role::Primary {
                    return Ok(None);
                }
                locks.push((true, store));
                let prim = locks.len() - 1;
                live_of[pidx] = Some(prim);
                let mut backup = None;
                let mut backup_node = None;
                if let Some(bid) = pl.backup {
                    if let Some(bn) = self.node(bid) {
                        if bn.is_alive() {
                            locks.push((true, bn.partition(&meta.def.name, pidx)?));
                            backup = Some(locks.len() - 1);
                            backup_node = Some(bid);
                        }
                    }
                }
                targets.push(FastTarget { pidx, prim, backup, prim_node, backup_node });
            } else {
                let (store, _, _) = self.replica_store(meta, pidx, pl, false)?;
                locks.push((false, store));
                live_of[pidx] = Some(locks.len() - 1);
            }
        }
        Ok(Some(FastLockSet { locks, targets, live_of }))
    }

    /// Re-check, **under the held latches**, that every fast target's
    /// backup-mirror decision still matches node liveness. `fast_lock`
    /// decides inclusion from `is_alive()` before the latches are taken; a
    /// node that changes state in between — it dies, or it is a rejoiner
    /// whose final cut we were queued behind and which flipped `Alive`
    /// while we waited — would make the statement apply to one replica set
    /// while `append_committed` logs to another, silently diverging a
    /// fresh replica's store from its WAL. On mismatch the caller returns
    /// `Ok(None)` and the statement falls back to the interpreted path,
    /// whose lock machinery revalidates and rebuilds its lock set.
    ///
    /// The check also re-fetches the catalog entry and compares it by
    /// `Arc` identity: a topology cut (promotion, partition move, split)
    /// swaps in a fresh `TableMeta` *while holding the partition latches
    /// we just queued behind*, so observing the captured `Arc` still
    /// installed proves the placements (and routing) the lock set was
    /// built from are still current. A writer that latched after a cut
    /// would otherwise apply to an orphaned store or mis-route a moved
    /// key. (Safe to read the catalog here: no path holds the catalog
    /// lock while waiting on a partition latch.)
    fn fast_mirror_valid(&self, meta: &TableMeta, targets: &[FastTarget]) -> bool {
        let key = meta.def.name.to_lowercase();
        let current = self.catalog.read().unwrap().get(&key).cloned();
        match current {
            Some(cur) if std::ptr::eq(Arc::as_ptr(&cur), meta as *const TableMeta) => {}
            _ => return false,
        }
        targets.iter().all(|t| {
            let backup_alive = meta.placements[t.pidx]
                .backup
                .and_then(|b| self.node(b))
                .map_or(false, |n| n.is_alive());
            backup_alive == t.backup.is_some()
        })
    }

    /// Compiled point/batch UPDATE: route → probe → re-check → apply in
    /// place, mirroring the interpreted executor's observable behavior
    /// (match order, ORDER BY + LIMIT compaction, RETURNING projection,
    /// abort semantics) without touching the AST.
    fn fast_update(&self, p: &UpdatePlan, params: &[Value]) -> Result<Option<StatementResult>> {
        let meta = self.meta(&p.table)?;
        let def = meta.def.clone();
        let Some(parts) = p.route.resolve(&def, params) else { return Ok(None) };
        let now = self.clock.now();
        let Some(set) = self.fast_lock(&meta, &parts, false)? else {
            return Ok(None);
        };
        let (locks, targets) = (set.locks, set.targets);
        let t_latch = self.obs.start();
        let mut guards: Vec<Guard<'_>> = locks
            .iter()
            .map(|(w, s)| if *w { Guard::W(s.write().unwrap()) } else { Guard::R(s.read().unwrap()) })
            .collect();
        if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
            span::stage_add(Stage::Latch, n);
        }
        if !self.fast_mirror_valid(&meta, &targets) {
            return Ok(None); // node state changed while we queued for latches
        }
        self.obs.part_add_list(PartMetric::Claims, &parts);
        let pre_versions = fast_pre_versions(&guards, &targets);

        // Match phase: probe candidates under the held latches, re-checking
        // the full predicate (index buckets may contain hash collisions).
        // With ORDER BY + LIMIT (the claim pattern) the working set is
        // periodically compacted, exactly like the interpreted executor.
        let dirs: Vec<bool> = p.order.iter().map(|(_, asc)| *asc).collect();
        let mut matches: Vec<(usize, Slot, Vec<Value>)> = Vec::new();
        let compact_at = match (p.limit, p.order.is_empty()) {
            (Some(n), false) => Some(topn_cap(n)),
            _ => None,
        };
        for (ti, t) in targets.iter().enumerate() {
            let store = store_of(&guards, t.prim);
            let mut consider = |slot: Slot, row: &Row| {
                if !p.preds.iter().all(|c| c.matches(&row.values, params)) {
                    return;
                }
                let key: Vec<Value> =
                    p.order.iter().map(|(ci, _)| row.values[*ci].clone()).collect();
                matches.push((ti, slot, key));
                if let Some(cap) = compact_at {
                    if matches.len() >= cap {
                        matches.sort_by(|(_, _, ka), (_, _, kb)| cmp_order_keys(ka, kb, &dirs));
                        matches.truncate(p.limit.unwrap_or(0) as usize);
                    }
                }
            };
            probe_candidates(store, &p.probe, params, &mut consider);
        }
        if !p.order.is_empty() {
            matches.sort_by(|(_, _, ka), (_, _, kb)| cmp_order_keys(ka, kb, &dirs));
        }
        if let Some(n) = p.limit {
            matches.truncate(n as usize);
        }

        // Apply phase: one in-place update per matched row on the primary,
        // mirrored synchronously to the backup; the displaced old row is
        // kept as undo state and both replicas share the new row's single
        // materialization (handles, not clones).
        let mut applied: Vec<(usize, Slot, Arc<Row>, Arc<Row>, u64)> = Vec::new();
        let mut failure: Option<Error> = None;
        for (ti, slot, _) in &matches {
            let t = &targets[*ti];
            let built: Result<Row> = (|| {
                let store = store_of(&guards, t.prim);
                let old = store.get(*slot).ok_or_else(|| {
                    Error::Engine(format!("matched slot {slot} vanished mid-statement"))
                })?;
                let mut vals = old.values.clone();
                for (ci, e) in &p.sets {
                    vals[*ci] = e.eval(&old.values, params, now)?;
                }
                def.schema.coerce_row(Row::new(vals))
            })();
            let new_row = match built {
                Ok(r) => r,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            };
            let new_arc = Arc::new(new_row);
            match store_of_mut(&mut guards, t.prim)
                .and_then(|s| s.update_arc(*slot, new_arc.clone()))
            {
                Ok(old) => {
                    let lsn = store_of(&guards, t.prim).version;
                    let mut backup_err = None;
                    if let Some(bi) = t.backup {
                        if let Err(e) = store_of_mut(&mut guards, bi)
                            .and_then(|s| s.update_arc(*slot, new_arc.clone()))
                        {
                            backup_err = Some(e);
                        }
                    }
                    if let Some(e) = backup_err {
                        // restore the primary before unwinding
                        store_of_mut(&mut guards, t.prim)
                            .and_then(|s| s.update_arc(*slot, old.clone()).map(|_| ()))
                            .unwrap_or_else(|e2| {
                                panic!("fast-path rollback failed: {e2} (original error: {e})")
                            });
                        failure = Some(e);
                        break;
                    }
                    applied.push((*ti, *slot, old, new_arc, lsn));
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = failure {
            for (ti, slot, old, _, _) in applied.into_iter().rev() {
                let t = &targets[ti];
                if let Some(bi) = t.backup {
                    store_of_mut(&mut guards, bi)
                        .and_then(|s| s.update_arc(slot, old.clone()).map(|_| ()))
                        .unwrap_or_else(|e2| {
                            panic!("fast-path rollback failed: {e2} (original error: {e})")
                        });
                }
                store_of_mut(&mut guards, t.prim)
                    .and_then(|s| s.update_arc(slot, old).map(|_| ()))
                    .unwrap_or_else(|e2| {
                        panic!("fast-path rollback failed: {e2} (original error: {e})")
                    });
            }
            fast_restore_versions(&mut guards, &pre_versions);
            return Err(Error::TxnAborted(e.to_string()));
        }

        let result = match &p.returning {
            Some(cols) => {
                let columns: Vec<String> = cols.iter().map(|(_, name)| name.clone()).collect();
                let rows: Vec<Row> = applied
                    .iter()
                    .map(|(_, _, _, new, _)| {
                        Row::new(cols.iter().map(|(ci, _)| new.values[*ci].clone()).collect())
                    })
                    .collect();
                StatementResult::Rows(ResultSet { columns, rows })
            }
            None => StatementResult::Affected(applied.len()),
        };
        // Redo ops share the applied row via `Arc`; the WAL append happens
        // after the latches drop, like the interpreted commit, but its
        // epoch and node targets are captured here, under them.
        let ops: Vec<(u64, LogOp)> = applied
            .iter()
            .map(|(ti, slot, _, new, lsn)| {
                (
                    *lsn,
                    LogOp::Update {
                        table: p.table.clone(),
                        pidx: targets[*ti].pidx,
                        slot: *slot,
                        row: new.clone(),
                    },
                )
            })
            .collect();
        let epoch = self.cluster_epoch();
        drop(guards);
        self.append_committed_fast(epoch, &ops, &targets)?;
        Ok(Some(result))
    }

    /// Compiled point DELETE (probe + re-check; the interpreter full-scans).
    fn fast_delete(&self, p: &DeletePlan, params: &[Value]) -> Result<Option<StatementResult>> {
        let meta = self.meta(&p.table)?;
        let def = meta.def.clone();
        let Some(parts) = p.route.resolve(&def, params) else { return Ok(None) };
        let Some(set) = self.fast_lock(&meta, &parts, false)? else {
            return Ok(None);
        };
        let (locks, targets) = (set.locks, set.targets);
        let t_latch = self.obs.start();
        let mut guards: Vec<Guard<'_>> = locks
            .iter()
            .map(|(w, s)| if *w { Guard::W(s.write().unwrap()) } else { Guard::R(s.read().unwrap()) })
            .collect();
        if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
            span::stage_add(Stage::Latch, n);
        }
        if !self.fast_mirror_valid(&meta, &targets) {
            return Ok(None); // node state changed while we queued for latches
        }
        self.obs.part_add_list(PartMetric::Claims, &parts);
        let pre_versions = fast_pre_versions(&guards, &targets);

        // Victims in ascending slot order per partition: matches the
        // interpreted scan and keeps slab free-list evolution (and thus
        // replica slot assignment) deterministic.
        let mut victims: Vec<(usize, Slot)> = Vec::new();
        for (ti, t) in targets.iter().enumerate() {
            let store = store_of(&guards, t.prim);
            let start = victims.len();
            let mut consider = |slot: Slot, row: &Row| {
                if p.preds.iter().all(|c| c.matches(&row.values, params)) {
                    victims.push((ti, slot));
                }
            };
            probe_candidates(store, &p.probe, params, &mut consider);
            victims[start..].sort_unstable_by_key(|(_, s)| *s);
        }

        let mut applied: Vec<(usize, Slot, Arc<Row>, u64)> = Vec::new();
        let mut failure: Option<Error> = None;
        for (ti, slot) in &victims {
            let t = &targets[*ti];
            match store_of_mut(&mut guards, t.prim).and_then(|s| s.delete(*slot)) {
                Ok(old) => {
                    let lsn = store_of(&guards, t.prim).version;
                    let mut backup_err = None;
                    if let Some(bi) = t.backup {
                        if let Err(e) =
                            store_of_mut(&mut guards, bi).and_then(|s| s.delete(*slot).map(|_| ()))
                        {
                            backup_err = Some(e);
                        }
                    }
                    if let Some(e) = backup_err {
                        store_of_mut(&mut guards, t.prim)
                            .and_then(|s| s.insert_at_arc(*slot, old.clone()))
                            .unwrap_or_else(|e2| {
                                panic!("fast-path rollback failed: {e2} (original error: {e})")
                            });
                        failure = Some(e);
                        break;
                    }
                    applied.push((*ti, *slot, old, lsn));
                }
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = failure {
            // Slot-addressed re-inserts land every row back exactly where
            // it was, like the interpreted rollback.
            for (ti, slot, old, _) in applied.into_iter().rev() {
                let t = &targets[ti];
                if let Some(bi) = t.backup {
                    store_of_mut(&mut guards, bi)
                        .and_then(|s| s.insert_at_arc(slot, old.clone()))
                        .unwrap_or_else(|e2| {
                            panic!("fast-path rollback failed: {e2} (original error: {e})")
                        });
                }
                store_of_mut(&mut guards, t.prim)
                    .and_then(|s| s.insert_at_arc(slot, old))
                    .unwrap_or_else(|e2| {
                        panic!("fast-path rollback failed: {e2} (original error: {e})")
                    });
            }
            fast_restore_versions(&mut guards, &pre_versions);
            return Err(Error::TxnAborted(e.to_string()));
        }

        let ops: Vec<(u64, LogOp)> = applied
            .iter()
            .map(|(ti, slot, _, lsn)| {
                (
                    *lsn,
                    LogOp::Delete { table: p.table.clone(), pidx: targets[*ti].pidx, slot: *slot },
                )
            })
            .collect();
        let n = applied.len();
        let epoch = self.cluster_epoch();
        drop(guards);
        self.append_committed_fast(epoch, &ops, &targets)?;
        Ok(Some(StatementResult::Affected(n)))
    }

    /// Compiled single-row / batch INSERT. Rows are evaluated and routed
    /// before locking; only the partitions the batch lands in are
    /// write-locked (the interpreter write-locks every partition), with
    /// sibling partitions read-latched just for the cross-partition PK
    /// probe when the table needs it.
    fn fast_insert(&self, p: &InsertPlan, rows: &[&[Value]]) -> Result<Option<StatementResult>> {
        let meta = self.meta(&p.table)?;
        let def = meta.def.clone();
        let now = self.clock.now();
        let mut built: Vec<(usize, Row)> = Vec::with_capacity(rows.len());
        for &params in rows {
            let build: Result<(usize, Row)> = (|| {
                let vals = p
                    .row
                    .iter()
                    .map(|e| e.eval(&[], params, now))
                    .collect::<Result<Vec<Value>>>()?;
                let row = def.schema.coerce_row(Row::new(vals))?;
                let pidx = def.partition_of_row(&row.values)?;
                Ok((pidx, row))
            })();
            match build {
                Ok(x) => built.push(x),
                // nothing is applied yet: aborting here leaves the same
                // no-trace state as the interpreted rollback
                Err(e) => return Err(Error::TxnAborted(e.to_string())),
            }
        }
        let mut parts: Vec<usize> = built.iter().map(|(pidx, _)| *pidx).collect();
        parts.sort_unstable();
        parts.dedup();
        let Some(set) = self.fast_lock(&meta, &parts, p.cross_partition_pk)? else {
            return Ok(None);
        };
        let (locks, targets, live_of) = (set.locks, set.targets, set.live_of);
        let t_latch = self.obs.start();
        let mut guards: Vec<Guard<'_>> = locks
            .iter()
            .map(|(w, s)| if *w { Guard::W(s.write().unwrap()) } else { Guard::R(s.read().unwrap()) })
            .collect();
        if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
            span::stage_add(Stage::Latch, n);
        }
        if !self.fast_mirror_valid(&meta, &targets) {
            return Ok(None); // node state changed while we queued for latches
        }
        self.obs.part_add_list(PartMetric::Claims, &parts);
        let pre_versions = fast_pre_versions(&guards, &targets);
        let mut target_of: Vec<Option<usize>> = vec![None; def.num_partitions()];
        for (ti, t) in targets.iter().enumerate() {
            target_of[t.pidx] = Some(ti);
        }
        let pk_ci = def.pk_idx();

        let mut applied: Vec<(usize, Slot, Arc<Row>, u64)> = Vec::new();
        let mut failure: Option<Error> = None;
        'rows: for (pidx, row) in &built {
            if p.cross_partition_pk {
                if let Some(k) = pk_ci.and_then(|ci| row.values[ci].as_i64()) {
                    for other in 0..def.num_partitions() {
                        if other == *pidx {
                            continue;
                        }
                        let Some(gi) = live_of[other] else { continue };
                        if store_of(&guards, gi).slot_by_pk(k).is_some() {
                            failure = Some(Error::Constraint(format!(
                                "duplicate primary key {k} in '{}'",
                                def.name
                            )));
                            break 'rows;
                        }
                    }
                }
            }
            let ti = target_of[*pidx].expect("row routed to an unlocked partition");
            let t = &targets[ti];
            let arc = Arc::new(row.clone());
            match store_of_mut(&mut guards, t.prim).and_then(|s| s.insert_arc(arc.clone())) {
                Ok(slot) => {
                    let lsn = store_of(&guards, t.prim).version;
                    if let Some(bi) = t.backup {
                        // slot-addressed apply: canonical allocation means
                        // the backup lands the row in the same slot, or
                        // divergence surfaces right here — and both
                        // replicas share the one materialization
                        if let Err(e) = store_of_mut(&mut guards, bi)
                            .and_then(|s| s.insert_at_arc(slot, arc.clone()))
                        {
                            store_of_mut(&mut guards, t.prim)
                                .and_then(|s| s.delete(slot).map(|_| ()))
                                .unwrap_or_else(|e2| {
                                    panic!(
                                        "fast-path rollback failed: {e2} (original error: {e})"
                                    )
                                });
                            failure = Some(e);
                            break 'rows;
                        }
                    }
                    applied.push((ti, slot, arc, lsn));
                }
                Err(e) => {
                    failure = Some(e);
                    break 'rows;
                }
            }
        }
        if let Some(e) = failure {
            for (ti, slot, _, _) in applied.into_iter().rev() {
                let t = &targets[ti];
                if let Some(bi) = t.backup {
                    store_of_mut(&mut guards, bi)
                        .and_then(|s| s.delete(slot).map(|_| ()))
                        .unwrap_or_else(|e2| {
                            panic!("fast-path rollback failed: {e2} (original error: {e})")
                        });
                }
                store_of_mut(&mut guards, t.prim)
                    .and_then(|s| s.delete(slot).map(|_| ()))
                    .unwrap_or_else(|e2| {
                        panic!("fast-path rollback failed: {e2} (original error: {e})")
                    });
            }
            fast_restore_versions(&mut guards, &pre_versions);
            return Err(Error::TxnAborted(e.to_string()));
        }

        let ops: Vec<(u64, LogOp)> = applied
            .iter()
            .map(|(ti, slot, row, lsn)| {
                (
                    *lsn,
                    LogOp::Insert {
                        table: p.table.clone(),
                        pidx: targets[*ti].pidx,
                        slot: *slot,
                        row: row.clone(),
                    },
                )
            })
            .collect();
        let n = applied.len();
        let epoch = self.cluster_epoch();
        drop(guards);
        self.append_committed_fast(epoch, &ops, &targets)?;
        Ok(Some(StatementResult::Affected(n)))
    }

    /// Compiled indexed-equality SELECT (the `getREADYtasks` shape): one
    /// pruned partition, index probe, bounded top-n working set — the
    /// interpreted centralized plan, minus the interpreter.
    fn fast_select(&self, p: &SelectPlan, params: &[Value]) -> Result<Option<StatementResult>> {
        let meta = self.meta(&p.table)?;
        let def = meta.def.clone();
        let Some(parts) = p.route.resolve(&def, params) else { return Ok(None) };
        let mut locks: Vec<Arc<RwLock<PartitionStore>>> = Vec::with_capacity(parts.len());
        for &pidx in &parts {
            let pl = &meta.placements[pidx];
            let (store, _, _) = self.replica_store(&meta, pidx, pl, false)?;
            locks.push(store);
        }
        let t_latch = self.obs.start();
        let guards: Vec<RwLockReadGuard<'_, PartitionStore>> =
            locks.iter().map(|s| s.read().unwrap()).collect();
        if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
            span::stage_add(Stage::Latch, n);
        }
        // Under the held read latches, the captured meta must still be the
        // installed catalog entry: an online split rewrote the routing the
        // probe was resolved against (a moved key would probe the wrong —
        // now residue-filtered — store and silently miss). Fall back to
        // the interpreted path, which revalidates and rebuilds.
        {
            let key = def.name.to_lowercase();
            let current = self.catalog.read().unwrap().get(&key).cloned();
            match current {
                Some(cur) if Arc::ptr_eq(&cur, &meta) => {}
                _ => return Ok(None),
            }
        }
        self.obs.part_add_list(PartMetric::Scans, &parts);

        let dirs: Vec<bool> = p.order.iter().map(|(_, asc)| *asc).collect();
        let selected: Vec<Row> = if let (Some(limit), false) = (p.limit, p.order.is_empty()) {
            // top-n mirror: bounded working set with threshold pruning,
            // candidates in index-bucket order (same tie-breaking as the
            // interpreted top-n executor)
            let cap = topn_cap(limit);
            let mut kept: Vec<(Vec<Value>, Row)> = Vec::new();
            let mut threshold: Option<Vec<Value>> = None;
            for g in &guards {
                let store: &PartitionStore = g;
                let mut consider = |_slot: Slot, row: &Row| {
                    if !p.preds.iter().all(|c| c.matches(&row.values, params)) {
                        return;
                    }
                    let key: Vec<Value> =
                        p.order.iter().map(|(ci, _)| row.values[*ci].clone()).collect();
                    if let Some(th) = &threshold {
                        if cmp_order_keys(&key, th, &dirs) != std::cmp::Ordering::Less {
                            return;
                        }
                    }
                    kept.push((key, row.clone()));
                    if kept.len() >= cap {
                        kept.sort_by(|(ka, _), (kb, _)| cmp_order_keys(ka, kb, &dirs));
                        kept.truncate(limit as usize);
                        threshold = kept.last().map(|(k, _)| k.clone());
                    }
                };
                probe_candidates(store, &p.probe, params, &mut consider);
            }
            kept.sort_by(|(ka, _), (kb, _)| cmp_order_keys(ka, kb, &dirs));
            kept.truncate(limit as usize);
            kept.into_iter().map(|(_, r)| r).collect()
        } else {
            // general mirror: candidates in ascending slot order, full
            // collection, stable sort when ORDER BY is present
            let mut rows_keys: Vec<(Vec<Value>, Row)> = Vec::new();
            'parts: for g in &guards {
                let store: &PartitionStore = g;
                for slot in sorted_candidates(store, &p.probe, params) {
                    let Some(row) = store.get(slot) else { continue };
                    if !p.preds.iter().all(|c| c.matches(&row.values, params)) {
                        continue;
                    }
                    let key: Vec<Value> =
                        p.order.iter().map(|(ci, _)| row.values[*ci].clone()).collect();
                    rows_keys.push((key, row.clone()));
                    if p.order.is_empty() {
                        if let Some(n) = p.limit {
                            if rows_keys.len() >= n as usize {
                                break 'parts;
                            }
                        }
                    }
                }
            }
            if !p.order.is_empty() {
                rows_keys.sort_by(|(ka, _), (kb, _)| cmp_order_keys(ka, kb, &dirs));
            }
            if let Some(n) = p.limit {
                rows_keys.truncate(n as usize);
            }
            rows_keys.into_iter().map(|(_, r)| r).collect()
        };
        drop(guards);
        let columns: Vec<String> = p.cols.iter().map(|(_, n)| n.clone()).collect();
        let rows = selected
            .into_iter()
            .map(|r| Row::new(p.cols.iter().map(|(ci, _)| r.values[*ci].clone()).collect()))
            .collect();
        Ok(Some(StatementResult::Rows(ResultSet { columns, rows })))
    }

    /// Append one commit's redo records — `(partition LSN, op)` pairs — to
    /// the WAL segments of the nodes the commit **actually applied to**
    /// (primary and mirrored backup both log, as NDB fragments do), after
    /// latches drop. Shared by the interpreted commit and every fast
    /// executor; this is the commit stream the group-commit window batches.
    ///
    /// Both `epoch` and `targets` are captured by the executor while its
    /// write latches are held, together with the mirror decision itself:
    /// store contents, WAL contents and epoch stamps all derive from one
    /// liveness observation. Re-checking `is_alive()` here used to let a
    /// commit racing a rejoin hand-off log to a replica whose store it had
    /// excluded (store/WAL divergence on the fresh replica), and sampling
    /// the epoch here let a commit be stamped arbitrarily later than it
    /// ran. (Under-latch capture orders the stamp against heal/rejoin
    /// fence stamps, which take the same latches; a commit racing a
    /// *promotion* can still come out one epoch high — see the note in
    /// `exec_txn_inner` for why that direction is benign.)
    fn append_committed(
        &self,
        epoch: u64,
        ops: Vec<(u64, LogOp)>,
        targets: &FxHashMap<(String, usize), Vec<u32>>,
    ) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        let mut per_node: FxHashMap<u32, Vec<(u64, LogOp)>> = FxHashMap::default();
        for (lsn, op) in ops {
            let key = (op.table().to_lowercase(), op.pidx());
            let nids = targets.get(&key).ok_or_else(|| {
                Error::Engine(format!("commit has no WAL target set for {}[{}]", key.0, key.1))
            })?;
            for nid in nids {
                per_node.entry(*nid).or_default().push((lsn, op.clone()));
            }
        }
        for (nid, nops) in per_node {
            if let Some(n) = self.node(nid) {
                n.log_commit(epoch, &nops)?;
            }
        }
        Ok(())
    }

    /// Lean append for the compiled fast paths: one table, and the node
    /// target set is exactly what the [`FastTarget`]s captured under the
    /// write latches — no per-call maps or key strings on the claim loop
    /// (PR 3's constraint). Group-commit accounting matches
    /// `append_committed`: one `log_commit` per node carrying all of the
    /// commit's ops for that node.
    fn append_committed_fast(
        &self,
        epoch: u64,
        ops: &[(u64, LogOp)],
        targets: &[FastTarget],
    ) -> Result<()> {
        if ops.is_empty() {
            return Ok(());
        }
        let mut nodes: Vec<u32> = Vec::with_capacity(2 * targets.len());
        for t in targets {
            if !nodes.contains(&t.prim_node) {
                nodes.push(t.prim_node);
            }
            if let Some(b) = t.backup_node {
                if !nodes.contains(&b) {
                    nodes.push(b);
                }
            }
        }
        for nid in nodes {
            let nops: Vec<(u64, LogOp)> = ops
                .iter()
                .filter(|(_, op)| {
                    targets.iter().any(|t| {
                        t.pidx == op.pidx() && (t.prim_node == nid || t.backup_node == Some(nid))
                    })
                })
                .cloned()
                .collect();
            if nops.is_empty() {
                continue; // a target that matched no rows involves its nodes in nothing
            }
            if let Some(n) = self.node(nid) {
                n.log_commit(epoch, &nops)?;
            }
        }
        Ok(())
    }

    // ---------- statement entry points ----------

    /// Execute one statement, auto-commit, untagged (steering/CLI default).
    pub fn exec(&self, sql_text: &str) -> Result<StatementResult> {
        self.exec_tagged(0, AccessKind::Other, sql_text)
    }

    /// Execute one statement, recording latency under (node, kind).
    pub fn exec_tagged(
        &self,
        node: u32,
        kind: AccessKind,
        sql_text: &str,
    ) -> Result<StatementResult> {
        let stmt = sql::parse(sql_text)?;
        self.exec_stmt(node, kind, &stmt)
    }

    /// Execute one pre-parsed statement. Auto-commit SELECTs route through
    /// the scatter-gather engine (lock-free snapshot reads, parallel
    /// partials) when eligible; everything else — DML, DDL, and the point
    /// SELECTs where a single pruned partition plus index probe wins —
    /// takes the centralized 2PL path.
    pub fn exec_stmt(
        &self,
        node: u32,
        kind: AccessKind,
        stmt: &Statement,
    ) -> Result<StatementResult> {
        let _span = span::begin(&self.obs, "exec_stmt");
        let t0 = Instant::now();
        let r = self.exec_stmt_routed(stmt);
        self.stats.record(node, kind, t0.elapsed().as_secs_f64());
        r
    }

    fn exec_stmt_routed(&self, stmt: &Statement) -> Result<StatementResult> {
        if let Statement::Select(s) = stmt {
            // System-table hook: a SELECT touching `monitoring` sees a
            // fresh materialization of the registry. The refresh itself
            // runs DELETE + prepared INSERTs, which never re-enter here.
            if select_references(s, MONITORING_TABLE) {
                self.refresh_monitoring()?;
            }
            if let Some(rs) = self.try_scatter_select(s)? {
                return Ok(StatementResult::Rows(rs));
            }
            self.routes.centralized.fetch_add(1, AtomicOrdering::Relaxed);
            self.obs.inc(Counter::SelectCentralized);
        }
        Ok(self
            .exec_txn_inner(std::slice::from_ref(stmt))?
            .pop()
            .expect("one result per statement"))
    }

    /// Execute one SELECT through the centralized 2PL path, bypassing the
    /// scatter-gather router. Used by the equivalence tests and benchmarks
    /// to compare both executors on identical statements; not a hot path.
    pub fn query_centralized(&self, sql_text: &str) -> Result<ResultSet> {
        let stmt = sql::parse(sql_text)?;
        let r = self
            .exec_txn_inner(std::slice::from_ref(&stmt))?
            .pop()
            .expect("one result per statement");
        match r {
            StatementResult::Rows(rs) => Ok(rs),
            other => Err(Error::Engine(format!("expected rows, got {other:?}"))),
        }
    }

    // ---------- the scatter-gather read path ----------

    /// Route one auto-commit SELECT. `Ok(Some(rows))` means the
    /// scatter-gather engine served it off partition snapshots without
    /// taking 2PL locks; `Ok(None)` means the centralized path should run
    /// (single pruned partition without aggregates, where index probes and
    /// the bounded top-n working set are the better plan).
    fn try_scatter_select(&self, s: &SelectStmt) -> Result<Option<ResultSet>> {
        let now = self.clock.now();
        if s.joins.is_empty() {
            let meta = self.meta(&s.from.table)?;
            let parts = prune_partitions(&meta.def, s.from.binding(), s.where_.as_ref());
            // Cheap pre-check so the claim/point hot path skips the plan
            // split entirely. (Aggregates hidden behind a select alias in
            // ORDER BY/HAVING are caught by the full split below; a
            // single-partition alias case harmlessly runs centralized.)
            let has_agg = !s.group_by.is_empty()
                || s.items.iter().any(
                    |it| matches!(it, SelectItem::Expr { expr, .. } if expr.has_aggregate()),
                )
                || s.having.as_ref().map_or(false, |e| e.has_aggregate())
                || s.order_by.iter().any(|(e, _)| e.has_aggregate());
            if !has_agg && parts.len() <= 1 {
                return Ok(None);
            }
            // `has_agg` implies `plan.aggregated` (alias substitution can
            // only add aggregate nodes, never remove them), so the
            // single-partition fallback above is the complete routing rule.
            let Some(plan) = ScatterPlan::build(s) else {
                return Ok(None);
            };
            self.obs.part_add_list(PartMetric::Scans, &parts);
            let t_scan = self.obs.start();
            let snaps = self.partition_snapshots(&[(meta.clone(), parts)])?;
            let rs = query_engine::scatter_gather(
                self.scan_pool(),
                &plan,
                s.from.binding(),
                &snaps[0],
                &self.scan_metrics,
                now,
            )?;
            if let Some(n) = self.obs.rec_since(Hist::ScatterScan, t_scan) {
                span::stage_add(Stage::Scan, n);
            }
            self.routes.scatter.fetch_add(1, AtomicOrdering::Relaxed);
            self.obs.inc(Counter::SelectScatter);
            return Ok(Some(rs));
        }
        // Join shape: snapshot every involved partition in one consistent
        // cut, filter them in parallel, join at the coordinator. Inner-join
        // sides prune on the WHERE clause like the base table; left-outer
        // right sides must scan full to keep padding semantics.
        let mut specs: Vec<(Arc<TableMeta>, Vec<usize>)> = Vec::with_capacity(1 + s.joins.len());
        let base_meta = self.meta(&s.from.table)?;
        let base_parts = prune_partitions(&base_meta.def, s.from.binding(), s.where_.as_ref());
        specs.push((base_meta, base_parts));
        for j in &s.joins {
            let jm = self.meta(&j.table.table)?;
            let parts = if j.left_outer {
                (0..jm.def.num_partitions()).collect()
            } else {
                prune_partitions(&jm.def, j.table.binding(), s.where_.as_ref())
            };
            specs.push((jm, parts));
        }
        for (_, parts) in &specs {
            self.obs.part_add_list(PartMetric::Scans, parts);
        }
        let t_scan = self.obs.start();
        let snaps = self.partition_snapshots(&specs)?;
        let rs =
            query_engine::snapshot_join(self.scan_pool(), s, &snaps, &self.scan_metrics, now)?;
        if let Some(n) = self.obs.rec_since(Hist::ScatterScan, t_scan) {
            span::stage_add(Stage::Scan, n);
        }
        self.routes.snapshot_join.fetch_add(1, AtomicOrdering::Relaxed);
        self.obs.inc(Counter::SelectSnapshotJoin);
        Ok(Some(rs))
    }

    /// Acquire versioned snapshots of the listed `(table, partitions)`
    /// targets at one consistent cut: resolve each partition to its live
    /// replica (primary, or backup under failover), take every read latch
    /// in the canonical `(table, pidx)` order the 2PL executor also uses
    /// (so this can never deadlock against a writing transaction), take
    /// each partition's chunk snapshot, and release all latches. Writers
    /// are blocked only for the duration of the snapshot calls — an `Arc`
    /// bump per clean chunk plus a re-seal of chunks dirtied since the
    /// last snapshot, O(changed) rather than O(partition) — not for the
    /// query's execution, which is the whole point.
    pub(crate) fn partition_snapshots(
        &self,
        specs: &[(Arc<TableMeta>, Vec<usize>)],
    ) -> Result<Vec<TableSnapshots>> {
        // The caller resolved its partition lists against these same meta
        // handles (`meta.def`), so the identity check under the latches
        // below covers the pruning too: a split committed any time after
        // the caller fetched a meta — not just during acquisition — is
        // detected, instead of silently scanning the pre-split partition
        // list and missing the rows the cut moved.
        // Dedup (table, pidx): self-joins reference the same partition more
        // than once, and re-locking the same RwLock on one thread can
        // deadlock against a queued writer.
        let mut uniq: Vec<(String, usize, Arc<RwLock<PartitionStore>>)> = Vec::new();
        let mut seen: rustc_hash::FxHashSet<(String, usize)> = rustc_hash::FxHashSet::default();
        for (meta, parts) in specs {
            let key = meta.def.name.to_lowercase();
            for &pidx in parts {
                if !seen.insert((key.clone(), pidx)) {
                    continue;
                }
                let pl = &meta.placements[pidx];
                let (store, _, _) = self.replica_store(meta, pidx, pl, false)?;
                uniq.push((key.clone(), pidx, store));
            }
        }
        uniq.sort_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
        let pos: FxHashMap<(String, usize), usize> = uniq
            .iter()
            .enumerate()
            .map(|(i, e)| ((e.0.clone(), e.1), i))
            .collect();
        let snapshots: Vec<ChunkSnapshot> = {
            let guards: Vec<RwLockReadGuard<'_, PartitionStore>> =
                uniq.iter().map(|e| e.2.read().unwrap()).collect();
            // Under the held latches, verify every meta is still the
            // installed catalog entry. An online **split** rewrites rows
            // under write latches on the affected partition and swaps the
            // entry before releasing them, so a mismatch here means the
            // partition list the stores were resolved from is stale and
            // the snapshot could miss moved rows. (A pure move/flip is
            // data-preserving, and its write-excluding cut can't overlap
            // a split.) Error out; the caller's Unavailable path retries.
            {
                let cat = self.catalog.read().unwrap();
                for (meta, _) in specs {
                    let key = meta.def.name.to_lowercase();
                    match cat.get(&key) {
                        Some(cur) if Arc::ptr_eq(cur, meta) => {}
                        _ => {
                            return Err(Error::Unavailable(
                                "topology changed during snapshot acquisition; retry".into(),
                            ))
                        }
                    }
                }
            }
            guards.iter().map(|g| g.snapshot()).collect()
            // guards drop here: latches held only across the chunk bumps
        };
        let mut out = Vec::with_capacity(specs.len());
        for (meta, parts) in specs {
            let key = meta.def.name.to_lowercase();
            let mut tp: Vec<(usize, ChunkSnapshot)> = parts
                .iter()
                .map(|&pidx| (pidx, snapshots[pos[&(key.clone(), pidx)]].clone()))
                .collect();
            tp.sort_by_key(|(p, _)| *p);
            out.push(TableSnapshots { def: meta.def.clone(), parts: tp });
        }
        Ok(out)
    }

    /// Execute a batch of statements atomically (all-or-nothing), 2PL over
    /// the union of their partition lock sets.
    pub fn exec_txn(
        &self,
        node: u32,
        kind: AccessKind,
        stmts: &[Statement],
    ) -> Result<Vec<StatementResult>> {
        let _span = span::begin(&self.obs, "exec_txn");
        let t0 = Instant::now();
        let r = self.exec_txn_inner(stmts);
        self.stats.record(node, kind, t0.elapsed().as_secs_f64());
        r
    }

    /// Convenience: SELECT returning rows.
    pub fn query(&self, sql_text: &str) -> Result<ResultSet> {
        match self.exec(sql_text)? {
            StatementResult::Rows(r) => Ok(r),
            other => Err(Error::Engine(format!("expected rows, got {other:?}"))),
        }
    }

    /// Convenience: DML returning affected-row count.
    pub fn execute(&self, sql_text: &str) -> Result<usize> {
        match self.exec(sql_text)? {
            StatementResult::Affected(n) => Ok(n),
            StatementResult::Ok => Ok(0),
            other => Err(Error::Engine(format!("expected affected count, got {other:?}"))),
        }
    }

    // ---------- the transaction engine ----------

    fn exec_txn_inner(&self, stmts: &[Statement]) -> Result<Vec<StatementResult>> {
        // DDL runs outside the lock machinery (catalog has its own lock).
        if stmts.len() == 1 {
            if let Statement::CreateTable { .. } = &stmts[0] {
                return Ok(vec![self.exec_create(&stmts[0])?]);
            }
        }

        // Phase 0: compute the union lock set (canonical order).
        let build = || -> Result<(Vec<LockReq>, FxHashMap<String, Arc<TableMeta>>)> {
            let mut reqs: FxHashMap<(String, usize, Role), LockReq> = FxHashMap::default();
            let mut placements: FxHashMap<String, Arc<TableMeta>> = FxHashMap::default();
            for s in stmts {
                self.collect_locks(s, &mut reqs, &mut placements)?;
            }
            let mut ordered: Vec<LockReq> = reqs.into_values().collect();
            ordered.sort_by(|a, b| {
                (&a.table, a.pidx, a.role, a.node).cmp(&(&b.table, b.pidx, b.role, b.node))
            });
            Ok((ordered, placements))
        };
        // Phase 1 (2PL growing): acquire all guards in canonical order.
        fn acquire(ordered: &[LockReq]) -> Vec<Guard<'_>> {
            ordered
                .iter()
                .map(|r| {
                    if r.write {
                        Guard::W(r.store.write().unwrap())
                    } else {
                        Guard::R(r.store.read().unwrap())
                    }
                })
                .collect()
        }
        let (mut ordered, mut placements) = build()?;
        let t_latch = self.obs.start();
        let mut guards = acquire(&ordered);

        // The lock set's backup-mirror decisions were made from
        // `is_alive()` *before* the latches were acquired. A node that
        // changed state while we queued — it died, or it is a rejoiner
        // whose final cut held these latches and flipped it `Alive` — would
        // let the transaction apply to one replica set while logging to
        // another, silently diverging the fresh replica. Re-check under the
        // held latches and rebuild the lock set on mismatch; state flips
        // are rare, so this converges immediately in practice (the bound
        // only guards against a flapping failure injector).
        let mut attempts = 0usize;
        while !self.mirror_set_valid(&ordered, &placements) {
            attempts += 1;
            if attempts > 16 {
                return Err(Error::Unavailable(
                    "cluster membership kept changing during lock acquisition".into(),
                ));
            }
            drop(guards);
            (ordered, placements) = build()?;
            guards = acquire(&ordered);
        }
        // growing phase complete (initial acquisition + rare rebuilds)
        if let Some(n) = self.obs.rec_since(Hist::LatchWait, t_latch) {
            span::stage_add(Stage::Latch, n);
        }

        // WAL target set: the nodes each written partition actually
        // applies to, captured from the validated (latched) lock set so
        // the commit's append cannot disagree with its apply.
        let mut wal_targets: FxHashMap<(String, usize), Vec<u32>> = FxHashMap::default();
        for r in &ordered {
            if r.write {
                wal_targets.entry((r.table.clone(), r.pidx)).or_default().push(r.node);
            }
        }

        let index: FxHashMap<(String, usize, Role), usize> = ordered
            .iter()
            .enumerate()
            .map(|(i, r)| ((r.table.clone(), r.pidx, r.role), i))
            .collect();
        let mut ctx = ExecCtx {
            guards,
            index,
            placements,
            now: self.clock.now(),
            applied: Vec::new(),
            pre_versions: FxHashMap::default(),
        };

        // Execute statements against locked primaries, collecting undo info.
        let mut results = Vec::with_capacity(stmts.len());
        let mut failed: Option<Error> = None;
        for s in stmts {
            match self.exec_one(&mut ctx, s) {
                Ok(r) => results.push(r),
                Err(e) => {
                    failed = Some(e);
                    break;
                }
            }
        }

        if let Some(e) = failed {
            // Rollback: undo primary mutations in reverse order.
            let undos: Vec<Undo> = ctx.applied.drain(..).map(|(_, _, u)| u).rev().collect();
            for u in undos {
                let r = match &u {
                    Undo::Remove { table, pidx, slot } => {
                        let (t, p, s) = (table.clone(), *pidx, *slot);
                        ctx.store_mut(&t, p, Role::Primary).and_then(|st| st.delete(s).map(|_| ()))
                    }
                    Undo::Restore { table, pidx, slot, row } => {
                        let (t, p, s, r2) = (table.clone(), *pidx, *slot, row.clone());
                        ctx.store_mut(&t, p, Role::Primary)
                            .and_then(|st| st.update_arc(s, r2).map(|_| ()))
                    }
                    Undo::Reinsert { table, pidx, slot, row } => {
                        let (t, p, s, r2) = (table.clone(), *pidx, *slot, row.clone());
                        ctx.store_mut(&t, p, Role::Primary)
                            .and_then(|st| st.insert_at_arc(s, r2))
                    }
                };
                if let Err(e2) = r {
                    // A failing rollback is unrecoverable corruption.
                    panic!("rollback failed: {e2} (original error: {e})");
                }
            }
            // Aborted work must not consume partition LSNs: restore every
            // touched primary's version so the redo sequence stays dense.
            let restore: Vec<((String, usize), u64)> = ctx.pre_versions.drain().collect();
            for ((t, p), v) in restore {
                match ctx.store_mut(&t, p, Role::Primary) {
                    Ok(st) => st.version = v,
                    Err(e2) => panic!("rollback version restore failed: {e2}"),
                }
            }
            return Err(Error::TxnAborted(e.to_string()));
        }

        // Phase 2 (commit): apply redo ops to backups (whose write guards we
        // already hold) and append to the hosting nodes' WAL segments.
        let ops: Vec<(u64, LogOp)> =
            ctx.applied.iter().map(|(lsn, op, _)| (*lsn, op.clone())).collect();
        for (_, op) in &ops {
            let table = op.table().to_string();
            let pidx = op.pidx();
            if ctx.has(&table, pidx, Role::Backup) {
                let store = ctx.store_mut(&table, pidx, Role::Backup)?;
                // shared handles: the backup aliases the primary's row
                // materialization (one allocation per committed row across
                // both replicas and the WAL)
                match op {
                    LogOp::Insert { slot, row, .. } => {
                        store.insert_at_arc(*slot, row.clone()).unwrap_or_else(|e| {
                            panic!("replica divergence on {table}[{pidx}]: {e}")
                        });
                    }
                    LogOp::Update { slot, row, .. } => {
                        store.update_arc(*slot, row.clone())?;
                    }
                    LogOp::Delete { slot, .. } => {
                        store.delete(*slot)?;
                    }
                }
            }
        }
        // The commit's epoch stamp is sampled while the write latches are
        // still held. Heal and the rejoin cut stamp replica fences under
        // these same latches, so a fence can no longer leapfrog a commit
        // it serialized after (the spurious-fencing direction). Promotion
        // itself bumps the epoch under only the catalog lock, so a commit
        // racing one can still be stamped one epoch high — benign: every
        // replica in the commit's target set applied the write, and a
        // too-new stamp only passes fences the record never needed to
        // cross.
        let epoch = self.cluster_epoch();
        drop(ctx);
        // WAL append after releasing row locks (commit record).
        self.append_committed(epoch, ops, &wal_targets)?;
        Ok(results)
    }

    /// Validation half of the mirror-set rule (see `exec_txn_inner`):
    /// under the held latches, every write-locked primary must mirror to
    /// its backup exactly when that backup's node is alive *now*. Two
    /// checks run under the latches:
    ///
    /// 1. every captured `TableMeta` is still the installed catalog entry
    ///    (`Arc` identity) — a topology cut (promotion, partition move,
    ///    split) swaps the entry while holding the partition latches, so a
    ///    transaction that latched after the cut must rebuild its lock set
    ///    against the new placements rather than write to orphaned stores;
    /// 2. the backup-mirror decision still matches node liveness.
    fn mirror_set_valid(
        &self,
        ordered: &[LockReq],
        placements: &FxHashMap<String, Arc<TableMeta>>,
    ) -> bool {
        {
            let cat = self.catalog.read().unwrap();
            for (key, captured) in placements {
                match cat.get(key) {
                    Some(cur) if Arc::ptr_eq(cur, captured) => {}
                    _ => return false,
                }
            }
        }
        let mirrored: rustc_hash::FxHashSet<(&str, usize)> = ordered
            .iter()
            .filter(|r| r.role == Role::Backup && r.write)
            .map(|r| (r.table.as_str(), r.pidx))
            .collect();
        ordered.iter().all(|r| {
            if !r.write || r.role != Role::Primary {
                return true;
            }
            let backup_alive = placements
                .get(&r.table)
                .and_then(|m| m.placements[r.pidx].backup)
                .and_then(|b| self.node(b))
                .map_or(false, |n| n.is_alive());
            backup_alive == mirrored.contains(&(r.table.as_str(), r.pidx))
        })
    }

    /// Add a statement's lock requirements to `reqs`.
    fn collect_locks(
        &self,
        stmt: &Statement,
        reqs: &mut FxHashMap<(String, usize, Role), LockReq>,
        placements: &mut FxHashMap<String, Arc<TableMeta>>,
    ) -> Result<()> {
        let mut add = |cluster: &DbCluster,
                       table: &str,
                       parts: Vec<usize>,
                       write: bool|
         -> Result<()> {
            let meta = cluster.meta(table)?;
            let key = meta.def.name.to_lowercase();
            placements.entry(key.clone()).or_insert_with(|| meta.clone());
            for pidx in parts {
                let pl = &meta.placements[pidx];
                let (store, node, role) = cluster.replica_store(&meta, pidx, pl, write)?;
                let entry_key = (key.clone(), pidx, role);
                let e = reqs.entry(entry_key).or_insert(LockReq {
                    table: key.clone(),
                    pidx,
                    node,
                    role,
                    write,
                    store,
                });
                e.write |= write;
                // Writes also lock the backup replica (synchronous apply
                // happens under the same critical section).
                if write && role == Role::Primary {
                    if let Some(bid) = pl.backup {
                        if let Some(bn) = cluster.node(bid) {
                            if bn.is_alive() {
                                let bstore = bn.partition(&meta.def.name, pidx)?;
                                let bkey = (key.clone(), pidx, Role::Backup);
                                let be = reqs.entry(bkey).or_insert(LockReq {
                                    table: key.clone(),
                                    pidx,
                                    node: bid,
                                    role: Role::Backup,
                                    write: true,
                                    store: bstore,
                                });
                                be.write = true;
                            }
                        }
                    }
                }
            }
            Ok(())
        };

        match stmt {
            Statement::Select(s) => {
                let meta = self.meta(&s.from.table)?;
                let parts = prune_partitions(&meta.def, s.from.binding(), s.where_.as_ref());
                add(self, &s.from.table, parts, false)?;
                for j in &s.joins {
                    let jm = self.meta(&j.table.table)?;
                    add(self, &j.table.table, (0..jm.def.num_partitions()).collect(), false)?;
                }
            }
            Statement::Insert { table, .. } => {
                let meta = self.meta(table)?;
                // Partition routing needs evaluated rows; to keep the lock
                // set superset-safe, lock all partitions for writes when the
                // table is multi-partition, plus all partitions for the
                // cross-partition PK check. Single-partition tables lock one.
                add(self, table, (0..meta.def.num_partitions()).collect(), true)?;
            }
            Statement::Update { table, sets, where_, .. } => {
                let meta = self.meta(&table.table)?;
                let moves_partition = meta
                    .def
                    .partition_col_idx()
                    .map(|ci| {
                        let pname = &meta.def.schema.columns[ci].name;
                        sets.iter().any(|(c, _)| c.eq_ignore_ascii_case(pname))
                    })
                    .unwrap_or(false);
                let parts = if moves_partition {
                    (0..meta.def.num_partitions()).collect()
                } else {
                    prune_partitions(&meta.def, table.binding(), where_.as_ref())
                };
                add(self, &table.table, parts, true)?;
            }
            Statement::Delete { table, where_ } => {
                let meta = self.meta(&table.table)?;
                let parts = prune_partitions(&meta.def, table.binding(), where_.as_ref());
                add(self, &table.table, parts, true)?;
            }
            Statement::CreateTable { .. } => {
                return Err(Error::Engine("DDL inside transaction".into()))
            }
        }
        Ok(())
    }

    // ---------- per-statement executors ----------

    fn exec_one(&self, ctx: &mut ExecCtx<'_>, stmt: &Statement) -> Result<StatementResult> {
        match stmt {
            Statement::Select(s) => self.exec_select(ctx, s).map(StatementResult::Rows),
            Statement::Insert { table, columns, values } => {
                self.exec_insert(ctx, table, columns, values).map(StatementResult::Affected)
            }
            Statement::Update { table, sets, where_, order_by, limit, returning } => {
                self.exec_update(ctx, table, sets, where_, order_by, *limit, returning)
            }
            Statement::Delete { table, where_ } => {
                self.exec_delete(ctx, table, where_).map(StatementResult::Affected)
            }
            Statement::CreateTable { .. } => Err(Error::Engine("DDL inside transaction".into())),
        }
    }

    fn exec_create(&self, stmt: &Statement) -> Result<StatementResult> {
        let Statement::CreateTable { name, columns, partition_by, primary_key, indexes } = stmt
        else {
            unreachable!()
        };
        let schema = Schema::new(
            columns
                .iter()
                .map(|c| Column { name: c.name.clone(), ty: c.ty, nullable: !c.not_null })
                .collect(),
        )?;
        let mut def = TableDef::new(name.clone(), schema);
        if let Some((col, n)) = partition_by {
            def = def.partition_by_hash(col, *n)?;
        }
        if let Some(pk) = primary_key {
            def = def.with_primary_key(pk)?;
        }
        for ix in indexes {
            def = def.with_index(ix)?;
        }
        self.create_table(def)?;
        Ok(StatementResult::Ok)
    }

    /// Scan a table's locked partitions into a `TableInput`, using a
    /// secondary/PK index when a `col = literal` conjunct allows it, and
    /// applying `filter` (a pre-extracted single-table predicate) row by
    /// row so join inputs stay small.
    fn scan_input(
        &self,
        ctx: &ExecCtx<'_>,
        table: &str,
        binding: &str,
        where_: Option<&Expr>,
        filter: Option<&Expr>,
    ) -> Result<TableInput> {
        let meta = ctx
            .placements
            .get(&table.to_lowercase())
            .cloned()
            .ok_or_else(|| Error::Engine(format!("table '{table}' not in txn scope")))?;
        let def = &meta.def;
        let parts = prune_partitions(def, binding, where_);
        let index_probe = where_.and_then(|w| index_probe_for(def, binding, w));
        let layout =
            Layout::of_table(binding, def.schema.columns.iter().map(|c| c.name.clone()));
        let fb = match filter {
            Some(f) => Some(bind(f, &layout)?),
            None => None,
        };
        let ectx = ctx.ectx();
        let mut rows = Vec::new();
        let mut push = |r: &Row| -> Result<()> {
            let keep = match &fb {
                Some(b) => b.matches(&r.values, &ectx)?,
                None => true,
            };
            if keep {
                rows.push(r.clone());
            }
            Ok(())
        };
        for pidx in parts {
            // read whichever role is locked (primary normally, backup in
            // failover)
            let role = if ctx.has(&def.name.to_lowercase(), pidx, Role::Primary) {
                Role::Primary
            } else {
                Role::Backup
            };
            let store = ctx.store(&def.name.to_lowercase(), pidx, role)?;
            match &index_probe {
                Some((ci, v)) => {
                    if let Some(slots) = store.slots_by_index(*ci, v) {
                        let mut slots = slots.to_vec();
                        slots.sort_unstable();
                        for s in slots {
                            if let Some(r) = store.get(s) {
                                push(r)?;
                            }
                        }
                    } else if let Some(pk_ci) = def.pk_idx().filter(|pi| pi == ci) {
                        let _ = pk_ci;
                        if let Some(k) = v.as_i64() {
                            if let Some(s) = store.slot_by_pk(k) {
                                if let Some(r) = store.get(s) {
                                    push(r)?;
                                }
                            }
                        }
                    } else {
                        for (_, r) in store.iter() {
                            push(r)?;
                        }
                    }
                }
                None => {
                    for (_, r) in store.iter() {
                        push(r)?;
                    }
                }
            }
        }
        Ok(TableInput {
            binding: binding.to_string(),
            columns: def.schema.columns.iter().map(|c| c.name.clone()).collect(),
            rows,
        })
    }

    /// Top-N fast path for `SELECT ... FROM t WHERE ... ORDER BY ... LIMIT n`
    /// (the `getREADYtasks` pattern): evaluate predicate and sort keys on
    /// borrowed rows, keep a bounded working set, clone only the survivors.
    /// Returns `None` when the statement doesn't fit the pattern (joins,
    /// aggregates, alias-only order keys, ...), falling back to the general
    /// pipeline.
    fn try_topn_select(&self, ctx: &ExecCtx<'_>, s: &SelectStmt) -> Result<Option<ResultSet>> {
        let Some(limit) = s.limit else { return Ok(None) };
        if !s.joins.is_empty()
            || !s.group_by.is_empty()
            || s.having.is_some()
            || s.order_by.is_empty()
        {
            return Ok(None);
        }
        let has_agg = s
            .items
            .iter()
            .any(|it| matches!(it, SelectItem::Expr { expr, .. } if expr.has_aggregate()))
            || s.order_by.iter().any(|(e, _)| e.has_aggregate());
        if has_agg {
            return Ok(None);
        }
        let Some(meta) = ctx.placements.get(&s.from.table.to_lowercase()).cloned() else {
            return Ok(None);
        };
        let def = meta.def.clone();
        let tkey = def.name.to_lowercase();
        let binding = s.from.binding();
        let layout =
            Layout::of_table(binding, def.schema.columns.iter().map(|c| c.name.clone()));
        // order keys must bind against base columns (aliases fall back)
        let Ok(order_bound) = s
            .order_by
            .iter()
            .map(|(e, asc)| Ok((bind(e, &layout)?, *asc)))
            .collect::<Result<Vec<_>>>()
        else {
            return Ok(None);
        };
        let wb = match &s.where_ {
            Some(w) => match bind(w, &layout) {
                Ok(b) => Some(b),
                Err(_) => return Ok(None),
            },
            None => None,
        };
        let ectx = ctx.ectx();
        let parts = prune_partitions(&def, binding, s.where_.as_ref());
        let index_probe = s.where_.as_ref().and_then(|w| index_probe_for(&def, binding, w));
        let cap = topn_cap(limit);
        let dirs: Vec<bool> = order_bound.iter().map(|(_, asc)| *asc).collect();
        let mut kept: Vec<(Vec<Value>, Row)> = Vec::new();
        // once the working set has been compacted, rows sorting after the
        // current n-th key can be skipped without cloning
        let mut threshold: Option<Vec<Value>> = None;
        for pidx in parts {
            let role = if ctx.has(&tkey, pidx, Role::Primary) { Role::Primary } else { Role::Backup };
            let store = ctx.store(&tkey, pidx, role)?;
            let mut consider = |row: &Row| -> Result<()> {
                let ok = match &wb {
                    Some(b) => b.matches(&row.values, &ectx)?,
                    None => true,
                };
                if ok {
                    let key = order_bound
                        .iter()
                        .map(|(b, _)| b.eval(&row.values, &ectx))
                        .collect::<Result<Vec<_>>>()?;
                    if let Some(t) = &threshold {
                        if cmp_order_keys(&key, t, &dirs) != std::cmp::Ordering::Less {
                            return Ok(());
                        }
                    }
                    kept.push((key, row.clone()));
                    if kept.len() >= cap {
                        kept.sort_by(|(ka, _), (kb, _)| cmp_order_keys(ka, kb, &dirs));
                        kept.truncate(limit as usize);
                        threshold = kept.last().map(|(k, _)| k.clone());
                    }
                }
                Ok(())
            };
            match &index_probe {
                Some((ci, v)) => match store.slots_by_index(*ci, v) {
                    Some(slots) => {
                        for &slot in slots {
                            if let Some(r) = store.get(slot) {
                                consider(r)?;
                            }
                        }
                    }
                    None if def.pk_idx() == Some(*ci) => {
                        if let Some(k) = v.as_i64() {
                            if let Some(slot) = store.slot_by_pk(k) {
                                if let Some(r) = store.get(slot) {
                                    consider(r)?;
                                }
                            }
                        }
                    }
                    None => {
                        for (_, r) in store.iter() {
                            consider(r)?;
                        }
                    }
                },
                None => {
                    for (_, r) in store.iter() {
                        consider(r)?;
                    }
                }
            }
        }
        kept.sort_by(|(ka, _), (kb, _)| cmp_order_keys(ka, kb, &dirs));
        kept.truncate(limit as usize);
        let input = TableInput {
            binding: binding.to_string(),
            columns: def.schema.columns.iter().map(|c| c.name.clone()).collect(),
            rows: kept.into_iter().map(|(_, r)| r).collect(),
        };
        run_select(s, vec![input], &ectx).map(Some)
    }

    fn exec_select(&self, ctx: &mut ExecCtx<'_>, s: &SelectStmt) -> Result<ResultSet> {
        if let Some(rs) = self.try_topn_select(ctx, s)? {
            return Ok(rs);
        }
        // WHERE pushdown: a conjunct that resolves entirely against one
        // table's columns filters that table's scan. Legal for the base
        // table and inner-join tables; pushing into the right side of a
        // LEFT JOIN would change its padding semantics, so those scan full.
        let single_table_filter = |table: &str, binding: &str| -> Result<Option<Expr>> {
            let Some(w) = &s.where_ else { return Ok(None) };
            let meta = ctx
                .placements
                .get(&table.to_lowercase())
                .cloned()
                .ok_or_else(|| Error::Engine(format!("table '{table}' not in txn scope")))?;
            let layout = Layout::of_table(
                binding,
                meta.def.schema.columns.iter().map(|c| c.name.clone()),
            );
            let mut kept: Option<Expr> = None;
            for c in w.conjuncts() {
                if !c.has_aggregate() && bind(c, &layout).is_ok() {
                    kept = Some(match kept {
                        None => c.clone(),
                        Some(prev) => Expr::Binary(
                            sql::Op::And,
                            Box::new(prev),
                            Box::new(c.clone()),
                        ),
                    });
                }
            }
            Ok(kept)
        };

        let mut inputs = Vec::with_capacity(1 + s.joins.len());
        let base_filter = single_table_filter(&s.from.table, s.from.binding())?;
        inputs.push(self.scan_input(
            ctx,
            &s.from.table,
            s.from.binding(),
            s.where_.as_ref(),
            base_filter.as_ref(),
        )?);
        for j in &s.joins {
            let filter = if j.left_outer {
                None
            } else {
                single_table_filter(&j.table.table, j.table.binding())?
            };
            inputs.push(self.scan_input(
                ctx,
                &j.table.table,
                j.table.binding(),
                filter.as_ref(),
                filter.as_ref(),
            )?);
        }
        run_select(s, inputs, &ctx.ectx())
    }

    fn exec_insert(
        &self,
        ctx: &mut ExecCtx<'_>,
        table: &str,
        columns: &[String],
        values: &[Vec<Expr>],
    ) -> Result<usize> {
        let meta = ctx
            .placements
            .get(&table.to_lowercase())
            .cloned()
            .ok_or_else(|| Error::Engine(format!("table '{table}' not in txn scope")))?;
        let def = meta.def.clone();
        let schema = def.schema.clone();
        let tkey = def.name.to_lowercase();

        // Column list: explicit or full schema order.
        let col_indices: Vec<usize> = if columns.is_empty() {
            (0..schema.len()).collect()
        } else {
            columns
                .iter()
                .map(|c| {
                    schema
                        .index_of(c)
                        .ok_or_else(|| Error::Catalog(format!("unknown column '{c}' in INSERT")))
                })
                .collect::<Result<Vec<_>>>()?
        };

        let empty_layout = Layout::default();
        let ectx = ctx.ectx();
        let mut n = 0;
        for tuple in values {
            if tuple.len() != col_indices.len() {
                return Err(Error::Type(format!(
                    "INSERT arity mismatch: {} values for {} columns",
                    tuple.len(),
                    col_indices.len()
                )));
            }
            let mut vals = vec![Value::Null; schema.len()];
            for (e, ci) in tuple.iter().zip(&col_indices) {
                let b = bind(e, &empty_layout)?;
                vals[*ci] = b.eval(&[], &ectx)?;
            }
            let row = schema.coerce_row(Row::new(vals))?;
            let pidx = def.partition_of_row(&row.values)?;

            // Cross-partition PK uniqueness (PK != partition key).
            if let Some(pk_ci) = def.pk_idx() {
                if def.partition_col_idx() != Some(pk_ci) && def.num_partitions() > 1 {
                    if let Some(k) = row.values[pk_ci].as_i64() {
                        for other in 0..def.num_partitions() {
                            if other == pidx {
                                continue;
                            }
                            let role = if ctx.has(&tkey, other, Role::Primary) {
                                Role::Primary
                            } else {
                                Role::Backup
                            };
                            let store = ctx.store(&tkey, other, role)?;
                            if store.slot_by_pk(k).is_some() {
                                return Err(Error::Constraint(format!(
                                    "duplicate primary key {k} in '{}'",
                                    def.name
                                )));
                            }
                        }
                    }
                }
            }

            ctx.note_pre_version(&tkey, pidx)?;
            let store = ctx.store_mut(&tkey, pidx, Role::Primary)?;
            let arc = Arc::new(row);
            let slot = store.insert_arc(arc.clone())?;
            let lsn = store.version;
            ctx.applied.push((
                lsn,
                LogOp::Insert { table: tkey.clone(), pidx, slot, row: arc },
                Undo::Remove { table: tkey.clone(), pidx, slot },
            ));
            n += 1;
        }
        Ok(n)
    }

    #[allow(clippy::too_many_arguments)]
    fn exec_update(
        &self,
        ctx: &mut ExecCtx<'_>,
        table: &TableRef,
        sets: &[(String, Expr)],
        where_: &Option<Expr>,
        order_by: &[(Expr, bool)],
        limit: Option<u64>,
        returning: &Option<Vec<SelectItem>>,
    ) -> Result<StatementResult> {
        let meta = ctx
            .placements
            .get(&table.table.to_lowercase())
            .cloned()
            .ok_or_else(|| Error::Engine(format!("table '{}' not in txn scope", table.table)))?;
        let def = meta.def.clone();
        let tkey = def.name.to_lowercase();
        let binding = table.binding();
        let layout =
            Layout::of_table(binding, def.schema.columns.iter().map(|c| c.name.clone()));
        let ectx = ctx.ectx();

        let wb = match where_ {
            Some(w) => Some(bind(w, &layout)?),
            None => None,
        };
        let order_bound: Vec<(crate::storage::sql::expr::Bound, bool)> = order_by
            .iter()
            .map(|(e, asc)| Ok((bind(e, &layout)?, *asc)))
            .collect::<Result<Vec<_>>>()?;
        let set_bound: Vec<(usize, crate::storage::sql::expr::Bound)> = sets
            .iter()
            .map(|(c, e)| {
                let ci = def
                    .schema
                    .index_of(c)
                    .ok_or_else(|| Error::Catalog(format!("unknown column '{c}' in UPDATE")))?;
                Ok((ci, bind(e, &layout)?))
            })
            .collect::<Result<Vec<_>>>()?;

        // Gather matches across locked partitions (with index probe).
        let parts = prune_partitions(&def, binding, where_.as_ref());
        let index_probe = where_.as_ref().and_then(|w| index_probe_for(&def, binding, w));
        let dirs: Vec<bool> = order_bound.iter().map(|(_, asc)| *asc).collect();
        let sort_matches = |matches: &mut Vec<(usize, usize, Vec<Value>)>| {
            matches.sort_by(|(_, _, ka), (_, _, kb)| cmp_order_keys(ka, kb, &dirs));
        };
        let mut matches: Vec<(usize, usize, Vec<Value>)> = Vec::new(); // (pidx, slot, order key)
        // top-N compaction: with ORDER BY + LIMIT (the claim pattern) we
        // never keep more than a bounded working set of candidates
        let compact_at = match (limit, order_bound.is_empty()) {
            (Some(n), false) => Some(topn_cap(n)),
            _ => None,
        };
        for pidx in &parts {
            let store = ctx.store(&tkey, *pidx, Role::Primary)?;
            let mut consider = |slot: usize| -> Result<()> {
                let Some(row) = store.get(slot) else { return Ok(()) };
                let ok = match &wb {
                    Some(b) => b.matches(&row.values, &ectx)?,
                    None => true,
                };
                if ok {
                    let key = order_bound
                        .iter()
                        .map(|(b, _)| b.eval(&row.values, &ectx))
                        .collect::<Result<Vec<_>>>()?;
                    matches.push((*pidx, slot, key));
                    if let Some(cap) = compact_at {
                        if matches.len() >= cap {
                            sort_matches(&mut matches);
                            matches.truncate(limit.unwrap_or(0) as usize);
                        }
                    }
                }
                Ok(())
            };
            match &index_probe {
                // candidate order is irrelevant: ORDER BY sorting (or the
                // unordered-update semantics) decides the outcome
                Some((ci, v)) => match store.slots_by_index(*ci, v) {
                    // borrowed bucket: no per-probe allocation on the
                    // claim loop even when `READY` spans the partition
                    Some(slots) => {
                        for &slot in slots {
                            consider(slot)?;
                        }
                    }
                    // PK fast path: `WHERE taskid = N` is a point lookup,
                    // not a partition scan (updateToFINISHED hot path).
                    None if def.pk_idx() == Some(*ci) => {
                        if let Some(k) = v.as_i64() {
                            if let Some(slot) = store.slot_by_pk(k) {
                                consider(slot)?;
                            }
                        }
                    }
                    None => {
                        for (slot, _) in store.iter() {
                            consider(slot)?;
                        }
                    }
                },
                None => {
                    for (slot, _) in store.iter() {
                        consider(slot)?;
                    }
                }
            }
        }
        if !order_bound.is_empty() {
            sort_matches(&mut matches);
        }
        if let Some(n) = limit {
            matches.truncate(n as usize);
        }

        // Apply. Old and new rows travel as shared handles: the undo
        // state, the redo list, the backup apply and the WAL all alias one
        // materialization per row version.
        let mut new_rows: Vec<Arc<Row>> = Vec::with_capacity(matches.len());
        for (pidx, slot, _) in &matches {
            let old = {
                let store = ctx.store(&tkey, *pidx, Role::Primary)?;
                store.get_arc(*slot).ok_or_else(|| {
                    Error::Engine(format!("matched slot {slot} vanished mid-statement"))
                })?
            };
            let mut new_vals = old.values.clone();
            for (ci, b) in &set_bound {
                new_vals[*ci] = b.eval(&old.values, &ectx)?;
            }
            let new_row = Arc::new(def.schema.coerce_row(Row::new(new_vals))?);
            let new_pidx = def.partition_of_row(&new_row.values)?;
            if new_pidx == *pidx {
                ctx.note_pre_version(&tkey, *pidx)?;
                let store = ctx.store_mut(&tkey, *pidx, Role::Primary)?;
                store.update_arc(*slot, new_row.clone())?;
                let lsn = store.version;
                ctx.applied.push((
                    lsn,
                    LogOp::Update {
                        table: tkey.clone(),
                        pidx: *pidx,
                        slot: *slot,
                        row: new_row.clone(),
                    },
                    Undo::Restore { table: tkey.clone(), pidx: *pidx, slot: *slot, row: old },
                ));
            } else {
                // Row moves partitions (e.g. work stealing rewrites
                // worker_id): delete + insert.
                ctx.note_pre_version(&tkey, *pidx)?;
                ctx.note_pre_version(&tkey, new_pidx)?;
                let lsn = {
                    let store = ctx.store_mut(&tkey, *pidx, Role::Primary)?;
                    store.delete(*slot)?;
                    store.version
                };
                ctx.applied.push((
                    lsn,
                    LogOp::Delete { table: tkey.clone(), pidx: *pidx, slot: *slot },
                    Undo::Reinsert {
                        table: tkey.clone(),
                        pidx: *pidx,
                        slot: *slot,
                        row: old,
                    },
                ));
                let store = ctx.store_mut(&tkey, new_pidx, Role::Primary)?;
                let new_slot = store.insert_arc(new_row.clone())?;
                let lsn = store.version;
                ctx.applied.push((
                    lsn,
                    LogOp::Insert {
                        table: tkey.clone(),
                        pidx: new_pidx,
                        slot: new_slot,
                        row: new_row.clone(),
                    },
                    Undo::Remove { table: tkey.clone(), pidx: new_pidx, slot: new_slot },
                ));
            }
            new_rows.push(new_row);
        }

        // RETURNING projection over the new rows.
        if let Some(items) = returning {
            let input = TableInput {
                binding: binding.to_string(),
                columns: def.schema.columns.iter().map(|c| c.name.clone()).collect(),
                rows: new_rows.iter().map(|r| r.as_ref().clone()).collect(),
            };
            let pseudo = SelectStmt {
                items: items.clone(),
                from: TableRef { table: def.name.clone(), alias: Some(binding.to_string()) },
                joins: vec![],
                where_: None,
                group_by: vec![],
                having: None,
                order_by: vec![],
                limit: None,
            };
            return run_select(&pseudo, vec![input], &ectx).map(StatementResult::Rows);
        }
        Ok(StatementResult::Affected(matches.len()))
    }

    fn exec_delete(
        &self,
        ctx: &mut ExecCtx<'_>,
        table: &TableRef,
        where_: &Option<Expr>,
    ) -> Result<usize> {
        let meta = ctx
            .placements
            .get(&table.table.to_lowercase())
            .cloned()
            .ok_or_else(|| Error::Engine(format!("table '{}' not in txn scope", table.table)))?;
        let def = meta.def.clone();
        let tkey = def.name.to_lowercase();
        let binding = table.binding();
        let layout =
            Layout::of_table(binding, def.schema.columns.iter().map(|c| c.name.clone()));
        let ectx = ctx.ectx();
        let wb = match where_ {
            Some(w) => Some(bind(w, &layout)?),
            None => None,
        };
        let parts = prune_partitions(&def, binding, where_.as_ref());
        let mut victims: Vec<(usize, usize)> = Vec::new();
        for pidx in &parts {
            let store = ctx.store(&tkey, *pidx, Role::Primary)?;
            for (slot, row) in store.iter() {
                let ok = match &wb {
                    Some(b) => b.matches(&row.values, &ectx)?,
                    None => true,
                };
                if ok {
                    victims.push((*pidx, slot));
                }
            }
        }
        for (pidx, slot) in &victims {
            ctx.note_pre_version(&tkey, *pidx)?;
            let store = ctx.store_mut(&tkey, *pidx, Role::Primary)?;
            let old = store.delete(*slot)?;
            let lsn = store.version;
            ctx.applied.push((
                lsn,
                LogOp::Delete { table: tkey.clone(), pidx: *pidx, slot: *slot },
                Undo::Reinsert { table: tkey.clone(), pidx: *pidx, slot: *slot, row: old },
            ));
        }
        Ok(victims.len())
    }
}

// ---------- fast-path plumbing ----------

/// How an OCC point-DML attempt resolved (see `DbCluster::occ_update`).
enum OccOutcome {
    /// Completed on the optimistic path (committed, or a clean no-match).
    Done(StatementResult),
    /// Hand the statement to the 2PL fast path: either the shape is not
    /// OCC-eligible (non-PK probe, multi-partition route, scan-shaped
    /// ORDER BY/LIMIT) or the retry budget was exhausted under conflict.
    TwoPL,
    /// Routing/mirror state the compiled paths do not handle (dead
    /// unpromoted primary, liveness flip under the latches, non-integer
    /// partition key): fall through to the interpreted executor, exactly
    /// like the 2PL fast path's `Ok(None)`.
    Interpret,
}

/// Validation-conflict budget before an OCC statement gives up and takes
/// the 2PL fast path. Small on purpose: under sustained same-row conflict
/// the pessimistic latch is the faster discipline, and the fallback keeps
/// worst-case latency bounded instead of livelocking.
const OCC_MAX_RETRIES: u64 = 4;

/// Jittered exponential backoff between OCC validation conflicts. The
/// jitter (a thread-local xoshiro stream, seeded per thread) decorrelates
/// claimers that collided once so they do not collide again in lockstep;
/// later attempts also yield the scheduler, which matters when the winner
/// holds the commit latch but not a core.
fn occ_backoff(attempt: u64) {
    use std::cell::RefCell;
    static SEED: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static RNG: RefCell<Rng> =
            RefCell::new(Rng::new(SEED.fetch_add(1, AtomicOrdering::Relaxed)));
    }
    let cap = 32i64 << attempt.min(8);
    let spins = RNG.with(|r| r.borrow_mut().range(cap / 2, cap + 1));
    for _ in 0..spins {
        std::hint::spin_loop();
    }
    if attempt >= 2 {
        std::thread::yield_now();
    }
}

/// One write-locked partition of a fast statement: its index plus the
/// guard positions of the live primary and (when mirrored) backup replica.
/// The node ids behind those guards are the partition's WAL target set —
/// `append_committed` logs to exactly the nodes the write applied to.
struct FastTarget {
    pidx: usize,
    prim: usize,
    backup: Option<usize>,
    prim_node: u32,
    backup_node: Option<u32>,
}

/// The latch set of one fast statement: `(write, store)` pairs in canonical
/// acquisition order, the write targets, and the live-replica guard index
/// per partition (for the cross-partition PK probe).
struct FastLockSet {
    locks: Vec<(bool, Arc<RwLock<PartitionStore>>)>,
    targets: Vec<FastTarget>,
    live_of: Vec<Option<usize>>,
}

/// Immutable view of a held fast-path guard.
fn store_of<'g>(guards: &'g [Guard<'_>], i: usize) -> &'g PartitionStore {
    match &guards[i] {
        Guard::R(g) => g,
        Guard::W(g) => g,
    }
}

/// Mutable view of a held fast-path guard; targets are always write-locked.
fn store_of_mut<'g>(guards: &'g mut [Guard<'_>], i: usize) -> Result<&'g mut PartitionStore> {
    match &mut guards[i] {
        Guard::W(g) => Ok(g),
        Guard::R(_) => Err(Error::Engine("fast path write through a read latch".into())),
    }
}

/// Pre-statement versions of every write-locked replica (primary and
/// backup) of a fast statement, captured right after latch acquisition.
/// Restored on abort so aborted work never consumes partition LSNs.
fn fast_pre_versions(guards: &[Guard<'_>], targets: &[FastTarget]) -> Vec<(usize, u64)> {
    let mut pre = Vec::with_capacity(targets.len() * 2);
    for t in targets {
        pre.push((t.prim, store_of(guards, t.prim).version));
        if let Some(bi) = t.backup {
            pre.push((bi, store_of(guards, bi).version));
        }
    }
    pre
}

/// Abort tail of the fast paths: put every touched replica's version back.
fn fast_restore_versions(guards: &mut [Guard<'_>], pre: &[(usize, u64)]) {
    for (gi, v) in pre {
        if let Ok(s) = store_of_mut(guards, *gi) {
            s.version = *v;
        }
    }
}

/// Feed the probe's candidate rows to `consider`, in the same order the
/// interpreted executors visit them (index bucket order / PK point / slab
/// order). Candidates are a superset of the matches — callers re-check the
/// full predicate.
fn probe_candidates(
    store: &PartitionStore,
    probe: &Probe,
    params: &[Value],
    consider: &mut dyn FnMut(Slot, &Row),
) {
    match probe {
        Probe::Pk(v) => {
            if let Some(k) = v.get(params).as_i64() {
                if let Some(slot) = store.slot_by_pk(k) {
                    if let Some(row) = store.get(slot) {
                        consider(slot, row);
                    }
                }
            }
        }
        Probe::Index { col, val } => {
            if let Some(slots) = store.slots_by_index(*col, val.get(params)) {
                for &slot in slots {
                    if let Some(row) = store.get(slot) {
                        consider(slot, row);
                    }
                }
            }
        }
        Probe::Scan => {
            for (slot, row) in store.iter() {
                consider(slot, row);
            }
        }
    }
}

/// The probe's candidate slots in ascending order (mirror of the
/// interpreted general scan, whose probe slots are sorted).
fn sorted_candidates(store: &PartitionStore, probe: &Probe, params: &[Value]) -> Vec<Slot> {
    match probe {
        Probe::Pk(v) => match v.get(params).as_i64().and_then(|k| store.slot_by_pk(k)) {
            Some(s) => vec![s],
            None => Vec::new(),
        },
        Probe::Index { col, val } => {
            let mut slots: Vec<Slot> = store
                .slots_by_index(*col, val.get(params))
                .map(|s| s.to_vec())
                .unwrap_or_default();
            slots.sort_unstable();
            slots
        }
        Probe::Scan => store.iter().map(|(s, _)| s).collect(),
    }
}

/// Compare two ORDER BY key tuples under per-key sort directions. This is
/// the one comparator shared by the interpreted executors and the compiled
/// fast path — tie-breaking can never drift between them.
fn cmp_order_keys(ka: &[Value], kb: &[Value], dirs: &[bool]) -> std::cmp::Ordering {
    for ((a, b), asc) in ka.iter().zip(kb.iter()).zip(dirs.iter()) {
        let o = a.total_cmp(b);
        let o = if *asc { o } else { o.reverse() };
        if o != std::cmp::Ordering::Equal {
            return o;
        }
    }
    std::cmp::Ordering::Equal
}

/// Bounded working-set size for ORDER BY + LIMIT match compaction, shared
/// by the interpreted executors and the compiled fast path for the same
/// reason as [`cmp_order_keys`].
fn topn_cap(limit: u64) -> usize {
    ((limit as usize) * 4).max(512)
}

/// Partitions that can possibly match `where_` for a table bound as
/// `binding`: a conjunct `partition_col = <int literal>` (unqualified or
/// qualified with the binding) prunes to exactly one partition.
fn prune_partitions(def: &TableDef, binding: &str, where_: Option<&Expr>) -> Vec<usize> {
    if let (Some(ci), Some(w)) = (def.partition_col_idx(), where_) {
        let pcol = &def.schema.columns[ci].name;
        for c in w.conjuncts() {
            if let Expr::Binary(sql::Op::Eq, a, b) = c {
                let pair = match (a.as_ref(), b.as_ref()) {
                    (Expr::Col { table, name }, Expr::Lit(Value::Int(k)))
                    | (Expr::Lit(Value::Int(k)), Expr::Col { table, name }) => {
                        Some((table.as_deref(), name.as_str(), *k))
                    }
                    _ => None,
                };
                if let Some((qual, name, k)) = pair {
                    let qual_ok = qual.map_or(true, |q| q.eq_ignore_ascii_case(binding));
                    if qual_ok && name.eq_ignore_ascii_case(pcol) {
                        return vec![def.partition_of_key(k)];
                    }
                }
            }
        }
    }
    (0..def.num_partitions()).collect()
}

/// If some conjunct pins an indexed (or PK) column to a literal, return
/// (schema column index, literal) for an index probe.
fn index_probe_for(def: &TableDef, binding: &str, where_: &Expr) -> Option<(usize, Value)> {
    for c in where_.conjuncts() {
        if let Expr::Binary(sql::Op::Eq, a, b) = c {
            let pair = match (a.as_ref(), b.as_ref()) {
                (Expr::Col { table, name }, Expr::Lit(v))
                | (Expr::Lit(v), Expr::Col { table, name }) => {
                    Some((table.as_deref(), name.as_str(), v))
                }
                _ => None,
            };
            if let Some((qual, name, v)) = pair {
                let qual_ok = qual.map_or(true, |q| q.eq_ignore_ascii_case(binding));
                if !qual_ok {
                    continue;
                }
                if let Some(ci) = def.schema.index_of(name) {
                    let indexed = def.indexes.iter().any(|x| x.eq_ignore_ascii_case(name));
                    let is_pk = def.pk_idx() == Some(ci);
                    if indexed || is_pk {
                        return Some((ci, v.clone()));
                    }
                }
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster() -> Arc<DbCluster> {
        let c = DbCluster::start(ClusterConfig::default()).unwrap();
        c.exec(
            "CREATE TABLE workqueue (taskid INT NOT NULL, actid INT, workerid INT NOT NULL, \
             status TEXT, dur FLOAT, starttime FLOAT, endtime FLOAT) \
             PARTITION BY HASH(workerid) PARTITIONS 4 PRIMARY KEY (taskid) INDEX (status)",
        )
        .unwrap();
        c.exec(
            "CREATE TABLE workers (id INT NOT NULL, host TEXT) PRIMARY KEY (id)",
        )
        .unwrap();
        c
    }

    fn seed(c: &DbCluster, n: usize, workers: i64) {
        for i in 0..n {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                 VALUES ({}, {}, {}, 'READY', {}.0)",
                i,
                i % 3,
                i as i64 % workers,
                i % 7
            ))
            .unwrap();
        }
        for w in 0..workers {
            c.execute(&format!("INSERT INTO workers (id, host) VALUES ({w}, 'node{w}')"))
                .unwrap();
        }
    }

    #[test]
    fn create_insert_select_roundtrip() {
        let c = cluster();
        seed(&c, 20, 4);
        assert_eq!(c.table_rows("workqueue").unwrap(), 20);
        let rs = c
            .query("SELECT taskid FROM workqueue WHERE workerid = 1 AND status = 'READY' ORDER BY taskid")
            .unwrap();
        assert_eq!(rs.rows.len(), 5);
        assert_eq!(rs.rows[0].values[0], Value::Int(1));
    }

    #[test]
    fn update_limit_returning_dequeues_atomically() {
        let c = cluster();
        seed(&c, 20, 4);
        let r = c
            .exec(
                "UPDATE workqueue SET status = 'RUNNING', starttime = NOW() \
                 WHERE workerid = 2 AND status = 'READY' ORDER BY taskid LIMIT 3 \
                 RETURNING taskid, status",
            )
            .unwrap()
            .rows();
        assert_eq!(r.rows.len(), 3);
        assert_eq!(r.rows[0].values[0], Value::Int(2)); // smallest taskid with workerid=2 still READY
        assert_eq!(r.rows[0].values[1], Value::str("RUNNING"));
        // 5 tasks had workerid=2; 3 claimed, 2 left
        let left = c
            .query("SELECT COUNT(*) FROM workqueue WHERE workerid = 2 AND status = 'READY'")
            .unwrap();
        assert_eq!(left.rows[0].values[0], Value::Int(2));
    }

    #[test]
    fn pk_uniqueness_across_partitions() {
        let c = cluster();
        c.execute("INSERT INTO workqueue (taskid, workerid, status) VALUES (1, 0, 'READY')")
            .unwrap();
        // same taskid, different partition (workerid 1) must still fail
        let e = c.execute("INSERT INTO workqueue (taskid, workerid, status) VALUES (1, 1, 'READY')");
        assert!(e.is_err(), "cross-partition duplicate PK accepted");
        assert_eq!(c.table_rows("workqueue").unwrap(), 1);
    }

    #[test]
    fn update_moving_partition_key_relocates_row() {
        let c = cluster();
        c.execute("INSERT INTO workqueue (taskid, workerid, status) VALUES (1, 0, 'READY')")
            .unwrap();
        let n = c
            .execute("UPDATE workqueue SET workerid = 3 WHERE taskid = 1")
            .unwrap();
        assert_eq!(n, 1);
        let rs = c.query("SELECT workerid FROM workqueue WHERE workerid = 3").unwrap();
        assert_eq!(rs.rows.len(), 1);
        let rs = c.query("SELECT COUNT(*) FROM workqueue WHERE workerid = 0").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(0));
        // row is findable by PK afterwards
        let rs = c.query("SELECT workerid FROM workqueue WHERE taskid = 1").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(3));
    }

    #[test]
    fn join_across_tables() {
        let c = cluster();
        seed(&c, 12, 4);
        let rs = c
            .query(
                "SELECT w.host, COUNT(*) AS n FROM workqueue t JOIN workers w \
                 ON t.workerid = w.id GROUP BY w.host ORDER BY w.host",
            )
            .unwrap();
        assert_eq!(rs.rows.len(), 4);
        assert_eq!(rs.rows[0].values[1], Value::Int(3));
    }

    #[test]
    fn delete_with_predicate() {
        let c = cluster();
        seed(&c, 12, 4);
        let n = c.execute("DELETE FROM workqueue WHERE actid = 0").unwrap();
        assert_eq!(n, 4);
        assert_eq!(c.table_rows("workqueue").unwrap(), 8);
    }

    #[test]
    fn txn_atomicity_rolls_back_all_statements() {
        let c = cluster();
        seed(&c, 4, 4);
        let stmts = vec![
            sql::parse("UPDATE workqueue SET status = 'RUNNING' WHERE taskid = 0").unwrap(),
            // second statement violates NOT NULL on workerid -> whole txn aborts
            sql::parse("UPDATE workqueue SET workerid = NULL WHERE taskid = 1").unwrap(),
        ];
        let e = c.exec_txn(0, AccessKind::Other, &stmts);
        assert!(e.is_err());
        // first statement's effect must be rolled back
        let rs = c.query("SELECT status FROM workqueue WHERE taskid = 0").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::str("READY"));
    }

    #[test]
    fn replica_failover_serves_reads_and_writes() {
        let c = cluster();
        seed(&c, 16, 4);
        let before = c.table_rows("workqueue").unwrap();
        // Find which node holds a primary and kill it.
        c.kill_node(0).unwrap();
        let promoted = c.promote_dead_primaries();
        assert!(promoted > 0, "some primaries lived on node 0");
        // reads and writes still work against promoted backups
        assert_eq!(c.table_rows("workqueue").unwrap(), before);
        let n = c
            .execute("UPDATE workqueue SET status = 'RUNNING' WHERE workerid = 1")
            .unwrap();
        assert!(n > 0);
        // revive + heal restores redundancy
        c.revive_node(0).unwrap();
        let healed = c.heal().unwrap();
        assert!(healed > 0);
    }

    #[test]
    fn stats_are_recorded_per_kind() {
        let c = cluster();
        seed(&c, 4, 4);
        c.exec_tagged(2, AccessKind::GetReadyTasks, "SELECT * FROM workqueue WHERE workerid = 1")
            .unwrap();
        let s = c.stats.get(AccessKind::GetReadyTasks);
        assert_eq!(s.count, 1);
        assert!(s.total_secs > 0.0);
    }

    #[test]
    fn db_size_accounting() {
        let c = cluster();
        assert_eq!(c.total_bytes(), 0);
        seed(&c, 50, 4);
        let b = c.total_bytes();
        assert!(b > 1000, "50 rows should be > 1KB, got {b}");
        assert!(c.table_bytes("workqueue").unwrap() > c.table_bytes("workers").unwrap());
    }

    #[test]
    fn unknown_tables_and_columns_error() {
        let c = cluster();
        assert!(c.query("SELECT * FROM nope").is_err());
        assert!(c.execute("INSERT INTO workers (nope) VALUES (1)").is_err());
        assert!(c.execute("UPDATE workers SET nope = 1").is_err());
        assert!(c.exec("CREATE TABLE workers (id INT)").is_err(), "duplicate table");
    }

    #[test]
    fn prepare_bind_execute_roundtrip() {
        let c = cluster();
        seed(&c, 20, 4);
        let sel = c
            .prepare(
                "SELECT taskid FROM workqueue WHERE workerid = ? AND status = ? ORDER BY taskid",
            )
            .unwrap();
        assert_eq!(sel.param_count(), 2);
        let rs = c.query_prepared(&sel, &[Value::Int(1), Value::str("READY")]).unwrap();
        assert_eq!(rs.rows.len(), 5);
        // same handle, different binding
        let rs = c.query_prepared(&sel, &[Value::Int(1), Value::str("RUNNING")]).unwrap();
        assert!(rs.rows.is_empty());
        // prepared update with string + numeric params
        let upd = c
            .prepare("UPDATE workqueue SET status = ?, endtime = ? WHERE taskid = ?")
            .unwrap();
        let n = c
            .exec_prepared(
                0,
                AccessKind::UpdateToFinished,
                &upd,
                &[Value::str("FINISHED"), Value::Float(9.5), Value::Int(3)],
            )
            .unwrap()
            .affected();
        assert_eq!(n, 1);
    }

    #[test]
    fn prepare_is_cached_and_validated() {
        let c = cluster();
        let sql = "SELECT taskid FROM workqueue WHERE taskid = ?";
        c.prepare(sql).unwrap();
        let before = c.cached_plans();
        c.prepare(sql).unwrap();
        assert_eq!(c.cached_plans(), before, "re-prepare must hit the cache");
        // catalog misses surface at prepare time
        assert!(c.prepare("SELECT * FROM nope WHERE a = ?").is_err());
        assert!(c.prepare("INSERT INTO workers (nope) VALUES (?)").is_err());
        assert!(c.prepare("UPDATE workers SET nope = ? WHERE id = 1").is_err());
        // arity mismatches too
        assert!(c.prepare("INSERT INTO workers (id, host) VALUES (?)").is_err());
    }

    #[test]
    fn prepared_strings_need_no_escaping() {
        let c = cluster();
        let ins = c
            .prepare("INSERT INTO workers (id, host) VALUES (?, ?)")
            .unwrap();
        let hostile = "it's; DROP TABLE workers -- '";
        c.exec_prepared(0, AccessKind::Other, &ins, &[Value::Int(1), Value::str(hostile)])
            .unwrap();
        let sel = c.prepare("SELECT host FROM workers WHERE host = ?").unwrap();
        let rs = c.query_prepared(&sel, &[Value::str(hostile)]).unwrap();
        assert_eq!(rs.rows.len(), 1);
        assert_eq!(rs.rows[0].values[0], Value::str(hostile));
    }

    #[test]
    fn prepared_batch_insert_is_atomic() {
        let c = cluster();
        let ins = c
            .prepare(
                "INSERT INTO workqueue (taskid, actid, workerid, status, dur) \
                 VALUES (?, ?, ?, 'READY', ?)",
            )
            .unwrap();
        let rows: Vec<Vec<Value>> = (0..10)
            .map(|i| vec![Value::Int(i), Value::Int(1), Value::Int(i % 4), Value::Float(1.0)])
            .collect();
        let n = c
            .exec_prepared_batch(0, AccessKind::InsertTasks, &ins, &rows)
            .unwrap()
            .affected();
        assert_eq!(n, 10);
        assert_eq!(c.table_rows("workqueue").unwrap(), 10);
        // duplicate PK anywhere in the batch aborts the whole batch
        let dup: Vec<Vec<Value>> = [100, 101, 5].iter()
            .map(|i| vec![Value::Int(*i), Value::Int(1), Value::Int(0), Value::Float(1.0)])
            .collect();
        assert!(c.exec_prepared_batch(0, AccessKind::InsertTasks, &ins, &dup).is_err());
        assert_eq!(c.table_rows("workqueue").unwrap(), 10, "aborted batch left rows behind");
    }

    #[test]
    fn prepared_statement_prunes_partitions_like_literals() {
        // `workerid = ?` must route to one partition after binding: the
        // claim pattern's partition-locality is the paper's §3.2 point.
        let c = cluster();
        seed(&c, 16, 4);
        let upd = c
            .prepare(
                "UPDATE workqueue SET status = ? WHERE workerid = ? AND status = 'READY' \
                 ORDER BY taskid LIMIT 1 RETURNING taskid",
            )
            .unwrap();
        let rs = c
            .exec_prepared(0, AccessKind::UpdateToRunning, &upd, &[Value::str("RUNNING"), Value::Int(2)])
            .unwrap()
            .rows();
        assert_eq!(rs.rows.len(), 1);
        assert_eq!(rs.rows[0].values[0], Value::Int(2));
    }

    #[test]
    fn aggregate_selects_route_through_scatter_gather() {
        let c = cluster();
        seed(&c, 40, 4);
        let q = "SELECT status, COUNT(*) AS n, AVG(dur) FROM workqueue \
                 GROUP BY status ORDER BY status";
        let scattered = c.query(q).unwrap();
        let central = c.query_centralized(q).unwrap();
        assert_eq!(scattered, central, "scatter-gather must match centralized");
        let scatter = c.route_counts().scatter;
        assert!(scatter >= 1, "aggregate query must take the scatter path");
    }

    #[test]
    fn join_selects_route_through_snapshot_join() {
        let c = cluster();
        seed(&c, 12, 4);
        let q = "SELECT w.host, COUNT(*) AS n FROM workqueue t JOIN workers w \
                 ON t.workerid = w.id GROUP BY w.host ORDER BY w.host";
        let a = c.query(q).unwrap();
        let b = c.query_centralized(q).unwrap();
        assert_eq!(a, b);
        let join = c.route_counts().snapshot_join;
        assert!(join >= 1, "join query must take the snapshot-join path");
    }

    #[test]
    fn point_reads_stay_on_the_centralized_index_path() {
        let c = cluster();
        seed(&c, 16, 4);
        c.query(
            "SELECT taskid FROM workqueue WHERE workerid = 1 AND status = 'READY' \
             ORDER BY taskid LIMIT 4",
        )
        .unwrap();
        let counts = c.route_counts();
        assert_eq!(counts.scatter, 0, "single pruned partition must not scatter");
        assert_eq!(counts.snapshot_join, 0);
        assert!(counts.centralized >= 1);
    }

    #[test]
    fn prepared_describe_renders_the_chosen_plan() {
        let c = cluster();
        let p = c
            .prepare("SELECT status, COUNT(*) FROM workqueue WHERE workerid = ? GROUP BY status")
            .unwrap();
        let d = p.describe();
        assert!(d.contains("scatter-gather aggregate"), "{d}");
        assert!(d.contains("COUNT(*)"), "{d}");
        assert!(d.contains("workerid = ?0"), "{d}");
        assert!(d.contains("resolved at bind"), "{d}");
        let p = c
            .prepare("SELECT t.taskid FROM workqueue t JOIN workers w ON t.workerid = w.id")
            .unwrap();
        assert!(p.describe().contains("snapshot-join"), "{}", p.describe());
        let p = c.prepare("UPDATE workqueue SET status = ? WHERE taskid = ?").unwrap();
        assert!(
            p.describe().contains("centralized transactional write"),
            "{}",
            p.describe()
        );
    }

    #[test]
    fn footprint_counts_survive_dead_partitions() {
        // No replication: killing a node makes its partitions unreachable,
        // which used to abort table_bytes and erase whole tables from
        // total_bytes. Now dead partitions are skipped, live ones counted.
        let c = DbCluster::start(
            ClusterConfig::builder().replication(false).build().unwrap(),
        )
        .unwrap();
        c.exec(
            "CREATE TABLE workqueue (taskid INT NOT NULL, workerid INT NOT NULL, \
             status TEXT) PARTITION BY HASH(workerid) PARTITIONS 4 PRIMARY KEY (taskid)",
        )
        .unwrap();
        for i in 0..40 {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status) VALUES ({i}, {}, 'READY')",
                i % 4
            ))
            .unwrap();
        }
        let full_bytes = c.table_bytes("workqueue").unwrap();
        let full_rows = c.table_rows("workqueue").unwrap();
        assert!(full_bytes > 0);
        assert_eq!(full_rows, 40);
        c.kill_node(1).unwrap();
        let part_bytes = c.table_bytes("workqueue").unwrap();
        let part_rows = c.table_rows("workqueue").unwrap();
        assert!(part_bytes > 0 && part_bytes < full_bytes, "live partitions still counted");
        assert!(part_rows > 0 && part_rows < full_rows);
        assert!(c.total_bytes() > 0, "total_bytes must not drop the whole table");
        assert!(c.table_bytes("nope").is_err(), "unknown table still errors");
    }

    #[test]
    fn select_sees_snapshot_under_concurrent_writers() {
        // smoke test: 4 writer threads + 4 reader threads on the same WQ
        let c = cluster();
        seed(&c, 100, 4);
        let mut handles = Vec::new();
        for w in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                loop {
                    let r = c
                        .exec(&format!(
                            "UPDATE workqueue SET status = 'RUNNING' \
                             WHERE workerid = {w} AND status = 'READY' ORDER BY taskid LIMIT 1 \
                             RETURNING taskid"
                        ))
                        .unwrap()
                        .rows();
                    if r.rows.is_empty() {
                        break;
                    }
                }
            }));
        }
        for _ in 0..4 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let rs = c
                        .query("SELECT COUNT(*) FROM workqueue")
                        .unwrap();
                    assert_eq!(rs.rows[0].values[0], Value::Int(100));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let rs = c.query("SELECT COUNT(*) FROM workqueue WHERE status = 'RUNNING'").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(100));
    }

    #[test]
    fn builder_validates_config() {
        assert!(ClusterConfig::builder().data_nodes(0).build().is_err());
        assert!(ClusterConfig::builder().data_nodes(1).replication(true).build().is_err());
        let cfg = ClusterConfig::builder().data_nodes(1).replication(false).build().unwrap();
        assert_eq!(cfg.data_nodes, 1);
        assert!(!cfg.replication);
    }

    #[test]
    fn topology_reports_placement_and_classes() {
        let c = cluster();
        seed(&c, 20, 4);
        let t = c.topology();
        assert_eq!(t.nodes.len(), 2);
        assert!(t.nodes.iter().all(|n| n.state == NodeState::Alive));
        let wq = t.tables.iter().find(|x| x.table == "workqueue").unwrap();
        assert_eq!(wq.partitions.len(), 4);
        assert_eq!(wq.partitions[1].class, Some((4, 1)));
        assert_eq!(wq.partitions.iter().map(|p| p.rows).sum::<usize>(), 20);
        for p in &wq.partitions {
            assert_ne!(Some(p.primary), p.backup, "primary and backup must differ");
        }
    }

    #[test]
    fn add_node_then_rebalance_moves_primary() {
        let c = cluster();
        seed(&c, 40, 4);
        let fp = c.fingerprint().unwrap();
        let id = c.add_node().unwrap();
        assert_eq!(id, 2);
        assert_eq!(c.node(id).unwrap().state(), NodeState::Joining);
        c.rebalance_partition("workqueue", 1, id).unwrap();
        assert_eq!(c.node(id).unwrap().state(), NodeState::Alive);
        let t = c.topology();
        let wq = t.tables.iter().find(|x| x.table == "workqueue").unwrap();
        assert_eq!(wq.partitions[1].primary, id);
        assert_eq!(c.fingerprint().unwrap(), fp, "move must preserve every row");
        // idempotent: moving again is a no-op
        c.rebalance_partition("workqueue", 1, id).unwrap();
        // the moved partition still serves claims end to end
        let r = c
            .exec(
                "UPDATE workqueue SET status = 'RUNNING' \
                 WHERE workerid = 1 AND status = 'READY' ORDER BY taskid LIMIT 2 \
                 RETURNING taskid",
            )
            .unwrap()
            .rows();
        assert_eq!(r.rows.len(), 2);
    }

    #[test]
    fn rebalance_onto_backup_is_role_flip() {
        let c = cluster();
        seed(&c, 12, 4);
        let before = c.topology();
        let wq = before.tables.iter().find(|x| x.table == "workqueue").unwrap();
        let old = wq.partitions[2];
        let to = old.backup.expect("default config replicates");
        let fp = c.fingerprint().unwrap();
        c.rebalance_partition("workqueue", 2, to).unwrap();
        let after = c.topology();
        let wq = after.tables.iter().find(|x| x.table == "workqueue").unwrap();
        assert_eq!(wq.partitions[2].primary, to);
        assert_eq!(wq.partitions[2].backup, Some(old.primary));
        assert_eq!(c.fingerprint().unwrap(), fp);
        assert!(after.epoch > before.epoch, "a cut must open a new epoch");
    }

    #[test]
    fn split_partition_redistributes_rows() {
        let c = cluster();
        seed(&c, 40, 4);
        // workerid 5 ≡ 1 (mod 4) routes to partition 1 pre-split and to the
        // new residue class (mod 8 == 5) post-split
        for i in 100..110 {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status) VALUES ({i}, 5, 'READY')"
            ))
            .unwrap();
        }
        let fp = c.fingerprint().unwrap();
        let new_pidx = c.split_partition("workqueue", 1).unwrap();
        assert_eq!(new_pidx, 4);
        let t = c.topology();
        let wq = t.tables.iter().find(|x| x.table == "workqueue").unwrap();
        assert_eq!(wq.partitions.len(), 5);
        assert_eq!(wq.partitions[1].class, Some((8, 1)));
        assert_eq!(wq.partitions[4].class, Some((8, 5)));
        assert_eq!(wq.partitions[1].rows, 10, "workerid=1 rows stay");
        assert_eq!(wq.partitions[4].rows, 10, "workerid=5 rows moved");
        assert_eq!(c.fingerprint().unwrap(), fp, "split must preserve every row");
        // routing to the new partition works for reads, point writes, and PK
        let rs = c.query("SELECT COUNT(*) FROM workqueue WHERE workerid = 5").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(10));
        let r = c
            .exec(
                "UPDATE workqueue SET status = 'RUNNING' \
                 WHERE workerid = 5 AND status = 'READY' ORDER BY taskid LIMIT 1 \
                 RETURNING taskid",
            )
            .unwrap()
            .rows();
        assert_eq!(r.rows.len(), 1);
        assert_eq!(r.rows[0].values[0], Value::Int(100));
        let rs = c.query("SELECT workerid FROM workqueue WHERE taskid = 105").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(5));
        // scatter aggregate sees both halves of the old partition
        let rs = c.query("SELECT COUNT(*) FROM workqueue").unwrap();
        assert_eq!(rs.rows[0].values[0], Value::Int(50));
    }

    #[test]
    fn split_survives_kill_and_rejoin() {
        use crate::storage::replication::AvailabilityManager;
        let c = cluster();
        seed(&c, 24, 4);
        let new_pidx = c.split_partition("workqueue", 3).unwrap();
        let fp = c.fingerprint().unwrap();
        let t = c.topology();
        let wq = t.tables.iter().find(|x| x.table == "workqueue").unwrap();
        let victim = wq.partitions[new_pidx].primary;
        c.kill_node(victim).unwrap();
        assert!(c.promote_dead_primaries() > 0);
        assert_eq!(c.fingerprint().unwrap(), fp, "failover after split loses nothing");
        c.restart_node(victim).unwrap();
        let mgr = AvailabilityManager::new(c.clone());
        for _ in 0..4 {
            mgr.sweep().unwrap();
        }
        assert_eq!(c.node(victim).unwrap().state(), NodeState::Alive);
        assert_eq!(c.fingerprint().unwrap(), fp);
    }

    #[test]
    fn advise_topology_flags_hot_partition() {
        let c = cluster();
        // partition 1 gets most of the rows and all of the write traffic
        for i in 0..24 {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status) VALUES ({i}, 1, 'READY')"
            ))
            .unwrap();
        }
        for (i, w) in [(100, 0), (101, 2), (102, 3)] {
            c.execute(&format!(
                "INSERT INTO workqueue (taskid, workerid, status) VALUES ({i}, {w}, 'READY')"
            ))
            .unwrap();
        }
        for _ in 0..8 {
            c.exec(
                "UPDATE workqueue SET status = 'RUNNING' \
                 WHERE workerid = 1 AND status = 'READY' ORDER BY taskid LIMIT 1 \
                 RETURNING taskid",
            )
            .unwrap();
        }
        let advice = c.advise_topology();
        let hot = advice
            .iter()
            .find(|a| a.table == "workqueue" && a.pidx == 1)
            .expect("partition 1 must be flagged");
        assert_eq!(hot.action, AdviceAction::Split);
    }
}
