//! Per-access-kind latency accounting.
//!
//! Experiments 5 and 6 of the paper measure "time spent accessing the DBMS"
//! overall and broken down per query kind (`getREADYtasks`,
//! `updateToRUNNING`, ...). Every statement executed through a
//! [`crate::storage::Connector`] carries an [`AccessKind`] tag and lands
//! here. The same numbers calibrate the discrete-event simulator.

use rustc_hash::FxHashMap;
use std::sync::Mutex;

/// Well-known access tags used by the d-Chiron engine. Matches the labels
/// of paper Figure 12. `Other` covers ad-hoc/steering SQL.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AccessKind {
    GetReadyTasks,
    GetFileFields,
    UpdateToRunning,
    UpdateToFinished,
    UpdateTaskOutput,
    InsertTasks,
    UpdateWorkerHeartbeat,
    UpdateActivityStatus,
    InsertProvenance,
    InsertDomainData,
    Steering,
    Other,
}

impl AccessKind {
    pub fn label(self) -> &'static str {
        match self {
            AccessKind::GetReadyTasks => "getREADYtasks",
            AccessKind::GetFileFields => "getFileFields",
            AccessKind::UpdateToRunning => "updateToRUNNING",
            AccessKind::UpdateToFinished => "updateToFINISHED",
            AccessKind::UpdateTaskOutput => "updateTaskOutput",
            AccessKind::InsertTasks => "insertTasks",
            AccessKind::UpdateWorkerHeartbeat => "updateWorkerHeartbeat",
            AccessKind::UpdateActivityStatus => "updateActivityStatus",
            AccessKind::InsertProvenance => "insertProvenance",
            AccessKind::InsertDomainData => "insertDomainData",
            AccessKind::Steering => "steeringQuery",
            AccessKind::Other => "other",
        }
    }

    /// Read-only kinds (Figure 12 splits read vs update time).
    pub fn is_read(self) -> bool {
        matches!(
            self,
            AccessKind::GetReadyTasks | AccessKind::GetFileFields | AccessKind::Steering
        )
    }

    pub fn all() -> &'static [AccessKind] {
        use AccessKind::*;
        &[
            GetReadyTasks,
            GetFileFields,
            UpdateToRunning,
            UpdateToFinished,
            UpdateTaskOutput,
            InsertTasks,
            UpdateWorkerHeartbeat,
            UpdateActivityStatus,
            InsertProvenance,
            InsertDomainData,
            Steering,
            Other,
        ]
    }
}

/// Aggregate statistics for one access kind.
#[derive(Clone, Copy, Debug, Default)]
pub struct AccessStat {
    pub count: u64,
    pub total_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

impl AccessStat {
    fn record(&mut self, secs: f64) {
        if self.count == 0 {
            self.min_secs = secs;
            self.max_secs = secs;
        } else {
            self.min_secs = self.min_secs.min(secs);
            self.max_secs = self.max_secs.max(secs);
        }
        self.count += 1;
        self.total_secs += secs;
    }

    pub fn mean_secs(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.total_secs / self.count as f64
        }
    }
}

/// Registry of access statistics, cheap to share across worker threads.
///
/// Also tracks the per-node sums the paper uses for Experiment 5: "for each
/// node, we add up all elapsed times [and] consider the time spent accessing
/// the DBMS in a workflow execution as the maximum sum obtained this way".
#[derive(Default)]
pub struct StatsRegistry {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    by_kind: FxHashMap<AccessKind, AccessStat>,
    by_node: FxHashMap<u32, f64>,
}

impl StatsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one access of `kind` from worker node `node` taking `secs`.
    pub fn record(&self, node: u32, kind: AccessKind, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        g.by_kind.entry(kind).or_default().record(secs);
        *g.by_node.entry(node).or_insert(0.0) += secs;
    }

    /// Stats for one kind.
    pub fn get(&self, kind: AccessKind) -> AccessStat {
        self.inner.lock().unwrap().by_kind.get(&kind).copied().unwrap_or_default()
    }

    /// Snapshot of all kinds with at least one access.
    pub fn snapshot(&self) -> Vec<(AccessKind, AccessStat)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<_> = g.by_kind.iter().map(|(k, s)| (*k, *s)).collect();
        v.sort_by(|a, b| b.1.total_secs.partial_cmp(&a.1.total_secs).unwrap());
        v
    }

    /// Total time across all kinds.
    pub fn total_secs(&self) -> f64 {
        self.inner.lock().unwrap().by_kind.values().map(|s| s.total_secs).sum()
    }

    /// The paper's Experiment-5 metric: max over nodes of that node's summed
    /// DBMS access time.
    pub fn max_node_secs(&self) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .by_node
            .values()
            .fold(0.0f64, |a, b| a.max(*b))
    }

    /// Percentage breakdown by kind relative to total (Figure 12 rows).
    pub fn percentages(&self) -> Vec<(AccessKind, f64)> {
        let total = self.total_secs();
        if total <= 0.0 {
            return vec![];
        }
        self.snapshot()
            .into_iter()
            .map(|(k, s)| (k, 100.0 * s.total_secs / total))
            .collect()
    }

    pub fn reset(&self) {
        let mut g = self.inner.lock().unwrap();
        g.by_kind.clear();
        g.by_node.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_aggregate() {
        let r = StatsRegistry::new();
        r.record(0, AccessKind::GetReadyTasks, 0.010);
        r.record(0, AccessKind::GetReadyTasks, 0.030);
        r.record(1, AccessKind::UpdateToRunning, 0.005);
        let g = r.get(AccessKind::GetReadyTasks);
        assert_eq!(g.count, 2);
        assert!((g.total_secs - 0.040).abs() < 1e-12);
        assert!((g.mean_secs() - 0.020).abs() < 1e-12);
        assert_eq!(g.min_secs, 0.010);
        assert_eq!(g.max_secs, 0.030);
        assert!((r.total_secs() - 0.045).abs() < 1e-12);
    }

    #[test]
    fn max_node_metric() {
        let r = StatsRegistry::new();
        r.record(0, AccessKind::GetReadyTasks, 0.5);
        r.record(1, AccessKind::GetReadyTasks, 0.2);
        r.record(1, AccessKind::UpdateToFinished, 0.4);
        assert!((r.max_node_secs() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn percentages_sum_to_100() {
        let r = StatsRegistry::new();
        r.record(0, AccessKind::GetReadyTasks, 3.0);
        r.record(0, AccessKind::UpdateToFinished, 1.0);
        let p = r.percentages();
        let total: f64 = p.iter().map(|(_, pc)| pc).sum();
        assert!((total - 100.0).abs() < 1e-9);
        assert_eq!(p[0].0, AccessKind::GetReadyTasks);
        assert!((p[0].1 - 75.0).abs() < 1e-9);
    }

    #[test]
    fn kind_labels_and_read_split() {
        assert_eq!(AccessKind::GetReadyTasks.label(), "getREADYtasks");
        assert!(AccessKind::GetReadyTasks.is_read());
        assert!(!AccessKind::UpdateToRunning.is_read());
        assert_eq!(AccessKind::all().len(), 12);
    }
}
